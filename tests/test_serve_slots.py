"""SlotServer unit tests (`repro.launch.serve`) — the fixed-slot batching
model the event-engine serving layer (`repro.net.serve`) reuses the shape
of. Until now the launcher was only exercised end to end as a script
(tests/test_serving.py); these pin the slot mechanics one at a time:
prefill-into-free-slot admission, lockstep decode ticks, done-request
eviction, and slot reuse after completion."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch.serve import Request, SlotServer


@pytest.fixture(scope="module")
def setup():
    cfg = ARCHS["qwen3-0.6b"].reduced()
    from repro.models import build_model
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def make_server(setup, slots=2, max_len=24):
    cfg, params = setup
    return SlotServer(cfg, params, slots=slots, max_len=max_len)


def make_req(setup, rid, prompt_len=8, max_new=4):
    cfg, _ = setup
    rng = np.random.default_rng(rid)
    return Request(
        rid, rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32),
        max_new=max_new,
    )


def test_admit_prefills_into_free_slot(setup):
    server = make_server(setup, slots=2)
    r0, r1, r2 = (make_req(setup, i) for i in range(3))
    assert server.admit(r0)
    # prefill appended the first token and pinned the request to a slot
    assert len(r0.out) == 1
    assert server.active[0] is r0 and server.active[1] is None
    assert int(server.tokens[0, 0]) == r0.out[-1]
    assert server.admit(r1)
    assert server.active[1] is r1
    # pool full: admission refuses (the caller's queue keeps the request)
    assert not server.admit(r2)
    assert len(r2.out) == 0


def test_tick_decodes_all_active_slots_in_lockstep(setup):
    server = make_server(setup, slots=2)
    r0 = make_req(setup, 0, max_new=8)
    r1 = make_req(setup, 1, max_new=8)
    server.admit(r0)
    server.admit(r1)
    n0, n1 = len(r0.out), len(r1.out)
    server.tick()
    # ONE decode step advanced BOTH requests by exactly one token
    assert len(r0.out) == n0 + 1 and len(r1.out) == n1 + 1
    assert int(server.tokens[0, 0]) == r0.out[-1]
    assert int(server.tokens[1, 0]) == r1.out[-1]
    # a tick with nothing active is a no-op (no decode dispatched)
    idle = make_server(setup, slots=2)
    tok_before = np.asarray(idle.tokens).copy()
    idle.tick()
    np.testing.assert_array_equal(np.asarray(idle.tokens), tok_before)


def test_done_request_evicts_and_frees_its_slot(setup):
    server = make_server(setup, slots=2)
    req = make_req(setup, 0, max_new=3)
    server.admit(req)
    ticks = 0
    while not req.done:
        server.tick()
        ticks += 1
        assert ticks < 10
    assert len(req.out) >= req.max_new
    # eviction freed the slot; the server idles without it
    assert server.active[0] is None
    assert not any(server.active)


def test_slot_reused_after_completion(setup):
    server = make_server(setup, slots=1)
    first = make_req(setup, 0, max_new=2)
    second = make_req(setup, 1, max_new=2)
    assert server.admit(first)
    assert not server.admit(second)         # single slot busy
    while not first.done:
        server.tick()
    # the freed slot admits the next request — same slot index
    assert server.admit(second)
    assert server.active[0] is second
    while not second.done:
        server.tick()
    assert second.done and len(second.out) >= second.max_new
