"""Continuous-time event engine (`repro.net.events`).

Pins the acceptance invariants of the engine:

* the ``event_pop`` Pallas kernel is bitwise its pure-lax oracle
  (property-tested over adversarial tie patterns);
* DEGENERATE-LIMIT EQUIVALENCE: with a uniform deterministic per-edge
  delay equal to the sync period (and, for the e2e form, iteration
  completions arriving through the same host driver), the event engine's
  merge sequence — dags, bank state, and PRNG key alike — is BITWISE the
  ``engine="ticks"`` fused path, property-tested over overlays, losses,
  partitions, and interleaved publishes;
* heterogeneous latencies depart in the honest direction: fast links
  deliver before the first tick, slow links at their true cadence, and
  bank chunk-drains recover the bandwidth the stride model forfeits;
* the in-system §IV simulation reproduces the Eq. (4) equilibrium on a
  well-connected overlay and responds to h as the closed form says.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dag as dag_lib
from repro.core import stability
from repro.configs.base import DagFLConfig
from repro.kernels import event_pop as pop_kernel
from repro.kernels import ref as kernel_ref
from repro.net import events as events_lib
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig

CAP, K = 32, 2
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Kernel layer: queue-head reduction
# ---------------------------------------------------------------------------


def test_event_pop_ref_tie_breaks():
    t = jnp.asarray([2.0, 1.0, 1.0, 1.0, 1.0])
    k = jnp.asarray([0, 1, 0, 0, 0], jnp.int32)
    s = jnp.asarray([0, 1, 7, 3, 5], jnp.int32)
    v = jnp.asarray([True, True, True, True, True])
    idx, found = kernel_ref.event_pop_ref(t, k, s, v)
    assert bool(found) and int(idx) == 3      # min time, then kind, then seq
    # invalidate the winner: next head is the seq-5 slot
    v = v.at[3].set(False)
    idx, _ = kernel_ref.event_pop_ref(t, k, s, v)
    assert int(idx) == 4
    # nothing valid: found False, idx 0
    idx, found = kernel_ref.event_pop_ref(t, k, s, jnp.zeros(5, bool))
    assert not bool(found) and int(idx) == 0


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), q=st.integers(1, 70),
       block_q=st.sampled_from([4, 16, 512]))
def test_property_event_pop_pallas_matches_ref(seed, q, block_q):
    """Property: kernel == oracle, including duplicate (time, kind, seq)
    keys (first-slot tie-break) and all-invalid queues."""
    rng = np.random.default_rng(seed)
    t = rng.choice([0.25, 1.0, 1.5, 7.75], q).astype(np.float32)
    k = rng.integers(0, 4, q).astype(np.int32)
    s = rng.integers(0, 6, q).astype(np.int32)
    v = rng.random(q) < 0.5
    args = (jnp.asarray(t), jnp.asarray(k), jnp.asarray(s), jnp.asarray(v))
    ri, rf = kernel_ref.event_pop_ref(*args)
    pi, pf = pop_kernel.event_pop_pallas(*args, block_q=block_q)
    assert bool(rf) == bool(pf)
    assert int(ri) == int(pi)


def test_delivery_intervals_replace_strides():
    """The interval IS the latency — not ceil(latency/period)*period — with
    zero-latency links on the protocol period."""
    top = topo.ring(4, link_latency=3.7)
    iv = events_lib.delivery_intervals(top, 1.0)
    assert np.allclose(iv[top.adjacency], 3.7)
    top0 = topo.ring(4)
    iv0 = events_lib.delivery_intervals(top0, 1.0)
    assert np.allclose(iv0[top0.adjacency], 1.0)
    assert np.all(np.isinf(iv0[~top0.adjacency]))


# ---------------------------------------------------------------------------
# GossipNetwork engine="events": semantics
# ---------------------------------------------------------------------------


def genesis(num_nodes):
    d = dag_lib.empty_dag(CAP, K, num_nodes + 1)
    return dag_lib.publish(
        d, jnp.asarray(num_nodes, jnp.int32), jnp.float32(0.0),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )


def make_net(top, engine="events", sync_period=1.0, partition=None, seed=0,
             impl="fused", bank_cfg=None):
    return gossip_lib.GossipNetwork(
        genesis(top.num_nodes), bank=jnp.zeros((CAP, 8)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=sync_period, seed=seed,
                                    impl=impl, engine=engine),
        partition=partition, bank_cfg=bank_cfg,
    )


def publish_on(net, node, seq, t, params=None):
    d = replica_lib.publish_local(
        net.read(node), seq, jnp.asarray(node, jnp.int32), jnp.float32(t),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(seq % CAP, jnp.int32),
    )
    net.write(node, d)
    if net.bank_cfg is not None:
        if params is None:
            params = jnp.full((8,), float(seq))
        net.bank_commit(node, seq % CAP, params)


def assert_dags_equal(a, b, msg=""):
    for name in dag_lib.DagState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{msg}{name}",
        )


def test_fast_links_deliver_before_the_tick():
    """A 0.5 s link delivers at 0.5 s; the stride model waits for the 1 s
    tick — THE semantic the event engine exists for."""
    tick_net = make_net(topo.ring(6, link_latency=0.5), engine="ticks")
    ev_net = make_net(topo.ring(6, link_latency=0.5), engine="events")
    publish_on(tick_net, 0, 1, 0.1)
    publish_on(ev_net, 0, 1, 0.1)
    tick_net.advance(0.6)
    ev_net.advance(0.6)
    assert (tick_net.missing_rows() > 0).sum() == 5      # nothing until t=1
    assert (ev_net.missing_rows() > 0).sum() == 3        # neighbors heard
    ev_net.advance(1.0)                                  # second hop at 1.0
    assert (ev_net.missing_rows() > 0).sum() == 1


def test_slow_links_fire_at_true_cadence():
    """latency 1.5, period 1: the stride model quantizes to every 2nd tick
    (hops at t=1, 3, 5); events deliver at 1.5, 3.0, 4.5."""
    net = make_net(topo.ring(8, link_latency=1.5), engine="events")
    publish_on(net, 0, 1, 0.1)
    net.advance(1.4)
    assert (net.missing_rows() > 0).sum() == 7
    net.advance(1.5)
    assert (net.missing_rows() > 0).sum() == 5
    net.advance(3.0)
    assert (net.missing_rows() > 0).sum() == 3
    net.advance(4.5)
    assert (net.missing_rows() > 0).sum() == 1


def test_events_ideal_wire_routes_to_converge():
    net = make_net(topo.ring(6, link_latency=2.5), engine="events",
                   sync_period=0.0)
    publish_on(net, 0, 1, 0.5)
    net.advance(1.0)
    assert net.synced()


def test_events_full_drop_blocks_everything():
    net = make_net(topo.ring(6, drop=1.0, link_latency=1.0), engine="events")
    publish_on(net, 0, 1, 0.5)
    net.advance(10.0)
    assert (net.missing_rows() > 0).sum() == 5


def test_events_mesh_not_supported():
    from repro.net import mesh as mesh_lib
    if jax.device_count() < 2:
        pytest.skip("needs >1 device to build a mesh")
    with pytest.raises(NotImplementedError):
        make_net_mesh = gossip_lib.GossipNetwork(
            genesis(8), bank=jnp.zeros((CAP, 8)), top=topo.ring(8),
            cfg=gossip_lib.GossipConfig(engine="events"),
            mesh=mesh_lib.make_gossip_mesh(nodes=2, model=1),
        )


def test_events_mesh_rejected_in_subprocess():
    """Runs on every lane: forces 8 host devices in a child process and
    checks that engine='events' + mesh is rejected — the event queue is not
    mesh-sharded yet (ROADMAP follow-up), and a mesh-aware regression that
    silently accepted the combination would otherwise only fail the
    8-device CI lane."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.core import dag as dag_lib
        from repro.net import gossip as G, mesh as M
        from repro.net import topology as topo
        assert jax.device_count() == 8, jax.device_count()
        CAP, K = 32, 2
        d = dag_lib.empty_dag(CAP, K, 9)
        d = dag_lib.publish(d, jnp.asarray(8, jnp.int32), jnp.float32(0.0),
            jnp.full((K,), dag_lib.NO_TX, jnp.int32), jnp.float32(0.5),
            jnp.float32(0.0), jnp.asarray(0, jnp.int32))
        try:
            G.GossipNetwork(d, bank=jnp.zeros((CAP, 8)), top=topo.ring(8),
                cfg=G.GossipConfig(engine="events"),
                mesh=M.make_gossip_mesh(nodes=2, model=4))
        except NotImplementedError:
            print("OK")
        else:
            raise SystemExit("engine='events' + mesh was accepted")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        make_net(topo.ring(4), engine="heap")


def test_events_partition_suppresses_and_heals():
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(6), t_start=0.5, t_end=4.5,
    )
    net = make_net(topo.full(6, link_latency=1.0), engine="events",
                   partition=part)
    publish_on(net, 0, 1, 0.2)
    net.advance(4.0)                       # all deliveries inside the split
    assert (net.missing_rows() > 0).sum() == 3     # far side starved
    net.advance(5.0)                       # healed delivery at t=5
    assert net.synced()


# ---------------------------------------------------------------------------
# THE acceptance invariant: degenerate uniform delay == ticks, bitwise
# ---------------------------------------------------------------------------


IMPLS = ["fused", "scan"]


@pytest.mark.parametrize("impl", IMPLS)
def test_degenerate_limit_bitwise_equal_unit(impl):
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(6), t_start=2.5, t_end=4.5,
    )
    top = topo.ring(6, link_latency=1.0, drop=0.3, seed=3)
    a = make_net(top, engine="ticks", partition=part, seed=7, impl=impl)
    b = make_net(top, engine="events", partition=part, seed=7, impl=impl)
    publish_on(a, 0, 1, 0.3)
    publish_on(b, 0, 1, 0.3)
    for t in (1.0, 2.0, 3.5, 6.0):
        a.advance(t)
        b.advance(t)
        if t == 2.0:
            publish_on(a, 2, 2, 2.1)
            publish_on(b, 2, 2, 2.1)
        assert_dags_equal(a.replicas.dags, b.replicas.dags, msg=f"t={t}:")
    # the PRNG streams stayed in lockstep (one split per tick == per batch),
    # so even a subsequent converge flush matches bitwise
    np.testing.assert_array_equal(np.asarray(a._key), np.asarray(b._key))
    assert a.converge(at_time=10.0) == b.converge(at_time=10.0)
    assert_dags_equal(a.replicas.dags, b.replicas.dags, msg="converge:")


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    overlay=st.sampled_from(["ring", "er", "star", "full"]),
    impl=st.sampled_from(IMPLS),
    drop=st.sampled_from([0.0, 0.3]),
    split=st.booleans(),
)
def test_property_degenerate_limit_bitwise(seed, overlay, impl, drop, split):
    """Property (acceptance): uniform per-edge delay == sync period makes
    the event engine's merge sequence bitwise the tick path over any
    overlay, loss rate, partition schedule, and publish interleaving."""
    n = 8
    builders = {
        "ring": lambda: topo.ring(n, link_latency=1.0, drop=drop,
                                  seed=seed % 997),
        "er": lambda: topo.erdos_renyi(n, 0.4, link_latency=1.0, drop=drop,
                                       seed=seed % 997),
        "star": lambda: topo.star(n, link_latency=1.0, drop=drop),
        "full": lambda: topo.full(n, link_latency=1.0, drop=drop),
    }
    part = (
        gossip_lib.PartitionSchedule(
            assignment=topo.split_halves(n), t_start=1.5, t_end=3.5,
        ) if split else None
    )
    top = builders[overlay]()
    a = make_net(top, engine="ticks", partition=part, seed=seed % 1013,
                 impl=impl)
    b = make_net(top, engine="events", partition=part, seed=seed % 1013,
                 impl=impl)
    rng = np.random.default_rng(seed)
    for seq in range(1, 4):
        node = int(rng.integers(0, n))
        publish_on(a, node, seq, 0.1 * seq)
        publish_on(b, node, seq, 0.1 * seq)
    for t in (1.0, 2.5, 5.0):
        a.advance(t)
        b.advance(t)
        assert_dags_equal(a.replicas.dags, b.replicas.dags, msg=f"t={t}:")
    np.testing.assert_array_equal(np.asarray(a._key), np.asarray(b._key))


def test_degenerate_overflow_window_fast_forwards_like_ticks():
    """An advance window longer than max_ticks_per_advance periods: the
    tick engine fast-forwards (elides the backlog AND its PRNG splits);
    the event engine must elide identically — same rounds, same key
    stream, same post-window schedule — or every later lossy round
    diverges permanently."""
    top = topo.ring(6, link_latency=1.0, drop=0.3, seed=3)
    a = make_net(top, engine="ticks", seed=7)
    b = make_net(top, engine="events", seed=7)
    publish_on(a, 0, 1, 0.3)
    publish_on(b, 0, 1, 0.3)
    a.advance(100.0)                  # 100 periods > the 64-tick cap
    b.advance(100.0)
    np.testing.assert_array_equal(np.asarray(a._key), np.asarray(b._key))
    assert_dags_equal(a.replicas.dags, b.replicas.dags, msg="overflow:")
    publish_on(a, 2, 2, 100.5)
    publish_on(b, 2, 2, 100.5)
    for t in (101.0, 104.0, 170.0):   # 170: a second overflowing window
        a.advance(t)
        b.advance(t)
        assert_dags_equal(a.replicas.dags, b.replicas.dags, msg=f"t={t}:")
    np.testing.assert_array_equal(np.asarray(a._key), np.asarray(b._key))


@pytest.mark.parametrize("impl", IMPLS)
def test_degenerate_bank_unlimited_bitwise(impl):
    """Bank gossip at unlimited capacity rides the degenerate limit too:
    rows AND transport state (have/credit/sent) bitwise the tick path."""
    top = topo.ring(6, link_latency=1.0, drop=0.2, seed=1)
    a = make_net(top, engine="ticks", impl=impl,
                 bank_cfg=BankGossipConfig(chunks_per_slot=4), seed=3)
    b = make_net(top, engine="events", impl=impl,
                 bank_cfg=BankGossipConfig(chunks_per_slot=4), seed=3)
    publish_on(a, 0, 1, 0.3)
    publish_on(b, 0, 1, 0.3)
    publish_on(a, 4, 2, 0.5)
    publish_on(b, 4, 2, 0.5)
    for t in (1.0, 3.0, 6.0):
        a.advance(t)
        b.advance(t)
        assert_dags_equal(a.replicas.dags, b.replicas.dags, msg=f"t={t}:")
        for f in ("have", "credit", "sent"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a.replicas.bank_state, f)),
                np.asarray(getattr(b.replicas.bank_state, f)),
                err_msg=f"t={t}:{f}",
            )


def test_e2e_degenerate_engines_bitwise():
    """run_dagfl_gossip: the full FL sim — Algorithm-2 prepare/commit
    interleaved through the same host driver — is bitwise identical across
    engines in the uniform-delay limit (curve, timing, union ledger)."""
    from repro.fl.experiments import default_dagfl_config, make_cnn_setup
    from repro.fl.systems import SimConfig, run_dagfl_gossip

    n = 8
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=10, eval_every=5, seed=0)
    results = []
    for engine in ("ticks", "events"):
        task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)
        results.append(run_dagfl_gossip(
            task, nodes, dcfg, sim, gval,
            topology=topo.ring(n, link_latency=1.0, seed=0),
            gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=0),
            engine=engine,
        ))
    base, ev = results
    np.testing.assert_array_equal(base.accs, ev.accs)
    np.testing.assert_array_equal(base.times, ev.times)
    assert_dags_equal(base.extras["dag"], ev.extras["dag"], msg="union:")
    assert ev.extras["events_processed"] > 0
    assert base.extras["events_processed"] == 0


# ---------------------------------------------------------------------------
# Bank chunk-drains: continuous accrual beats tick quantization
# ---------------------------------------------------------------------------


def test_bank_drains_recover_strided_bandwidth():
    """latency 2, period 1, 8 B/s links, 8 B chunks: the stride model fires
    every 2nd tick and forfeits the idle tick's budget (one chunk per 2 s);
    the event engine accrues continuously and drains a chunk every second —
    the payload completes in about half the time."""
    cfg = BankGossipConfig(chunks_per_slot=4)
    tick_net = make_net(topo.ring(2, link_latency=2.0, bandwidth=64.0),
                        engine="ticks", bank_cfg=cfg)
    ev_net = make_net(topo.ring(2, link_latency=2.0, bandwidth=64.0),
                      engine="events", bank_cfg=cfg)
    publish_on(tick_net, 0, 1, 0.2)
    publish_on(ev_net, 0, 1, 0.2)
    for t in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0):
        tick_net.advance(t)
        ev_net.advance(t)
    # events: row at t=2 (2 chunks accrued), drains at 3 and 4 -> done
    # ticks: one chunk per fired tick at t=1,3,5,7 -> done only at t=7
    assert int(ev_net.missing_chunks()[1]) == 0
    assert int(tick_net.missing_chunks()[1]) == 0
    ev2 = make_net(topo.ring(2, link_latency=2.0, bandwidth=64.0),
                   engine="events", bank_cfg=cfg)
    tick2 = make_net(topo.ring(2, link_latency=2.0, bandwidth=64.0),
                     engine="ticks", bank_cfg=cfg)
    publish_on(ev2, 0, 1, 0.2)
    publish_on(tick2, 0, 1, 0.2)
    ev2.advance(4.0)
    tick2.advance(4.0)
    assert int(ev2.missing_chunks()[1]) == 0        # strictly earlier
    assert int(tick2.missing_chunks()[1]) > 0


def test_bank_drain_respects_partition():
    """A partitioned link neither merges nor drains; after healing the
    payload completes without having banked the partition window."""
    part = gossip_lib.PartitionSchedule(
        assignment=np.asarray([0, 1]), t_start=0.5, t_end=6.5,
    )
    cfg = BankGossipConfig(chunks_per_slot=4)
    net = make_net(topo.ring(2, link_latency=1.0, bandwidth=64.0),
                   engine="events", bank_cfg=cfg, partition=part)
    publish_on(net, 0, 1, 0.2)
    net.advance(6.0)
    assert int(net.missing_rows()[1]) == 1          # row never crossed
    assert float(net.bytes_sent()) == 0.0
    net.advance(12.0)                               # healed: row + chunks
    assert int(net.missing_rows()[1]) == 0
    assert int(net.missing_chunks()[1]) == 0


# ---------------------------------------------------------------------------
# The §IV in-system simulation
# ---------------------------------------------------------------------------


def test_insystem_tips_match_eq4_on_bench_point():
    """Acceptance: the in-system tail-mean tip count lands within 15% of
    the Eq. (4) closed form on a well-connected overlay with delivery
    intervals well under h (bench-grid scale: benchmarks/stability_tips)."""
    cfg = DagFLConfig(num_nodes=16, alpha=5, k=2)
    f = 1.5e9
    pred = stability.equilibrium_tips(cfg, f)
    trace = events_lib.simulate_insystem_tips(
        topo.full(16), h=stability.iteration_delay(cfg, f),
        arrival_rate=cfg.arrival_rate, k=cfg.k, tau_max=cfg.tau_max,
        horizon=600.0, capacity=256, seed=0, sync_period=0.25,
    )
    assert trace.overflow == 0
    assert trace.published > 400                  # lambda=1 over 600 s
    sim = trace.tail_mean(0.5)
    assert sim == pytest.approx(pred, rel=0.15), (sim, pred)


def test_insystem_tips_scale_with_h():
    """Eq. (4): L0 is linear in h — quadrupling every node's iteration
    delay must raise the measured equilibrium accordingly."""
    top = topo.full(8)
    lo = events_lib.simulate_insystem_tips(
        top, h=1.0, arrival_rate=1.0, k=2, tau_max=60.0, horizon=250.0,
        capacity=256, seed=1, sync_period=0.25,
    )
    hi = events_lib.simulate_insystem_tips(
        top, h=4.0, arrival_rate=1.0, k=2, tau_max=60.0, horizon=250.0,
        capacity=256, seed=1, sync_period=0.25,
    )
    assert hi.tail_mean(0.5) > 1.8 * lo.tail_mean(0.5)


def test_insystem_slow_gossip_inflates_tips():
    """Stale views approve already-approved tips: a sluggish overlay floats
    the union tip count above the fast-gossip measurement."""
    cfg = dict(h=2.0, arrival_rate=1.0, k=2, tau_max=60.0, horizon=300.0,
               capacity=256, seed=0)
    fast = events_lib.simulate_insystem_tips(
        topo.full(8), sync_period=0.1, **cfg)
    slow = events_lib.simulate_insystem_tips(
        topo.ring(8, link_latency=4.0), sync_period=4.0, **cfg)
    assert slow.staleness.max() > fast.staleness.max()
    assert slow.tail_mean(0.5) > fast.tail_mean(0.5)


def test_insystem_trace_empty_tail_mean_is_nan():
    """The in-system trace shares stability.tail_mean's rule: an empty
    trace is NaN, never a silent 0.0 that reads as a zero-tip equilibrium."""
    tr = events_lib.InSystemTrace(
        times=np.zeros(0), tips=np.zeros(0), staleness=np.zeros(0),
        published=0, overflow=0, union=None,
    )
    assert np.isnan(tr.tail_mean())


def test_insystem_per_node_h_and_counters():
    """Heterogeneous h_i: every node still publishes (arrivals are uniform)
    and the union's per-node counters account every transaction."""
    h = np.asarray([0.5] * 6 + [6.0, 6.0], np.float32)   # two stragglers
    trace = events_lib.simulate_insystem_tips(
        topo.k_regular(8, 4), h=h, arrival_rate=1.0, k=2, tau_max=60.0,
        horizon=200.0, capacity=256, seed=2, sync_period=0.5,
    )
    pub = np.asarray(trace.union.published_per_node)
    assert trace.overflow == 0
    assert int(pub[:8].sum()) == trace.published
    assert (pub[:8] > 0).all()
