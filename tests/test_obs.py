"""In-loop telemetry (`repro.obs`).

Pins the acceptance invariants of the observability layer:

* ZERO PERTURBATION: an obs-instrumented run — metric accumulators and
  the trace ring threaded through every jitted loop as scan/while
  carries — is BITWISE the obs-off run (final ReplicaSet, bank state,
  and PRNG key alike), property-tested over engines, round impls,
  overlays, and partition schedules, plus a mesh-sharded subprocess run;
* overflow is honest: both the metrics series and the trace ring keep
  the FIRST N records and count the rest in ``dropped`` — no silent
  wraparound;
* the drained Chrome trace round-trips ``json.loads`` with monotone
  per-track timestamps, and the host-side PUBLISH/COMMIT records account
  every driver iteration;
* every jitted dispatch routes through the ``_dispatch`` counting funnel
  (``device_calls`` == the sum of the per-entry-point breakdown both
  engines expose in ``SimResult.extras``).
"""
import json
import os
import subprocess
import sys
import textwrap
from collections import defaultdict

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dag as dag_lib
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig
from repro.obs import (KIND_COMMIT, KIND_DELIVER, KIND_PARTITION,
                       KIND_PUBLISH, ObsConfig, chrome_trace,
                       metrics_jsonl_lines)
from repro.obs import trace as trace_lib

CAP, K = 32, 2
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def genesis(num_nodes):
    d = dag_lib.empty_dag(CAP, K, num_nodes + 1)
    return dag_lib.publish(
        d, jnp.asarray(num_nodes, jnp.int32), jnp.float32(0.0),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )


def make_net(top, engine="ticks", obs=None, bank_cfg=None, impl="fused",
             partition=None, seed=7, sync_period=1.0):
    return gossip_lib.GossipNetwork(
        genesis(top.num_nodes), bank=jnp.zeros((CAP, 8)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=sync_period, seed=seed,
                                    impl=impl, engine=engine),
        partition=partition, bank_cfg=bank_cfg, obs_cfg=obs,
    )


def publish_on(net, node, seq, t):
    d = replica_lib.publish_local(
        net.read(node), seq, jnp.asarray(node, jnp.int32), jnp.float32(t),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(seq % CAP, jnp.int32),
    )
    net.write(node, d)
    if net.bank_cfg is not None:
        net.bank_commit(node, seq % CAP, jnp.full((8,), float(seq)))


def assert_dags_equal(a, b, msg=""):
    for name in dag_lib.DagState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{msg}{name}",
        )


def assert_nets_bitwise(a, b, msg=""):
    assert_dags_equal(a.replicas.dags, b.replicas.dags, msg=msg)
    np.testing.assert_array_equal(
        np.asarray(a._key), np.asarray(b._key), err_msg=f"{msg}key"
    )
    if a.bank_cfg is not None:
        for f in ("have", "credit", "sent"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a.replicas.bank_state, f)),
                np.asarray(getattr(b.replicas.bank_state, f)),
                err_msg=f"{msg}{f}",
            )


# ---------------------------------------------------------------------------
# THE acceptance invariant: obs-on is bitwise obs-off
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["ticks", "events"])
@pytest.mark.parametrize("bank", [None, BankGossipConfig(chunks_per_slot=4)])
def test_obs_on_bitwise_obs_off_unit(engine, bank):
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(6), t_start=1.5, t_end=3.5,
    )
    top = topo.ring(6, link_latency=1.0, drop=0.3, seed=3)
    a = make_net(top, engine, obs=None, bank_cfg=bank, partition=part)
    b = make_net(top, engine, obs=ObsConfig(), bank_cfg=bank, partition=part)
    publish_on(a, 0, 1, 0.3)
    publish_on(b, 0, 1, 0.3)
    for t in (1.0, 2.5, 6.0):
        a.advance(t)
        b.advance(t)
        assert_nets_bitwise(a, b, msg=f"t={t}:")
    assert a.converge(at_time=20.0) == b.converge(at_time=20.0)
    assert_nets_bitwise(a, b, msg="converge:")
    rep = b.obs_report()
    assert rep.rounds > 0 and len(rep.series["t"]) == rep.rounds
    assert len(rep.trace["t"]) > 0 and rep.trace_dropped == 0


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    overlay=st.sampled_from(["ring", "er", "star"]),
    engine=st.sampled_from(["ticks", "events"]),
    impl=st.sampled_from(["fused", "scan"]),
    split=st.booleans(),
)
def test_property_obs_on_bitwise_obs_off(seed, overlay, engine, impl, split):
    """Property (acceptance): collection is a pure read — threading the
    collectors through the carries perturbs nothing, over any overlay,
    engine, round impl, partition schedule, and publish interleaving."""
    n = 8
    builders = {
        "ring": lambda: topo.ring(n, link_latency=1.0, drop=0.3,
                                  seed=seed % 997),
        "er": lambda: topo.erdos_renyi(n, 0.4, link_latency=1.0, drop=0.3,
                                       seed=seed % 997),
        "star": lambda: topo.star(n, link_latency=1.0, drop=0.3),
    }
    part = (
        gossip_lib.PartitionSchedule(
            assignment=topo.split_halves(n), t_start=1.5, t_end=3.5,
        ) if split else None
    )
    top = builders[overlay]()
    a = make_net(top, engine, obs=None, impl=impl, partition=part,
                 seed=seed % 1013)
    b = make_net(top, engine, obs=ObsConfig(), impl=impl, partition=part,
                 seed=seed % 1013)
    rng = np.random.default_rng(seed)
    for seq in range(1, 4):
        node = int(rng.integers(0, n))
        publish_on(a, node, seq, 0.1 * seq)
        publish_on(b, node, seq, 0.1 * seq)
    for t in (1.0, 2.5, 5.0):
        a.advance(t)
        b.advance(t)
        assert_nets_bitwise(a, b, msg=f"t={t}:")


def test_obs_mesh_bitwise_in_subprocess():
    """Runs on every lane: forces 8 host devices in a child process and
    checks that the mesh-sharded path with collectors on stays bitwise the
    obs-off mesh run AND the single-device obs-off run — obs rides the
    same GSPMD reductions as every other cross-replica fold."""
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import dag as dag_lib
        from repro.net import gossip as G, mesh as M, replica as R
        from repro.net import topology as topo
        from repro.obs import ObsConfig
        assert jax.device_count() == 8, jax.device_count()
        CAP, K = 16, 2
        d = dag_lib.empty_dag(CAP, K, 17)
        d = dag_lib.publish(d, jnp.asarray(16, jnp.int32), jnp.float32(0.0),
            jnp.full((K,), dag_lib.NO_TX, jnp.int32), jnp.float32(0.5),
            jnp.float32(0.0), jnp.asarray(0, jnp.int32))
        def net(mesh, obs):
            return G.GossipNetwork(d, bank=jnp.zeros((CAP, 4)),
                top=topo.ring(16, drop=0.2, seed=1),
                cfg=G.GossipConfig(sync_period=1.0, seed=5), mesh=mesh,
                obs_cfg=obs)
        mesh = M.make_gossip_mesh(nodes=2, model=4)
        a, b, c = net(None, None), net(mesh, None), net(mesh, ObsConfig())
        for n_ in (a, b, c):
            dd = R.publish_local(n_.read(3), 1, jnp.asarray(3, jnp.int32),
                jnp.float32(0.1), jnp.full((K,), dag_lib.NO_TX, jnp.int32),
                jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(1, jnp.int32))
            n_.write(3, dd)
        a.advance(4.0); b.advance(4.0); c.advance(4.0)
        assert (a.converge(at_time=50.0) == b.converge(at_time=50.0)
                == c.converge(at_time=50.0))
        for f in dag_lib.DagState._fields:
            for other, tag in ((b, "mesh"), (c, "mesh+obs")):
                np.testing.assert_array_equal(
                    np.asarray(getattr(a.replicas.dags, f)),
                    np.asarray(getattr(other.replicas.dags, f)),
                    err_msg=tag + ":" + f)
        np.testing.assert_array_equal(np.asarray(b._key), np.asarray(c._key))
        rep = c.obs_report()
        assert rep.rounds > 0 and len(rep.series["t"]) == rep.rounds
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=1200,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# Overflow policy: keep the first N, count the rest, never wrap
# ---------------------------------------------------------------------------


def test_trace_ring_overflow_counts_instead_of_wrapping():
    ring = trace_lib.init_trace(4)
    mask = jnp.asarray([[False, True, True], [True, False, False],
                        [False, False, False]])
    ring = trace_lib.append_edges(ring, 1.0, KIND_DELIVER, mask, 2.0)
    assert int(ring.cursor) == 3 and int(ring.dropped) == 0
    ring = trace_lib.append_edges(ring, 2.0, KIND_DELIVER, mask, 5.0)
    assert int(ring.cursor) == 6
    assert int(ring.dropped) == 2                 # two records past capacity
    # first-N policy: slots 0-2 hold the t=1 records untouched, slot 3 the
    # first t=2 record — the t=1 prefix was NOT overwritten
    np.testing.assert_array_equal(np.asarray(ring.t), [1.0, 1.0, 1.0, 2.0])
    np.testing.assert_array_equal(np.asarray(ring.arg), [2.0, 2.0, 2.0, 5.0])
    # flat-index order assigns slots deterministically: (0,1), (0,2), (1,0)
    np.testing.assert_array_equal(np.asarray(ring.src), [1, 2, 0, 1])
    np.testing.assert_array_equal(np.asarray(ring.dst), [0, 0, 1, 0])
    rec = trace_lib.drain(ring)
    assert len(rec["t"]) == 4                     # drain never exceeds cap


def test_metrics_series_overflow_counts_instead_of_wrapping():
    obs = ObsConfig(series_capacity=2)
    net = make_net(topo.ring(4, link_latency=1.0), obs=obs)
    publish_on(net, 0, 1, 0.1)
    net.advance(5.0)                              # 5 rounds into 2 slots
    rep = net.obs_report()
    assert rep.rounds == 5
    assert rep.samples_dropped == 3
    assert len(rep.series["t"]) == 2
    np.testing.assert_array_equal(rep.series["t"], [1.0, 2.0])   # first two


def test_obs_trace_false_skips_ring_but_keeps_metrics():
    obs = ObsConfig(trace=False)
    net = make_net(topo.ring(4, link_latency=1.0), obs=obs)
    publish_on(net, 0, 1, 0.1)
    net.advance(3.0)
    rep = net.obs_report()
    assert rep.rounds == 3 and len(rep.series["t"]) == 3
    assert len(rep.trace["t"]) == 0


# ---------------------------------------------------------------------------
# Metrics semantics on a known schedule
# ---------------------------------------------------------------------------


def test_metrics_series_tracks_known_propagation():
    """One row on a loss-free 4-ring: neighbors merge at t=1 (2 rows
    delta), the far node at t=2 (1 row), then quiescence — and the
    staleness series collapses to 0 exactly when the overlay syncs."""
    net = make_net(topo.ring(4, link_latency=1.0), obs=ObsConfig())
    publish_on(net, 0, 1, 0.1)
    net.advance(3.0)
    rep = net.obs_report()
    np.testing.assert_array_equal(rep.series["t"], [1.0, 2.0, 3.0])
    np.testing.assert_array_equal(rep.series["rows_delta"], [2, 1, 0])
    np.testing.assert_array_equal(rep.series["staleness"], [1, 0, 0])
    assert int(rep.rows_merged.sum()) == 3        # 3 replica merges in all
    deliver = rep.trace["kind"] == KIND_DELIVER
    assert deliver.sum() == net.topology.adjacency.sum() * 3   # per round


def test_staleness_link_series_pinpoints_the_lagging_pair():
    """The per-link lag matrix names WHO owes WHOM: after node 0 publishes
    on a 4-ring, the t=1 sample shows the far node (2) as the one receiver
    still lacking the row from every holder — and the matrix collapses to
    zero exactly when the overlay syncs. Diagonal is identically zero
    (``replica.missing_vs_peer``)."""
    net = make_net(topo.ring(4, link_latency=1.0), obs=ObsConfig())
    publish_on(net, 0, 1, 0.1)
    net.advance(2.0)
    rep = net.obs_report()
    link = rep.series["staleness_link"]
    assert link.shape == (2, 4, 4)
    np.testing.assert_array_equal(link[:, range(4), range(4)], 0)
    # t=1: nodes 0,1,3 hold the row; receiver 2 lacks it vs each of them
    np.testing.assert_array_equal(link[0, 2, [0, 1, 3]], 1)
    np.testing.assert_array_equal(link[0, [0, 1, 3]], 0)
    # t=2: fully synced, nobody owes anybody
    np.testing.assert_array_equal(link[1], 0)
    # consistency: lag vs the union is bounded by the worst per-peer lag
    assert rep.series["staleness"][0] == link[0].max()


def test_bank_metrics_reach_the_series():
    cfg = BankGossipConfig(chunks_per_slot=4)
    net = make_net(topo.ring(2, link_latency=1.0, bandwidth=64.0),
                   obs=ObsConfig(), bank_cfg=cfg)
    publish_on(net, 0, 1, 0.2)
    net.advance(6.0)
    rep = net.obs_report()
    assert rep.series["chunk_lag"].max() > 0      # backlog was visible
    assert rep.series["bytes_total"][-1] > 0      # and the byte meter ran
    assert rep.final["chunk_lag"] == 0.0          # fully drained by t=6
    assert float(rep.link_bytes.sum()) == rep.final["bytes_sent"]
    drain_mask = rep.trace["kind"] == trace_lib.KIND_DRAIN
    assert drain_mask.sum() > 0
    assert rep.trace["arg"][drain_mask].sum() == rep.final["bytes_sent"]


def test_partition_trace_records_begin_and_heal():
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(6), t_start=1.5, t_end=3.5,
    )
    net = make_net(topo.full(6, link_latency=1.0), obs=ObsConfig(),
                   partition=part)
    publish_on(net, 0, 1, 0.2)
    net.advance(6.0)
    rec = net.obs_report().trace
    pmask = rec["kind"] == KIND_PARTITION
    assert pmask.sum() == 2                        # begin + heal, once each
    np.testing.assert_array_equal(rec["t"][pmask], [1.5, 3.5])
    np.testing.assert_array_equal(rec["arg"][pmask], [1.0, 0.0])
    assert (rec["src"][pmask] == -1).all()


# ---------------------------------------------------------------------------
# Dispatch funnel: device_calls == the per-entry-point breakdown
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine,label", [("ticks", "advance"),
                                          ("events", "advance_events")])
def test_dispatch_counts_breakdown(engine, label):
    net = make_net(topo.ring(4, link_latency=1.0), engine=engine)
    publish_on(net, 0, 1, 0.1)
    net.advance(2.0)
    net.converge(at_time=10.0)
    assert net.dispatch_counts[label] >= 1
    assert net.dispatch_counts["converge"] == 1
    assert net.device_calls == sum(net.dispatch_counts.values())


def test_dispatch_counts_cover_bank_commit():
    net = make_net(topo.ring(4, link_latency=1.0),
                   bank_cfg=BankGossipConfig(chunks_per_slot=4))
    publish_on(net, 0, 1, 0.1)                     # publishes + bank_commit
    net.advance(2.0)
    assert net.dispatch_counts["bank_commit"] == 1
    assert net.dispatch_counts["advance_bank"] == 1
    assert net.device_calls == sum(net.dispatch_counts.values())


# ---------------------------------------------------------------------------
# Export: Chrome trace round-trip + JSONL
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def e2e_report():
    from repro.fl.experiments import default_dagfl_config, make_cnn_setup
    from repro.fl.systems import SimConfig, run_dagfl_gossip

    n, iters = 6, 8
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=iters, eval_every=4, seed=0)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)
    res = run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.ring(n, link_latency=1.0, seed=0),
        gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=0),
        engine="events", bank_gossip=BankGossipConfig(chunks_per_slot=4),
        obs=ObsConfig(),
    )
    return res, iters


def test_e2e_extras_expose_obs_and_dispatch_counts(e2e_report):
    res, iters = e2e_report
    rep = res.extras["obs"]
    assert rep.engine == "events" and rep.rounds > 0
    assert res.extras["dispatch_counts"]          # breakdown in extras too
    assert res.extras["device_calls"] == sum(
        res.extras["dispatch_counts"].values()
    )
    # host records account every driver iteration
    kinds = rep.trace["kind"]
    assert (kinds == KIND_PUBLISH).sum() == iters
    assert (kinds == KIND_COMMIT).sum() == iters


def test_chrome_trace_roundtrips_with_monotone_tracks(e2e_report):
    res, _ = e2e_report
    doc = json.loads(json.dumps(chrome_trace(res.extras["obs"])))
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms" and len(evs) > 0
    named = [e for e in evs if e["ph"] == "M" and e["name"] == "thread_name"]
    assert len(named) == res.extras["obs"].num_nodes + 1   # nodes + overlay
    per_track = defaultdict(list)
    for e in evs:
        if e["ph"] != "M":
            assert e["ts"] >= 0 and e.get("dur", 0) >= 0
            per_track[(e["pid"], e["tid"])].append(e["ts"])
    assert per_track
    for track, ts in per_track.items():
        assert ts == sorted(ts), f"track {track} not monotone"


def test_metrics_jsonl_lines_parse(e2e_report):
    res, _ = e2e_report
    rep = res.extras["obs"]
    lines = metrics_jsonl_lines(rep)
    assert len(lines) == 1 + len(rep.series["t"])
    head = json.loads(lines[0])
    assert head["kind"] == "summary" and head["rounds"] == rep.rounds
    for ln in lines[1:]:
        row = json.loads(ln)
        assert row["kind"] == "sample" and set(row) >= {
            "t", "tips", "staleness", "rows_delta", "chunk_lag", "bytes_total"
        }
