"""Wire compression for bank commits (`repro.kernels.delta_codec`).

Pins the acceptance invariants of the codec layer:

* ROUND-TRIP BOUND: blocked symmetric quantization reconstructs every
  element to within half a quantization step — ``amax(block) / (2 *
  qmax)`` per block — property-tested per dtype; all-zero blocks (and
  therefore the padding ``_to_blocks`` appends) round-trip EXACTLY;
* TOP-K EXACTNESS: with ``k >= nnz(block)`` the masked delta IS the
  delta — sparsification only ever drops the smallest-|d| surplus, and
  ties break deterministically toward the earlier index;
* KERNEL == ORACLE: the Pallas kernels agree with the pure-lax refs —
  codes and masks exactly, scales to float rounding (the jitted kernel
  may compile ``x / scale`` as a reciprocal multiply);
* IDENTITY IS LITERAL: ``DeltaCodec(kind="none")`` (and ``codec=None``)
  runs the engines' uncompressed programs bitwise — final replicas,
  bank state, and PRNG key — over engines x overlays x faults on/off;
* PRICING: an active codec scales every byte the meter records by
  exactly ``wire_ratio()`` when both runs move the same chunks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dag as dag_lib
from repro.kernels import ref
from repro.kernels.delta_codec import (BLOCK, DeltaCodec, _to_blocks,
                                       codec_key, quant_blocks,
                                       quant_blocks_pallas, topk_blocks,
                                       topk_blocks_pallas)
from repro.net import faults as faults_lib
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig
from repro.net.faults import FaultConfig

CAP, K = 32, 2
BANK = BankGossipConfig(chunks_per_slot=4)


def genesis(num_nodes):
    d = dag_lib.empty_dag(CAP, K, num_nodes + 1)
    return dag_lib.publish(
        d, jnp.asarray(num_nodes, jnp.int32), jnp.float32(0.0),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )


def make_net(top, engine="ticks", bank_cfg=BANK, faults=None, seed=7):
    return gossip_lib.GossipNetwork(
        genesis(top.num_nodes), bank=jnp.zeros((CAP, 8)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=1.0, seed=seed,
                                    engine=engine),
        bank_cfg=bank_cfg, faults_cfg=faults,
    )


def publish_on(net, node, seq, t):
    d = replica_lib.publish_local(
        net.read(node), seq, jnp.asarray(node, jnp.int32), jnp.float32(t),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(seq % CAP, jnp.int32),
    )
    net.write(node, d)
    if net.bank_cfg is not None:
        net.bank_commit(node, seq % CAP, jnp.full((8,), float(seq)))


def assert_nets_bitwise(a, b, msg=""):
    for name in dag_lib.DagState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.replicas.dags, name)),
            np.asarray(getattr(b.replicas.dags, name)),
            err_msg=f"{msg}{name}",
        )
    np.testing.assert_array_equal(
        np.asarray(a._key), np.asarray(b._key), err_msg=f"{msg}key"
    )
    if a.bank_cfg is not None:
        for f in ("have", "credit", "sent"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a.replicas.bank_state, f)),
                np.asarray(getattr(b.replicas.bank_state, f)),
                err_msg=f"{msg}{f}",
            )


# ---------------------------------------------------------------------------
# Round-trip error bound per dtype
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 700),
    kind=st.sampled_from(["int8", "int4"]),
    scale=st.floats(1e-3, 1e3),
)
def test_property_quant_roundtrip_error_bound(seed, n, kind, scale):
    """Property (acceptance): dequant(quant(x)) is within half a step —
    ``amax(block) / (2 * qmax)`` — of x, elementwise, for any length
    (padding included) and magnitude."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * scale).astype(np.float32)
    codec = DeltaCodec(kind=kind, impl="lax")
    base = jnp.zeros((n,), jnp.float32)
    enc = codec.encode(x, base)
    out = np.asarray(codec.decode(enc, base))
    qmax = 127 if kind == "int8" else 7
    blocks = np.asarray(_to_blocks(jnp.asarray(x), codec.block))
    step = np.abs(blocks).max(axis=-1) / (2.0 * qmax)
    bound = np.repeat(step, codec.block)[:n] + 1e-6 * scale
    np.testing.assert_array_less(np.abs(out - x), bound + 1e-12)


@pytest.mark.parametrize("kind", ["int8", "int4"])
def test_quant_zero_blocks_roundtrip_exactly(kind):
    """All-zero blocks get scale exactly 1.0 and codes 0 — the property
    that makes ``_to_blocks`` padding invisible after decode."""
    codec = DeltaCodec(kind=kind, impl="lax")
    x = jnp.zeros((5, 3), jnp.float32)
    out = codec.decode(codec.encode(x, x), x)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    codes, scales = ref.quant_blocks_ref(jnp.zeros((4, BLOCK)), 127)
    np.testing.assert_array_equal(np.asarray(scales), 1.0)
    np.testing.assert_array_equal(np.asarray(codes), 0)


# ---------------------------------------------------------------------------
# Top-k exactness
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nnz=st.integers(0, 8))
def test_property_topk_exact_when_k_covers_nnz(seed, nnz):
    """Property (acceptance): zeros never outrank a nonzero, so any block
    with ``nnz <= k`` survives masking bit-for-bit."""
    rng = np.random.default_rng(seed)
    d = np.zeros((3, BLOCK), np.float32)
    for r in range(d.shape[0]):
        idx = rng.choice(BLOCK, size=nnz, replace=False)
        d[r, idx] = rng.standard_normal(nnz).astype(np.float32)
    out = np.asarray(ref.topk_blocks_ref(jnp.asarray(d), max(nnz, 1)))
    np.testing.assert_array_equal(out, d)


def test_topk_keeps_largest_and_breaks_ties_low_index():
    d = jnp.asarray([[0.5, -2.0, 1.0, 1.0, 0.1, 0.0, 0.0, 0.0]], jnp.float32)
    out = np.asarray(ref.topk_blocks_ref(d, 2))
    np.testing.assert_array_equal(
        out, [[0.0, -2.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]]
    )
    codec = DeltaCodec(kind="topk", impl="lax")
    assert codec.topk_k() == 8            # 0.0625 * 128
    assert codec.wire_ratio() == pytest.approx(0.125)


def test_topk_codec_roundtrip_applies_masked_delta():
    rng = np.random.default_rng(0)
    base = jnp.asarray(rng.standard_normal(300), jnp.float32)
    new = base + jnp.asarray(rng.standard_normal(300) * 0.01, jnp.float32)
    codec = DeltaCodec(kind="topk", topk_frac=1.0, impl="lax")
    out = np.asarray(codec.decode(codec.encode(new, base), base))
    np.testing.assert_allclose(out, np.asarray(new), rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# Kernel == oracle
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    nb=st.integers(1, 33),
    qmax=st.sampled_from([127, 7]),
)
def test_property_quant_kernel_matches_oracle(seed, nb, qmax):
    """Codes exactly; scales to float rounding (the jitted kernel may
    compile the division as a reciprocal multiply)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((nb, BLOCK)), jnp.float32)
    ck, sk = quant_blocks_pallas(x, qmax, interpret=True)
    cr, sr = ref.quant_blocks_ref(x, qmax)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    assert ck.dtype == jnp.int8


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nb=st.integers(1, 17),
       k=st.integers(1, 128))
def test_property_topk_kernel_matches_oracle(seed, nb, k):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.standard_normal((nb, BLOCK)), jnp.float32)
    out_k = topk_blocks_pallas(d, k, interpret=True)
    out_r = ref.topk_blocks_ref(d, k)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_dispatchers_follow_backend_rule():
    x = jnp.ones((2, BLOCK), jnp.float32)
    for impl in (None, "lax", "pallas"):
        c, s = quant_blocks(x, 127, impl=impl)
        assert c.shape == (2, BLOCK) and s.shape == (2,)
        assert topk_blocks(x, 4, impl=impl).shape == (2, BLOCK)
    with pytest.raises(ValueError, match="impl"):
        quant_blocks(x, 127, impl="cuda")
    with pytest.raises(ValueError, match="impl"):
        topk_blocks(x, 4, impl="cuda")
    with pytest.raises(ValueError, match="kind"):
        DeltaCodec(kind="zstd")


# ---------------------------------------------------------------------------
# Identity is literal: kind="none" is bitwise the codec=None program
# ---------------------------------------------------------------------------


def test_codec_key_maps_identity_to_none():
    assert codec_key(None) is None
    assert codec_key(DeltaCodec(kind="none")) is None
    assert codec_key(DeltaCodec(kind="topk", topk_frac=1.0)) is None
    active = DeltaCodec(kind="int8")
    assert codec_key(active) is active


@pytest.mark.parametrize("engine", ["ticks", "events"])
def test_identity_codec_bitwise_uncompressed_unit(engine):
    top = topo.ring(6, link_latency=1.0, bandwidth=256.0, seed=3)
    a = make_net(top, engine, bank_cfg=BankGossipConfig(chunks_per_slot=4))
    b = make_net(top, engine, bank_cfg=BankGossipConfig(
        chunks_per_slot=4, codec=DeltaCodec(kind="none")))
    for seq, (node, t) in enumerate([(0, 0.2), (3, 0.4)], start=1):
        publish_on(a, node, seq, t)
        publish_on(b, node, seq, t)
    for t in (1.0, 2.5, 6.0):
        a.advance(t)
        b.advance(t)
        assert_nets_bitwise(a, b, msg=f"t={t}:")
    assert a.converge(at_time=30.0) == b.converge(at_time=30.0)
    assert_nets_bitwise(a, b, msg="converge:")


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    overlay=st.sampled_from(["ring", "star", "full"]),
    engine=st.sampled_from(["ticks", "events"]),
    faulted=st.booleans(),
)
def test_property_identity_codec_bitwise_uncompressed(seed, overlay, engine,
                                                      faulted):
    """Property (acceptance): ``DeltaCodec(kind="none")`` keys the SAME
    jitted programs as ``codec=None`` — bitwise over overlays, engines,
    and with the fault layer armed (spoofers active, digests verified)."""
    n = 6
    builders = {
        "ring": lambda: topo.ring(n, link_latency=1.0, seed=seed % 997),
        "star": lambda: topo.star(n, link_latency=1.0),
        "full": lambda: topo.full(n, link_latency=1.0),
    }
    faults = (
        FaultConfig(
            roles=(faults_lib.ROLE_SPOOF,) + (faults_lib.ROLE_HONEST,) * (n - 1),
            spoof_rate=1.0, verify_digests=True, quarantine_after=2,
        ) if faulted else None
    )
    top = builders[overlay]()
    a = make_net(top, engine, bank_cfg=BankGossipConfig(chunks_per_slot=4),
                 faults=faults, seed=seed % 1013)
    b = make_net(top, engine,
                 bank_cfg=BankGossipConfig(chunks_per_slot=4,
                                           codec=DeltaCodec(kind="none")),
                 faults=faults, seed=seed % 1013)
    rng = np.random.default_rng(seed)
    for seq in range(1, 4):
        node = int(rng.integers(0, n))
        publish_on(a, node, seq, 0.1 * seq)
        publish_on(b, node, seq, 0.1 * seq)
    for t in (1.0, 2.5, 5.0):
        a.advance(t)
        b.advance(t)
        assert_nets_bitwise(a, b, msg=f"t={t}:")


# ---------------------------------------------------------------------------
# Pricing: the byte meter scales by exactly wire_ratio
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["ticks", "events"])
@pytest.mark.parametrize("kind", ["int8", "int4", "topk"])
def test_active_codec_prices_bytes_at_wire_ratio(engine, kind):
    """With capacity to move every needed chunk, the compressed run moves
    the SAME chunks as the raw run and the meter records exactly
    ``wire_ratio()`` times the bytes (afford/credit/sent all price the
    encoded size)."""
    codec = DeltaCodec(kind=kind)
    top = topo.ring(4, link_latency=1.0, bandwidth=1e9, seed=3)
    a = make_net(top, engine, bank_cfg=BankGossipConfig(chunks_per_slot=4))
    b = make_net(top, engine,
                 bank_cfg=BankGossipConfig(chunks_per_slot=4, codec=codec))
    publish_on(a, 0, 1, 0.2)
    publish_on(b, 0, 1, 0.2)
    for t in (1.0, 2.0, 3.0):
        a.advance(t)
        b.advance(t)
    sent_a = np.asarray(a.replicas.bank_state.sent)
    sent_b = np.asarray(b.replicas.bank_state.sent)
    assert sent_a.sum() > 0               # the raw run actually moved chunks
    np.testing.assert_allclose(
        sent_b, sent_a * codec.wire_ratio(), rtol=1e-6
    )


def test_commit_store_holds_dequantized_values():
    """The shared store holds what a receiver would decode — quantization
    error enters training exactly once, at commit, and every node reads
    the same bytes (the single-shared-store fidelity rule)."""
    codec = DeltaCodec(kind="int8", impl="lax")
    params = jnp.asarray(np.random.default_rng(0).standard_normal(8),
                         jnp.float32)
    base = jnp.zeros((8,), jnp.float32)
    enc = codec.encode(params, base)
    stored = codec.decode(enc, base)
    # idempotence: re-encoding the stored value reproduces the wire bytes
    enc2 = codec.encode(stored, base)
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(enc)[0]),
        np.asarray(jax.tree_util.tree_leaves(enc2)[0]),
    )
