"""§IV stability model: Eqs. (4)-(8) + Poisson simulation agreement."""
import numpy as np
import pytest

from repro.configs.base import DagFLConfig
from repro.core import stability


def cfg(**kw):
    base = dict(num_nodes=100, alpha=5, k=2, tau_max=20.0, beta=1)
    base.update(kw)
    return DagFLConfig(**base)


def test_delay_formulas_table1_cnn():
    """Table-I CNN constants at f = 1.5 GHz."""
    c = cfg()
    f = 1.5e9
    d0 = stability.training_delay(c, f)       # 500 * 0.3MB*8 * 1 / 1.5e9
    assert abs(d0 - 500 * 0.3e6 * 8 * 1 / 1.5e9) < 1e-9
    d1 = stability.validation_delay(c, f)     # 160 * 0.3MB*8 * 5 / 1.5e9
    assert abs(d1 - 160 * 0.3e6 * 8 * 5 / 1.5e9) < 1e-9
    h = stability.iteration_delay(c, f)
    assert abs(h - (d0 + d1)) < 1e-12
    # paper's DAG-FL per-iteration compute delay is ~2.1 s at these constants
    assert 1.0 < h < 4.0


def test_equilibrium_eq4_closed_form():
    c = cfg()
    h = stability.iteration_delay(c, 1.5e9)
    L0 = stability.equilibrium_tips(c, 1.5e9)
    assert abs(L0 - c.k * c.arrival_rate * h / (c.k - 1)) < 1e-9


def test_larger_k_reduces_tip_count():
    """§IV.A: increasing k shrinks L0 (k/(k-1) decreasing)."""
    l2 = stability.equilibrium_tips(cfg(k=2, alpha=5))
    l4 = stability.equilibrium_tips(cfg(k=4, alpha=6))
    # same h would give smaller factor; alpha also changes h, so compare factor
    c2, c4 = cfg(k=2, alpha=5), cfg(k=4, alpha=6)
    f2 = c2.k / (c2.k - 1)
    f4 = c4.k / (c4.k - 1)
    assert f4 < f2
    assert l4 / stability.iteration_delay(c4, None or 1.5e9) < l2 / stability.iteration_delay(c2, 1.5e9)


def test_tail_mean_guards_short_traces():
    """Regression: len * frac < 1 produced tips[-0:] — the WHOLE trace —
    silently; now the estimate degrades to the last sample, and an empty
    trace is NaN instead of a numpy mean-of-empty warning."""
    tr = stability.TipTrace(np.asarray([0.0, 1.0]), np.asarray([10.0, 4.0]))
    assert tr.tail_mean(0.4) == 4.0              # n clamps to 1: last sample
    assert tr.tail_mean(0.5) == 4.0
    assert tr.tail_mean(1.0) == 7.0
    empty = stability.TipTrace(np.asarray([]), np.asarray([]))
    assert np.isnan(empty.tail_mean())


@pytest.mark.parametrize("k", [2, 3])
def test_simulation_matches_eq4(k):
    c = cfg(k=k, alpha=5)
    f = 1.5e9
    trace = stability.simulate_tip_count(c, horizon=1500.0, seed=0, f=f)
    sim = trace.tail_mean(0.5)
    pred = stability.equilibrium_tips(c, f)
    # Eq. (4) is derived under tangle approximations; 35% agreement band
    assert sim == pytest.approx(pred, rel=0.35), (sim, pred)
