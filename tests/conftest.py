import os
import sys

import pytest

# tests must see ONE device unless the environment forces more (the CI
# 8-device lane exports XLA_FLAGS=--xla_force_host_platform_device_count=8;
# the dry-run sets its own flags in-process); keep any user XLA_FLAGS but
# never force a device count here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_KNOWN_FAILURES_FILE = os.path.join(os.path.dirname(__file__), "known_failures.txt")


def _known_failures():
    """Node ids of the pre-existing seed failures (see ROADMAP Open items)."""
    try:
        with open(_KNOWN_FAILURES_FILE) as f:
            lines = (ln.split("#", 1)[0].strip() for ln in f)
            return {ln for ln in lines if ln}
    except OSError:
        return set()


@pytest.fixture(autouse=True, scope="module")
def _drop_jax_caches_between_modules():
    """Release compiled XLA executables after each test module.

    The suite compiles hundreds of large while_loop programs in one
    process; with every executable held live by jax's in-process jit
    cache, the XLA CPU backend eventually segfaults inside
    backend_compile when a late module (the obs+serve event-engine
    programs are the largest in the suite) compiles on top of all of
    them. Per-module teardown bounds the live-executable set; reuse
    within a module — where the bitwise-equivalence tests rely on the
    cache — is untouched.
    """
    yield
    import jax

    jax.clear_caches()


def pytest_collection_modifyitems(config, items):
    """Strict-xfail every known seed failure.

    A listed test that fails is expected (CI stays green on real signal); a
    listed test that PASSES is reported as a failure — fixing one must also
    delete its line from tests/known_failures.txt. Node ids are matched both
    rootdir-relative ("tests/test_x.py::t") and bare ("test_x.py::t") so the
    list works from the repo root and from inside tests/.
    """
    known = _known_failures()
    if not known:
        return
    known |= {k.split("/", 1)[1] for k in known if k.startswith("tests/")}
    for item in items:
        if item.nodeid in known or f"tests/{item.nodeid}" in known:
            item.add_marker(
                pytest.mark.xfail(
                    reason="known seed failure (tests/known_failures.txt)",
                    strict=True,
                )
            )
