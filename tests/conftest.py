import os
import sys

# tests must see ONE device (the dry-run sets its own flags in-process);
# keep any user XLA_FLAGS but never force a device count here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
