"""Integration: the five FL systems run and produce sane results (small scale)."""
import numpy as np
import pytest

from repro.fl.experiments import default_dagfl_config, make_cnn_setup, make_lstm_setup
from repro.fl.systems import (
    SYSTEMS,
    SimConfig,
    run_async,
    run_block,
    run_dagfl,
    run_dagfl_gossip,
    run_google,
)
from repro.net import topology as topo
from repro.net.gossip import GossipConfig, PartitionSchedule


@pytest.fixture(scope="module")
def cnn_setup():
    task, nodes, gval, gen = make_cnn_setup(num_nodes=16, seed=0)
    dcfg = default_dagfl_config(num_nodes=16)
    sim = SimConfig(iterations=60, eval_every=20, seed=0)
    return task, nodes, gval, dcfg, sim


@pytest.mark.parametrize(
    "runner", [run_dagfl, run_dagfl_gossip, run_async, run_block, run_google]
)
def test_system_runs_and_improves_or_stays_finite(cnn_setup, runner):
    task, nodes, gval, dcfg, sim = cnn_setup
    res = runner(task, nodes, dcfg, sim, gval)
    assert len(res.accs) >= 2
    assert np.all(np.isfinite(res.accs))
    assert res.avg_latency > 0
    assert res.times[-1] > 0


def test_gossip_registered_in_systems():
    assert SYSTEMS["dagfl_gossip"] is run_dagfl_gossip


@pytest.fixture(scope="module")
def ideal_wire_base():
    n, dcfg = 12, default_dagfl_config(num_nodes=12)
    sim = SimConfig(iterations=40, eval_every=10, seed=0)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)
    return run_dagfl(task, nodes, dcfg, sim, gval)


@pytest.mark.parametrize("impl", ["fused", "scan"])
def test_gossip_ideal_wire_recovers_shared_ledger(ideal_wire_base, impl):
    """sync period -> 0, drop 0, connected overlay: the gossip system's
    accuracy curve must match run_dagfl within noise (here: exactly, same
    RNG streams + deterministic CPU ops) — under both the reference scan
    round and the fused kernel round."""
    n, dcfg = 12, default_dagfl_config(num_nodes=12)
    sim = SimConfig(iterations=40, eval_every=10, seed=0)
    base = ideal_wire_base
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)   # fresh node RNGs
    ideal = run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.full(n),
        gossip=GossipConfig(sync_period=0.0, seed=0, impl=impl),
    )
    np.testing.assert_allclose(ideal.accs, base.accs, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ideal.times, base.times, rtol=1e-9)
    # serialized commits: no duplicate-approval deficit in the ideal limit
    assert ideal.extras["approvals_issued"] == ideal.extras["approvals_in_union"]


@pytest.mark.parametrize("runner", [run_dagfl, run_dagfl_gossip])
def test_zero_iteration_run_returns_empty_curve(runner):
    """Regression: iterations=0 used to crash on the trailing eval (its
    completion time never got bound); now it returns an empty-curve result."""
    n = 6
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=0, eval_every=10, seed=0)
    res = runner(task, nodes, dcfg, sim, gval)
    assert len(res.iters) == len(res.times) == len(res.accs) == 0
    assert res.avg_latency == 0.0
    assert res.acc_at(100) == 0.0
    assert len(res.extras["behaviors"]) == n


def test_gossip_stale_overlay_diverges_and_reports_metrics():
    n, dcfg = 12, default_dagfl_config(num_nodes=12)
    sim = SimConfig(iterations=40, eval_every=10, seed=0)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)
    res = run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.ring(n), gossip=GossipConfig(sync_period=4.0, seed=0),
    )
    assert np.all(np.isfinite(res.accs))
    assert res.extras["sync_rounds"] > 0
    # a slow ring leaves some replicas behind the union view at the end
    assert res.extras["missing_rows_final"].max() > 0
    assert res.extras["divergence_curve"].shape[1] == 3


def test_gossip_partition_runs_and_heals_visibility():
    """A mid-run partition splits the overlay; after healing, gossip pulls
    every replica back to the union view."""
    n, dcfg = 10, default_dagfl_config(num_nodes=10)
    sim = SimConfig(iterations=30, eval_every=10, seed=0)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)
    part = PartitionSchedule(assignment=topo.split_halves(n), t_start=5.0, t_end=20.0)
    res = run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.full(n), gossip=GossipConfig(sync_period=0.5, seed=0),
        partition=part,
    )
    assert np.all(np.isfinite(res.accs))
    # replicas reconverge once the schedule heals and ticks keep flowing
    from repro.net import replica as replica_lib
    from repro.net.gossip import GossipNetwork

    rs = res.extras["replicas"]
    net = GossipNetwork(
        replica_lib.read_replica(rs, 0), rs.bank, topo.full(n),
        GossipConfig(sync_period=0.5, seed=1),
    )
    net.replicas = rs
    assert net.converge(at_time=1e9)
    assert bool(replica_lib.replicas_synced(net.replicas.dags))


def test_latency_ordering_matches_table2(cnn_setup):
    """Google's synchronous rounds are the slowest per iteration (Table II)."""
    task, nodes, gval, dcfg, sim = cnn_setup
    dag = run_dagfl(task, nodes, dcfg, sim, gval)
    goo = run_google(task, nodes, dcfg, sim, gval)
    asy = run_async(task, nodes, dcfg, sim, gval)
    assert goo.avg_latency > dag.avg_latency
    assert goo.avg_latency > asy.avg_latency


def test_dagfl_contribution_extras(cnn_setup):
    task, nodes, gval, dcfg, sim = cnn_setup
    res = run_dagfl(task, nodes, dcfg, sim, gval)
    assert "contribution_m0" in res.extras
    assert len(res.extras["behaviors"]) == len(nodes)


def test_lstm_task_systems_run():
    task, nodes, gval, corpus = make_lstm_setup(num_nodes=10, seed=0)
    dcfg = default_dagfl_config(num_nodes=10, task="lstm")
    sim = SimConfig(iterations=20, eval_every=10, seed=0, minibatch=8,
                    steps_per_iter=2, val_size=8)
    res = run_dagfl(task, nodes, dcfg, sim, gval)
    assert np.all(np.isfinite(res.accs))
