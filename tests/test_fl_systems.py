"""Integration: the four FL systems run and produce sane results (small scale)."""
import numpy as np
import pytest

from repro.fl.experiments import default_dagfl_config, make_cnn_setup, make_lstm_setup
from repro.fl.systems import SimConfig, run_async, run_block, run_dagfl, run_google


@pytest.fixture(scope="module")
def cnn_setup():
    task, nodes, gval, gen = make_cnn_setup(num_nodes=16, seed=0)
    dcfg = default_dagfl_config(num_nodes=16)
    sim = SimConfig(iterations=60, eval_every=20, seed=0)
    return task, nodes, gval, dcfg, sim


@pytest.mark.parametrize("runner", [run_dagfl, run_async, run_block, run_google])
def test_system_runs_and_improves_or_stays_finite(cnn_setup, runner):
    task, nodes, gval, dcfg, sim = cnn_setup
    res = runner(task, nodes, dcfg, sim, gval)
    assert len(res.accs) >= 2
    assert np.all(np.isfinite(res.accs))
    assert res.avg_latency > 0
    assert res.times[-1] > 0


def test_latency_ordering_matches_table2(cnn_setup):
    """Google's synchronous rounds are the slowest per iteration (Table II)."""
    task, nodes, gval, dcfg, sim = cnn_setup
    dag = run_dagfl(task, nodes, dcfg, sim, gval)
    goo = run_google(task, nodes, dcfg, sim, gval)
    asy = run_async(task, nodes, dcfg, sim, gval)
    assert goo.avg_latency > dag.avg_latency
    assert goo.avg_latency > asy.avg_latency


def test_dagfl_contribution_extras(cnn_setup):
    task, nodes, gval, dcfg, sim = cnn_setup
    res = run_dagfl(task, nodes, dcfg, sim, gval)
    assert "contribution_m0" in res.extras
    assert len(res.extras["behaviors"]) == len(nodes)


def test_lstm_task_systems_run():
    task, nodes, gval, corpus = make_lstm_setup(num_nodes=10, seed=0)
    dcfg = default_dagfl_config(num_nodes=10, task="lstm")
    sim = SimConfig(iterations=20, eval_every=10, seed=0, minibatch=8,
                    steps_per_iter=2, val_size=8)
    res = run_dagfl(task, nodes, dcfg, sim, gval)
    assert np.all(np.isfinite(res.accs))
