"""Serving launcher: slot admission, lockstep decode, request completion."""
import jax
import numpy as np

from repro.configs import ARCHS
from repro.launch.serve import Request, SlotServer
from repro.models import build_model


def test_slot_server_completes_requests():
    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    server = SlotServer(cfg, params, slots=2, max_len=24)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32), max_new=4)
        for i in range(3)
    ]
    pending = list(reqs)
    ticks = 0
    while pending or any(server.active):
        while pending and server.admit(pending[0]):
            pending.pop(0)
        server.tick()
        ticks += 1
        assert ticks < 50
    for r in reqs:
        assert r.done and len(r.out) >= r.max_new
    # slots must have been reused (3 requests, 2 slots)
    assert ticks >= 2
