"""Optional-hypothesis shim for the property-test modules.

The tier-1 container does not ship ``hypothesis`` (and nothing may be pip
installed there), but several modules mix property tests with plain unit
tests. A module-level ``pytest.importorskip("hypothesis")`` would throw the
unit tests away with the bathwater, so instead the property-test modules do

    from _hypothesis_compat import given, settings, st

which re-exports the real hypothesis API when it is installed (CI installs it
via requirements.txt) and otherwise substitutes stubs whose ``@given`` turns
the test into a single skip — collection always succeeds, unit tests always
run, property tests run wherever hypothesis exists.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.importorskip("hypothesis")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Any ``st.<name>(...)`` call returns None; @given never runs it."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
