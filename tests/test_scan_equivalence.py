"""Chunked (TPU-native) vs sequential formulations must agree exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.mamba import ssd_chunked, ssd_scan
from repro.models.rwkv import wkv_chunked, wkv_scan


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([16, 32]))
def test_wkv_chunked_equals_scan(seed, chunk):
    key = jax.random.PRNGKey(seed)
    B, T, H, hd = 2, 64, 2, 8
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    # decays from mild to extreme (log w in [-e^2, ~0])
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd)) * 2.0)
    u = jax.random.uniform(ks[4], (H, hd))
    S0 = jax.random.normal(ks[5], (B, H, hd, hd))
    y1, s1 = wkv_scan(r, k, v, logw, u, S0)
    y2, s2 = wkv_chunked(r, k, v, logw, u, S0, chunk=chunk)
    np.testing.assert_allclose(y1, y2, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(s1, s2, rtol=5e-4, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([16, 64]))
def test_ssd_chunked_equals_scan(seed, chunk):
    key = jax.random.PRNGKey(seed)
    B, T, H, P, N = 2, 128, 3, 8, 4
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, T, H, P))
    Bm = jax.random.normal(ks[1], (B, T, N))
    Cm = jax.random.normal(ks[2], (B, T, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[4], (H,)))
    S0 = jax.random.normal(key, (B, H, P, N))
    y1, s1 = ssd_scan(xh, Bm, Cm, dt, A, S0)
    y2, s2 = ssd_chunked(xh, Bm, Cm, dt, A, S0, chunk=chunk)
    np.testing.assert_allclose(y1, y2, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(s1, s2, rtol=5e-4, atol=5e-4)


def test_wkv_state_carries_across_calls():
    """Processing a sequence in two halves == one pass (streaming decode)."""
    key = jax.random.PRNGKey(0)
    B, T, H, hd = 1, 32, 2, 8
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd)))
    u = jax.random.uniform(ks[4], (H, hd))
    S0 = jnp.zeros((B, H, hd, hd))
    y_full, s_full = wkv_scan(r, k, v, logw, u, S0)
    y1, s_mid = wkv_scan(r[:, :16], k[:, :16], v[:, :16], logw[:, :16], u, S0)
    y2, s_end = wkv_scan(r[:, 16:], k[:, 16:], v[:, 16:], logw[:, 16:], u, s_mid)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s_end, s_full, rtol=1e-5, atol=1e-5)
