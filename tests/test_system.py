"""End-to-end behaviour of the DAG-FL system (the paper's claims, small scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DagFLConfig
from repro.core import Controller, make_dagfl_iteration
from repro.core.anomaly import contribution_report
from repro.data import MnistLike, paper_partition
from repro.fl.tasks import bench_cnn_task


@pytest.fixture(scope="module")
def setup():
    task = bench_cnn_task()
    cfg = DagFLConfig(num_nodes=12, capacity=64, alpha=5, k=2, tau_max=40.0, beta=1)
    gen = MnistLike(image_size=16, seed=0)
    nodes = paper_partition(gen, num_nodes=12, shard_size=30, uniform_per_node=30)
    rng = np.random.default_rng(0)
    val = gen.balanced(rng, 128)
    vb = {"x": jnp.asarray(val.x), "y": jnp.asarray(val.y)}
    return task, cfg, nodes, vb, rng


def run_iterations(task, cfg, nodes, vb, rng, n_iters, poisoned=()):
    from repro.fl.tasks import make_epoch_train

    ctrl = Controller(cfg, task.eval_fn, target_accuracy=0.99)
    state = ctrl.genesis(task.init(jax.random.PRNGKey(0)), vb)
    # one paper 'iteration' = an epoch (several minibatches), Section V.A.1
    it_fn = jax.jit(make_dagfl_iteration(cfg, task.eval_fn, make_epoch_train(task)))
    dag, bank = state.dag, state.bank
    accs = []
    steps = 4
    for i in range(n_iters):
        nid = i % len(nodes)
        ds = nodes[nid]
        idx = rng.integers(0, len(ds.y), (steps, 32))
        x, y = ds.x[idx], ds.y[idx]
        if nid in poisoned:
            y = rng.integers(0, 10, y.shape).astype(y.dtype)
        out = it_fn(dag, bank, nid, float(i) + 1.0, jax.random.PRNGKey(i),
                    {"x": jnp.asarray(x), "y": jnp.asarray(y)}, vb)
        dag, bank = out.dag, out.bank
        accs.append(float(out.new_accuracy))
    state.dag, state.bank = dag, bank
    state = ctrl.check(state, jax.random.PRNGKey(99), float(n_iters) + 1.0, vb)
    return state, dag, accs


def test_dagfl_learns(setup):
    task, cfg, nodes, vb, rng = setup
    state, dag, accs = run_iterations(task, cfg, nodes, vb, rng, 260)
    assert np.mean(accs[-10:]) > np.mean(accs[:10]) + 0.1, "no learning progress"
    assert state.best_accuracy > 0.25


def test_controller_terminates_at_target(setup):
    task, cfg, nodes, vb, rng = setup
    ctrl = Controller(cfg, task.eval_fn, target_accuracy=0.05)  # trivially low
    state = ctrl.genesis(task.init(jax.random.PRNGKey(0)), vb)
    it_fn = jax.jit(make_dagfl_iteration(cfg, task.eval_fn, task.train_fn))
    ds = nodes[0]
    out = it_fn(state.dag, state.bank, 0, 1.0, jax.random.PRNGKey(0),
                {"x": jnp.asarray(ds.x[:32]), "y": jnp.asarray(ds.y[:32])}, vb)
    state.dag, state.bank = out.dag, out.bank
    state = ctrl.check(state, jax.random.PRNGKey(1), 2.0, vb)
    assert state.done, "end signal missing despite ACC_t >= ACC_0"


def test_poisoning_detected_and_tolerated(setup):
    """Section V.4 mechanism: poisoned transactions carry clearly lower
    validation accuracy (what tip selection discriminates on), and the
    co-constructed model still learns despite 2/12 poisoning nodes."""
    task, cfg, nodes, vb, rng = setup
    poisoned = {0, 1}
    state, dag, accs = run_iterations(task, cfg, nodes, vb, rng, 260, poisoned=poisoned)
    pub = np.asarray(dag.publisher)
    acc = np.asarray(dag.accuracy)
    mask = pub >= 0
    is_bad = np.isin(pub, list(poisoned)) & mask
    is_ok = ~np.isin(pub, list(poisoned)) & mask
    # poisoned publications score clearly below normal ones
    assert acc[is_bad].mean() < 0.75 * acc[is_ok].mean(), (
        acc[is_bad].mean(), acc[is_ok].mean())
    # and DAG-FL still makes progress (insensitivity, Fig. 6)
    assert state.best_accuracy > 0.2, state.best_accuracy


def test_weighted_aggregation_variant_runs(setup):
    task, cfg, nodes, vb, rng = setup
    it_fn = jax.jit(make_dagfl_iteration(cfg, task.eval_fn, task.train_fn, weighted=True))
    ctrl = Controller(cfg, task.eval_fn)
    state = ctrl.genesis(task.init(jax.random.PRNGKey(0)), vb)
    ds = nodes[0]
    out = it_fn(state.dag, state.bank, 0, 1.0, jax.random.PRNGKey(0),
                {"x": jnp.asarray(ds.x[:32]), "y": jnp.asarray(ds.y[:32])}, vb)
    assert bool(jnp.isfinite(out.new_accuracy))
