"""Algorithm 1 — the external agent E (`repro.core.controller`).

Direct units for the controller loop the systems drivers wrap: the genesis
transaction's shape, the no-valid-tips early return, and the
``ACC_t >= ACC_0`` end-signal condition.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DagFLConfig
from repro.core import bank as bank_lib
from repro.core import dag as dag_lib
from repro.core.controller import Controller


def _cfg(**kw):
    base = dict(num_nodes=6, alpha=3, k=2, capacity=16, target_accuracy=0.9)
    base.update(kw)
    return DagFLConfig(**base)


def _params():
    return {"w": jnp.arange(8.0), "b": jnp.ones((3,))}


def _eval_returning(values):
    """eval_fn stub: pops scripted accuracies, then repeats the last one."""
    seq = list(values)

    def eval_fn(params, batch):
        v = seq.pop(0) if len(seq) > 1 else seq[0]
        return jnp.asarray(v, jnp.float32)

    return eval_fn


def test_genesis_transaction_shape():
    """Genesis: row 0 is E's transaction — published by node id N at t=0,
    no approvals, model at bank slot 0 holding the initial params."""
    cfg = _cfg()
    ctrl = Controller(cfg, _eval_returning([0.25]))
    params = _params()
    state = ctrl.genesis(params, val_batch=None)
    dag = state.dag
    assert dag.publisher.shape == (cfg.capacity,)
    assert dag.approvals.shape == (cfg.capacity, cfg.k)
    assert int(dag.count) == 1
    assert int(dag.publisher[0]) == cfg.num_nodes          # E's node id
    assert float(dag.publish_time[0]) == 0.0
    assert np.all(np.asarray(dag.approvals[0]) == dag_lib.NO_TX)
    assert int(dag.approval_count[0]) == 0                 # genesis is a tip
    assert int(dag.model_slot[0]) == 0
    assert float(dag.accuracy[0]) == 0.25
    # the bank's slot 0 holds the genesis payload bitwise
    stored = bank_lib.bank_read(state.bank, jnp.asarray(0))
    for a, b in zip(jax.tree_util.tree_leaves(stored),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not state.done and state.checks == 0


def test_check_no_valid_tips_early_return():
    """Every tip staler than tau_max: check() must count the visit but
    leave the target model, best accuracy, and done flag untouched."""
    cfg = _cfg(tau_max=5.0)
    ctrl = Controller(cfg, _eval_returning([0.25]))
    state = ctrl.genesis(_params(), val_batch=None)
    # genesis published at t=0; now is far past the staleness threshold
    out = ctrl.check(state, jax.random.PRNGKey(0), now=100.0, val_batch=None)
    assert out.checks == 1
    assert out.target_model is None
    assert out.best_accuracy == 0.0
    assert not out.done


def test_check_tracks_best_and_stops_at_target():
    """ACC_t rises across checks: best/target update monotonically and the
    end signal fires exactly when ACC_t >= ACC_0."""
    cfg = _cfg(target_accuracy=0.9, tau_max=50.0)
    # scripted evals: genesis 0.2; check 1 validates tips (0.4) then scores
    # the candidate 0.5; check 2: 0.6 then 0.95 (>= ACC_0)
    ctrl = Controller(cfg, _eval_returning([0.2, 0.4, 0.5, 0.6, 0.95]))
    state = ctrl.genesis(_params(), val_batch=None)
    state = ctrl.check(state, jax.random.PRNGKey(1), now=1.0, val_batch=None)
    assert state.checks == 1
    assert state.best_accuracy == 0.5
    assert state.target_model is not None and not state.done
    state = ctrl.check(state, jax.random.PRNGKey(2), now=2.0, val_batch=None)
    assert state.checks == 2
    assert state.best_accuracy == np.float32(0.95)
    assert state.done                                      # end signal to D


def test_check_never_regresses_best():
    cfg = _cfg(target_accuracy=0.99, tau_max=50.0)
    ctrl = Controller(cfg, _eval_returning([0.2, 0.4, 0.7, 0.6, 0.3]))
    state = ctrl.genesis(_params(), val_batch=None)
    state = ctrl.check(state, jax.random.PRNGKey(1), now=1.0, val_batch=None)
    best = state.best_accuracy
    state = ctrl.check(state, jax.random.PRNGKey(2), now=2.0, val_batch=None)
    assert state.best_accuracy == best                     # 0.3 never wins
    assert not state.done
