"""Adversarial fault injection (`repro.net.faults`).

Pins the acceptance invariants of the robustness layer:

* ZERO PERTURBATION: ``faults=None`` — and an all-HONEST ``FaultConfig``,
  whose role draws are salted off the round key — is BITWISE the
  un-faulted run (final ReplicaSet, bank state, PRNG key), property-
  tested over engines, round impls, overlays, partitions, and the bank;
* the SPOOF defense holds: with digest verification on, a corrupted
  chunk NEVER enters any gated view (attack-success numerator == 0 over
  overlays x engines), rejections accrue against the spoofer, and its
  out-links are quarantined within bounded rounds; with verification
  off the same attack demonstrably lands (the defense is load-bearing);
* role semantics on known schedules: a CRASH window silences a node and
  ends (recovery, including through a concurrent partition), an ECLIPSE
  attacker monopolizes its target's intake (pinned on the star hub —
  the paper's single-point-of-failure overlay), SELECTIVE forwarding at
  p=0 blocks and at p=1 is bitwise honest, SYBIL forges approver-set
  inflation on the attacker's own rows;
* the telemetry coupling: fault runs surface rejected/quarantined
  series, KIND_REJECT trace records, and obs-on stays bitwise obs-off
  even under active faults.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dag as dag_lib
from repro.core.anomaly import rejection_credit
from repro.net import faults as faults_lib
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig
from repro.net.faults import FaultConfig
from repro.obs import KIND_REJECT, ObsConfig

CAP, K = 32, 2
BANK = BankGossipConfig(chunks_per_slot=4)


def genesis(num_nodes):
    d = dag_lib.empty_dag(CAP, K, num_nodes + 1)
    return dag_lib.publish(
        d, jnp.asarray(num_nodes, jnp.int32), jnp.float32(0.0),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )


def make_net(top, engine="ticks", faults=None, obs=None, bank_cfg=None,
             impl="fused", partition=None, seed=7, sync_period=1.0):
    return gossip_lib.GossipNetwork(
        genesis(top.num_nodes), bank=jnp.zeros((CAP, 8)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=sync_period, seed=seed,
                                    impl=impl, engine=engine),
        partition=partition, bank_cfg=bank_cfg, obs_cfg=obs,
        faults_cfg=faults,
    )


def publish_on(net, node, seq, t):
    d = replica_lib.publish_local(
        net.read(node), seq, jnp.asarray(node, jnp.int32), jnp.float32(t),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(seq % CAP, jnp.int32),
    )
    net.write(node, d)
    if net.bank_cfg is not None:
        net.bank_commit(node, seq % CAP, jnp.full((8,), float(seq)))


def assert_nets_bitwise(a, b, msg=""):
    for name in dag_lib.DagState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.replicas.dags, name)),
            np.asarray(getattr(b.replicas.dags, name)),
            err_msg=f"{msg}{name}",
        )
    np.testing.assert_array_equal(
        np.asarray(a._key), np.asarray(b._key), err_msg=f"{msg}key"
    )
    if a.bank_cfg is not None:
        for f in ("have", "credit", "sent"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a.replicas.bank_state, f)),
                np.asarray(getattr(b.replicas.bank_state, f)),
                err_msg=f"{msg}{f}",
            )


def honest(n):
    return FaultConfig(roles=(faults_lib.ROLE_HONEST,) * n)


# ---------------------------------------------------------------------------
# THE acceptance invariant: faults-off (and all-honest) is bitwise un-faulted
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["ticks", "events"])
@pytest.mark.parametrize("bank", [None, BANK])
def test_all_honest_bitwise_unfaulted_unit(engine, bank):
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(6), t_start=1.5, t_end=3.5,
    )
    top = topo.ring(6, link_latency=1.0, drop=0.3, seed=3)
    a = make_net(top, engine, faults=None, bank_cfg=bank, partition=part)
    b = make_net(top, engine, faults=honest(6), bank_cfg=bank, partition=part)
    publish_on(a, 0, 1, 0.3)
    publish_on(b, 0, 1, 0.3)
    for t in (1.0, 2.5, 6.0):
        a.advance(t)
        b.advance(t)
        assert_nets_bitwise(a, b, msg=f"t={t}:")
    assert a.converge(at_time=20.0) == b.converge(at_time=20.0)
    assert_nets_bitwise(a, b, msg="converge:")


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    overlay=st.sampled_from(["ring", "er", "star"]),
    engine=st.sampled_from(["ticks", "events"]),
    impl=st.sampled_from(["fused", "scan"]),
    split=st.booleans(),
)
def test_property_all_honest_bitwise_unfaulted(seed, overlay, engine, impl,
                                               split):
    """Property (acceptance): the fault layer's role draws are salted off
    the round key, so an all-honest config consumes NOTHING from the main
    PRNG stream — bitwise the un-faulted run over any overlay, engine,
    round impl, partition schedule, and publish interleaving (the
    ``faults=None`` analogue of ``tests/test_obs.py``)."""
    n = 8
    builders = {
        "ring": lambda: topo.ring(n, link_latency=1.0, drop=0.3,
                                  seed=seed % 997),
        "er": lambda: topo.erdos_renyi(n, 0.4, link_latency=1.0, drop=0.3,
                                       seed=seed % 997),
        "star": lambda: topo.star(n, link_latency=1.0, drop=0.3),
    }
    part = (
        gossip_lib.PartitionSchedule(
            assignment=topo.split_halves(n), t_start=1.5, t_end=3.5,
        ) if split else None
    )
    top = builders[overlay]()
    a = make_net(top, engine, faults=None, impl=impl, partition=part,
                 seed=seed % 1013)
    b = make_net(top, engine, faults=honest(n), impl=impl, partition=part,
                 seed=seed % 1013)
    rng = np.random.default_rng(seed)
    for seq in range(1, 4):
        node = int(rng.integers(0, n))
        publish_on(a, node, seq, 0.1 * seq)
        publish_on(b, node, seq, 0.1 * seq)
    for t in (1.0, 2.5, 5.0):
        a.advance(t)
        b.advance(t)
        assert_nets_bitwise(a, b, msg=f"t={t}:")


def test_selective_forward_prob_one_is_bitwise_honest():
    """p=1 selective forwarding suppresses nothing, and its Bernoulli
    draws live on the salted side stream — bitwise the honest run."""
    top = topo.ring(6, link_latency=1.0)
    sel = FaultConfig(roles=(0, 3, 0, 3, 0, 0), forward_prob=1.0)
    a = make_net(top, faults=honest(6))
    b = make_net(top, faults=sel)
    publish_on(a, 0, 1, 0.2)
    publish_on(b, 0, 1, 0.2)
    for t in (1.0, 3.0, 5.0):
        a.advance(t)
        b.advance(t)
        assert_nets_bitwise(a, b, msg=f"t={t}:")


# ---------------------------------------------------------------------------
# The SPOOF defense: corrupted chunks never reach a gated view
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    overlay=st.sampled_from(["ring", "star", "full"]),
    engine=st.sampled_from(["ticks", "events"]),
)
def test_property_spoofed_chunk_never_enters_gated_view(seed, overlay,
                                                        engine):
    """Property (acceptance): under an active spoofer with digest
    verification on, the attack-success numerator — corrupted chunks
    visible through any node's gated view — is ZERO, the spoofer accrues
    rejections, and its used out-links are quarantined."""
    n = 6
    builders = {
        "ring": lambda: topo.ring(n, link_latency=1.0),
        "star": lambda: topo.star(n, link_latency=1.0),
        "full": lambda: topo.full(n, link_latency=1.0),
    }
    spoofer = 0 if overlay == "star" else int(seed % n)   # star hub relays
    roles = tuple(
        faults_lib.ROLE_SPOOF if i == spoofer else faults_lib.ROLE_HONEST
        for i in range(n)
    )
    cfg = FaultConfig(roles=roles, spoof_rate=1.0, verify_digests=True,
                      quarantine_after=2)
    net = make_net(builders[overlay](), engine, faults=cfg, bank_cfg=BANK,
                   seed=seed % 1013)
    publish_on(net, spoofer, 1, 0.2)          # everyone must fetch from it
    publish_on(net, (spoofer + 1) % n, 2, 0.3)
    for t in np.arange(1.0, 11.0, 1.0):
        net.advance(float(t))
    rep = net.fault_report()
    np.testing.assert_array_equal(
        np.asarray(rep["tainted_in_views"]), 0,
        err_msg="corrupted chunk entered a gated view",
    )
    assert rep["rejected_total"] > 0
    # bounded-round quarantine: some receiver cut its link to the spoofer
    assert net.quarantined_links()[:, spoofer].any()
    credit = rep["rejection_credit"]
    assert credit[spoofer] < 1.0
    clean = [i for i in range(n) if i != spoofer]
    np.testing.assert_array_equal(credit[clean], 1.0)


def test_spoof_without_verification_lands():
    """Defense off -> the same attack demonstrably poisons views: the
    tainted payload spreads and becomes visible. Documents that digest
    verification, not luck, is what keeps the numerator at zero."""
    n = 5
    cfg = FaultConfig(roles=(faults_lib.ROLE_SPOOF,) + (0,) * (n - 1),
                      spoof_rate=1.0, verify_digests=False)
    net = make_net(topo.full(n, link_latency=1.0), faults=cfg, bank_cfg=BANK)
    publish_on(net, 0, 1, 0.2)
    for t in np.arange(1.0, 8.0, 1.0):
        net.advance(float(t))
    rep = net.fault_report()
    assert np.asarray(rep["tainted_in_views"]).sum() > 0
    assert rep["rejected_total"] == 0             # nothing was checked


def test_rejected_transfer_is_refetched_from_alternate_holder():
    """Bounded re-fetch: on a ring the spoofer's victim re-requests from
    its other neighbor once the spoofed link is quarantined — the row's
    payload still arrives everywhere (liveness under the defense)."""
    n = 6
    cfg = FaultConfig(roles=(0, 0, 0, faults_lib.ROLE_SPOOF, 0, 0),
                      spoof_rate=1.0, verify_digests=True, quarantine_after=2)
    net = make_net(topo.ring(n, link_latency=1.0), faults=cfg, bank_cfg=BANK)
    publish_on(net, 0, 1, 0.2)                    # honest publisher
    for t in np.arange(1.0, 16.0, 1.0):
        net.advance(float(t))
    # node 3 relays corrupted copies; 2 and 4 must pull around the ring
    rep = net.fault_report()
    np.testing.assert_array_equal(np.asarray(rep["tainted_in_views"]), 0)
    assert int(net.missing_chunks().max()) == 0   # payload fully delivered
    assert net.synced()


def test_rejection_credit_scores():
    rejects = jnp.zeros((4, 4), jnp.int32).at[1, 3].set(5).at[2, 3].set(2)
    credit = np.asarray(rejection_credit(rejects))
    np.testing.assert_array_equal(credit[:3], 1.0)   # clean senders exact 1
    assert credit[3] == pytest.approx(0.05)          # floored spoofer


# ---------------------------------------------------------------------------
# Role semantics on known schedules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["ticks", "events"])
def test_crash_window_silences_then_recovers(engine):
    cfg = FaultConfig(roles=(faults_lib.ROLE_CRASH, 0, 0, 0, 0, 0),
                      crash_start=0.0, crash_end=5.0)
    net = make_net(topo.ring(6, link_latency=1.0), engine, faults=cfg)
    publish_on(net, 0, 1, 0.2)
    net.advance(4.0)
    assert (np.asarray(net.missing_rows()) > 0).sum() == 5   # still silent
    net.advance(12.0)
    assert net.synced()                                       # churned back


def test_crash_during_partition_recovers_after_both_heal():
    """A node crashes across a partition window: neither the partition
    healing alone (node still crashed) nor the crash ending alone is
    enough until ticks flow again — then the overlay pulls every replica
    back to the union, including rows published by the crashed node
    while it was down."""
    n = 6
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(n), t_start=1.5, t_end=3.5,
    )
    cfg = FaultConfig(roles=(faults_lib.ROLE_CRASH,) + (0,) * (n - 1),
                      crash_start=0.0, crash_end=6.0)
    net = make_net(topo.full(n, link_latency=1.0), faults=cfg,
                   partition=part)
    publish_on(net, 0, 1, 0.2)        # on the node that is about to crash
    publish_on(net, 3, 2, 0.3)        # on the far side of the coming split
    net.advance(3.0)                  # inside crash AND partition
    assert (np.asarray(net.missing_rows()) > 0).any()
    net.advance(5.0)                  # partition healed, node 0 still down
    assert np.asarray(net.missing_rows())[0] > 0 or (
        np.asarray(net.missing_rows()) > 0
    ).any()
    net.advance(10.0)                 # crash window over too
    assert net.synced()


@pytest.mark.parametrize("engine", ["ticks", "events"])
def test_eclipse_on_star_hub_monopolizes_intake(engine):
    """Spoke 2 eclipses the hub of a star: the hub hears ONLY the
    attacker, so an honest spoke's row never reaches the hub — and,
    since every spoke depends on the hub, never reaches anyone else.
    The attacker's own rows still land (the monopoly, not an outage)."""
    n = 6
    cfg = FaultConfig(roles=(0, 0, faults_lib.ROLE_ECLIPSE, 0, 0, 0),
                      eclipse_target=0)
    net = make_net(topo.star(n, link_latency=1.0), engine, faults=cfg)
    publish_on(net, 1, 1, 0.2)        # honest spoke
    publish_on(net, 2, 2, 0.3)        # the attacker
    for t in np.arange(1.0, 8.0, 1.0):
        net.advance(float(t))
    assert np.asarray(net.missing_rows())[0] > 0   # hub lags the union
    # attacker's row still landed at the hub; the honest spoke's never did
    pubs = np.asarray(net.read(0).publisher)
    assert (pubs == 2).any()
    assert not (pubs == 1).any()


def test_selective_forward_prob_zero_blocks_sender():
    cfg = FaultConfig(roles=(faults_lib.ROLE_SELECTIVE, 0, 0, 0, 0, 0),
                      forward_prob=0.0)
    net = make_net(topo.ring(6, link_latency=1.0), faults=cfg)
    publish_on(net, 0, 1, 0.2)
    net.advance(10.0)
    assert (np.asarray(net.missing_rows()) > 0).sum() == 5


def test_sybil_inflates_approvals_on_own_rows_only():
    n = 6
    cfg = FaultConfig(roles=(0, 0, faults_lib.ROLE_SYBIL, 0, 0, 0))
    net = make_net(topo.full(n, link_latency=1.0), faults=cfg)
    publish_on(net, 2, 1, 0.2)        # the sybil's row
    publish_on(net, 1, 2, 0.3)        # an honest row
    net.advance(2.0)
    u = net.union()
    ac = np.asarray(u.approval_count)
    appr = np.asarray(u.approvers)
    assert ac[1] >= n                 # forged full approver set
    assert appr[1].sum() == ac[1]     # exact-union invariant still holds
    assert ac[2] == 0                 # honest row untouched


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------


def test_validate_rejects_bad_configs():
    top = topo.ring(4)
    with pytest.raises(ValueError, match="roles"):
        make_net(top, faults=FaultConfig(roles=(0, 0, 0)))
    with pytest.raises(ValueError, match="eclipse"):
        make_net(top, faults=FaultConfig(roles=(2, 0, 0, 0)))
    with pytest.raises(ValueError, match="bank"):
        make_net(top, faults=FaultConfig(roles=(4, 0, 0, 0)))
    with pytest.raises(ValueError, match="quarantine"):
        make_net(top, faults=FaultConfig(roles=(0,) * 4, quarantine_after=0),
                 bank_cfg=BANK)


# ---------------------------------------------------------------------------
# Telemetry coupling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["ticks", "events"])
def test_fault_telemetry_series_and_reject_trace(engine):
    n = 5
    cfg = FaultConfig(roles=(0, 0, 0, 0, faults_lib.ROLE_SPOOF),
                      spoof_rate=1.0, verify_digests=True, quarantine_after=3)
    a = make_net(topo.full(n, link_latency=1.0), engine, faults=cfg,
                 bank_cfg=BANK)
    b = make_net(topo.full(n, link_latency=1.0), engine, faults=cfg,
                 bank_cfg=BANK, obs=ObsConfig())
    publish_on(a, 4, 1, 0.2)
    publish_on(b, 4, 1, 0.2)
    for t in np.arange(1.0, 8.0, 1.0):
        a.advance(float(t))
        b.advance(float(t))
    # obs never perturbs the FAULTED trajectory either
    assert_nets_bitwise(a, b, msg="obs-on faulted:")
    np.testing.assert_array_equal(
        np.asarray(a._fstate.rejects), np.asarray(b._fstate.rejects)
    )
    rep = b.obs_report()
    assert rep.series["rejected"][-1] > 0
    assert rep.series["quarantined"][-1] > 0
    assert rep.series["staleness_node"].shape[1] == n
    assert (rep.trace["kind"] == KIND_REJECT).sum() > 0
    assert "rejected" in rep.final and rep.final["rejected"] > 0
