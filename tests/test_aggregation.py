"""Eq.-(1) aggregation: three implementations agree + algebraic properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import aggregation as agg
from repro.core import bank as bank_lib


def rand_tree(key, k=None):
    k1, k2, k3 = jax.random.split(key, 3)
    shape = lambda s: (k,) + s if k else s
    return {
        "a": jax.random.normal(k1, shape((17, 5))),
        "b": {"c": jax.random.normal(k2, shape((3, 4, 2))), "d": jax.random.normal(k3, shape((11,)))},
    }


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(2, 6))
def test_pytree_flat_bank_agree(seed, k):
    key = jax.random.PRNGKey(seed)
    stacked = rand_tree(key, k)
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (k,)))

    out_tree = agg.fedavg_pytree(stacked, w)
    out_flat = agg.fedavg_flat(stacked, w)
    bank = stacked
    out_bank = bank_lib.bank_average(bank, jnp.arange(k), w)

    for a, b in zip(jax.tree_util.tree_leaves(out_tree), jax.tree_util.tree_leaves(out_flat)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(out_tree), jax.tree_util.tree_leaves(out_bank)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_convex_combination_bounds(seed):
    """FedAvg output lies within [min, max] of the inputs, element-wise."""
    key = jax.random.PRNGKey(seed)
    stacked = jax.random.normal(key, (4, 50))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (4,)))
    out = agg.fedavg_pytree(stacked, w)
    assert bool(jnp.all(out <= jnp.max(stacked, 0) + 1e-6))
    assert bool(jnp.all(out >= jnp.min(stacked, 0) - 1e-6))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_permutation_invariance(seed):
    key = jax.random.PRNGKey(seed)
    stacked = jax.random.normal(key, (5, 31))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (5,)))
    perm = jax.random.permutation(jax.random.fold_in(key, 2), 5)
    out1 = agg.fedavg_pytree(stacked, w)
    out2 = agg.fedavg_pytree(stacked[perm], w[perm])
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-6)


def test_identity_when_single_model():
    x = jnp.arange(12.0).reshape(3, 4)
    stacked = x[None]
    out = agg.fedavg_pytree(stacked, jnp.ones((1,)))
    np.testing.assert_allclose(out, x)


def test_bank_average_skips_invalid_and_renormalizes():
    bank = jnp.stack([jnp.ones((6,)), 3 * jnp.ones((6,)), 100 * jnp.ones((6,))])
    out = bank_lib.bank_average(bank, jnp.asarray([0, 1, -1]), jnp.full((3,), 1 / 3))
    np.testing.assert_allclose(out, 2 * jnp.ones((6,)), rtol=1e-6)


def test_staleness_accuracy_weights():
    acc = jnp.asarray([0.9, 0.5, 0.9])
    stale = jnp.asarray([0.0, 0.0, 19.0])
    w = agg.staleness_accuracy_weights(acc, stale, tau_max=20.0)
    np.testing.assert_allclose(jnp.sum(w), 1.0, rtol=1e-6)
    assert w[0] > w[1]          # higher accuracy wins
    assert w[0] > w[2]          # fresher wins at equal accuracy


def test_auth_checksum_detects_change():
    key = jax.random.PRNGKey(0)
    tree = rand_tree(key)
    t1 = bank_lib.auth_checksum(tree)
    tree2 = jax.tree_util.tree_map(lambda x: x, tree)
    tree2["a"] = tree2["a"].at[0, 0].add(0.5)
    t2 = bank_lib.auth_checksum(tree2)
    assert abs(float(t1 - t2)) > 1e-6
