"""Checkpoint round trips for params, DAG state, and optimizer state."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_meta, load_pytree, save_pytree
from repro.configs import ARCHS, TrainConfig
from repro.core import dag as dag_lib
from repro.models import build_model
from repro.optim import init_optimizer


def test_params_roundtrip(tmp_path):
    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p = str(tmp_path / "ckpt")
    save_pytree(p, params, meta={"arch": cfg.name, "step": 7})
    restored = load_pytree(p, jax.tree_util.tree_map(jnp.zeros_like, params))
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert load_meta(p)["step"] == 7


def test_dag_roundtrip(tmp_path):
    dag = dag_lib.empty_dag(16, 2, 4)
    dag = dag_lib.publish(
        dag, jnp.asarray(1), jnp.asarray(2.0),
        jnp.asarray([-1, -1], jnp.int32), jnp.asarray(0.4),
        jnp.asarray(1.25), jnp.asarray(0),
    )
    p = str(tmp_path / "dag")
    save_pytree(p, dag)
    restored = load_pytree(p, dag_lib.empty_dag(16, 2, 4))
    assert int(restored.count) == 1
    assert float(restored.accuracy[0]) == float(dag.accuracy[0])


def test_structure_mismatch_raises(tmp_path):
    p = str(tmp_path / "x")
    save_pytree(p, {"a": jnp.zeros(3)})
    try:
        load_pytree(p, {"b": jnp.zeros(3)})
        assert False, "should have raised"
    except ValueError:
        pass


def test_opt_state_roundtrip(tmp_path):
    cfg = ARCHS["olmo-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_optimizer(TrainConfig(optimizer="adam"), params)
    p = str(tmp_path / "opt")
    save_pytree(p, opt)
    restored = load_pytree(p, jax.tree_util.tree_map(jnp.zeros_like, opt))
    assert int(restored.step) == int(opt.step)
