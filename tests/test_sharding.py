"""Distribution-layer tests: run in a SUBPROCESS with 8 forced host devices
(so the main pytest process keeps its single-device view)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=1200,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_fl_train_step_compiles_and_runs_on_small_mesh():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch
        from repro.configs.base import DagFLConfig, TrainConfig
        from repro.models import build_model
        from repro.sharding import fl_step as fl
        from repro.launch.mesh import make_test_mesh

        cfg = get_arch("qwen3-0.6b").reduced()
        model = build_model(cfg)
        mesh = make_test_mesh(data=4, model=2)
        N = 4
        step = jax.jit(fl.make_dagfl_train_step(
            model, cfg, TrainConfig(optimizer="sgd", learning_rate=1e-2),
            DagFLConfig(num_nodes=N, alpha=3, k=2, tau_max=1e9), N))
        keys = jax.random.split(jax.random.PRNGKey(0), N)
        stacked = jax.vmap(model.init)(keys)
        frontier = fl.init_frontier(N)
        toks = jax.random.randint(jax.random.PRNGKey(1), (N, 2, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        val = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (N, 1, 32), 0, cfg.vocab_size)}
        with mesh:
            p2, f2, m = step(stacked, frontier, batch, val, jax.random.PRNGKey(3))
        assert np.isfinite(float(m["mean_val_acc"]))
        assert float(f2.now) == 1.0
        # a second round uses the scores of the first
        with mesh:
            p3, f3, m2 = step(p2, f2, batch, val, jax.random.PRNGKey(4))
        assert np.isfinite(float(m2["mean_val_acc"]))
        print("OK")
    """)
    assert "OK" in run_sub(code)


def test_dryrun_single_pair_on_8_devices():
    """plan_for + lower + compile on a tiny mesh (mechanism test of dryrun)."""
    code = textwrap.dedent("""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.configs import SHAPES, get_arch
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import plan_for
        import dataclasses

        cfg = dataclasses.replace(
            get_arch("olmo-1b").reduced(), name="olmo-1b")
        shape = dataclasses.replace(SHAPES["decode_32k"], seq_len=128, global_batch=8)
        mesh = make_test_mesh(data=2, model=4)
        plan = plan_for(cfg, shape, mesh)
        with mesh:
            jitted = jax.jit(plan.fn,
                in_shardings=jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), plan.in_specs,
                    is_leaf=lambda x: isinstance(x, PartitionSpec)),
                out_shardings=jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), plan.out_specs,
                    is_leaf=lambda x: isinstance(x, PartitionSpec)))
            compiled = jitted.lower(*plan.args).compile()
        print("OK", compiled.cost_analysis() is not None)
    """)
    assert "OK" in run_sub(code)


def test_aggregate_matches_local_math():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding.fl_step import aggregate
        C = jnp.asarray([[0.5, 0.5, 0.0], [0.0, 1.0, 0.0], [1/3, 1/3, 1/3]])
        stacked = {"w": jnp.arange(12.0).reshape(3, 4)}
        out = aggregate(C, stacked)
        np.testing.assert_allclose(out["w"], C @ stacked["w"], rtol=1e-6)
        print("OK")
    """)
    assert "OK" in run_sub(code)


def test_select_peers_respects_staleness_and_self_exclusion():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding.fl_step import Frontier, select_peers
        N = 6
        f = Frontier(
            scores=jnp.ones((N, N)) * 0.5,
            publish_time=jnp.asarray([0., 0., 5., 5., 5., 5.]),
            approval_count=jnp.zeros((N,), jnp.int32),
            total_published=jnp.ones((N,), jnp.int32),
            total_contributing=jnp.zeros((N,), jnp.int32),
            now=jnp.asarray(10.0))
        C = select_peers(f, jax.random.PRNGKey(0), alpha=3, k=2, tau_max=6.0)
        C = np.asarray(C)
        np.testing.assert_allclose(C.sum(1), 1.0, rtol=1e-5)
        # nodes 0,1 are stale: nobody may select them
        assert C[:, 0].sum() == 0 and C[:, 1].sum() == 0
        # no self-selection (all rows had eligible peers)
        assert np.all(np.diag(C) == 0)
        print("OK")
    """)
    assert "OK" in run_sub(code)
