"""DAG ledger invariants: unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import dag as dag_lib

CAP, K, N = 64, 2, 8


def fresh_dag():
    return dag_lib.empty_dag(CAP, K, N)


def publish_n(dag, n, approvals=None, t0=0.0, dt=1.0):
    for i in range(n):
        ap = approvals(i, dag) if approvals else jnp.full((K,), dag_lib.NO_TX, jnp.int32)
        dag = dag_lib.publish(
            dag,
            jnp.asarray(i % N, jnp.int32),
            jnp.asarray(t0 + i * dt, jnp.float32),
            ap,
            jnp.asarray(0.5, jnp.float32),
            jnp.asarray(0.0, jnp.float32),
            jnp.asarray(i % CAP, jnp.int32),
        )
    return dag


def test_publish_appends():
    dag = publish_n(fresh_dag(), 5)
    assert int(dag.count) == 5
    assert int(jnp.sum(dag.publisher >= 0)) == 5


def test_tips_are_unapproved_and_fresh():
    dag = publish_n(fresh_dag(), 5)
    tips = dag_lib.tip_mask(dag, jnp.float32(4.0), tau_max=10.0)
    assert int(jnp.sum(tips)) == 5
    # approve rows 0,1 via a publish
    dag = dag_lib.publish(
        dag, jnp.asarray(0), jnp.asarray(5.0), jnp.asarray([0, 1], jnp.int32),
        jnp.asarray(0.5), jnp.asarray(0.0), jnp.asarray(5),
    )
    tips = dag_lib.tip_mask(dag, jnp.float32(5.0), tau_max=10.0)
    assert not bool(tips[0]) and not bool(tips[1])
    assert bool(tips[5])          # the new transaction is a tip


def test_staleness_threshold_excludes_old():
    dag = publish_n(fresh_dag(), 5, dt=10.0)   # publish times 0..40
    tips = dag_lib.tip_mask(dag, jnp.float32(45.0), tau_max=20.0)
    # only rows with publish_time >= 25 qualify: rows 3 (30) and 4 (40)
    assert int(jnp.sum(tips)) == 2


def test_acyclicity_approvals_point_backwards():
    def approve_prev(i, dag):
        if i == 0:
            return jnp.full((K,), dag_lib.NO_TX, jnp.int32)
        prev = int(jnp.mod(dag.count - 1, CAP))
        return jnp.asarray([prev, dag_lib.NO_TX], jnp.int32)

    dag = publish_n(fresh_dag(), 10, approvals=approve_prev)
    rows = np.arange(10)
    for r in rows:
        for a in np.asarray(dag.approvals[r]):
            if a >= 0:
                assert a < r      # edges always to older rows


def test_contribution_counters():
    def approve_prev(i, dag):
        if i == 0:
            return jnp.full((K,), dag_lib.NO_TX, jnp.int32)
        prev = int(jnp.mod(dag.count - 1, CAP))
        return jnp.asarray([prev, dag_lib.NO_TX], jnp.int32)

    dag = publish_n(fresh_dag(), 9, approvals=approve_prev)
    # every node published; all but the newest got >= 1 approval
    assert int(jnp.sum(dag.published_per_node)) == 9
    assert int(jnp.sum(dag.contributing_m0)) == 8


@settings(max_examples=30, deadline=None)
@given(
    n_pub=st.integers(1, 40),
    alpha=st.integers(1, 8),
    tau=st.floats(1.0, 50.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_select_tips_valid_and_unique(n_pub, alpha, tau, seed):
    dag = publish_n(fresh_dag(), n_pub)
    now = jnp.float32(n_pub)
    idx, nvalid = dag_lib.select_tips(dag, jax.random.PRNGKey(seed), alpha, now, tau)
    idx = np.asarray(idx)
    valid = idx[idx >= 0]
    # unique, actually tips, count consistent
    assert len(set(valid.tolist())) == len(valid)
    assert int(nvalid) == len(valid)
    mask = np.asarray(dag_lib.tip_mask(dag, now, tau))
    for v in valid:
        assert mask[v]
    assert len(valid) == min(alpha, mask.sum())


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bonus=st.floats(2.0, 8.0))
def test_select_tips_bias_prefers_biased_nodes(seed, bonus):
    dag = publish_n(fresh_dag(), 32)
    now = jnp.float32(40.0)
    node_bias = jnp.zeros((N + 1,)).at[0].set(bonus)   # favor node 0
    counts = 0
    trials = 20
    for t in range(trials):
        idx, _ = dag_lib.select_tips(
            dag, jax.random.PRNGKey(seed + t), 4, now, 100.0, node_bias=node_bias
        )
        pubs = np.asarray(dag.publisher)[np.asarray(idx)[np.asarray(idx) >= 0]]
        counts += (pubs == 0).sum()
    # node 0 published 4/32 rows; with bias it should be picked far above 4/32
    assert counts / (trials * 4) > 4 / 32


def test_merge_prefers_longer_history():
    a = publish_n(fresh_dag(), 3)
    b = publish_n(fresh_dag(), 6)
    m = dag_lib.merge(a, b)
    assert int(m.count) == 6


# --- merge divergence (gossip replicas, repro.net) --------------------------


def leaves_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def publish_row(dag, row, publisher, t, approvals=None, seq=None):
    ap = approvals if approvals is not None else jnp.full((K,), dag_lib.NO_TX, jnp.int32)
    new_count = jnp.maximum(dag.count, (seq if seq is not None else row) + 1)
    return dag_lib.publish_at(
        dag, jnp.asarray(row, jnp.int32), new_count,
        jnp.asarray(publisher, jnp.int32), jnp.asarray(t, jnp.float32), ap,
        jnp.asarray(0.5, jnp.float32), jnp.asarray(0.0, jnp.float32),
        jnp.asarray(row, jnp.int32),
    )


def test_merge_keeps_divergent_rows_from_both_sides():
    """Replicas that published DIFFERENT rows at the same count must not lose
    either row (the old 'longer history wins' merge dropped the shorter
    replica's rows wholesale)."""
    base = publish_n(fresh_dag(), 2)
    a = publish_row(base, 2, publisher=1, t=5.0)      # A's row 2
    b = publish_row(base, 3, publisher=2, t=5.5)      # B's row 3 (global rows)
    m = dag_lib.merge(a, b)
    assert int(m.count) == 4
    assert int(m.publisher[2]) == 1 and int(m.publisher[3]) == 2
    assert int(jnp.sum(m.publisher >= 0)) == 4


def test_merge_is_commutative_and_deterministic():
    base = publish_n(fresh_dag(), 2)
    a = publish_row(base, 2, publisher=1, t=5.0)
    b = publish_row(base, 2, publisher=2, t=6.0)      # same SLOT, different tx
    ab, ba = dag_lib.merge(a, b), dag_lib.merge(b, a)
    assert leaves_equal(ab, ba)
    # later (publish_time, publisher) identity wins the slot
    assert int(ab.publisher[2]) == 2
    assert float(ab.publish_time[2]) == 6.0


def test_merge_tie_breaks_on_publisher():
    base = publish_n(fresh_dag(), 2)
    a = publish_row(base, 2, publisher=1, t=5.0)
    b = publish_row(base, 2, publisher=4, t=5.0)      # exact same time
    ab, ba = dag_lib.merge(a, b), dag_lib.merge(b, a)
    assert leaves_equal(ab, ba)
    assert int(ab.publisher[2]) == 4


def test_merge_is_associative():
    base = publish_n(fresh_dag(), 1)
    a = publish_row(base, 1, publisher=1, t=2.0)
    b = publish_row(base, 2, publisher=2, t=3.0)
    c = publish_row(base, 1, publisher=3, t=4.0)      # conflicts with a's slot
    left = dag_lib.merge(dag_lib.merge(a, b), c)
    right = dag_lib.merge(a, dag_lib.merge(b, c))
    assert leaves_equal(left, right)
    assert int(left.publisher[1]) == 3                # later identity won


def test_merge_counters_never_decrease():
    """Approver sets for a shared row union exactly (distinct approvers from
    each side all count once) and the per-node contribution counters merge by
    max — merging can only add knowledge."""
    base = publish_n(fresh_dag(), 3)
    approve0 = jnp.asarray([0, dag_lib.NO_TX], jnp.int32)
    approve01 = jnp.asarray([0, 1], jnp.int32)
    a = publish_row(base, 3, publisher=1, t=5.0, approvals=approve0)
    b = publish_row(base, 4, publisher=2, t=5.5, approvals=approve01)
    assert int(a.approval_count[0]) == 1 and int(b.approval_count[0]) == 1
    for m in (dag_lib.merge(a, b), dag_lib.merge(b, a)):
        # row 0 was approved by node 1 on replica a and node 2 on replica b:
        # the exact union counts both (union-by-max would collapse to 1)
        assert int(m.approval_count[0]) == 2
        assert bool(m.approvers[0, 1]) and bool(m.approvers[0, 2])
        assert int(m.approval_count[1]) == int(b.approval_count[1])
        assert np.all(
            np.asarray(m.contributing_m0)
            >= np.maximum(np.asarray(a.contributing_m0), np.asarray(b.contributing_m0))
        )
        assert np.all(
            np.asarray(m.published_per_node)
            >= np.maximum(np.asarray(a.published_per_node), np.asarray(b.published_per_node))
        )


def test_merge_empty_adopts_other_side():
    a = fresh_dag()
    b = publish_n(fresh_dag(), 4)
    m = dag_lib.merge(a, b)
    assert leaves_equal(m, dag_lib.merge(b, a))
    assert int(m.count) == 4 and int(jnp.sum(m.publisher >= 0)) == 4
    # self-merge is the identity (idempotence)
    assert leaves_equal(dag_lib.merge(b, b), b)
