"""Pallas WKV kernel vs the sequential scan oracle (shape/decay sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv import wkv_pallas
from repro.models.rwkv import wkv_chunked, wkv_scan


@pytest.mark.parametrize("T,hd,chunk", [(64, 64, 32), (96, 128, 32), (32, 64, 16)])
@pytest.mark.parametrize("decay_scale", [0.5, 1.5])
def test_wkv_pallas_matches_scan(T, hd, chunk, decay_scale):
    key = jax.random.PRNGKey(T + hd)
    B, H = 2, 2
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd)) * decay_scale)
    u = jax.random.uniform(ks[4], (H, hd))
    y_ref, _ = wkv_scan(r, k, v, logw, u, jnp.zeros((B, H, hd, hd)))
    y = wkv_pallas(r, k, v, logw, u, chunk=chunk)
    np.testing.assert_allclose(y, y_ref, rtol=2e-3, atol=2e-3)


def test_wkv_pallas_matches_chunked_jnp():
    key = jax.random.PRNGKey(9)
    B, T, H, hd = 1, 64, 4, 64
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd)))
    u = jax.random.uniform(ks[4], (H, hd))
    y_jnp, _ = wkv_chunked(r, k, v, logw, u, jnp.zeros((B, H, hd, hd)), chunk=32)
    y_pal = wkv_pallas(r, k, v, logw, u, chunk=32)
    np.testing.assert_allclose(y_pal, y_jnp, rtol=1e-3, atol=1e-3)
