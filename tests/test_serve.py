"""Inference serving on the event engine (`repro.net.serve`).

Pins the acceptance invariants of the serving layer:

* ZERO-RATE DEGENERATE LIMIT: ``serve=None`` and ``ServeConfig(rate=0.0)``
  are BITWISE the PR-8 run — replicas, bank state, and PRNG key alike —
  across round impls, overlays, the bank, faulted arms, and partitions
  (the obs=None / faults=None / codec=None pattern: off is not a branch,
  off is the literal pre-serve program);
* Poisson arrivals are reproducible pure functions of (seed, node, count)
  with no host RNG — the engine's counters match an independent host
  replay exactly, and the long-horizon arrival counts match the
  configured rate (property-tested);
* service conserves requests (arrived = served + queued + inflight +
  dropped), batches respect the slot cap, and the staleness-at-admit
  samples are measured against the availability-GATED view — a
  constrained wire shows up as positive staleness, an idle ledger as 0;
* the counters export through ``repro.obs`` (requests_served /
  serve_staleness series, KIND_INFER trace records, "infer" Chrome-trace
  slices) and through ``run_dagfl_gossip(serve=...)`` ->
  ``extras["serve_report"]``;
* every node id in tests/known_failures.txt still collects (a renamed
  test would silently disable its strict xfail).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dag as dag_lib
from repro.net import events as events_lib
from repro.net import faults as faults_lib
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import serve as serve_lib
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig
from repro.net.faults import FaultConfig
from repro.net.serve import ServeConfig

CAP, K = 32, 2
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
IMPLS = ["fused", "scan"]


def genesis(num_nodes):
    d = dag_lib.empty_dag(CAP, K, num_nodes + 1)
    return dag_lib.publish(
        d, jnp.asarray(num_nodes, jnp.int32), jnp.float32(0.0),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )


def make_net(top, serve=None, sync_period=1.0, partition=None, seed=0,
             impl="fused", bank_cfg=None, obs_cfg=None, faults_cfg=None):
    return gossip_lib.GossipNetwork(
        genesis(top.num_nodes), bank=jnp.zeros((CAP, 8)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=sync_period, seed=seed,
                                    impl=impl, engine="events"),
        partition=partition, bank_cfg=bank_cfg, obs_cfg=obs_cfg,
        faults_cfg=faults_cfg, serve_cfg=serve,
    )


def publish_on(net, node, seq, t, params=None):
    d = replica_lib.publish_local(
        net.read(node), seq, jnp.asarray(node, jnp.int32), jnp.float32(t),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(seq % CAP, jnp.int32),
    )
    net.write(node, d)
    if net.bank_cfg is not None:
        if params is None:
            params = jnp.full((8,), float(seq))
        net.bank_commit(node, seq % CAP, params)


def assert_dags_equal(a, b, msg=""):
    for name in dag_lib.DagState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{msg}: dag field {name}",
        )


def assert_nets_bitwise(a, b, msg=""):
    assert_dags_equal(a.replicas.dags, b.replicas.dags, msg)
    np.testing.assert_array_equal(
        np.asarray(a._key), np.asarray(b._key), err_msg=f"{msg}: PRNG key"
    )
    if a.bank_cfg is not None:
        for name in a.replicas.bank_state._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a.replicas.bank_state, name)),
                np.asarray(getattr(b.replicas.bank_state, name)),
                err_msg=f"{msg}: bank field {name}",
            )


# ---------------------------------------------------------------------------
# Satellite: zero-rate degenerate limit — bitwise the PR-8 program
# ---------------------------------------------------------------------------


def _run_arm(serve, impl, arm, seed=0):
    n = 6
    bank_cfg = (BankGossipConfig(chunks_per_slot=2)
                if arm in ("bank", "bank_faults", "bank_partition") else None)
    faults_cfg = None
    if arm in ("faults", "bank_faults"):
        roles = (faults_lib.ROLE_SPOOF if bank_cfg is not None
                 else faults_lib.ROLE_SELECTIVE,) + (0,) * (n - 1)
        faults_cfg = FaultConfig(roles=roles)
    partition = None
    if arm == "bank_partition":
        partition = gossip_lib.PartitionSchedule(
            assignment=topo.split_halves(n), t_start=3.0, t_end=9.0
        )
    top = (topo.ring(n, link_latency=0.7) if arm == "faults"
           else topo.full(n, link_latency=1.0))
    net = make_net(top, serve=serve, impl=impl, seed=seed,
                   bank_cfg=bank_cfg, faults_cfg=faults_cfg,
                   partition=partition)
    for i in range(n):
        publish_on(net, i, 1 + i, 0.25 + 0.5 * i)
    net.advance(7.5)
    for i in range(n):
        publish_on(net, i, 1 + n + i, 8.0 + 0.25 * i)
    net.advance(15.0)
    return net


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize(
    "arm", ["plain", "bank", "faults", "bank_faults", "bank_partition"]
)
def test_zero_rate_bitwise_degenerate_limit(impl, arm):
    """serve=None and rate=0.0 both run the literal PR-8 program: same
    dags, same bank state, same PRNG key, for every engine arm."""
    base = _run_arm(None, impl, arm)
    zero = _run_arm(ServeConfig(rate=0.0), impl, arm)
    assert_nets_bitwise(base, zero, f"{impl}/{arm}")
    assert base.serve_report() is None and zero.serve_report() is None


def test_zero_rate_compiles_the_identical_program():
    """The static key maps rate<=0 to None, so a rate-0 net doesn't just
    agree numerically — it reuses the SAME cached jitted program object."""
    assert serve_lib.serve_key(None) is None
    assert serve_lib.serve_key(ServeConfig(rate=0.0)) is None
    assert serve_lib.serve_key(ServeConfig(rate=-1.0)) is None
    cfg = ServeConfig(rate=2.0)
    assert serve_lib.serve_key(cfg) is cfg
    # a rate-0 net takes the serve-free dispatch branch entirely: no
    # effective config, no INFER slots appended to the event queue
    top = topo.full(3, link_latency=1.0)
    zero = make_net(top, serve=ServeConfig(rate=0.0))
    none = make_net(top, serve=None)
    live = make_net(top, serve=cfg)
    assert zero._serve is None and none._serve is None
    assert zero._equeue.time.shape == none._equeue.time.shape
    assert live._serve is cfg
    assert (live._equeue.time.shape[0]
            == none._equeue.time.shape[0] + 2 * 3)
    assert int(jnp.sum(live._equeue.kind == events_lib.KIND_INFER)) == 6


def test_validate_serve_rejects_bad_configs():
    top = topo.full(4)
    with pytest.raises(ValueError, match="events"):
        gossip_lib.GossipNetwork(
            genesis(4), bank=jnp.zeros((CAP, 8)), top=top,
            cfg=gossip_lib.GossipConfig(sync_period=1.0, engine="ticks"),
            serve_cfg=ServeConfig(rate=1.0),
        )
    # rate-0 on the tick engine is fine: it degenerates to no serving
    gossip_lib.GossipNetwork(
        genesis(4), bank=jnp.zeros((CAP, 8)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=1.0, engine="ticks"),
        serve_cfg=ServeConfig(rate=0.0),
    )
    for bad in (ServeConfig(rate=1.0, slots=0),
                ServeConfig(rate=1.0, queue_cap=0),
                ServeConfig(rate=1.0, service_time=0.0)):
        with pytest.raises(ValueError):
            make_net(top, serve=bad)


# ---------------------------------------------------------------------------
# Arrival process: deterministic fold_in branch, no host RNG
# ---------------------------------------------------------------------------


def test_engine_arrivals_match_host_replay():
    """The engine's per-node arrival counters equal an independent host
    replay of the same (seed, node, count) fold_in chain — arrivals are a
    pure function of the config, not of engine scheduling."""
    cfg = ServeConfig(rate=2.0, service_time=0.05)
    seed, horizon, n = 3, 25.0, 4
    net = make_net(topo.full(n, link_latency=1.0), serve=cfg, seed=seed)
    net.advance(horizon)
    rep = net.serve_report()
    for node in range(n):
        expect = len(serve_lib.arrival_times(seed, cfg, node, horizon))
        assert int(rep["arrivals"][node]) == expect, f"node {node}"
    # and the whole report replays bitwise on a fresh identical net
    net2 = make_net(topo.full(n, link_latency=1.0), serve=cfg, seed=seed)
    net2.advance(horizon)
    rep2 = net2.serve_report()
    np.testing.assert_array_equal(rep["arrivals"], rep2["arrivals"])
    np.testing.assert_array_equal(rep["requests_served"],
                                  rep2["requests_served"])
    np.testing.assert_array_equal(rep["staleness_samples"],
                                  rep2["staleness_samples"])


def test_priced_drain_rearm_makes_strict_progress():
    """Regression: a priced drain's re-arm instant is computed so accrued
    credit EXACTLY completes a chunk, so every completion sits within f32
    rounding of the chunk boundary. When the rounding left ``credit`` just
    under ``chunk_bytes``, the re-arm collapsed onto its own instant and
    the advance livelocked against ``max_events_per_advance``, silently
    starving every event queued behind the spinning drain (arrivals
    included). The strict-progress clamp (``events.py``) pins: no advance
    leaves a valid past-due event behind, and the arrival counters still
    equal the host Poisson replay under heavy drain churn."""
    n, seed = 6, 0
    cfg = ServeConfig(rate=2.0, service_time=0.05)
    net = make_net(
        topo.ring(n, bandwidth=1e7), serve=cfg, seed=seed,
        bank_cfg=BankGossipConfig(chunks_per_slot=4, slot_bytes=7e6),
    )
    t = 0.0
    for k in range(12):
        publish_on(net, k % n, 1 + k, t,
                   params=jnp.full((8,), 1.0 + 0.37 * k))
        t += 0.937                     # irregular accrual windows
        net.advance(t)
        qt = np.asarray(net._equeue.time)
        qv = np.asarray(net._equeue.valid)
        stranded = qv & (qt <= t)
        assert not stranded.any(), (
            f"advance({t:.3f}) left due events at {qt[stranded]}"
        )
    rep = net.serve_report()
    for node in range(n):
        expect = len(serve_lib.arrival_times(seed, cfg, node, t))
        assert int(rep["arrivals"][node]) == expect, f"node {node}"


def test_serve_randomness_leaves_main_key_untouched():
    """INFER batches never split the main PRNG key: the key trajectory of
    a serving run equals the serve-free run over the same net events."""
    n = 4
    a = make_net(topo.full(n, link_latency=1.0), serve=None)
    b = make_net(topo.full(n, link_latency=1.0),
                 serve=ServeConfig(rate=3.0))
    for net in (a, b):
        for i in range(n):
            publish_on(net, i, 1 + i, 0.5 * i)
        net.advance(12.0)
    np.testing.assert_array_equal(np.asarray(a._key), np.asarray(b._key))
    assert_dags_equal(a.replicas.dags, b.replicas.dags, "serve-on vs off")


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), node=st.integers(0, 7),
       rate=st.sampled_from([0.5, 1.0, 2.0, 5.0]))
def test_property_poisson_rate_and_reproducibility(seed, node, rate):
    """Property: long-horizon arrival counts match the configured rate
    within Poisson bounds, and the sequence replays exactly per
    (seed, node)."""
    cfg = ServeConfig(rate=rate)
    horizon = 200.0 / rate                   # ~200 expected arrivals
    times = serve_lib.arrival_times(seed, cfg, node, horizon)
    mean = rate * horizon
    assert abs(len(times) - mean) <= 6.0 * np.sqrt(mean) + 3.0
    assert np.all(np.diff(times) > 0)        # strictly increasing
    again = serve_lib.arrival_times(seed, cfg, node, horizon)
    np.testing.assert_array_equal(times, again)
    # a different node draws a different stream (same seed)
    other = serve_lib.arrival_times(seed, cfg, (node + 1) % 8, horizon)
    assert len(other) != len(times) or not np.array_equal(times, other)


# ---------------------------------------------------------------------------
# Service semantics: conservation, batching, gated staleness
# ---------------------------------------------------------------------------


def _conserve(rep):
    lhs = rep["arrivals"]
    rhs = rep["requests_served"] + rep["queued"] + rep["inflight"] + \
        rep["dropped"]
    np.testing.assert_array_equal(lhs, rhs)


@pytest.mark.parametrize("bank", [False, True])
def test_serve_counters_conserve_and_batch_cap(bank):
    n = 4
    cfg = ServeConfig(rate=4.0, slots=3, service_time=0.2, queue_cap=8)
    bank_cfg = BankGossipConfig(chunks_per_slot=2) if bank else None
    net = make_net(topo.full(n, link_latency=1.0), serve=cfg,
                   bank_cfg=bank_cfg)
    for i in range(n):
        publish_on(net, i, 1 + i, 0.5 * i)
    net.advance(30.0)
    rep = net.serve_report()
    assert rep["served_total"] > 0
    _conserve(rep)
    # no batch exceeds the slot cap: served + inflight per admitted batch
    assert np.all(rep["batches"] > 0)
    assert np.all(rep["requests_served"] + rep["inflight"]
                  <= rep["batches"] * cfg.slots)
    # staleness samples were taken at admit instants, one per batch
    assert rep["samples"] + rep["samples_dropped"] == int(
        rep["batches"].sum()
    )
    assert np.all(rep["staleness_samples"] >= 0)
    assert np.isfinite(rep["staleness_p50"])


def test_queue_cap_drops_under_overload():
    """A service time far above the inter-arrival gap overloads the node:
    the queue saturates and the overflow is counted dropped, not lost."""
    n = 2
    cfg = ServeConfig(rate=10.0, slots=1, service_time=5.0, queue_cap=4)
    net = make_net(topo.full(n, link_latency=1.0), serve=cfg)
    net.advance(40.0)
    rep = net.serve_report()
    _conserve(rep)
    assert rep["dropped_total"] > 0
    assert np.all(rep["queued"] <= cfg.queue_cap)


def test_staleness_is_gated_by_chunk_availability():
    """With a constrained wire the serve-time staleness sees rows whose
    METADATA arrived but whose chunks did not — the gated view lags until
    payload lands, so positive staleness samples must appear even though
    row gossip alone would have converged."""
    n = 4
    cfg = ServeConfig(rate=3.0, service_time=0.05)
    slow = topo.full(n, link_latency=1.0, bandwidth=64.0)   # bits/s: ~slow
    net = make_net(slow, serve=cfg,
                   bank_cfg=BankGossipConfig(chunks_per_slot=2))
    for i in range(n):
        publish_on(net, i, 1 + i, 0.25)
    net.advance(20.0)
    rep = net.serve_report()
    assert rep["served_total"] > 0
    assert rep["staleness_max"] > 0
    # the same run over an unconstrained wire serves fresh views at the
    # tail (payload keeps up with metadata)
    fast = topo.full(n, link_latency=1.0)
    net2 = make_net(fast, serve=cfg,
                    bank_cfg=BankGossipConfig(chunks_per_slot=2))
    for i in range(n):
        publish_on(net2, i, 1 + i, 0.25)
    net2.advance(20.0)
    rep2 = net2.serve_report()
    tail = rep2["staleness_samples"][-max(1, rep2["samples"] // 4):]
    assert tail.max() <= rep["staleness_max"]
    assert tail.max() == 0


# ---------------------------------------------------------------------------
# Export: obs series, trace records, systems plumbing
# ---------------------------------------------------------------------------


def test_obs_series_and_infer_trace():
    from repro import obs as obs_lib

    n = 4
    net = make_net(topo.full(n, link_latency=1.0),
                   serve=ServeConfig(rate=3.0),
                   bank_cfg=BankGossipConfig(chunks_per_slot=2),
                   obs_cfg=obs_lib.ObsConfig())
    for i in range(n):
        publish_on(net, i, 1 + i, 0.5 * i)
    net.advance(15.0)
    rep = net.obs_report()
    served = rep.series["requests_served"]
    assert served.shape[1] == n
    assert np.all(np.diff(served, axis=0) >= 0)       # cumulative counters
    assert served[-1].sum() > 0
    stale = rep.series["serve_staleness"]
    assert np.all(stale >= -1)
    assert np.any(stale >= 0)                          # admits were sampled
    kinds = set(np.unique(rep.trace["kind"]).tolist())
    assert obs_lib.KIND_INFER in kinds
    # the infer records are node-diagonal with the batch size as arg
    m = rep.trace["kind"] == obs_lib.KIND_INFER
    np.testing.assert_array_equal(rep.trace["src"][m], rep.trace["dst"][m])
    assert np.all(rep.trace["arg"][m] >= 1)
    tr = obs_lib.chrome_trace(rep)
    names = {e["name"] for e in tr["traceEvents"]}
    assert "infer" in names
    # obs collection never perturbs the serve counters
    net2 = make_net(topo.full(n, link_latency=1.0),
                    serve=ServeConfig(rate=3.0),
                    bank_cfg=BankGossipConfig(chunks_per_slot=2))
    for i in range(n):
        publish_on(net2, i, 1 + i, 0.5 * i)
    net2.advance(15.0)
    np.testing.assert_array_equal(net.serve_report()["requests_served"],
                                  net2.serve_report()["requests_served"])
    assert_nets_bitwise(net, net2, "obs-on vs obs-off serving run")


def test_run_dagfl_gossip_serve_report_and_zero_rate():
    """End to end: serve=... surfaces extras["serve_report"]; rate 0 is
    the literal no-serve run (same accuracy curve, no report)."""
    from repro.fl.experiments import default_dagfl_config, make_cnn_setup
    from repro.fl.systems import SimConfig, run_dagfl_gossip

    n = 6
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=16, eval_every=8, seed=0)

    def run(serve):
        task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)
        return run_dagfl_gossip(
            task, nodes, dcfg, sim, gval,
            topology=topo.full(n, link_latency=0.5),
            gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=0),
            engine="events", serve=serve,
        )

    base = run(None)
    zero = run(ServeConfig(rate=0.0))
    served = run(ServeConfig(rate=2.0, service_time=0.05))
    assert "serve_report" not in base.extras
    assert "serve_report" not in zero.extras
    np.testing.assert_array_equal(base.accs, zero.accs)
    np.testing.assert_array_equal(base.times, zero.times)
    sr = served.extras["serve_report"]
    assert sr["served_total"] > 0
    assert np.isfinite(sr["staleness_p50"])
    # serving is a pure reader of the ledger: training is unperturbed
    np.testing.assert_array_equal(base.accs, served.accs)


# ---------------------------------------------------------------------------
# Satellite: known_failures.txt hygiene
# ---------------------------------------------------------------------------


def test_known_failures_ids_still_collect():
    """Every node id in tests/known_failures.txt must still exist — a
    renamed or deleted test would silently disable its strict xfail."""
    path = os.path.join(REPO, "tests", "known_failures.txt")
    with open(path) as f:
        ids = [ln.split("#", 1)[0].strip() for ln in f]
    ids = [i for i in ids if i]
    assert ids, "known_failures.txt unexpectedly empty"
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q",
         "-p", "no:cacheprovider", *ids],
        cwd=REPO, capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, (
        "stale node id(s) in tests/known_failures.txt — update the list "
        "alongside the rename/delete:\n" + proc.stdout + proc.stderr
    )
