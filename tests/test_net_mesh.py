"""Mesh-sharded gossip == single-device gossip, bitwise.

The sharded round (``repro.net.mesh`` + ``gossip._shard_round``) partitions
the ReplicaSet's leading receiver axis over the mesh's "nodes" axis: each
shard all-gathers the sender rows once, winner-reduces its own receiver
block, and writes back only that block. Everything here asserts BITWISE
equality with the single-device paths — the one-shot round (all impls), the
tick-batched ``advance`` scan, and the while-loop ``converge``, including a
partition/heal schedule — on ring / Erdős–Rényi / star overlays.

Multi-device tests need ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the CI 8-device lane) and skip on single-device runners; one subprocess
test pins those flags itself so every lane exercises the mesh path.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dag as dag_lib
from repro.net import gossip as gossip_lib
from repro.net import mesh as mesh_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo

CAP, K = 16, 2
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
    "(the CI 8-device lane)",
)


def random_stacked(rng, r, cap=CAP, num_nodes=8, k=K) -> dag_lib.DagState:
    """Adversarial random replicas (same generator as test_gossip_merge):
    duplicate keys with divergent payloads pin the tie-break order, not just
    the CRDT happy path."""
    pub = rng.integers(-1, num_nodes, (r, cap)).astype(np.int32)
    t = np.where(pub >= 0, rng.integers(0, 4, (r, cap)) * 0.5, 0.0)
    approvers = (rng.random((r, cap, num_nodes)) < 0.3) & (pub[..., None] >= 0)
    return dag_lib.DagState(
        publisher=jnp.asarray(pub),
        publish_time=jnp.asarray(t, jnp.float32),
        approvals=jnp.asarray(rng.integers(-1, cap, (r, cap, k)), jnp.int32),
        approvers=jnp.asarray(approvers),
        approval_count=jnp.asarray(approvers.sum(-1), jnp.int32),
        accuracy=jnp.asarray(rng.random((r, cap)), jnp.float32),
        auth_tag=jnp.asarray(rng.random((r, cap)), jnp.float32),
        model_slot=jnp.asarray(rng.integers(-1, cap, (r, cap)), jnp.int32),
        count=jnp.asarray(rng.integers(0, 3 * cap, (r,)), jnp.int32),
        published_per_node=jnp.asarray(rng.integers(0, 5, (r, num_nodes)), jnp.int32),
        contributing_m0=jnp.asarray(rng.integers(0, 5, (r, num_nodes)), jnp.int32),
        contributing_m1=jnp.asarray(rng.integers(0, 5, (r, num_nodes)), jnp.int32),
    )


def assert_dags_equal(a: dag_lib.DagState, b: dag_lib.DagState, msg="") -> None:
    for name in dag_lib.DagState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{msg}{name}",
        )


def genesis(num_nodes):
    d = dag_lib.empty_dag(CAP, K, num_nodes + 1)
    return dag_lib.publish(
        d, jnp.asarray(num_nodes, jnp.int32), jnp.float32(0.0),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )


def make_net(top, mesh=None, impl="fused", sync_period=1.0, partition=None, seed=0):
    return gossip_lib.GossipNetwork(
        genesis(top.num_nodes), bank=jnp.zeros((CAP, 4)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=sync_period, seed=seed, impl=impl),
        partition=partition, mesh=mesh,
    )


def publish_on(net, node, seq, t):
    d = net.read(node)
    d = replica_lib.publish_local(
        d, seq, jnp.asarray(node, jnp.int32), jnp.float32(t),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(seq % CAP, jnp.int32),
    )
    net.write(node, d)


OVERLAYS = {
    "ring": lambda n, seed: topo.ring(n, drop=0.3, seed=seed),
    "er": lambda n, seed: topo.erdos_renyi(n, 0.3, seed=seed),
    "star": lambda n, seed: topo.star(n),
}


# ---------------------------------------------------------------------------
# Mesh construction / validation (device-count independent)
# ---------------------------------------------------------------------------


def test_mesh_single_node_axis_accepts_any_overlay():
    mesh = mesh_lib.make_gossip_mesh(nodes=1)
    assert mesh_lib.nodes_axis_size(mesh) == 1
    mesh_lib.validate_replica_mesh(7, mesh)   # nodes=1 divides everything
    # a single-shard mesh still runs the shard_map path end to end
    net = make_net(topo.ring(6), mesh=mesh)
    publish_on(net, 0, 1, 0.5)
    assert net.converge(at_time=50.0)
    ref = make_net(topo.ring(6))
    publish_on(ref, 0, 1, 0.5)
    assert ref.converge(at_time=50.0)
    assert_dags_equal(net.replicas.dags, ref.replicas.dags, msg="1-shard:")


def test_mesh_needs_enough_devices():
    with pytest.raises(ValueError):
        mesh_lib.make_gossip_mesh(nodes=jax.device_count() + 1)


@multidevice
def test_mesh_rejects_indivisible_overlay():
    mesh = mesh_lib.make_gossip_mesh(nodes=8)
    with pytest.raises(ValueError):
        mesh_lib.validate_replica_mesh(7, mesh)
    with pytest.raises(ValueError):
        make_net(topo.ring(9), mesh=mesh)


# ---------------------------------------------------------------------------
# One-shot round equivalence (all impls, 2x4 and 8x1 meshes)
# ---------------------------------------------------------------------------


@multidevice
@pytest.mark.parametrize("mesh_shape", [(2, 4), (8, 1)])
@pytest.mark.parametrize("impl", ["fused", "lax", "pallas", "scan"])
def test_sharded_round_matches_single_device(mesh_shape, impl):
    mesh = mesh_lib.make_gossip_mesh(nodes=mesh_shape[0], model=mesh_shape[1])
    rng = np.random.default_rng(0)
    r = 16
    single = gossip_lib.make_gossip_round(impl)
    sharded = gossip_lib.make_gossip_round(impl, mesh=mesh)
    for edges in [np.zeros((r, r), bool), np.triu(np.ones((r, r), bool), 1)] + [
        rng.random((r, r)) < 0.4 for _ in range(3)
    ]:
        dags = random_stacked(rng, r)
        assert_dags_equal(
            single(dags, jnp.asarray(edges)), sharded(dags, jnp.asarray(edges)),
            msg=f"{mesh_shape}/{impl}/",
        )


# ---------------------------------------------------------------------------
# Driver equivalence: advance windows, converge, partition/heal
# ---------------------------------------------------------------------------


@multidevice
@pytest.mark.parametrize("overlay", sorted(OVERLAYS))
def test_mesh_network_advance_and_heal_bitwise(overlay):
    n = 16
    mesh = mesh_lib.make_gossip_mesh(nodes=8)
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(n), t_start=2.5, t_end=6.5
    )
    a = make_net(OVERLAYS[overlay](n, 3), partition=part, seed=7)
    b = make_net(OVERLAYS[overlay](n, 3), mesh=mesh, partition=part, seed=7)
    rng = np.random.default_rng(4)
    for seq in range(1, 5):
        node = int(rng.integers(0, n))
        publish_on(a, node, seq, 0.1 * seq)
        publish_on(b, node, seq, 0.1 * seq)
    for t in (1.0, 3.0, 5.0, 8.0):      # pre-partition, split, split, healed
        a.advance(t)
        b.advance(t)
        assert_dags_equal(a.replicas.dags, b.replicas.dags, msg=f"{overlay}@{t}:")
    sa, sb = a.converge(at_time=100.0), b.converge(at_time=100.0)
    assert sa == sb
    assert_dags_equal(a.replicas.dags, b.replicas.dags, msg=f"{overlay}@conv:")
    assert b.synced() == a.synced()


@multidevice
def test_mesh_replicas_actually_sharded():
    """The point of the exercise: each device holds R/shards receiver rows."""
    n, shards = 16, 8
    net = make_net(topo.ring(n), mesh=mesh_lib.make_gossip_mesh(nodes=shards))
    pub = net.replicas.dags.publisher
    assert len(pub.sharding.device_set) == shards
    shard_rows = {s.data.shape[0] for s in pub.addressable_shards}
    assert shard_rows == {n // shards}
    net.advance(2.0)                     # sharding survives the jitted scan
    assert len(net.replicas.dags.publisher.sharding.device_set) == shards


@multidevice
@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    overlay=st.sampled_from(sorted(OVERLAYS)),
    window=st.integers(1, 8),
    split=st.booleans(),
)
def test_property_mesh_round_equals_fused(seed, overlay, window, split):
    """Property: a mesh-sharded sync schedule — optionally through a
    partition/heal — is bitwise the single-device fused schedule (and hence,
    by test_gossip_merge, the PR-1 scan fold)."""
    n = 16
    mesh = mesh_lib.make_gossip_mesh(nodes=8)
    part = (
        gossip_lib.PartitionSchedule(
            assignment=topo.split_halves(n),
            t_start=1.0, t_end=1.0 + window / 2.0,
        )
        if split else None
    )
    top = OVERLAYS[overlay](n, seed % 997)
    a = make_net(top, partition=part, seed=seed % 1013)
    b = make_net(top, mesh=mesh, partition=part, seed=seed % 1013)
    rng = np.random.default_rng(seed)
    for seq in range(1, 4):
        node = int(rng.integers(0, n))
        publish_on(a, node, seq, 0.1 * seq)
        publish_on(b, node, seq, 0.1 * seq)
    a.advance(float(window))
    b.advance(float(window))
    assert_dags_equal(a.replicas.dags, b.replicas.dags, msg="advance:")
    sa, sb = a.converge(at_time=float(window) + 20.0), b.converge(at_time=float(window) + 20.0)
    assert sa == sb
    assert_dags_equal(a.replicas.dags, b.replicas.dags, msg="converge:")


# ---------------------------------------------------------------------------
# e2e sim + single-device lane coverage (subprocess pins its own XLA flags)
# ---------------------------------------------------------------------------


@multidevice
def test_run_dagfl_gossip_mesh_matches_single_device():
    from repro.fl.experiments import default_dagfl_config, make_cnn_setup
    from repro.fl.systems import SimConfig, run_dagfl_gossip

    n = 16
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=12, eval_every=6, seed=0)
    mesh = mesh_lib.make_gossip_mesh(nodes=8)
    results = []
    for m in (None, mesh):
        task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)
        results.append(run_dagfl_gossip(
            task, nodes, dcfg, sim, gval,
            topology=topo.ring(n, seed=0),
            gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=0),
            mesh=m,
        ))
    base, sharded = results
    np.testing.assert_array_equal(base.accs, sharded.accs)
    assert_dags_equal(base.extras["dag"], sharded.extras["dag"], msg="union:")
    assert base.extras["sync_rounds"] == sharded.extras["sync_rounds"]


def test_sharded_round_equivalence_in_subprocess():
    """Runs on every lane: forces 8 host devices in a child process and
    checks one advance+converge schedule bitwise against single-device."""
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import dag as dag_lib
        from repro.net import gossip as G, mesh as M, replica as R
        from repro.net import topology as topo
        assert jax.device_count() == 8, jax.device_count()
        CAP, K = 16, 2
        d = dag_lib.empty_dag(CAP, K, 17)
        d = dag_lib.publish(d, jnp.asarray(16, jnp.int32), jnp.float32(0.0),
            jnp.full((K,), dag_lib.NO_TX, jnp.int32), jnp.float32(0.5),
            jnp.float32(0.0), jnp.asarray(0, jnp.int32))
        def net(mesh):
            return G.GossipNetwork(d, bank=jnp.zeros((CAP, 4)),
                top=topo.ring(16, drop=0.2, seed=1),
                cfg=G.GossipConfig(sync_period=1.0, seed=5), mesh=mesh)
        a, b = net(None), net(M.make_gossip_mesh(nodes=2, model=4))
        for n_ in (a, b):
            dd = R.publish_local(n_.read(3), 1, jnp.asarray(3, jnp.int32),
                jnp.float32(0.1), jnp.full((K,), dag_lib.NO_TX, jnp.int32),
                jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(1, jnp.int32))
            n_.write(3, dd)
        a.advance(4.0); b.advance(4.0)
        assert a.converge(at_time=50.0) == b.converge(at_time=50.0)
        for f in dag_lib.DagState._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a.replicas.dags, f)),
                np.asarray(getattr(b.replicas.dags, f)), err_msg=f)
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=1200,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout
