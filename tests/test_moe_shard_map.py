"""Expert-parallel (shard_map all-to-all) MoE == the sorted-dispatch oracle."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=1200,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_shard_map_moe_matches_sorted_oracle():
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.models import moe as moe_lib
        from repro.models.moe_shard_map import make_moe_shard_map

        cfg = dataclasses.replace(
            ARCHS["deepseek-v2-236b"].reduced(),
            num_experts=8, experts_per_token=2, num_shared_experts=0,
            moe_d_ff=32, d_model=64, dtype="float32")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))

        y_ref, _ = moe_lib.moe_apply_sorted(
            cfg, params, x.reshape(-1, cfg.d_model), capacity_factor=8.0)
        y_ref = y_ref.reshape(x.shape)
        with mesh:
            y_sm, aux = jax.jit(make_moe_shard_map(cfg, mesh, capacity_factor=8.0))(
                params, x)
        np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        print("OK")
    """)
    assert "OK" in run_sub(code)


def test_shard_map_moe_grad_flows():
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.models import moe as moe_lib
        from repro.models.moe_shard_map import make_moe_shard_map

        cfg = dataclasses.replace(
            ARCHS["kimi-k2-1t-a32b"].reduced(),
            num_experts=8, experts_per_token=2, num_shared_experts=0,
            moe_d_ff=32, d_model=64, dtype="float32")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
        routed = {k: v for k, v in params.items() if k != "shared"}
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        fn = make_moe_shard_map(cfg, mesh, capacity_factor=8.0)

        def loss(p):
            y, aux = fn(p, x)
            return jnp.sum(y ** 2) + 0.01 * jnp.sum(aux)

        with mesh:
            g = jax.jit(jax.grad(loss))(routed)
        gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
        assert np.isfinite(gn) and gn > 0, gn
        print("OK")
    """)
    assert "OK" in run_sub(code)
