"""Overlay builders: shape/symmetry invariants + connectivity helpers."""
import numpy as np
import pytest

from repro.net import topology as topo


@pytest.mark.parametrize(
    "build",
    [
        lambda: topo.ring(9),
        lambda: topo.k_regular(10, 4),
        lambda: topo.erdos_renyi(14, 0.5, seed=3),
        lambda: topo.star(8),
        lambda: topo.full(6),
    ],
)
def test_builder_invariants(build):
    t = build()
    n = t.num_nodes
    assert t.adjacency.shape == (n, n)
    assert t.latency.shape == (n, n)
    assert t.drop.shape == (n, n)
    assert not t.adjacency.diagonal().any()
    assert (t.adjacency == t.adjacency.T).all()
    assert np.isinf(t.latency[~t.adjacency]).all()
    assert (t.drop[~t.adjacency] == 0).all()


def test_ring_degrees_and_diameter():
    t = topo.ring(8)
    assert (t.degree() == 2).all()
    assert topo.is_connected(t.adjacency)
    # 8-cycle diameter = 4 hops; 1 tick per hop at unit period
    assert topo.path_latency_bound(t, 1.0) == pytest.approx(4.0)


def test_k_regular_degree_and_feasibility():
    assert (topo.k_regular(10, 4).degree() == 4).all()
    assert (topo.k_regular(10, 5).degree() == 5).all()   # odd k, even n: antipode
    assert (topo.full(7).degree() == 6).all()
    with pytest.raises(ValueError):
        topo.k_regular(9, 5)          # n*k odd: infeasible
    with pytest.raises(ValueError):
        topo.k_regular(4, 4)          # k >= n


def test_star_hub_and_spokes():
    t = topo.star(9, hub=2)
    deg = t.degree()
    assert deg[2] == 8
    assert (np.delete(deg, 2) == 1).all()
    assert topo.is_connected(t.adjacency)


def test_erdos_renyi_extremes():
    empty = topo.erdos_renyi(8, 0.0, seed=0)
    assert empty.adjacency.sum() == 0
    assert topo.components(empty.adjacency).max() == 7
    dense = topo.erdos_renyi(8, 1.0, seed=0)
    assert (dense.degree() == 7).all()


def test_components_and_partition_matrix():
    t = topo.ring(6)
    assert (topo.components(t.adjacency) == 0).all()
    assignment = topo.split_halves(6)
    mask = topo.partition_matrix(assignment)
    cut = t.adjacency & ~mask
    assert cut.sum() == 4            # the two cross-half ring edges, both dirs
    # the partitioned overlay really has two components
    assert topo.components(t.adjacency & mask).max() == 1


def test_split_random_partitions_full_overlay():
    assignment = topo.split_random(12, 3, seed=5)
    assert set(np.unique(assignment)) <= {0, 1, 2}
    t = topo.full(12)
    masked = t.adjacency & topo.partition_matrix(assignment)
    # each component label present becomes exactly one component
    assert topo.components(masked).max() == len(np.unique(assignment)) - 1


def test_latency_jitter_and_drop_land_on_links_only():
    t = topo.ring(10, link_latency=0.5, latency_jitter=0.3, drop=0.2, seed=1)
    on = t.adjacency
    assert (t.latency[on] >= 0.5).all() and (t.latency[on] <= 0.8 + 1e-6).all()
    assert (t.latency == t.latency.T).all()          # symmetric per-link draw
    assert (t.drop[on] == np.float32(0.2)).all()


def test_latency_bound_accounts_for_slow_links():
    fast = topo.ring(6, link_latency=0.0)
    slow = topo.ring(6, link_latency=2.5)
    # slow links fire every ceil(2.5/1.0)=3 ticks -> 3x the bound
    assert topo.path_latency_bound(slow, 1.0) == pytest.approx(
        3.0 * topo.path_latency_bound(fast, 1.0)
    )
