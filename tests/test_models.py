"""Per-arch smoke tests (reduced configs) + decode==forward consistency.

Assignment requirement: every arch instantiates a REDUCED variant (2 layers,
d_model <= 512, <= 4 experts), runs one forward/train step on CPU, asserts
output shapes and no NaNs. Plus: a prefill+decode step must reproduce the
full-sequence forward logits at the next position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, TrainConfig
from repro.models import build_model
from repro.optim import init_optimizer

ARCH_NAMES = sorted(ARCHS)
B, S = 2, 16


def make_batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend_tokens:
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim)
        )
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_config_bounds(name):
    cfg = ARCHS[name].reduced()
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train(name):
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, key)

    logits, aux = model.forward(params, batch["tokens"], batch.get("frontend"))
    assert logits.shape == (B, S + cfg.frontend_tokens, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite forward"

    tc = TrainConfig(optimizer="sgd", learning_rate=0.01)
    opt = init_optimizer(tc, params)
    p2, opt2, metrics = model.train_step(tc, params, opt, batch, 0.01)
    assert bool(jnp.isfinite(metrics["loss"])), "non-finite loss"
    # params actually changed
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
    )
    assert changed


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_forward(name):
    """prefill(S tokens) + decode(token S) == forward(S+1 tokens) at position S."""
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    frontend = None
    if cfg.frontend_tokens:
        frontend = jax.random.normal(key, (B, cfg.frontend_tokens, cfg.frontend_dim))

    full_logits, _ = model.forward(params, tokens, frontend)
    want = full_logits[:, -1, :]

    _, cache = model.prefill(
        params, tokens[:, :S], frontend,
        cache_len=S + cfg.frontend_tokens + 4,
    )
    got, _ = model.decode_step(params, tokens[:, S:], cache)
    np.testing.assert_allclose(
        np.asarray(got[:, 0, :], np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_sliding_window_variant_decodes():
    """long_500k policy: SW variant of a dense arch runs with a ring cache."""
    from repro.configs import long_context_variant
    import dataclasses

    cfg = long_context_variant(
        dataclasses.replace(ARCHS["olmo-1b"], attention="full")
    ).reduced()
    assert cfg.attention == "sliding_window"
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    W = cfg.window_size
    T = W * 2  # sequence longer than the window
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, T + 1), 0, cfg.vocab_size)

    full_logits, _ = model.forward(params, tokens, None)
    _, cache = model.prefill(params, tokens[:, :T], None, cache_len=T)
    got, _ = model.decode_step(params, tokens[:, T:], cache)
    np.testing.assert_allclose(
        np.asarray(got[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_multi_step_decode_consistency():
    """Greedy decode 4 steps == forward logits at each position (dense arch)."""
    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    T = 12
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, T), 0, cfg.vocab_size)
    full_logits, _ = model.forward(params, tokens, None)

    _, cache = model.prefill(params, tokens[:, :8], None, cache_len=T + 2)
    for t in range(8, T):
        got, cache = model.decode_step(params, tokens[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(got[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=2e-2, atol=2e-2,
        )
