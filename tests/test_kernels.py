"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("k", [2, 5, 8])
@pytest.mark.parametrize("n", [1000, 16384, 50000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_sweep(k, n, dtype):
    key = jax.random.PRNGKey(k * 100 + n % 97)
    w = jax.nn.softmax(jax.random.normal(key, (k,)))
    m = jax.random.normal(jax.random.fold_in(key, 1), (k, n)).astype(dtype)
    out = ops.fedavg(w, m, block_n=8192)
    expect = ref.fedavg_ref(w, m)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("k", [2, 6])
@pytest.mark.parametrize("n", [4096, 40000])
def test_model_distance_sweep(k, n):
    key = jax.random.PRNGKey(k + n)
    m = jax.random.normal(key, (k, n))
    out = ops.model_distance(m, block_n=8192)
    expect = ref.model_distance_ref(m)
    scale = float(jnp.mean(jnp.abs(expect))) + 1.0
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-5 * scale * n ** 0.5)
    # symmetry + nonnegativity (up to fp noise)
    np.testing.assert_allclose(out, out.T, rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("S,hd,H,KV", [(128, 64, 4, 4), (256, 64, 8, 2), (192, 128, 4, 1)])
@pytest.mark.parametrize("window", [0, 96])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, hd, H, KV, window, dtype):
    key = jax.random.PRNGKey(S + H + window)
    B = 2
    q = (jax.random.normal(key, (B, H, S, hd)) * 0.3).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, hd)) * 0.3).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, S, hd)).astype(dtype)
    out = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    expect = ref.mqa_attention_ref(q, k, v, window=window)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("S,H,KV,hd", [(256, 4, 4, 64), (512, 8, 2, 64), (384, 4, 1, 128)])
def test_decode_attention_sweep(S, H, KV, hd):
    key = jax.random.PRNGKey(S + H)
    B = 3
    q = jax.random.normal(key, (B, H, hd)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    lens = jnp.asarray([S // 3, S, 1], jnp.int32)
    out = ops.decode_attention(q, k, v, lens, block_s=128)
    expect = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=2e-3)


def test_flash_matches_model_sdpa():
    """The Pallas kernel and the model's chunked jnp path agree."""
    from repro.models.attention import chunked_sdpa

    key = jax.random.PRNGKey(7)
    B, S, H, KV, hd = 2, 256, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, hd)) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    jnp_out = chunked_sdpa(q, k, v, block_q=64)
    # kernel layout (B,H,S,hd)
    pall = ops.flash_attention(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        block_q=64, block_k=64,
    )
    np.testing.assert_allclose(
        jnp.moveaxis(pall, 1, 2), jnp_out, rtol=2e-3, atol=2e-3
    )
