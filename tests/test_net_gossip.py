"""Anti-entropy gossip: propagation, loss, latency strides, partition/heal.

Propagation-semantics tests run under both round implementations —
``impl="scan"`` (the PR-1 reference fold) and ``impl="fused"`` (the kernel
reduction fast path) — they must be indistinguishable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dag as dag_lib
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo

CAP, K = 32, 2


def genesis(num_nodes):
    d = dag_lib.empty_dag(CAP, K, num_nodes + 1)
    return dag_lib.publish(
        d, jnp.asarray(num_nodes, jnp.int32), jnp.float32(0.0),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )


IMPLS = ["fused", "scan"]


def make_net(top, sync_period=1.0, partition=None, seed=0, impl="fused"):
    n = top.num_nodes
    return gossip_lib.GossipNetwork(
        genesis(n), bank=jnp.zeros((CAP, 4)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=sync_period, seed=seed, impl=impl),
        partition=partition,
    )


def publish_on(net, node, seq, t, approvals=None):
    ap = approvals if approvals is not None else jnp.full((K,), dag_lib.NO_TX, jnp.int32)
    d = net.read(node)
    d = replica_lib.publish_local(
        d, seq, jnp.asarray(node, jnp.int32), jnp.float32(t), ap,
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(seq % CAP, jnp.int32),
    )
    net.write(node, d)


def test_replica_roundtrip_and_shared_start():
    net = make_net(topo.ring(5))
    assert net.replicas.num_replicas == 5
    assert net.synced()
    d0 = net.read(3)
    assert int(d0.count) == 1
    publish_on(net, 3, seq=1, t=0.5)
    assert not net.synced()
    assert int(net.read(3).count) == 2
    assert int(net.read(0).count) == 1          # others unaffected until sync


@pytest.mark.parametrize("impl", IMPLS)
def test_ring_propagates_one_hop_per_tick(impl):
    net = make_net(topo.ring(6), impl=impl)
    publish_on(net, 0, seq=1, t=0.5)
    assert (net.missing_rows() > 0).sum() == 5
    net.advance(1.0)                             # neighbors 1 and 5 learn
    assert (net.missing_rows() > 0).sum() == 3
    net.advance(2.0)
    assert (net.missing_rows() > 0).sum() == 1
    net.advance(3.0)                             # antipode reached
    assert net.synced()


@pytest.mark.parametrize("impl", IMPLS)
def test_full_drop_blocks_everything(impl):
    net = make_net(topo.ring(6, drop=1.0), impl=impl)
    publish_on(net, 0, seq=1, t=0.5)
    net.advance(10.0)
    assert (net.missing_rows() > 0).sum() == 5
    assert not net.synced()


@pytest.mark.parametrize("impl", IMPLS)
def test_latency_stride_halves_sync_rate(impl):
    # link latency 2x the period: links fire only on even ticks
    net = make_net(topo.ring(6, link_latency=2.0), sync_period=1.0, impl=impl)
    publish_on(net, 0, seq=1, t=0.1)
    net.advance(1.0)                             # tick 0 fires (0 % 2 == 0)
    assert (net.missing_rows() > 0).sum() == 3
    net.advance(2.0)                             # tick 1: strided out, no-op
    assert (net.missing_rows() > 0).sum() == 3
    net.advance(3.0)                             # tick 2 fires
    assert (net.missing_rows() > 0).sum() == 1


@pytest.mark.parametrize("impl", IMPLS)
def test_gossip_round_is_single_jitted_call(impl):
    """The round must accept the whole stacked replica set in one call."""
    net = make_net(topo.full(8), impl=impl)
    publish_on(net, 2, seq=1, t=0.5)
    round_fn = gossip_lib.make_gossip_round(impl)
    edges = jnp.asarray(net.topology.adjacency)
    out = round_fn(net.replicas.dags, edges)     # (R, ...) in, (R, ...) out
    assert out.publisher.shape == net.replicas.dags.publisher.shape
    assert bool(replica_lib.replicas_synced(out))


def test_union_view_counts():
    net = make_net(topo.ring(4))
    publish_on(net, 0, seq=1, t=0.5)
    publish_on(net, 2, seq=2, t=0.6, approvals=jnp.asarray([0, dag_lib.NO_TX], jnp.int32))
    union = net.union()
    assert int(union.count) == 3
    assert int(jnp.sum(union.publisher >= 0)) == 3
    assert int(union.approval_count[0]) == 1     # node 2's credit survives union


@pytest.mark.parametrize("impl", IMPLS)
def test_partition_then_heal_converges_identically(impl):
    """Acceptance: split for [t_a, t_b), publish on both sides, heal -> all
    replicas converge to the identical DagState."""
    n = 8
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(n), t_start=1.5, t_end=6.5,
    )
    net = make_net(topo.full(n), sync_period=1.0, partition=part, impl=impl)

    publish_on(net, 0, seq=1, t=0.2)             # pre-partition: reaches all
    net.advance(1.0)
    assert net.synced()

    # during the partition each side publishes its own history
    publish_on(net, 1, seq=2, t=2.0, approvals=jnp.asarray([1, -1], jnp.int32))
    publish_on(net, 5, seq=3, t=2.1, approvals=jnp.asarray([1, -1], jnp.int32))
    net.advance(3.0)                             # intra-component sync only
    left, right = net.read(0), net.read(n - 1)
    assert int(left.count) == 3                  # side A saw seq 2
    assert int(right.count) == 4                 # side B saw seq 3
    assert not net.synced()
    # row 2 is visible on side A, row 3 on side B — disjoint views
    assert int(left.publisher[3]) < 0 and int(right.publisher[3]) >= 0
    assert int(left.publisher[2]) >= 0 and int(right.publisher[2]) < 0

    net.advance(7.0)                             # schedule healed at t=6.5
    assert net.converge(at_time=8.0)
    assert net.synced()
    merged = net.read(0)
    union = net.union()
    for a, b in zip(jax.tree_util.tree_leaves(merged), jax.tree_util.tree_leaves(union)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # both divergent rows survive, and the shared ancestor's concurrent
    # credits from either side of the partition BOTH count after healing
    # (the exact approver-set union; union-by-max used to collapse them)
    assert int(union.publisher[2]) == 1 and int(union.publisher[3]) == 5
    assert int(union.approval_count[1]) == 2
    assert bool(union.approvers[1, 1]) and bool(union.approvers[1, 5])


@pytest.mark.parametrize("impl", IMPLS)
def test_ideal_wire_ignores_link_latency(impl):
    """sync_period <= 0 is an ideal wire: latency strides must not apply
    (regression: ceil(latency/1e-9) overflowed int32 and disabled gossip)."""
    net = make_net(topo.ring(6, link_latency=2.5), sync_period=0.0, impl=impl)
    publish_on(net, 0, seq=1, t=0.5)
    net.advance(1.0)
    assert net.synced()


@pytest.mark.parametrize("impl", IMPLS)
def test_converge_covers_strided_links(impl):
    """converge()'s tick bound must account for links that only fire every
    ceil(latency/period) ticks (regression: bound was num_nodes alone)."""
    net = make_net(topo.ring(8, link_latency=3.0), sync_period=1.0, impl=impl)
    publish_on(net, 0, seq=1, t=0.1)
    assert net.converge(at_time=100.0)
    assert net.synced()


@pytest.mark.parametrize("impl", IMPLS)
def test_disconnected_overlay_never_converges(impl):
    net = make_net(topo.erdos_renyi(6, 0.0), impl=impl)     # no links at all
    publish_on(net, 0, seq=1, t=0.1)
    assert not net.converge(at_time=5.0)
