"""MoE dispatch: sorted (production) vs dense (oracle) + routing properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCHS
from repro.models import moe as moe_lib


def small_cfg(E=4, k=2, act="swiglu"):
    return dataclasses.replace(
        ARCHS["deepseek-v2-236b"].reduced(),
        num_experts=E, experts_per_token=k, num_shared_experts=1,
        moe_d_ff=32, d_model=64, act=act, dtype="float32",
    )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), E=st.sampled_from([4, 8]), k=st.sampled_from([1, 2]))
def test_sorted_equals_dense_without_drops(seed, E, k):
    cfg = dataclasses.replace(small_cfg(E, k), num_shared_experts=0)
    key = jax.random.PRNGKey(seed)
    params = moe_lib.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (24, cfg.d_model))
    y_dense, aux_d = moe_lib.moe_apply_dense(cfg, params, x)
    # capacity_factor large enough that nothing drops
    y_sorted, aux_s = moe_lib.moe_apply_sorted(cfg, params, x, capacity_factor=8.0)
    np.testing.assert_allclose(y_dense, y_sorted, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(aux_d, aux_s, rtol=1e-5)


def test_router_gates_normalized():
    cfg = small_cfg()
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    gates, idx, aux = moe_lib.route(cfg, params, x)
    np.testing.assert_allclose(jnp.sum(gates, -1), jnp.ones(16), rtol=1e-5)
    assert int(jnp.max(idx)) < cfg.num_experts
    # aux >= 1 (equals num_experts * sum(load*importance) >= 1 by Cauchy-Schwarz)
    assert float(aux) >= 0.99


def test_capacity_drop_reduces_output_not_nan():
    cfg = dataclasses.replace(small_cfg(), num_shared_experts=0)
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    # force congestion: all tokens identical -> same experts
    x = jnp.ones((64, cfg.d_model))
    y, _ = moe_lib.moe_apply_sorted(cfg, params, x, capacity_factor=0.25)
    assert bool(jnp.all(jnp.isfinite(y)))
    # some tokens must have been dropped (zero rows allowed)
    y_full, _ = moe_lib.moe_apply_sorted(cfg, params, x, capacity_factor=8.0)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_full)) + 1e-3


def test_shared_expert_always_applies():
    cfg = small_cfg()
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 6, cfg.d_model))
    y, _ = moe_lib.moe_apply(cfg, params, x, impl="sorted")
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_grad_flows():
    cfg = dataclasses.replace(small_cfg(), num_shared_experts=0)
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, cfg.d_model))

    def loss(p):
        y, aux = moe_lib.moe_apply_sorted(cfg, p, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
