"""Fused anti-entropy fast path: bitwise equivalence with the PR-1 fold.

The fused round (winner reduction + payload gather, ``repro.kernels.
gossip_merge`` + ``dag.merge_select``) must be BITWISE-identical to the
reference ``vmap``-over-``scan`` fold of ``dag.merge`` — on adversarial
random states (duplicate keys with divergent payloads, empty rows, random
masks), not just states reachable through ``publish``. Likewise one
tick-batched ``advance`` must equal the same ticks issued one dispatch at a
time, and the ``lax.while_loop`` ``converge`` must behave like the host
loop it replaced.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dag as dag_lib
from repro.kernels import ref as kref
from repro.kernels.gossip_merge import gossip_winner, gossip_winner_pallas
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo

CAP, K = 16, 2
IMPLS = ["fused", "lax", "pallas"]


def random_stacked(rng, r, cap=CAP, num_nodes=8, k=K) -> dag_lib.DagState:
    """Random stacked replicas — intentionally NOT publish-reachable: the
    same (publish_time, publisher) key can carry different payloads on
    different replicas, so the tests pin the tie-break order, not just the
    CRDT happy path."""
    pub = rng.integers(-1, num_nodes, (r, cap)).astype(np.int32)
    t = np.where(pub >= 0, rng.integers(0, 4, (r, cap)) * 0.5, 0.0)
    approvers = (rng.random((r, cap, num_nodes)) < 0.3) & (pub[..., None] >= 0)
    return dag_lib.DagState(
        publisher=jnp.asarray(pub),
        publish_time=jnp.asarray(t, jnp.float32),
        approvals=jnp.asarray(rng.integers(-1, cap, (r, cap, k)), jnp.int32),
        approvers=jnp.asarray(approvers),
        approval_count=jnp.asarray(approvers.sum(-1), jnp.int32),
        accuracy=jnp.asarray(rng.random((r, cap)), jnp.float32),
        auth_tag=jnp.asarray(rng.random((r, cap)), jnp.float32),
        model_slot=jnp.asarray(rng.integers(-1, cap, (r, cap)), jnp.int32),
        count=jnp.asarray(rng.integers(0, 3 * cap, (r,)), jnp.int32),
        published_per_node=jnp.asarray(rng.integers(0, 5, (r, num_nodes)), jnp.int32),
        contributing_m0=jnp.asarray(rng.integers(0, 5, (r, num_nodes)), jnp.int32),
        contributing_m1=jnp.asarray(rng.integers(0, 5, (r, num_nodes)), jnp.int32),
    )


def assert_dags_equal(a: dag_lib.DagState, b: dag_lib.DagState) -> None:
    for name in dag_lib.DagState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)), err_msg=name
        )


def _edge_cases(r):
    return [
        np.zeros((r, r), bool),                    # nobody hears anybody
        np.ones((r, r), bool) & ~np.eye(r, dtype=bool),  # full overlay
        np.triu(np.ones((r, r), bool), 1),         # asymmetric delivery
    ]


@pytest.mark.parametrize("impl", IMPLS)
def test_fused_round_matches_scan_on_random_states(impl):
    rng = np.random.default_rng(0)
    scan = gossip_lib.make_gossip_round("scan")
    fused = gossip_lib.make_gossip_round(impl)
    r = 9
    masks = _edge_cases(r) + [rng.random((r, r)) < 0.4 for _ in range(6)]
    for edges in masks:
        dags = random_stacked(rng, r)
        assert_dags_equal(scan(dags, jnp.asarray(edges)), fused(dags, jnp.asarray(edges)))


def test_pallas_kernel_matches_lax_oracle_all_block_widths():
    """The Pallas kernel (interpret mode here) against the pure-lax oracle,
    including a block width that forces column padding."""
    rng = np.random.default_rng(1)
    for bc in (4, 8, 16, 64):          # 64 > CAP: single padded block
        dags = random_stacked(rng, 7)
        mask = jnp.asarray(rng.random((7, 7)) < 0.5) | jnp.eye(7, dtype=bool)
        ref_out = kref.gossip_winner_ref(
            dags.publish_time, dags.publisher, dags.approval_count, mask
        )
        pal_out = gossip_winner_pallas(
            dags.publish_time, dags.publisher, dags.approval_count, mask,
            block_c=bc, interpret=True,
        )
        for a, b in zip(ref_out, pal_out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    r=st.integers(2, 12),
    cap=st.integers(1, 24),
    edge_p=st.floats(0.0, 1.0),
)
def test_property_fused_round_equals_scan(seed, r, cap, edge_p):
    rng = np.random.default_rng(seed)
    dags = random_stacked(rng, r, cap=cap)
    edges = jnp.asarray(rng.random((r, r)) < edge_p)
    scan = gossip_lib.make_gossip_round("scan")(dags, edges)
    for impl in IMPLS:
        assert_dags_equal(scan, gossip_lib.make_gossip_round(impl)(dags, edges))


def test_merge_all_matches_sequential_fold():
    """The union reduction (Rr=1 winner pass) == left fold of dag.merge."""
    rng = np.random.default_rng(2)
    for _ in range(5):
        dags = random_stacked(rng, 6)
        replicas = [
            jax.tree_util.tree_map(lambda x: x[i], dags) for i in range(6)
        ]
        folded = functools.reduce(dag_lib.merge, replicas)
        assert_dags_equal(folded, replica_lib.merge_all(dags))


# ---------------------------------------------------------------------------
# Tick batching / device-resident converge
# ---------------------------------------------------------------------------


def _genesis(num_nodes):
    d = dag_lib.empty_dag(CAP, K, num_nodes + 1)
    return dag_lib.publish(
        d, jnp.asarray(num_nodes, jnp.int32), jnp.float32(0.0),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )


def _make_net(top, impl, sync_period=1.0, partition=None, seed=0):
    n = top.num_nodes
    return gossip_lib.GossipNetwork(
        _genesis(n), bank=jnp.zeros((CAP, 4)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=sync_period, seed=seed, impl=impl),
        partition=partition,
    )


def _seed_rows(net, rng, count=5):
    for seq in range(1, count + 1):
        node = int(rng.integers(0, net.topology.num_nodes))
        d = net.read(node)
        d = replica_lib.publish_local(
            d, seq, jnp.asarray(node, jnp.int32), jnp.float32(0.1 * seq),
            jnp.full((K,), dag_lib.NO_TX, jnp.int32),
            jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(seq % CAP, jnp.int32),
        )
        net.write(node, d)


@pytest.mark.parametrize("impl", ["fused", "scan"])
def test_batched_advance_equals_sequential_ticks(impl):
    """advance(t) over a k-tick window == k _tick_once calls, bitwise —
    including PRNG-driven message loss and latency strides — in ONE
    device dispatch."""
    top = topo.ring(8, link_latency=2.0, drop=0.3, seed=3)
    batched = _make_net(top, impl, seed=7)
    stepped = _make_net(top, impl, seed=7)
    rng = np.random.default_rng(4)
    _seed_rows(batched, rng)
    _seed_rows(stepped, np.random.default_rng(4))

    calls_before = batched.device_calls
    batched.advance(8.0)                    # 8 periods -> one 8-tick batch
    assert batched.device_calls == calls_before + 1

    while stepped._next_tick_t <= 8.0:
        stepped._tick_once(stepped._next_tick_t)
        stepped._next_tick_t += stepped.cfg.sync_period

    assert batched.tick == stepped.tick == 8
    assert batched.rounds_run == stepped.rounds_run == 8
    assert_dags_equal(batched.replicas.dags, stepped.replicas.dags)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), window=st.integers(1, 12))
def test_property_batched_advance_equals_sequential(seed, window):
    top = topo.k_regular(8, 4, drop=0.2, seed=seed % 997)
    batched = _make_net(top, "fused", seed=seed % 1013)
    stepped = _make_net(top, "fused", seed=seed % 1013)
    rng = np.random.default_rng(seed)
    _seed_rows(batched, rng, count=3)
    _seed_rows(stepped, np.random.default_rng(seed), count=3)
    batched.advance(float(window))
    while stepped._next_tick_t <= float(window):
        stepped._tick_once(stepped._next_tick_t)
        stepped._next_tick_t += stepped.cfg.sync_period
    assert_dags_equal(batched.replicas.dags, stepped.replicas.dags)


@pytest.mark.parametrize("impl", ["fused", "scan"])
def test_converge_is_single_dispatch_and_reaches_fixpoint(impl):
    net = _make_net(topo.ring(8, link_latency=3.0), impl)
    _seed_rows(net, np.random.default_rng(5))
    calls = net.device_calls
    assert net.converge(at_time=100.0)
    assert net.device_calls == calls + 1    # whole fixpoint loop on device
    assert net.synced()
    # tick/rounds bookkeeping advanced together with the on-device loop
    assert net.tick == net.rounds_run > 0


def test_converge_respects_active_partition():
    n = 8
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(n), t_start=0.0, t_end=100.0
    )
    net = _make_net(topo.full(n), "fused", partition=part)
    _seed_rows(net, np.random.default_rng(6))
    assert not net.converge(at_time=50.0)      # split: fixpoint != full sync
    assert net.converge(at_time=200.0)         # healed: full sync
