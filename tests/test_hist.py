"""Streaming histogram telemetry (`repro.obs.hist`) + device spans.

Pins the acceptance invariants of the PR-10 quantile-sketch layer:

* ZERO PERTURBATION: a hist-instrumented run — five log-binned latency
  histograms threaded through every jitted loop next to ``MetricsState``
  — is BITWISE the obs-off run (final ReplicaSet, bank state, serve
  counters, and PRNG key alike) across ticks/events x bank x serve x
  faulted arms;
* the blocked ``hist_bincount`` Pallas kernel is EXACT against the
  pure-lax oracle and a numpy bincount, including the drop semantics for
  out-of-range indices (property-tested);
* histogram percentiles land within ONE BIN WIDTH of the exact
  ``numpy.percentile(..., method="inverted_cdf")`` answer — the error
  bound ``summary`` reports is honest (property-tested);
* ``ObsConfig.device_spans`` records PUBLISH/COMMIT through the device
  trace ring bitwise-equivalently to the host-buffered path (modulo the
  ring's f32 wire precision), without perturbing the simulation;
* ``simulate_insystem_tips(record_trace=True)`` leaves the measured
  series bitwise-unchanged, accounts one COMMIT per published
  transaction, and exports through the shared ``ObsReport`` format.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dag as dag_lib
from repro.kernels import ops, ref
from repro.net import events as events_lib
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig
from repro.net.faults import ROLE_HONEST, FaultConfig
from repro.net.serve import ServeConfig
from repro.obs import HistConfig, ObsConfig
from repro.obs import hist as hist_lib
from repro.obs import trace as trace_lib
from repro.obs.export import chrome_trace, metrics_jsonl_lines

CAP, K = 32, 2


def genesis(num_nodes):
    d = dag_lib.empty_dag(CAP, K, num_nodes + 1)
    return dag_lib.publish(
        d, jnp.asarray(num_nodes, jnp.int32), jnp.float32(0.0),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )


def make_net(top, engine="events", obs=None, bank_cfg=None, serve=None,
             faults=None, impl="fused", seed=7, sync_period=1.0):
    return gossip_lib.GossipNetwork(
        genesis(top.num_nodes), bank=jnp.zeros((CAP, 8)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=sync_period, seed=seed,
                                    impl=impl, engine=engine),
        bank_cfg=bank_cfg, obs_cfg=obs, serve_cfg=serve, faults_cfg=faults,
    )


def publish_on(net, node, seq, t):
    d = replica_lib.publish_local(
        net.read(node), seq, jnp.asarray(node, jnp.int32), jnp.float32(t),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(seq % CAP, jnp.int32),
    )
    net.write(node, d)
    if net.bank_cfg is not None:
        net.bank_commit(node, seq % CAP, jnp.full((8,), float(seq)))


def assert_nets_bitwise(a, b, msg=""):
    for name in dag_lib.DagState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.replicas.dags, name)),
            np.asarray(getattr(b.replicas.dags, name)),
            err_msg=f"{msg}dag.{name}",
        )
    np.testing.assert_array_equal(
        np.asarray(a._key), np.asarray(b._key), err_msg=f"{msg}key"
    )
    if a.bank_cfg is not None:
        for name in a.replicas.bank_state._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a.replicas.bank_state, name)),
                np.asarray(getattr(b.replicas.bank_state, name)),
                err_msg=f"{msg}bank.{name}",
            )
    if getattr(a, "_serve", None) is not None:
        for name in a._sstate._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a._sstate, name)),
                np.asarray(getattr(b._sstate, name)),
                err_msg=f"{msg}serve.{name}",
            )


# ---------------------------------------------------------------------------
# The kernel: blocked bincount == lax oracle == numpy, drops out of range
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 700),
    num_bins=st.sampled_from([1, 5, 65, 129]),
)
def test_property_hist_bincount_kernel_matches_oracle(seed, m, num_bins):
    rng = np.random.default_rng(seed)
    # indices straddle both out-of-range sides: the kernel and the oracle
    # must DROP them identically, never wrap or clamp
    idx = jnp.asarray(rng.integers(-3, num_bins + 3, (m,)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 5, (m,)), jnp.int32)
    exact = np.zeros((num_bins,), np.int64)
    for i, ww in zip(np.asarray(idx), np.asarray(w)):
        if 0 <= i < num_bins:
            exact[i] += ww
    oracle = np.asarray(ref.hist_bincount_ref(idx, w, num_bins))
    kernel = np.asarray(ops.hist_bincount(idx, w, num_bins, impl="pallas"))
    np.testing.assert_array_equal(oracle, exact)
    np.testing.assert_array_equal(kernel, exact)


def test_hist_bincount_lax_impl_dispatches():
    idx = jnp.asarray([0, 1, 1, 7, -1, 8], jnp.int32)
    w = jnp.ones((6,), jnp.int32)
    out = np.asarray(ops.hist_bincount(idx, w, 8, impl="lax"))
    np.testing.assert_array_equal(out, [1, 2, 0, 0, 0, 0, 0, 1])
    with pytest.raises(ValueError):
        ops.hist_bincount(idx, w, 8, impl="nope")


# ---------------------------------------------------------------------------
# Percentiles: within one bin width of exact, the reported bound honest
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 400),
    spread=st.sampled_from(["mid", "wide", "tiny", "huge"]),
)
def test_property_percentile_within_one_bin_of_exact(seed, m, spread):
    cfg = HistConfig()
    rng = np.random.default_rng(seed)
    scale = {"mid": 1.0, "wide": 50.0, "tiny": 1e-5, "huge": 5e3}[spread]
    values = rng.lognormal(mean=np.log(scale), sigma=2.0, size=m)
    counts = np.zeros((cfg.bins + 1,), np.int64)
    b = np.asarray(hist_lib.bin_index(jnp.asarray(values, jnp.float32), cfg))
    np.add.at(counts, b, 1)
    for q in (50.0, 95.0, 99.0):
        value, err = hist_lib.percentile(counts, cfg, q)
        exact = float(np.percentile(values, q, method="inverted_cdf"))
        if not np.isfinite(err):            # overflow bin: only hi is known
            assert exact >= cfg.hi * (1 - 1e-5)
            assert value == cfg.hi
        else:
            # the sketch reports the sample's bin UPPER edge with the bin
            # width as the bound; f32 binning gets edge-exact values a
            # relative epsilon of slack
            assert exact <= value * (1 + 1e-5)
            assert exact >= (value - err) * (1 - 1e-5)


def test_percentile_empty_histogram_is_nan():
    cfg = HistConfig()
    counts = np.zeros((cfg.bins + 1,), np.int64)
    value, err = hist_lib.percentile(counts, cfg, 50.0)
    assert np.isnan(value) and np.isnan(err)
    summ = hist_lib.summary(counts, cfg)
    assert summ["samples"] == 0 and np.isnan(summ["p50"])


def test_bin_edges_are_log_spaced_and_cover_the_range():
    cfg = HistConfig()
    e = np.asarray(hist_lib.edges(cfg))
    assert e.shape == (cfg.bins + 1,)
    assert np.isclose(e[0], cfg.lo) and np.isclose(e[-1], cfg.hi)
    ratios = e[1:] / e[:-1]
    np.testing.assert_allclose(ratios, ratios[0], rtol=1e-5)
    # underflow folds into bin 0, overflow into the last (bins) bin
    b = np.asarray(hist_lib.bin_index(
        jnp.asarray([0.0, cfg.lo / 10, cfg.hi * 10], jnp.float32), cfg
    ))
    np.testing.assert_array_equal(b, [0, 0, cfg.bins])


# ---------------------------------------------------------------------------
# THE acceptance invariant: hist-on is bitwise obs-off, every arm
# ---------------------------------------------------------------------------


ARMS = [
    ("ticks", None, None, None),
    ("ticks", BankGossipConfig(chunks_per_slot=4), None, None),
    ("events", BankGossipConfig(chunks_per_slot=4), None, None),
    ("events", BankGossipConfig(chunks_per_slot=4), ServeConfig(rate=2.0),
     None),
    ("ticks", BankGossipConfig(chunks_per_slot=4), None,
     FaultConfig(roles=(ROLE_HONEST,) * 6)),
    ("events", BankGossipConfig(chunks_per_slot=4), ServeConfig(rate=2.0),
     FaultConfig(roles=(ROLE_HONEST,) * 6)),
]


@pytest.mark.parametrize("engine,bank,serve,faults", ARMS)
def test_hist_on_bitwise_obs_off(engine, bank, serve, faults):
    top = topo.full(6, link_latency=1.0, seed=3)
    a = make_net(top, engine, obs=None, bank_cfg=bank, serve=serve,
                 faults=faults)
    b = make_net(top, engine, obs=ObsConfig(hist=HistConfig()),
                 bank_cfg=bank, serve=serve, faults=faults)
    for net in (a, b):
        for seq, (node, t) in enumerate([(0, 0.3), (2, 0.7), (4, 1.1)], 1):
            publish_on(net, node, seq, t)
    for t in (1.0, 2.5, 6.0):
        a.advance(t)
        b.advance(t)
        assert_nets_bitwise(a, b, msg=f"t={t}:")
    rep = b.obs_report()
    assert rep.hist is not None
    assert set(rep.hist["counts"]) == set(hist_lib.HIST_NAMES)


def test_hist_off_is_zero_leaves_next_to_metrics():
    """``ObsConfig()`` (hist=None) keeps ``MetricsState.hist`` an empty
    tuple — zero pytree leaves, so plain obs-on carries are untouched."""
    top = topo.ring(4, link_latency=1.0)
    net = make_net(top, "ticks", obs=ObsConfig())
    assert net._metrics.hist == ()
    assert net.obs_report().hist is None


def test_hist_populates_all_five_histograms():
    """Deterministic end-to-end: a full overlay with bank + serve load
    samples every histogram — merge, commit, chunk, queue-wait, and
    staleness-at-serve."""
    top = topo.full(6, link_latency=1.0, seed=3)
    net = make_net(top, "events", obs=ObsConfig(hist=HistConfig()),
                   bank_cfg=BankGossipConfig(chunks_per_slot=4),
                   serve=ServeConfig(rate=4.0))
    for seq, (node, t) in enumerate([(0, 0.3), (2, 0.7), (4, 1.1)], 1):
        publish_on(net, node, seq, t)
    net.advance(8.0)
    rep = net.obs_report()
    counts = {k: int(np.asarray(v).sum()) for k, v in rep.hist["counts"].items()}
    for name in hist_lib.HIST_NAMES:
        assert counts[name] > 0, f"{name} never sampled: {counts}"
    # the export paths carry the sketches: JSONL hist lines + counter tracks
    hist_lines = [json.loads(l) for l in metrics_jsonl_lines(rep)
                  if json.loads(l)["kind"] == "hist"]
    assert {l["name"] for l in hist_lines} == set(hist_lib.HIST_NAMES)
    for line in hist_lines:
        assert len(line["counts"]) == line["bins"] + 1
        assert line["p50"] is None or line["p50"] >= 0
    ct = chrome_trace(rep)
    counter_names = {e["name"] for e in ct["traceEvents"] if e["ph"] == "C"}
    assert {f"hist:{n}" for n in hist_lib.HIST_NAMES} <= counter_names
    json.loads(json.dumps(ct))              # NaN-free, serializable


def test_queue_wait_conserves_admitted_requests():
    """Every admitted request contributes exactly one queue-wait sample."""
    top = topo.full(4, link_latency=0.5, seed=1)
    net = make_net(top, "events", obs=ObsConfig(hist=HistConfig()),
                   bank_cfg=BankGossipConfig(chunks_per_slot=4),
                   serve=ServeConfig(rate=4.0))
    publish_on(net, 0, 1, 0.2)
    net.advance(6.0)
    srep = net.serve_report()
    qw = int(np.asarray(net.obs_report().hist["counts"]["queue_wait"]).sum())
    # admission is the sampling instant: every request that left the queue
    # (served, or still in flight at the horizon) weighed in exactly once
    admitted = (int(srep["arrived_total"]) - int(srep["dropped_total"])
                - int(np.asarray(srep["queued"]).sum()))
    assert qw > 0
    assert qw == admitted


# ---------------------------------------------------------------------------
# Satellite: device-recorded PUBLISH/COMMIT spans pin to the host path
# ---------------------------------------------------------------------------


def test_device_spans_bitwise_host_spans_on_ticks():
    top = topo.ring(6, link_latency=1.0, seed=3)
    h = make_net(top, "ticks", obs=ObsConfig())
    d = make_net(top, "ticks", obs=ObsConfig(device_spans=True))
    spans = [
        (0.3, trace_lib.KIND_PUBLISH, 0, 0, 0.5),
        (0.8, trace_lib.KIND_COMMIT, 0, 0, 1.0),
        (1.2, trace_lib.KIND_PUBLISH, 3, 3, 0.25),
        (1.7, trace_lib.KIND_COMMIT, 3, 3, 2.0),
    ]
    for seq, (node, t) in enumerate([(0, 0.3), (3, 1.2)], 1):
        publish_on(h, node, seq, t)
        publish_on(d, node, seq, t)
    for t, kind, src, dst, arg in spans:
        h.trace_span(t, kind, src, dst, arg)
        d.trace_span(t, kind, src, dst, arg)
    for t in (1.0, 2.5, 6.0):
        h.advance(t)
        d.advance(t)
        assert_nets_bitwise(h, d, msg=f"t={t}:")

    def span_records(rep):
        tr = rep.trace
        sel = np.isin(tr["kind"], (trace_lib.KIND_PUBLISH,
                                   trace_lib.KIND_COMMIT))
        rows = sorted(zip(
            # host buffers float64; the device ring carries f32 — the pin
            # is AFTER the wire cast
            np.asarray(tr["t"][sel], np.float32).tolist(),
            tr["kind"][sel].tolist(), tr["src"][sel].tolist(),
            tr["dst"][sel].tolist(),
            np.asarray(tr["arg"][sel], np.float32).tolist(),
        ))
        return rows

    host_rows = span_records(h.obs_report())
    dev_rows = span_records(d.obs_report())
    assert len(host_rows) == len(spans)
    assert host_rows == dev_rows
    # device spans are real dispatches, counted in the funnel
    assert d.obs_report().dispatch_counts.get("trace_device", 0) == len(spans)


def test_device_spans_off_is_dispatch_free():
    top = topo.ring(4, link_latency=1.0)
    net = make_net(top, "ticks", obs=ObsConfig())
    net.trace_span(0.5, trace_lib.KIND_PUBLISH, 0, 0, 0.5)
    assert net.obs_report().dispatch_counts.get("trace_device", 0) == 0


# ---------------------------------------------------------------------------
# Satellite: the in-system tip sim joins the shared obs format
# ---------------------------------------------------------------------------


def _tip_sim(record_trace):
    return events_lib.simulate_insystem_tips(
        topo.ring(4, link_latency=0.05), h=0.5, arrival_rate=4.0, k=2,
        tau_max=2.0, horizon=6.0, capacity=128, seed=3, sync_period=0.25,
        record_trace=record_trace,
    )


def test_insystem_record_trace_is_bitwise_neutral():
    a = _tip_sim(False)
    b = _tip_sim(True)
    np.testing.assert_array_equal(a.times, b.times)
    np.testing.assert_array_equal(a.tips, b.tips)
    np.testing.assert_array_equal(a.staleness, b.staleness)
    assert a.published == b.published and a.overflow == b.overflow
    assert a.tail_mean(0.5) == b.tail_mean(0.5)
    assert a.trace is None and b.trace is not None


def test_insystem_trace_accounts_every_publish():
    tr = _tip_sim(True)
    kinds = tr.trace["kind"]
    commits = int((kinds == trace_lib.KIND_COMMIT).sum())
    publishes = int((kinds == trace_lib.KIND_PUBLISH).sum())
    assert commits == tr.published
    # every committed iteration was started; extras are still in flight
    assert publishes >= commits
    assert tr.trace_dropped == 0
    # commit args carry the global sequence: 1..published, each once
    seqs = np.sort(tr.trace["arg"][kinds == trace_lib.KIND_COMMIT])
    np.testing.assert_array_equal(seqs, np.arange(1, tr.published + 1))


def test_insystem_to_report_exports_via_shared_format():
    tr = _tip_sim(True)
    rep = tr.to_report()
    assert rep.engine == "insystem"
    assert rep.num_nodes == 4
    assert rep.samples == len(tr.times)
    for key in ("t", "tips", "staleness"):
        assert key in rep.series
    lines = metrics_jsonl_lines(rep)
    assert all(isinstance(json.loads(l), dict) for l in lines)
    ct = chrome_trace(rep)
    names = {e["name"] for e in ct["traceEvents"]}
    assert "iteration" in names and "commit" in names
    json.loads(json.dumps(ct))
