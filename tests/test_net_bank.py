"""Bank gossip: content-addressed chunk transport over Table-I bandwidth.

Pins the three invariants of ``repro.net.bank``:

* the chunk-dedup reduction (Pallas kernel, interpreted here) is bitwise
  the pure-lax oracle, and transfer selection respects per-link whole-chunk
  budgets with rollover (property- and unit-tested);
* with UNLIMITED per-link capacity, ``run_dagfl_gossip`` with bank gossip
  enabled — and any ``GossipNetwork`` sync schedule, partitions included —
  is BITWISE the PR-3 bankless path for every round impl (the acceptance
  criterion: chunk transport is deterministic and never touches the PRNG
  stream);
* with finite capacity, availability lags row visibility at the configured
  bytes-per-tick rate, identical content dedups to zero bytes, and a
  partition/heal cycle reconverges availability, not just rows.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import dag as dag_lib
from repro.kernels import chunk_transfer as ck
from repro.kernels import ref as kernel_ref
from repro.net import bank as bank_lib
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig

CAP, K = 16, 2
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Kernel layer: dedup reduction + transfer selection
# ---------------------------------------------------------------------------


def test_chunk_dedup_pallas_matches_ref_unit():
    rng = np.random.default_rng(0)
    dig = rng.integers(0, 5, (13, 3)).astype(np.float32)   # forced collisions
    have = rng.random((6, 13, 3)) < 0.3
    ref = np.asarray(kernel_ref.chunk_dedup_ref(jnp.asarray(have), jnp.asarray(dig)))
    out = np.asarray(ck.chunk_dedup_pallas(
        jnp.asarray(have), jnp.asarray(dig), block_s=4))
    np.testing.assert_array_equal(ref, out)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(1, 20),
       c=st.integers(1, 4), vals=st.integers(2, 8))
def test_property_chunk_dedup_pallas_matches_ref(seed, s, c, vals):
    """Property: kernel == oracle on digest tables dense with collisions."""
    rng = np.random.default_rng(seed)
    dig = rng.integers(0, vals, (s, c)).astype(np.float32)
    have = rng.random((5, s, c)) < 0.4
    ref = np.asarray(kernel_ref.chunk_dedup_ref(jnp.asarray(have), jnp.asarray(dig)))
    out = np.asarray(ck.chunk_dedup_pallas(
        jnp.asarray(have), jnp.asarray(dig), block_s=8))
    np.testing.assert_array_equal(ref, out)


def test_chunk_dedup_same_content_across_slots():
    """A chunk held at ANY slot satisfies every same-digest chunk at that
    offset — the content-addressing that makes lazy republishes free."""
    dig = jnp.asarray([[1.0, 2.0], [1.0, 9.0], [7.0, 2.0]])
    have = jnp.zeros((1, 3, 2), bool).at[0, 0].set(True)   # only slot 0 held
    sat = np.asarray(ck.chunk_dedup(have, dig, impl="lax"))
    # slot 1 chunk 0 and slot 2 chunk 1 share slot 0's content
    np.testing.assert_array_equal(
        sat[0], [[True, True], [True, False], [False, True]]
    )


def test_transfer_select_budget_and_striping():
    need = jnp.asarray([[True, True, True]])
    src = jnp.asarray([[False, False, False],
                       [True, True, False],
                       [True, True, True]])
    edges = jnp.asarray([[False, True, True]])
    afford = jnp.asarray([[0, 1, 1]], jnp.int32)
    take, spent, pending = ck.transfer_select(need, src, edges, afford)
    # striping: chunk 0 (2 holders, 0 mod 2) -> sender 1; chunk 1 (1 mod 2)
    # -> sender 2; chunk 2 (sole holder) -> sender 2, over budget -> pending
    np.testing.assert_array_equal(np.asarray(take), [[True, True, False]])
    np.testing.assert_array_equal(np.asarray(spent), [[0, 1, 1]])
    np.testing.assert_array_equal(np.asarray(pending), [[False, False, True]])


def test_transfer_select_single_holder_is_lowest_index_rule():
    """One holder per chunk: striping degenerates to the PR-4 assignment."""
    need = jnp.asarray([[True, True]])
    src = jnp.asarray([[True, True], [False, False]])
    edges = jnp.asarray([[True, True]])
    afford = jnp.asarray([[2, 2]], jnp.int32)
    take, spent, pending = ck.transfer_select(need, src, edges, afford)
    np.testing.assert_array_equal(np.asarray(take), [[True, True]])
    np.testing.assert_array_equal(np.asarray(spent), [[2, 0]])
    np.testing.assert_array_equal(np.asarray(pending), [[False, False]])


def test_striping_uses_parallel_links_to_distinct_holders():
    """Satellite acceptance: two holders of the same content drain a slot
    in HALF the ticks — distinct chunks ride distinct links — where the
    PR-4 lowest-indexed assignment left the second link idle."""
    cfg = BankGossipConfig(chunks_per_slot=4)
    payload = jnp.arange(8.0)
    # slot 32 B over 4 chunks; 8 B/tick/link = one chunk per link per tick
    striped = make_net(topo.full(3, bandwidth=64.0), bank_cfg=cfg)
    publish_on(striped, 0, 1, 0.1, params=payload)
    publish_on(striped, 1, 2, 0.2, params=payload)   # identical content:
    # dedup makes BOTH 0 and 1 effective holders of every needed chunk
    control = make_net(topo.full(3, bandwidth=64.0), bank_cfg=cfg)
    publish_on(control, 0, 1, 0.1, params=payload)
    publish_on(control, 1, 2, 0.2, params=jnp.arange(8.0) + 100.0)  # distinct
    striped.advance(2.0)
    control.advance(2.0)
    # two holders, 4 distinct digests, 2 links x 1 chunk/tick -> 2 ticks
    assert int(striped.missing_chunks()[2]) == 0
    # single holder per slot: each slot needs 4 ticks on its own link
    assert int(control.missing_chunks()[2]) > 0
    # both of node 2's inbound links were actually paid for the same slot
    sent = np.asarray(striped.bank_state.sent)
    assert sent[2, 0] > 0 and sent[2, 1] > 0


def test_nan_payload_still_transfers_at_physical_identity():
    """Regression: a payload that trained to NaN digests to NaN, which
    compares unequal even to ITSELF — physical presence must short-circuit
    the digest match or the row would be gated out everywhere forever,
    committer included."""
    dig = jnp.asarray([[jnp.nan], [jnp.nan]])
    have = jnp.asarray([[[True], [False]]])       # node holds chunk (0, 0)
    for impl in ("lax", "pallas"):
        sat = np.asarray(ck.chunk_dedup(have, dig, impl=impl))
        assert sat[0, 0, 0], impl                 # physically held -> available
        assert not sat[0, 1, 0], impl             # NaN never dedups cross-slot
    # end to end: a NaN model still gossips and the run converges
    cfg = BankGossipConfig(chunks_per_slot=2)
    net = make_net(topo.ring(3, bandwidth=1e9), bank_cfg=cfg)
    publish_on(net, 0, 1, 0.2, params=jnp.full((8,), jnp.nan))
    assert net.converge(at_time=10.0)
    assert net.missing_chunks().max() == 0
    assert net.synced()


def test_chunk_digests_content_addressing():
    a = {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((5,))}
    b = {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((5,))}
    c = {"w": jnp.arange(12.0).reshape(3, 4).at[1, 1].add(1e-3), "b": jnp.ones((5,))}
    da, db, dc = (np.asarray(bank_lib.chunk_digests(x, 4)) for x in (a, b, c))
    np.testing.assert_array_equal(da, db)          # identical content, same tags
    assert (da != dc).any()                        # a bit flip moves some tag


# ---------------------------------------------------------------------------
# GossipNetwork transport semantics
# ---------------------------------------------------------------------------


def genesis(num_nodes):
    d = dag_lib.empty_dag(CAP, K, num_nodes + 1)
    return dag_lib.publish(
        d, jnp.asarray(num_nodes, jnp.int32), jnp.float32(0.0),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )


def make_net(top, bank_cfg=None, sync_period=1.0, partition=None, seed=0,
             impl="fused"):
    return gossip_lib.GossipNetwork(
        genesis(top.num_nodes), bank=jnp.zeros((CAP, 8)), top=top,
        cfg=gossip_lib.GossipConfig(sync_period=sync_period, seed=seed, impl=impl),
        partition=partition, bank_cfg=bank_cfg,
    )


def publish_on(net, node, seq, t, params=None):
    d = net.read(node)
    d = replica_lib.publish_local(
        d, seq, jnp.asarray(node, jnp.int32), jnp.float32(t),
        jnp.full((K,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(seq % CAP, jnp.int32),
    )
    net.write(node, d)
    if net.bank_cfg is not None:
        if params is None:
            params = jnp.full((8,), float(seq))
        net.bank_commit(node, seq % CAP, params)


def assert_dags_equal(a, b, msg=""):
    for name in dag_lib.DagState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{msg}{name}",
        )


def test_finite_bandwidth_availability_lags_visibility():
    """slot = 32 B over 4 chunks; 8 B/s links move ONE chunk per tick, so a
    neighbor needs 4 ticks of payload for a row it saw after 1."""
    cfg = BankGossipConfig(chunks_per_slot=4)
    net = make_net(topo.ring(4, bandwidth=64.0), bank_cfg=cfg)   # 8 B/tick
    publish_on(net, 0, 1, 0.5)
    net.advance(1.0)
    assert int(net.missing_rows()[1]) == 0        # metadata arrived...
    assert int(net.missing_chunks()[1]) == 3      # ...3 of 4 chunks still owed
    for t in (2.0, 3.0, 4.0):
        net.advance(t)
    assert int(net.missing_chunks()[1]) == 0
    # the gated view hides the row until the payload completes
    net2 = make_net(topo.ring(4, bandwidth=64.0), bank_cfg=cfg)
    publish_on(net2, 0, 1, 0.5)
    net2.advance(1.0)
    assert int(net2.read(1).publisher[1]) == 0           # raw replica sees it
    assert int(net2.read_view(1).publisher[1]) == -1     # usable view does not
    assert int(net2.read_view(0).publisher[1]) == 0      # committer has chunks


def test_dedup_makes_identical_payload_free():
    """Same bytes at two slots: after the first slot's chunks arrive, the
    second costs zero transfer bytes (content addressing)."""
    cfg = BankGossipConfig(chunks_per_slot=4)
    payload = jnp.full((8,), 7.0)
    net = make_net(topo.ring(2, bandwidth=1e9), bank_cfg=cfg)
    publish_on(net, 0, 1, 0.2, params=payload)
    net.advance(1.0)
    bytes_first = net.bytes_sent()
    assert bytes_first > 0
    assert net.missing_chunks().max() == 0
    publish_on(net, 0, 2, 1.5, params=payload)    # identical content again
    net.advance(2.0)
    assert net.missing_chunks().max() == 0        # usable immediately...
    assert net.bytes_sent() == bytes_first        # ...and zero new bytes


def test_credit_rolls_over_for_subchunk_bandwidth():
    """A link slower than one chunk per tick banks partial progress: chunk
    bytes 8, capacity 3 B/tick -> the first chunk completes on the third
    tick the link fires (9 B accrued, 1 B residual kept)."""
    cfg = BankGossipConfig(chunks_per_slot=4)
    net = make_net(topo.ring(2, bandwidth=24.0), bank_cfg=cfg)   # 3 B/tick
    publish_on(net, 0, 1, 0.2)
    for t, expect in ((1.0, 4), (2.0, 4), (3.0, 3)):
        net.advance(t)   # the row is visible from tick 0; chunks trickle
        assert int(net.missing_chunks()[1]) == expect, t
    credit = np.asarray(net.bank_state.credit)
    assert 0.0 < credit[1, 0] < float(net._chunk_bytes)


def test_partition_blocks_chunks_then_heals():
    """Rows outrun payloads into a partition: metadata crosses before the
    split, in-flight chunks are stranded on the far side (credit pauses,
    not resets), and converge only drains them after healing — the
    bank-aware fixpoint predicate plus the drain-extended tick bound."""
    n = 4
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(n), t_start=1.5, t_end=6.5,
    )
    # slot 32 B over 2 chunks; 8 B/tick -> 2 ticks per chunk, 4 per slot
    cfg = BankGossipConfig(chunks_per_slot=2)
    net = make_net(topo.full(n, bandwidth=64.0), bank_cfg=cfg, partition=part)
    publish_on(net, 0, 1, 0.2)
    net.advance(1.0)           # pre-split tick: row visible EVERYWHERE...
    assert int(net.missing_rows().max()) == 0
    assert (net.missing_chunks() > 0).sum() == 3   # ...payloads still owed
    net.advance(5.0)           # split: node 1 drains from 0; 2 and 3 starve
    missing = net.missing_chunks()
    assert missing[1] == 0 and missing[2] > 0 and missing[3] > 0
    assert not net.converge(at_time=5.0)      # still split: fixpoint != sync
    assert net.converge(at_time=7.0)          # healed: payloads drain
    assert net.missing_chunks().max() == 0
    assert net.synced()


def test_zero_bandwidth_never_delivers_payload():
    cfg = BankGossipConfig(chunks_per_slot=2)
    net = make_net(topo.ring(3, bandwidth=0.0), bank_cfg=cfg)
    publish_on(net, 0, 1, 0.2)
    net.advance(10.0)
    assert int(net.missing_rows().max()) == 0      # rows still travel free
    assert (net.missing_chunks() > 0).sum() == 2   # payload never will
    assert not net.converge(at_time=20.0)          # stall-detected, honest


# ---------------------------------------------------------------------------
# THE acceptance invariant: unlimited capacity == PR-3 path, bitwise
# ---------------------------------------------------------------------------


IMPLS = ["fused", "scan"]


@pytest.mark.parametrize("impl", IMPLS)
def test_infinite_bandwidth_schedule_bitwise_equal(impl):
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(6), t_start=1.5, t_end=4.5,
    )
    a = make_net(topo.ring(6, drop=0.3, seed=3), partition=part, impl=impl)
    b = make_net(topo.ring(6, drop=0.3, seed=3), partition=part, impl=impl,
                 bank_cfg=BankGossipConfig(chunks_per_slot=4))
    for seq, node in ((1, 0), (2, 3), (3, 5)):
        publish_on(a, node, seq, 0.1 * seq)
        publish_on(b, node, seq, 0.1 * seq)
    for t in (1.0, 3.0, 6.0):
        a.advance(t)
        b.advance(t)
        assert_dags_equal(a.replicas.dags, b.replicas.dags, msg=f"t={t}:")
    assert a.converge(at_time=50.0) == b.converge(at_time=50.0)
    assert_dags_equal(a.replicas.dags, b.replicas.dags, msg="converge:")
    assert b.missing_chunks().max() == 0


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    overlay=st.sampled_from(["ring", "er", "star"]),
    impl=st.sampled_from(IMPLS),
    split=st.booleans(),
)
def test_property_infinite_bandwidth_bitwise(seed, overlay, impl, split):
    """Property (acceptance): any sync schedule over any overlay — losses,
    strides, partitions — leaves the dags trajectory bitwise unchanged by
    enabling bank gossip with unlimited capacity, and availability fully
    tracks visibility at every advance boundary."""
    n = 8
    builders = {
        "ring": lambda: topo.ring(n, drop=0.3, seed=seed % 997),
        "er": lambda: topo.erdos_renyi(n, 0.4, seed=seed % 997),
        "star": lambda: topo.star(n),
    }
    part = (
        gossip_lib.PartitionSchedule(
            assignment=topo.split_halves(n), t_start=1.0, t_end=3.0,
        ) if split else None
    )
    top = builders[overlay]()
    a = make_net(top, partition=part, seed=seed % 1013, impl=impl)
    b = make_net(top, partition=part, seed=seed % 1013, impl=impl,
                 bank_cfg=BankGossipConfig(chunks_per_slot=3))
    rng = np.random.default_rng(seed)
    for seq in range(1, 4):
        node = int(rng.integers(0, n))
        publish_on(a, node, seq, 0.1 * seq)
        publish_on(b, node, seq, 0.1 * seq)
    for t in (2.0, 5.0):
        a.advance(t)
        b.advance(t)
        assert_dags_equal(a.replicas.dags, b.replicas.dags, msg=f"t={t}:")
        # payload availability == row visibility in the infinite-bw limit
        sat = np.asarray(bank_lib.missing_chunks_jit(
            b.replicas.dags, b.replicas.bank_state, b._digest, impl=None))
        assert sat.max() == 0
    assert a.converge(at_time=20.0) == b.converge(at_time=20.0)
    assert_dags_equal(a.replicas.dags, b.replicas.dags, msg="converge:")


@pytest.mark.parametrize("impl", IMPLS)
def test_e2e_infinite_bandwidth_sim_bitwise(impl):
    """run_dagfl_gossip: bank gossip with unlimited capacity reproduces the
    PR-3 run exactly — curve, union ledger, and timing."""
    from repro.fl.experiments import default_dagfl_config, make_cnn_setup
    from repro.fl.systems import SimConfig, run_dagfl_gossip

    n = 8
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=10, eval_every=5, seed=0)
    results = []
    for bg in (None, BankGossipConfig(chunks_per_slot=4)):
        task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)
        results.append(run_dagfl_gossip(
            task, nodes, dcfg, sim, gval,
            topology=topo.ring(n, seed=0),
            gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=0, impl=impl),
            bank_gossip=bg,
        ))
    base, banked = results
    np.testing.assert_array_equal(base.accs, banked.accs)
    np.testing.assert_array_equal(base.times, banked.times)
    assert_dags_equal(base.extras["dag"], banked.extras["dag"], msg="union:")
    assert base.extras["sync_rounds"] == banked.extras["sync_rounds"]
    assert banked.extras["bank_missing_final"].max() == 0
    assert banked.extras["bank_bytes_sent"] > 0     # transport was accounted


def test_e2e_table1_bandwidth_runs_and_reports_lag():
    """Table-I priced links at bench scale: the sim stays finite and the
    transport metrics expose the payload lag and the byte bill."""
    from repro.fl.experiments import default_dagfl_config, make_cnn_setup
    from repro.fl.systems import SimConfig, run_dagfl_gossip

    n = 8
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=10, eval_every=5, seed=0)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=0)
    res = run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.ring(n, seed=0, bandwidth=1e4),   # starved uplink
        gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=0),
        bank_gossip=BankGossipConfig(chunks_per_slot=4, slot_bytes=7e6),
    )
    assert np.all(np.isfinite(res.accs))
    assert res.extras["bank_lag_curve"].shape[1] == 3
    assert res.extras["bank_missing_final"].max() > 0   # payload really lags
    assert res.extras["bank_bytes_sent"] >= 0


def test_bank_mesh_equivalence_in_subprocess():
    """Runs on every lane: forces 8 host devices in a child process and
    checks a finite-bandwidth bank-gossip schedule bitwise against the
    single-device network (the sharded tick all-gathers availability
    bitmaps, never payloads)."""
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import dag as dag_lib
        from repro.net import gossip as G, mesh as M, replica as R
        from repro.net import topology as topo
        from repro.net.bank import BankGossipConfig
        assert jax.device_count() == 8, jax.device_count()
        CAP, K = 16, 2
        d = dag_lib.empty_dag(CAP, K, 17)
        d = dag_lib.publish(d, jnp.asarray(16, jnp.int32), jnp.float32(0.0),
            jnp.full((K,), dag_lib.NO_TX, jnp.int32), jnp.float32(0.5),
            jnp.float32(0.0), jnp.asarray(0, jnp.int32))
        def net(mesh):
            return G.GossipNetwork(d, bank=jnp.zeros((CAP, 8)),
                top=topo.ring(16, drop=0.2, seed=1, bandwidth=96.0),
                cfg=G.GossipConfig(sync_period=1.0, seed=5),
                bank_cfg=BankGossipConfig(chunks_per_slot=4), mesh=mesh)
        a, b = net(None), net(M.make_gossip_mesh(nodes=2, model=4))
        for n_ in (a, b):
            dd = R.publish_local(n_.read(3), 1, jnp.asarray(3, jnp.int32),
                jnp.float32(0.1), jnp.full((K,), dag_lib.NO_TX, jnp.int32),
                jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(1, jnp.int32))
            n_.write(3, dd)
            n_.bank_commit(3, 1, jnp.full((8,), 2.0))
        a.advance(5.0); b.advance(5.0)
        assert a.converge(at_time=60.0) == b.converge(at_time=60.0)
        for f in dag_lib.DagState._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a.replicas.dags, f)),
                np.asarray(getattr(b.replicas.dags, f)), err_msg=f)
        for f in ("have", "credit", "sent"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a.replicas.bank_state, f)),
                np.asarray(getattr(b.replicas.bank_state, f)), err_msg=f)
        np.testing.assert_array_equal(a.missing_chunks(), b.missing_chunks())
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=1200,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout
