"""Bandwidth-limited model gossip: Table-I link classes on a 16-node ring.

    python examples/bandwidth_limited.py [--nodes 16]

Up to PR 3 the simulator's model bank was shared host-side: a transaction
was usable the instant its DAG row arrived, so payload transport — the
traffic Table I prices at phi / B per transfer — was free. With
``bank_gossip`` enabled (repro.net.bank) every node must actually RECEIVE a
model's content-addressed chunks over its links before Algorithm 2 may
select or approve the transaction, and each chunk is charged against the
link's bits/s budget.

This walkthrough runs the same 16-node ring sim over the Table-I link
classes (100 Mbps — the paper's B — down to an IoT-class 1 Mbps uplink)
with the paper's phi = 7 MB model, and shows how time-to-model-availability
decouples from row visibility as links shrink: rows still travel in one
sync tick per hop, but the models behind them arrive later and later, and
tips wait on payloads.
"""
import argparse

import numpy as np

from repro.fl.experiments import default_dagfl_config, make_cnn_setup
from repro.fl.systems import SimConfig, run_dagfl_gossip
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig
from repro.net.gossip import GossipConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--iterations", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    n = args.nodes

    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=args.iterations, eval_every=10, seed=args.seed)

    print(f"{n}-node ring, phi = 7 MB per model (Table I), sync period 1 s\n")
    print(f"{'link class':>20} {'peak lag':>9} {'final lag':>10} "
          f"{'GB moved':>9} {'final acc':>10}")

    curves = {}
    for cls, bits in topo.TABLE1_LINK_CLASSES.items():
        task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=args.seed)
        res = run_dagfl_gossip(
            task, nodes, dcfg, sim, gval,
            topology=topo.ring(n, seed=args.seed, bandwidth=bits),
            gossip=GossipConfig(sync_period=1.0, seed=args.seed),
            bank_gossip=BankGossipConfig(chunks_per_slot=4, slot_bytes=7e6),
        )
        lag = res.extras["bank_lag_curve"]
        curves[cls] = res
        peak = int(lag[:, 2].max()) if len(lag) else 0
        print(f"{cls:>20} {peak:>9d} "
              f"{int(res.extras['bank_missing_final'].max()):>10d} "
              f"{res.extras['bank_bytes_sent'] / 1e9:>9.2f} "
              f"{res.accs[-1]:>10.3f}")

    print("\nlag = max over nodes of model chunks referenced by the local "
          "ledger but not yet received;\nthe 'ideal' wire is the PR-3 "
          "behavior (payloads free) and must show zero lag everywhere.")

    # availability-vs-visibility timeline for the constrained class
    cls = "constrained_1mbps"
    res = curves[cls]
    print(f"\n{cls}: payload lag vs row divergence over the run")
    print("  iter    time   max_missing_rows   max_missing_chunks")
    rows = {int(i): int(m) for i, _, m in res.extras["divergence_curve"]}
    for it, t, lagv in res.extras["bank_lag_curve"]:
        print(f"  {int(it):4d}  {t:6.1f}s   {rows.get(int(it), 0):12d} "
              f"      {int(lagv):12d}")

    ideal = curves["ideal"]
    same = np.array_equal(ideal.accs, res.accs)
    if same:
        print("\nconstrained accuracy curve happened to match ideal at this "
              "scale — the gating still shows in the lag table above")
    else:
        print("\nconstrained accuracy curve diverged from ideal: payload "
              "starvation changed which tips Algorithm 2 could approve")


if __name__ == "__main__":
    main()
