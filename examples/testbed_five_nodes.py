"""§V.B testbed analogue: 5 worker nodes + a host controller (Fig. 12/13).

    python examples/testbed_five_nodes.py

The paper deploys 5 Alibaba-cloud nodes + a host running DAG-FL Controlling;
here the 5 nodes are processes-in-one (the event loop serializes their
iterations) with IID-ish local data and high "bandwidth" (no wireless model),
mirroring the testbed conditions. Expected (Fig. 13): DAG-FL on 5 nodes
reaches higher accuracy than single-node training under the same number of
per-node iterations.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DagFLConfig
from repro.core import Controller, make_dagfl_iteration
from repro.data import MnistLike, paper_partition
from repro.fl.tasks import bench_cnn_task


def main():
    task = bench_cnn_task()
    gen = MnistLike(image_size=16, seed=0)
    rng = np.random.default_rng(0)
    val = gen.balanced(rng, 256)
    vb = {"x": jnp.asarray(val.x), "y": jnp.asarray(val.y)}
    iterations = 100

    # --- single-node baseline (same per-node data budget) ------------------
    nodes = paper_partition(gen, 5, shard_size=40, uniform_per_node=40, seed=1)
    solo = task.init(jax.random.PRNGKey(0))
    tf = jax.jit(task.train_fn)
    ef = jax.jit(task.eval_fn)
    ds0 = nodes[0]
    for i in range(iterations // 5):
        idx = rng.integers(0, len(ds0.y), 32)
        solo, _ = tf(solo, {"x": jnp.asarray(ds0.x[idx]), "y": jnp.asarray(ds0.y[idx])},
                     jax.random.PRNGKey(i))
    solo_acc = float(ef(solo, vb))

    # --- DAG-FL on 5 nodes --------------------------------------------------
    cfg = DagFLConfig(num_nodes=5, capacity=64, alpha=3, k=2, tau_max=60.0)
    ctrl = Controller(cfg, task.eval_fn, target_accuracy=0.95)
    state = ctrl.genesis(task.init(jax.random.PRNGKey(0)), vb)
    it_fn = jax.jit(make_dagfl_iteration(cfg, task.eval_fn, task.train_fn))
    dag, bank = state.dag, state.bank
    for i in range(iterations):
        nid = i % 5
        ds = nodes[nid]
        idx = rng.integers(0, len(ds.y), 32)
        out = it_fn(dag, bank, nid, float(i) + 1.0, jax.random.PRNGKey(i),
                    {"x": jnp.asarray(ds.x[idx]), "y": jnp.asarray(ds.y[idx])}, vb)
        dag, bank = out.dag, out.bank
    state.dag, state.bank = dag, bank
    state = ctrl.check(state, jax.random.PRNGKey(9), iterations + 1.0, vb)

    print(f"single node ({iterations//5} iters): acc={solo_acc:.3f}")
    print(f"DAG-FL 5 nodes ({iterations} iters, {iterations//5}/node): "
          f"acc={state.best_accuracy:.3f}")
    print("testbed expectation (Fig. 13): DAG-FL >= single node", )


if __name__ == "__main__":
    main()
