"""Train AND serve: Poisson inference load on the gossiped model bank.

    python examples/serve_under_gossip.py [--nodes 8] [--rate 2.0]

The paper's deployment story (§III) is that devices keep answering
inference requests from their local model while DAG consensus proceeds
asynchronously. With ``run_dagfl_gossip(serve=ServeConfig(...))`` on the
continuous-time event engine, every node receives its own Poisson
request stream and serves fixed-slot batches from its
availability-gated bank view — a request sees only rows whose model
chunks have physically arrived over the node's links.

This walkthrough runs the same training sim over three Table-I link
classes with the paper's phi = 7 MB payload and shows the decoupling
the serving layer makes measurable: throughput stays pinned to the
offered rate on every class (serving reads the local view, it never
waits on the wire), while staleness-at-serve — union rows the serving
node was missing at each batch admit — grows as links shrink. A final
arm splits the overlay mid-run and shows the partition paid for in
served-model lag, not in dropped requests.
"""
import argparse

import numpy as np

from repro.fl.experiments import default_dagfl_config, make_cnn_setup
from repro.fl.systems import SimConfig, run_dagfl_gossip
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig
from repro.net.gossip import GossipConfig, PartitionSchedule
from repro.net.serve import ServeConfig


def run_one(args, bandwidth, partition=None, slot_bytes=7e6):
    n = args.nodes
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=args.iterations,
                    eval_every=max(args.iterations // 4, 1), seed=args.seed)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=args.seed)
    return run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.ring(n, seed=args.seed, bandwidth=bandwidth),
        # phi = 7 MB on a priced link generates thousands of drain events;
        # headroom over the events-per-advance backstop so a saturated
        # final advance can never strand late arrivals
        gossip=GossipConfig(sync_period=1.0, seed=args.seed,
                            max_events_per_advance=65536),
        bank_gossip=BankGossipConfig(chunks_per_slot=4,
                                     slot_bytes=slot_bytes),
        engine="events", partition=partition,
        serve=ServeConfig(rate=args.rate, slots=4, service_time=0.05),
    )


def show(tag, res):
    rep = res.extras["serve_report"]
    horizon = float(res.times[-1]) if len(res.times) else 1.0
    def fmt(x):
        return f"{x:6.2f}" if np.isfinite(x) else f"{'-':>6}"

    print(f"{tag:>20} {rep['served_total']:>7d} "
          f"{rep['served_total'] / max(horizon, 1e-9):>8.2f} "
          f"{rep['dropped_total']:>8d} "
          f"{fmt(rep['staleness_p50'])} {fmt(rep['staleness_p99'])} "
          f"{rep['staleness_max']:>6d} {res.accs[-1]:>9.3f}")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--iterations", type=int, default=16)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson requests per node per second")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"{args.nodes}-node ring, phi = 7 MB per model (Table I), "
          f"{args.rate:g} req/s/node, 4-slot batches\n")
    print(f"{'arm':>20} {'served':>7} {'req/s':>8} {'dropped':>8} "
          f"{'p50':>6} {'p99':>6} {'max':>6} {'final acc':>9}")

    for cls in ("ideal", "lte_10mbps", "constrained_1mbps"):
        show(cls, run_one(args, topo.TABLE1_LINK_CLASSES[cls]))

    print("\np50/p99/max = staleness-at-serve percentiles: union rows the "
          "serving node was\nmissing from its availability-gated view at "
          "each batch admit. Throughput holds\non every arm — requests "
          "never wait on the wire — but the staleness tail prices\nwhat "
          "the transport had not yet delivered.")

    # A mid-run split, priced at a bench-scale 175 KB payload so chunks
    # complete within the horizon (at phi = 7 MB the chunk backlog already
    # saturates the gate and the split cannot make the view any staler),
    # against its unpartitioned twin at the same scale.
    print("\nlte_10mbps at a 175 KB payload, split halves for the middle "
          "third vs healed:")
    print(f"{'arm':>20} {'served':>7} {'req/s':>8} {'dropped':>8} "
          f"{'p50':>6} {'p99':>6} {'max':>6} {'final acc':>9}")
    part = PartitionSchedule(
        assignment=topo.split_halves(args.nodes),
        t_start=args.iterations / 3.0,
        t_end=2.0 * args.iterations / 3.0,
    )
    bw = topo.TABLE1_LINK_CLASSES["lte_10mbps"]
    show("healed", run_one(args, bw, slot_bytes=1.75e5))
    rep = show("partitioned", run_one(args, bw, partition=part,
                                      slot_bytes=1.75e5))

    # the tail accrues across the window and drains after the heal
    t = rep["staleness_t"]
    s = rep["staleness_samples"]
    late = t >= part.t_start
    if late.any() and (~late).any():
        print(f"\npartitioned arm: mean staleness {s[~late].mean():.2f} "
              f"before the split vs {s[late].mean():.2f} from the split "
              f"through the post-heal catch-up")


if __name__ == "__main__":
    main()
