"""Asynchronous stragglers under the continuous-time event engine.

    python examples/async_stragglers.py [--nodes 12]

§IV models iteration completions per node: h_i = d0 + d1 scales with the
node's CPU frequency (Eqs. 5-7), so a wide ``cpu_freq_range`` makes the
low-frequency tail the stragglers. The tick simulator could only quantize
that asynchrony; here every completion fires at its exact instant over a
gossiped overlay (``repro.net.events.simulate_insystem_tips``): stragglers
publish late against stale views, the union tip count floats above the
Eq. (4) closed form, and the staleness curve shows how far replicas trail
the union between deliveries.
"""
import argparse

import numpy as np

from repro.configs.base import DagFLConfig
from repro.core import stability
from repro.fl.latency import LatencyModel
from repro.net import topology as topo
from repro.net.events import simulate_insystem_tips


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=12)
    ap.add_argument("--horizon", type=float, default=400.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    n = args.nodes

    # a 6x CPU-frequency spread: the paper's (1, 2) GHz band widened so the
    # slow tail really straggles (h_i spans ~6x across the population)
    cfg = DagFLConfig(num_nodes=n, alpha=5, k=2, cpu_freq_range=(0.5e9, 3e9))
    lat = LatencyModel.create(cfg, seed=args.seed)
    h = lat.h_all()
    f_mean = 0.5 * sum(cfg.cpu_freq_range)
    pred = stability.equilibrium_tips(cfg, f_mean)

    # k-regular overlay with real per-link latencies: deliveries fire at
    # each link's actual wire time, not a tick grid
    top = topo.k_regular(n, 4, link_latency=0.2, latency_jitter=0.3,
                         seed=args.seed)
    print(f"{n} nodes, h_i in [{h.min():.2f}, {h.max():.2f}] s "
          f"(mean {h.mean():.2f}); Eq.(4) L0 at mean f: {pred:.2f}")
    trace = simulate_insystem_tips(
        top, h=h, arrival_rate=cfg.arrival_rate, k=cfg.k,
        tau_max=cfg.tau_max, horizon=args.horizon, capacity=256,
        seed=args.seed, sync_period=0.25,
    )
    assert trace.overflow == 0, "queue/trace overflow — raise max_pending"

    print(f"\npublished {trace.published} transactions over "
          f"{args.horizon:.0f} s; union tip tail-mean "
          f"{trace.tail_mean(0.5):.2f} (Eq. 4 predicts {pred:.2f})")

    print("\n  time     tips   max_staleness_rows")
    step = max(len(trace.times) // 16, 1)
    for i in range(0, len(trace.times), step):
        print(f"  {trace.times[i]:6.1f}  {trace.tips[i]:5.0f}   "
              f"{trace.staleness[i]:4.0f}")

    # who published what: the slow tail publishes just as often (arrivals
    # are uniform) but each of its iterations holds reserved tips h_i
    # seconds longer — the straggler contribution to the tip float
    pub = np.asarray(trace.union.published_per_node)[:n]
    order = np.argsort(lat.freqs)
    print("\n  node   f [GHz]   h_i [s]   published")
    for i in order:
        tag = "  <- straggler" if lat.freqs[i] < 0.8e9 else ""
        print(f"  {i:4d}   {lat.freqs[i] / 1e9:6.2f}   {h[i]:6.2f}   "
              f"{pub[i]:5d}{tag}")


if __name__ == "__main__":
    main()
