"""Launch-script example: the multi-pod dry-run for one (arch x shape).

    python examples/multi_pod_dryrun.py --arch olmo-1b \
        --shape train_4k --mesh both

Thin wrapper over ``repro.launch.dryrun`` (which must own the process:
XLA device count is locked at first jax init).
"""
import os
import subprocess
import sys

if __name__ == "__main__":
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    args = sys.argv[1:] or ["--arch", "olmo-1b", "--shape", "train_4k", "--mesh", "single"]
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.dryrun", *args], env=env, cwd=repo
    ))
