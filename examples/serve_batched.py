"""Batched serving demo: prefill + streaming decode on a reduced arch.

    python examples/serve_batched.py --arch gemma-2b --tokens 16

Builds the KV cache for a batch of prompts (prefill path, chunked attention)
then greedily decodes N tokens per request with the single-token decode step
— the same code paths the decode_32k / long_500k dry-run shapes lower.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, T = args.batch, args.prompt_len, args.tokens

    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size)
    frontend = None
    if cfg.frontend_tokens:
        frontend = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_tokens, cfg.frontend_dim)
        )

    prefill = jax.jit(lambda p, t, f: model.prefill(
        p, t, f, cache_len=P + cfg.frontend_tokens + T + 1))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, prompts, frontend)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    out = [tok]
    for _ in range(T - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={B} prompt={P} decoded={T} tokens "
          f"in {dt:.2f}s ({B*T/dt:.1f} tok/s incl. compile)")
    for b in range(min(B, 2)):
        print(f"  request {b}: {toks[b].tolist()}")


if __name__ == "__main__":
    main()
