"""Quickstart: DAG-FL federating the paper's CNN task on synthetic MNIST.

    python examples/quickstart.py [--iterations 150]

Shows the whole public API surface: config -> data partition -> controller
genesis (Algorithm 1) -> per-node consensus iterations (Algorithm 2) ->
target-model extraction + anomaly report.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DagFLConfig
from repro.core import Controller, make_dagfl_iteration
from repro.core.anomaly import contribution_report
from repro.data import MnistLike, paper_partition
from repro.fl.tasks import bench_cnn_task


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=150)
    ap.add_argument("--nodes", type=int, default=20)
    args = ap.parse_args()

    task = bench_cnn_task()
    cfg = DagFLConfig(num_nodes=args.nodes, capacity=128, alpha=5, k=2,
                      tau_max=30.0, beta=1)
    gen = MnistLike(image_size=16, seed=0)
    nodes = paper_partition(gen, args.nodes, shard_size=30, uniform_per_node=30)
    rng = np.random.default_rng(0)
    val = gen.balanced(rng, 256)
    vb = {"x": jnp.asarray(val.x), "y": jnp.asarray(val.y)}

    ctrl = Controller(cfg, task.eval_fn, target_accuracy=0.9)
    state = ctrl.genesis(task.init(jax.random.PRNGKey(0)), vb)
    iteration = jax.jit(make_dagfl_iteration(cfg, task.eval_fn, task.train_fn))

    dag, bank = state.dag, state.bank
    for i in range(args.iterations):
        nid = int(rng.integers(0, args.nodes))
        ds = nodes[nid]
        idx = rng.integers(0, len(ds.y), 32)
        out = iteration(
            dag, bank, nid, float(i) + 1.0, jax.random.PRNGKey(i),
            {"x": jnp.asarray(ds.x[idx]), "y": jnp.asarray(ds.y[idx])}, vb,
        )
        dag, bank = out.dag, out.bank
        if (i + 1) % 25 == 0:
            state.dag, state.bank = dag, bank
            state = ctrl.check(state, jax.random.PRNGKey(1000 + i), float(i) + 1.5, vb)
            print(f"iter {i+1:4d}  published_acc={float(out.new_accuracy):.3f}  "
                  f"target_acc={state.best_accuracy:.3f}  done={state.done}")
            if state.done:
                print("ACC_0 reached — controller broadcast the end signal.")
                break

    rep = contribution_report(dag, m=0)
    print(f"mean contribution rate r = {float(rep.mean_rate):.3f}")
    print("quickstart complete.")


if __name__ == "__main__":
    main()
