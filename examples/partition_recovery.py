"""Partition/heal walkthrough: §III.A's per-node DAGs under a network split.

    python examples/partition_recovery.py [--nodes 12]

Each node runs Algorithm 2 against its OWN DAG replica on a ring overlay
(repro.net). Mid-run the overlay is partitioned into two halves: the sides
keep training against divergent ledgers (row visibility splits, duplicate
approvals accumulate across the two stale views), then the schedule heals
and anti-entropy gossip pulls every replica back to the union view.
"""
import argparse

import numpy as np

from repro.fl.experiments import default_dagfl_config, make_cnn_setup
from repro.fl.systems import SimConfig, run_dagfl, run_dagfl_gossip
from repro.net import topology as topo
from repro.net.gossip import GossipConfig, GossipNetwork, PartitionSchedule
from repro.net.replica import read_replica, replicas_synced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=12)
    ap.add_argument("--iterations", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    n = args.nodes

    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=args.iterations, eval_every=15, seed=args.seed)
    t_split, t_heal = args.iterations / 3.0, 2.0 * args.iterations / 3.0

    # --- ideal shared-ledger baseline -------------------------------------
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=args.seed)
    base = run_dagfl(task, nodes, dcfg, sim, gval)
    print(f"shared-ledger baseline: final acc {base.accs[-1]:.3f}")

    # --- ring overlay with a mid-run partition ----------------------------
    schedule = PartitionSchedule(
        assignment=topo.split_halves(n), t_start=t_split, t_end=t_heal
    )
    print(f"partitioning halves for t in [{t_split:.0f}, {t_heal:.0f}) ...")
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=args.seed)
    res = run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.ring(n),
        gossip=GossipConfig(sync_period=1.0, seed=args.seed),
        partition=schedule,
    )

    print("\n  iter    time   target_acc   max_missing_rows")
    for (it, t, miss), acc in zip(res.extras["divergence_curve"], res.accs):
        phase = "SPLIT" if t_split <= t < t_heal else "     "
        print(f"  {int(it):4d}  {t:6.1f}s      {acc:.3f}    {int(miss):4d}  {phase}")

    dup = res.extras["approvals_issued"] - res.extras["approvals_in_union"]
    print(f"\nfinal acc {res.accs[-1]:.3f} (baseline {base.accs[-1]:.3f}); "
          f"sync rounds {res.extras['sync_rounds']}; "
          f"approval credits lost to ring eviction: {dup}")

    # --- heal to fixpoint: all replicas become the identical DagState -----
    rs = res.extras["replicas"]
    net = GossipNetwork(
        read_replica(rs, 0), rs.bank, topo.ring(n), GossipConfig(sync_period=1.0)
    )
    net.replicas = rs
    before = net.missing_rows()
    net.converge(at_time=float("inf"))
    print(f"post-run anti-entropy flush: missing rows {before.tolist()} -> "
          f"{net.missing_rows().tolist()}; "
          f"replicas identical: {bool(replicas_synced(net.replicas.dags))}")


if __name__ == "__main__":
    main()
