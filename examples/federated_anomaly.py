"""Anomaly detection demo: 20% poisoning nodes vs DAG-FL's consensus.

    python examples/federated_anomaly.py

Reproduces the Table-IV mechanism live: poisoned transactions get isolated
(low approval counts) and their publishers' contribution rates collapse,
while Google FL (no defense) loses accuracy on the same population.
"""
import numpy as np

from repro.fl.experiments import abnormal_experiment


def main():
    res = abnormal_experiment(
        "cnn", abnormal="poisoning", num_abnormal=8,
        iterations=250, seed=0, systems=("dagfl", "google"),
    )
    dag = res["dagfl"]
    goo = res["google"]
    print(f"final accuracy: DAG-FL={dag.accs[-1]:.3f}  Google FL={goo.accs[-1]:.3f}")

    behaviors = np.asarray(dag.extras["behaviors"])
    rates = dag.extras["contribution_m0"][: len(behaviors)]
    bad = rates[behaviors == "poisoning"]
    good = rates[behaviors == "normal"]
    print(f"contribution rate: poisoning r0={bad.mean():.3f}  normal={good.mean():.3f}  "
          f"ratio={bad.mean()/good.mean():.3f}")
    flagged = (rates < 0.5 * good.mean()) & (behaviors == "poisoning")
    print(f"detected {flagged.sum()}/{(behaviors=='poisoning').sum()} poisoning nodes "
          f"at the 0.5*r threshold")


if __name__ == "__main__":
    main()
