"""End-to-end driver: DAG-FL-train a ~100M-param LM for a few hundred steps.

    python examples/train_driver.py [--steps 200]

Uses the SAME jitted ``dagfl_train_step`` that the multi-pod dry-run lowers
on the 2x16x16 mesh — here it runs on the host CPU with 4 federated nodes
over synthetic token streams. Validation accuracy (next-token, val shards)
should climb as the nodes' models co-train through the DAG frontier.
"""
import argparse

from repro.launch.train import run, small_100m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--nodes", type=int, default=4)
    args = ap.parse_args()
    cfg = small_100m()
    run(cfg, steps=args.steps, nodes=args.nodes, batch_per_node=2,
        seq_len=256, lr=3e-3, log_every=10)


if __name__ == "__main__":
    main()
