"""RWKV-6 (Finch) 7B — attention-free RNN with data-dependent decay
[arXiv:2404.05892]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="rwkv",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    attention="none",
    rwkv_head_dim=64,          # 64 wkv heads of dim 64
    norm="layernorm",
    act="gelu",                # channel-mix uses squared relu internally
    citation="arXiv:2404.05892 (Eagle and Finch: RWKV-5/6)",
)
