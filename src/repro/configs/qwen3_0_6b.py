"""Qwen3-0.6B — dense decoder with QK-norm and GQA [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1000000.0,
    tie_embeddings=True,
    citation="hf:Qwen/Qwen3-8B model card (Qwen3 family)",
)
