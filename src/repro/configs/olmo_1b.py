"""OLMo-1B — dense decoder, non-parametric LayerNorm [arXiv:2402.00838]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,           # MHA (GQA kv=16)
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparam_layernorm",  # OLMo uses LN without scale/bias
    act="swiglu",
    tie_embeddings=True,
    citation="arXiv:2402.00838 (OLMo: Accelerating the Science of LMs)",
)
