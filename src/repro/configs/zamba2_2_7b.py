"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54 Mamba2 layers; ONE shared full-attention block (weights reused) applied
every 6 layers, ssm_state=64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=80,              # expand*d_model / 64 head dim
    ssm_expand=2,
    shared_attn_every=6,
    norm="rmsnorm",
    act="gelu",
    citation="arXiv:2411.15242 (Zamba2 suite)",
)
