"""Kimi K2 — trillion-parameter MoE, 32B activated [arXiv:2501.kimi2].

Paper-table spec: 61L, d_model=7168, 64 heads (GQA kv=8), 384 routed experts
top-8 with expert hidden 2048, plus 1 shared expert; vocab 163840.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18432,                # dense hidden for the first dense layer
    moe_d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    num_shared_experts=1,
    experts_per_token=8,
    first_dense_layers=1,
    norm="rmsnorm",
    act="swiglu",
    citation="arXiv:2501.kimi2 (Kimi K2, paper-table spec)",
)
