"""The paper's OWN two FL tasks (Section V.A), as framework configs.

* CNN task  — 2x(5x5 conv + 2x2 maxpool) + FC-512 + softmax on 28x28x1 images
  (McMahan et al. CNN on MNIST). Here driven with the synthetic MNIST-like
  dataset (offline container), same shapes/class structure.
* LSTM task — 2-layer 256-unit char-level LSTM over 80-char lines, 8-dim
  embedding (McMahan et al. Shakespeare model), driven with the synthetic
  char corpus.

These are the models the DAG-FL simulation platform federates; they are small
on purpose (the paper runs them on phones).
"""
from dataclasses import dataclass, field
from typing import Tuple

from repro.configs.base import DagFLConfig


@dataclass(frozen=True)
class CNNTaskConfig:
    name: str = "dagfl-cnn"
    image_size: int = 28
    channels: Tuple[int, int] = (32, 64)
    kernel: int = 5
    fc_units: int = 512
    num_classes: int = 10
    learning_rate: float = 0.002
    dagfl: DagFLConfig = field(
        default_factory=lambda: DagFLConfig(
            tx_size_bits=7e6 * 8,          # phi   = 7 MB   (Table I)
            minibatch_size_bits=0.3e6 * 8,  # phi_0 = 0.3 MB
            valset_size_bits=0.3e6 * 8,     # phi_1 = 0.3 MB
            beta=1,
            minibatch=100,
        )
    )
    citation = "DAG-FL paper Table I / McMahan et al. 2017 CNN"


@dataclass(frozen=True)
class LSTMTaskConfig:
    name: str = "dagfl-lstm"
    seq_len: int = 80
    embed_dim: int = 8
    hidden: int = 256
    num_layers: int = 2
    vocab_size: int = 90            # printable chars
    learning_rate: float = 0.3
    dagfl: DagFLConfig = field(
        default_factory=lambda: DagFLConfig(
            tx_size_bits=3e6 * 8,           # phi   = 3 MB (Table I)
            minibatch_size_bits=9e3 * 8,    # phi_0 = 9 KB
            valset_size_bits=9e3 * 8,       # phi_1 = 9 KB
            beta=5,
            minibatch=100,
        )
    )
    citation = "DAG-FL paper Table I / McMahan et al. 2017 stacked char-LSTM"


CNN_TASK = CNNTaskConfig()
LSTM_TASK = LSTMTaskConfig()
