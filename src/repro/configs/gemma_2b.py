"""Gemma-2B — dense decoder, GeGLU, head_dim=256, MQA [arXiv:2403.08295]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,            # MQA on the 2B variant
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    norm="rmsnorm",
    act="geglu",
    tie_embeddings=True,
    citation="arXiv:2403.08295 (Gemma)",
)
