"""Config dataclasses for the DAG-FL framework.

Everything is a frozen dataclass so configs hash, compare, and serialize
cleanly; ``reduced()`` derives the CPU smoke-test variant required by the
assignment (2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Sequence-mixing families understood by the model zoo.
FAMILIES = ("dense", "moe", "rwkv", "hybrid", "audio", "vlm")

# Attention kinds. "none" => attention-free (rwkv).
ATTENTION_KINDS = ("full", "sliding_window", "mla", "none")

NORM_KINDS = ("rmsnorm", "layernorm", "nonparam_layernorm")
ACT_KINDS = ("swiglu", "geglu", "gelu")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # one of FAMILIES
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    num_heads: int = 0               # 0 for attention-free archs
    num_kv_heads: int = 0
    head_dim: int = 0                # 0 => d_model // num_heads
    attention: str = "full"
    window_size: int = 8192          # used when attention == "sliding_window"
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    # --- MLA (DeepSeek-V2 style) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0              # 0 => head_dim

    # --- norms / MLP ---
    norm: str = "rmsnorm"
    act: str = "swiglu"

    # --- MoE ---
    num_experts: int = 0             # routed experts; 0 => dense MLP
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # 0 => d_ff (per-expert hidden)
    router_aux_loss: float = 0.01
    first_dense_layers: int = 0      # DeepSeek keeps layer 0 dense
    moe_impl: str = "sorted"         # "sorted" (prod) | "dense" (oracle)

    # --- SSM / RWKV ---
    ssm_state: int = 0               # Mamba2 state size per head
    ssm_heads: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64

    # --- hybrid (Zamba2): one SHARED attention block applied every k layers
    shared_attn_every: int = 0       # 0 => no shared attention blocks

    # --- modality frontend stubs (audio / vlm) ---
    frontend_tokens: int = 0         # prepended embedding positions from stub
    frontend_dim: int = 0            # raw embedding dim from the (stubbed) encoder

    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    citation: str = ""

    # -- derived ----------------------------------------------------------
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim or self.resolved_head_dim()

    def uses_attention(self) -> bool:
        return self.attention != "none"

    def is_moe(self) -> bool:
        return self.num_experts > 0

    def sub_quadratic(self) -> bool:
        """True when a 500k-token decode is admissible (bounded state)."""
        return self.attention in ("none", "sliding_window") or self.family in (
            "rwkv",
            "hybrid",
        )

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4) if self.num_heads else 0
        num_kv = min(self.num_kv_heads, num_heads) if self.num_kv_heads else 0
        if num_kv and self.num_kv_heads == 1:
            num_kv = 1  # preserve MQA structure
        head_dim = 64 if self.resolved_head_dim() else 0
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d_model,
            d_ff=min(self.d_ff, 512),
            moe_d_ff=min(self.moe_d_ff, 256) if self.moe_d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            kv_lora_rank=min(self.kv_lora_rank, 32) if self.kv_lora_rank else 0,
            q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
            rope_head_dim=min(self.rope_head_dim, 32) if self.kv_lora_rank else self.rope_head_dim,
            v_head_dim=64 if self.v_head_dim else 0,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            shared_attn_every=min(self.shared_attn_every, 2) if self.shared_attn_every else 0,
            window_size=min(self.window_size, 64),
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend_tokens else 0,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Closed-form parameter count (total, incl. all experts)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        total = V * d  # embed
        if not self.tie_embeddings:
            total += V * d
        hd = self.resolved_head_dim()
        vhd = self.resolved_v_head_dim()
        per_layer = 0
        if self.uses_attention() and self.family not in ("rwkv",):
            if self.attention == "mla":
                r_kv, r_q = self.kv_lora_rank, (self.q_lora_rank or self.d_model)
                per_attn = (
                    d * self.q_lora_rank if self.q_lora_rank else 0
                ) + r_q * self.num_heads * (hd + self.rope_head_dim)
                per_attn += d * (r_kv + self.rope_head_dim)
                per_attn += r_kv * self.num_kv_heads * (hd + vhd)
                per_attn += self.num_heads * vhd * d
            else:
                per_attn = d * self.num_heads * hd
                per_attn += 2 * d * self.num_kv_heads * hd
                per_attn += self.num_heads * hd * d
            if self.shared_attn_every:
                # one shared block, counted once below
                pass
            else:
                per_layer += per_attn
        if self.family == "rwkv":
            # time-mix (r,k,v,g,o) + decay + channel-mix approx
            per_layer += 5 * d * d + 2 * d * self.d_ff + d * self.d_ff
        elif self.family == "hybrid":
            # Zamba2-style: Mamba2 mixer only per layer; the MLP lives in the
            # single SHARED attention block (counted once below).
            din = self.ssm_expand * d
            per_layer += d * (2 * din + 2 * self.ssm_heads * self.ssm_state) + din * d
        else:
            n_gate = 2 if self.act in ("swiglu", "geglu") else 1
            if self.is_moe():
                eff = self.moe_d_ff or self.d_ff
                moe = self.num_experts * (n_gate + 1) * d * eff
                moe += self.num_shared_experts * (n_gate + 1) * d * eff
                moe += d * self.num_experts  # router
                per_layer += moe
            else:
                per_layer += (n_gate + 1) * d * self.d_ff
        total += L * per_layer
        if self.shared_attn_every and self.num_heads:
            shared = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
            shared += self.num_heads * hd * d
            n_gate = 2 if self.act in ("swiglu", "geglu") else 1
            shared += (n_gate + 1) * d * self.d_ff  # shared block's MLP
            total += shared
        return total

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: only routed top-k)."""
        if not self.is_moe():
            return self.param_count()
        dense_like = replace(
            self,
            num_experts=self.experts_per_token,
            num_shared_experts=self.num_shared_experts,
        )
        return dense_like.param_count()


# ---------------------------------------------------------------------------
# Input shapes (assignment block)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# DAG-FL deployment configuration (paper Table I + Algorithm params)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DagFLConfig:
    """Parameters of Algorithms 1 & 2 and the Table-I platform constants."""

    num_nodes: int = 100
    alpha: int = 5                  # tips sampled & validated per iteration
    k: int = 2                      # tips aggregated/approved (k < alpha)
    tau_max: float = 20.0           # staleness threshold [s]
    beta: int = 1                   # local epochs per iteration
    minibatch: int = 100
    target_accuracy: float = 0.97   # ACC_0 of Algorithm 1
    isolation_m: int = 0            # <= m approvals => isolated transaction
    capacity: int = 512             # ledger slots (struct-of-arrays)

    # Table-I platform constants (used by the latency model / simulator)
    tx_size_bits: float = 7e6 * 8            # phi   (CNN task default, 7 MB)
    minibatch_size_bits: float = 0.3e6 * 8   # phi_0
    valset_size_bits: float = 0.3e6 * 8      # phi_1
    train_density: float = 500.0             # eta_0 [cycles/bit]
    validate_density: float = 160.0          # eta_1 [cycles/bit]
    cpu_freq_range: Tuple[float, float] = (1e9, 2e9)  # f [Hz]
    bandwidth: float = 100e6                 # B [bit/s]
    arrival_rate: float = 1.0                # lambda [iterations/s]

    def __post_init__(self):
        assert self.k < self.alpha, "paper requires k < alpha"

    def expected_tips(self, h: Optional[float] = None) -> float:
        """Eq. (4): L0 = k*lambda*h / (k-1)."""
        if h is None:
            h = self.iteration_delay()
        return self.k * self.arrival_rate * h / (self.k - 1)

    def iteration_delay(self, f: Optional[float] = None) -> float:
        """Eqs. (5)-(7): h = d0 + d1 at mean CPU frequency."""
        if f is None:
            f = 0.5 * (self.cpu_freq_range[0] + self.cpu_freq_range[1])
        d0 = self.train_density * self.minibatch_size_bits * self.beta / f
        d1 = self.validate_density * self.valset_size_bits * self.alpha / f
        return d0 + d1


# ---------------------------------------------------------------------------
# Training / serving hyperparams
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 0.002
    momentum: float = 0.9
    optimizer: str = "sgd"          # "sgd" | "momentum" | "adam"
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    remat: bool = True
    seed: int = 0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeSpec
    train: TrainConfig = field(default_factory=TrainConfig)
    dagfl: DagFLConfig = field(default_factory=DagFLConfig)
    fl_mode: str = "node"           # "node" (data-axis FL) | "pod" | "off"


def to_dict(cfg) -> dict:
    return dataclasses.asdict(cfg)
