"""Qwen2.5-14B — dense decoder, GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B card
(family spec scaled per assignment table)]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1000000.0,
    citation="hf:Qwen/Qwen2.5-0.5B (Qwen2.5 family)",
)
