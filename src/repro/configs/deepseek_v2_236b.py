"""DeepSeek-V2 236B — MoE with Multi-head Latent Attention [arXiv:2405.04434].

MLA: KV compressed to kv_lora_rank=512 (+64 decoupled RoPE dims); MoE with
2 shared + 160 routed experts, top-6 routing, expert hidden 1536.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA: all heads decompress from the latent
    head_dim=128,              # qk nope head dim
    v_head_dim=128,
    d_ff=12288,                # dense-MLP hidden (first dense layer)
    moe_d_ff=1536,             # per-expert hidden
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    num_experts=160,
    num_shared_experts=2,
    experts_per_token=6,
    first_dense_layers=1,
    norm="rmsnorm",
    act="swiglu",
    citation="arXiv:2405.04434 (DeepSeek-V2)",
)
