from repro.configs.base import (
    DagFLConfig,
    ModelConfig,
    RunConfig,
    SHAPES,
    ShapeSpec,
    TrainConfig,
)
from repro.configs.registry import (
    ARCHS,
    POD_GRANULARITY,
    get_arch,
    get_shape,
    list_archs,
    long_context_variant,
    pairs_for_dryrun,
)

__all__ = [
    "DagFLConfig",
    "ModelConfig",
    "RunConfig",
    "SHAPES",
    "ShapeSpec",
    "TrainConfig",
    "ARCHS",
    "POD_GRANULARITY",
    "get_arch",
    "get_shape",
    "list_archs",
    "long_context_variant",
    "pairs_for_dryrun",
]
