"""PaliGemma-3B — SigLIP vision encoder + Gemma decoder [arXiv:2407.07726].

The SigLIP ViT + projector are a STUB per the assignment: ``input_specs``
provides precomputed patch embeddings; this config is the Gemma LM backbone.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,            # MQA (gemma backbone)
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    norm="rmsnorm",
    act="geglu",
    frontend_tokens=256,       # 224px / 14 patch -> 256 patches from SigLIP
    frontend_dim=1152,         # SigLIP So400m width
    tie_embeddings=True,
    citation="arXiv:2407.07726 (PaliGemma)",
)
