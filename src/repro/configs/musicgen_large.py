"""MusicGen-Large — decoder-only LM over EnCodec tokens [arXiv:2306.05284].

The EnCodec tokenizer/codec is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings; this config is the transformer backbone.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,            # EnCodec codebook size
    norm="layernorm",
    act="gelu",
    frontend_tokens=256,        # conditioning frames from the stubbed codec
    frontend_dim=2048,
    citation="arXiv:2306.05284 (Simple and Controllable Music Generation)",
)
