"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec

from repro.configs.olmo_1b import CONFIG as _olmo_1b
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek_v2
from repro.configs.gemma_2b import CONFIG as _gemma_2b
from repro.configs.qwen3_0_6b import CONFIG as _qwen3
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi_k2
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.rwkv6_7b import CONFIG as _rwkv6
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.qwen2_5_14b import CONFIG as _qwen2_5

ARCHS: Dict[str, ModelConfig] = {
    cfg.name: cfg
    for cfg in (
        _olmo_1b,
        _deepseek_v2,
        _gemma_2b,
        _qwen3,
        _kimi_k2,
        _musicgen,
        _paligemma,
        _rwkv6,
        _zamba2,
        _qwen2_5,
    )
}

# Architectures whose full replica cannot live on one 16-device model group of
# v5e (16 GB HBM) -> DAG-FL node granularity is a whole pod (DESIGN.md §5).
POD_GRANULARITY = frozenset({"deepseek-v2-236b", "kimi-k2-1t-a32b"})


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def list_archs() -> List[str]:
    return sorted(ARCHS)


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Return the sub-quadratic variant used for ``long_500k`` (DESIGN.md §6).

    SSM/hybrid archs are already sub-quadratic; full-attention archs switch to
    the sliding-window attention variant (bounded KV cache). MLA keeps its
    latent cache but also windows at 500k.
    """
    from dataclasses import replace

    if cfg.sub_quadratic():
        return cfg
    return replace(cfg, attention="sliding_window", window_size=8192)


def pairs_for_dryrun():
    """All (arch, shape) combinations with the long_500k policy applied."""
    out = []
    for arch in list_archs():
        cfg = get_arch(arch)
        for shape_name, shape in SHAPES.items():
            mcfg = cfg
            if shape_name == "long_500k":
                mcfg = long_context_variant(cfg)
            out.append((arch, shape_name, mcfg, shape))
    return out
