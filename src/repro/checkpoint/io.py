"""Checkpointing: DAG state + model bank + params as .npz + msgpack meta.

Pytrees are flattened with jax.tree_util key-paths so restore round-trips
exact structures (dicts, NamedTuples, lists). No external deps beyond numpy
and msgpack.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def save_pytree(path: str, tree: Any, meta: Optional[Dict] = None) -> None:
    """Serialize an arbitrary pytree of arrays to ``<path>.npz``."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {f"leaf{i}": np.asarray(v) for i, (_, v) in enumerate(flat)}
    keys = [_keystr(p) for p, _ in flat]
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    np.savez(path, __keys__=np.array(json.dumps(keys)), **arrays)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def load_pytree(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (leaf order must match)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path, allow_pickle=False)
    keys = json.loads(str(data["__keys__"]))
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    t_keys = [_keystr(p) for p, _ in flat_t]
    if keys != t_keys:
        raise ValueError(
            f"checkpoint structure mismatch: {len(keys)} leaves vs {len(t_keys)}; "
            f"first diff: {next((a, b) for a, b in zip(keys, t_keys) if a != b)}"
        )
    leaves = [data[f"leaf{i}"] for i in range(len(keys))]
    cast = [
        np.asarray(l, dtype=t.dtype) if hasattr(t, "dtype") else l
        for l, (_, t) in zip(leaves, flat_t)
    ]
    return jax.tree_util.tree_unflatten(treedef, cast)


def load_meta(path: str) -> Optional[Dict]:
    p = path + ".meta.json"
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None
