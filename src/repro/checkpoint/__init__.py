from repro.checkpoint.io import load_meta, load_pytree, save_pytree

__all__ = ["load_meta", "load_pytree", "save_pytree"]
