from repro.fl.latency import LatencyModel
from repro.fl.nodes import (
    SimNode,
    backdoor_eval_set,
    build_char_population,
    build_population,
)
from repro.fl.systems import SYSTEMS, SimConfig, SimResult
from repro.fl.tasks import CNNTask, LSTMTask, bench_cnn_task, bench_lstm_task

__all__ = [
    "LatencyModel",
    "SimNode",
    "backdoor_eval_set",
    "build_char_population",
    "build_population",
    "SYSTEMS",
    "SimConfig",
    "SimResult",
    "CNNTask",
    "LSTMTask",
    "bench_cnn_task",
    "bench_lstm_task",
]
