"""Experiment drivers reproducing the paper's figures/tables at bench scale.

Each function returns plain dicts/lists ready for the benchmark CSV writers.
Scale: 100 nodes and a few hundred iterations by default (the paper runs
5000-10000); EXPERIMENTS.md §Repro discusses what carries over.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import DagFLConfig
from repro.data.synthetic import CharCorpus, MnistLike
from repro.fl.nodes import (
    backdoor_eval_set,
    build_char_population,
    build_population,
)
from repro.fl.systems import SimConfig, SimResult, run_async, run_block, run_dagfl, run_google
from repro.fl.tasks import bench_cnn_task, bench_lstm_task


def default_dagfl_config(num_nodes: int = 100, task: str = "cnn") -> DagFLConfig:
    """Table-I constants; phi/phi0/phi1 differ between the CNN and LSTM rows."""
    if task == "cnn":
        return DagFLConfig(num_nodes=num_nodes, capacity=192, tau_max=20.0,
                           alpha=5, k=2, beta=1)
    return DagFLConfig(
        num_nodes=num_nodes, capacity=192, tau_max=20.0, alpha=5, k=2, beta=5,
        tx_size_bits=3e6 * 8, minibatch_size_bits=9e3 * 8, valset_size_bits=9e3 * 8,
    )


def make_cnn_setup(num_nodes=100, abnormal="normal", num_abnormal=0, seed=0,
                   image_size=16):
    task = bench_cnn_task()
    gen = MnistLike(image_size=image_size, seed=seed)
    nodes = build_population(gen, num_nodes, abnormal, num_abnormal, seed=seed)
    rng = np.random.default_rng(seed + 31)
    gval = gen.balanced(rng, 256)
    return task, nodes, {"x": gval.x, "y": gval.y}, gen


def make_lstm_setup(num_nodes=100, abnormal="normal", num_abnormal=0, seed=0):
    task = bench_lstm_task()
    corpus = CharCorpus(num_roles=30, seed=seed)
    nodes = build_char_population(corpus, num_nodes, abnormal, num_abnormal, seed=seed)
    rng = np.random.default_rng(seed + 31)
    lines = corpus.lines(rng, 0, 48)
    for r in range(1, 6):
        lines = np.concatenate([lines, corpus.lines(rng, r, 48)])
    return task, nodes, {"tokens": lines}, corpus


def run_all_systems(task, nodes, dcfg, sim, gval) -> Dict[str, SimResult]:
    return {
        "dagfl": run_dagfl(task, nodes, dcfg, sim, gval),
        "async": run_async(task, nodes, dcfg, sim, gval),
        "block": run_block(task, nodes, dcfg, sim, gval),
        "google": run_google(task, nodes, dcfg, sim, gval),
    }


# ---------------------------------------------------------------------------
# Table II — iteration latency
# ---------------------------------------------------------------------------


def iteration_delay_experiment(task_name="cnn", iterations=100, seed=0) -> Dict[str, float]:
    if task_name == "cnn":
        task, nodes, gval, _ = make_cnn_setup(seed=seed)
    else:
        task, nodes, gval, _ = make_lstm_setup(seed=seed)
    dcfg = default_dagfl_config(task=task_name)
    sim = SimConfig(iterations=iterations, eval_every=iterations, seed=seed)
    res = run_all_systems(task, nodes, dcfg, sim, gval)
    # Table II reports wall-clock for 100 iterations; with Poisson arrivals the
    # wall-clock is ~ arrivals + pipeline latency, so report both.
    out = {}
    for name, r in res.items():
        out[f"{name}_avg_iter_latency_s"] = r.avg_latency
        out[f"{name}_wallclock_100_iters_s"] = float(r.times[-1])
    return out


# ---------------------------------------------------------------------------
# Fig. 5 — ideal-case convergence
# ---------------------------------------------------------------------------


def ideal_convergence_experiment(task_name="cnn", iterations=400, seed=0):
    if task_name == "cnn":
        task, nodes, gval, _ = make_cnn_setup(seed=seed)
    else:
        task, nodes, gval, _ = make_lstm_setup(seed=seed)
    dcfg = default_dagfl_config(task=task_name)
    sim = SimConfig(iterations=iterations, eval_every=25, seed=seed)
    return run_all_systems(task, nodes, dcfg, sim, gval)


# ---------------------------------------------------------------------------
# Fig. 6-10 — abnormal-node sweeps; Table III — attack success
# ---------------------------------------------------------------------------


def abnormal_experiment(
    task_name="cnn", abnormal="lazy", num_abnormal=20, iterations=400, seed=0,
    systems=("dagfl", "async", "block", "google"),
):
    if task_name == "cnn":
        task, nodes, gval, gen = make_cnn_setup(
            abnormal=abnormal, num_abnormal=num_abnormal, seed=seed
        )
    else:
        task, nodes, gval, gen = make_lstm_setup(
            abnormal=abnormal, num_abnormal=num_abnormal, seed=seed
        )
    dcfg = default_dagfl_config(task=task_name)
    sim = SimConfig(iterations=iterations, eval_every=25, seed=seed)
    from repro.fl.systems import SYSTEMS

    res = {name: SYSTEMS[name](task, nodes, dcfg, sim, gval) for name in systems}

    if abnormal == "backdoor" and task_name == "cnn":
        rng = np.random.default_rng(seed + 77)
        trig = backdoor_eval_set(gen, rng, 256)
        import jax.numpy as jnp

        tb = {k: jnp.asarray(v) for k, v in trig.items()}
        for name, r in res.items():
            r.extras["attack_success"] = float(task.attack_success_rate(r.final_params, tb))
    return res


# ---------------------------------------------------------------------------
# Table IV — contribution rates
# ---------------------------------------------------------------------------


def contribution_experiment(
    task_name="cnn", abnormal="poisoning", num_abnormal=10, iterations=400, seed=0
):
    res = abnormal_experiment(
        task_name, abnormal, num_abnormal, iterations, seed, systems=("dagfl",)
    )["dagfl"]
    behaviors = np.array(res.extras["behaviors"])
    late = f"late_contribution_m0" in res.extras
    published = res.extras["late_published" if late else "published"][: len(behaviors)]
    rows = {}
    for m in (0, 1):
        key = f"late_contribution_m{m}" if late else f"contribution_m{m}"
        rates = res.extras[key][: len(behaviors)]
        active = published > 0
        ab = active & (behaviors == abnormal)
        nm = active & (behaviors == "normal")
        r0 = float(np.mean(rates[ab])) if ab.any() else float("nan")
        r = float(np.mean(rates[active])) if active.any() else float("nan")
        rows[m] = {"r0": r0, "r": r, "ratio": r0 / r if r else float("nan"),
                   "r_normal": float(np.mean(rates[nm])) if nm.any() else float("nan")}
    return rows
