"""The paper's two FL task models (Section V.A), in JAX.

* CNN: 2x (5x5 conv -> 2x2 maxpool) -> FC(512) ReLU -> softmax(10)
  (McMahan et al. 2017 MNIST CNN, lr 0.002, cross-entropy).
* LSTM: 8-dim char embedding -> 2x LSTM(256) -> softmax per char
  (the stacked character LSTM, lr 0.3 in the paper).

Each task exposes the interface DAG-FL core consumes:
  init(key) -> params
  eval_fn(params, batch) -> accuracy in [0,1]
  train_fn(params, batch, key) -> (params, metrics)   # one epoch/minibatch
Sizes are configurable so benches can run a scaled-down variant on CPU.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import softmax_xent


# ---------------------------------------------------------------------------
# CNN task
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CNNTask:
    image_size: int = 28
    channels: Tuple[int, int] = (32, 64)
    kernel: int = 5
    fc_units: int = 512
    num_classes: int = 10
    learning_rate: float = 0.002

    def init(self, key) -> Dict:
        c1, c2 = self.channels
        k = self.kernel
        ks = jax.random.split(key, 4)
        fm = self.image_size // 4                   # two 2x2 pools
        fan1 = k * k * 1
        fan2 = k * k * c1
        fan3 = fm * fm * c2
        return {
            "conv1": jax.random.normal(ks[0], (k, k, 1, c1)) / math.sqrt(fan1),
            "b1": jnp.zeros((c1,)),
            "conv2": jax.random.normal(ks[1], (k, k, c1, c2)) / math.sqrt(fan2),
            "b2": jnp.zeros((c2,)),
            "fc": jax.random.normal(ks[2], (fan3, self.fc_units)) / math.sqrt(fan3),
            "bfc": jnp.zeros((self.fc_units,)),
            "out": jax.random.normal(ks[3], (self.fc_units, self.num_classes))
            / math.sqrt(self.fc_units),
            "bout": jnp.zeros((self.num_classes,)),
        }

    def logits(self, params, x):
        def conv(h, w, b):
            h = jax.lax.conv_general_dilated(
                h, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
            )
            h = jax.nn.relu(h + b)
            return jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )

        h = conv(x, params["conv1"], params["b1"])
        h = conv(h, params["conv2"], params["b2"])
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ params["fc"] + params["bfc"])
        return h @ params["out"] + params["bout"]

    def loss(self, params, batch):
        logits = self.logits(params, batch["x"])
        return softmax_xent(logits, batch["y"])

    def eval_fn(self, params, batch) -> jnp.ndarray:
        logits = self.logits(params, batch["x"])
        return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))

    def train_fn(self, params, batch, key):
        loss, grads = jax.value_and_grad(self.loss)(params, batch)
        params = jax.tree_util.tree_map(
            lambda p, g: p - self.learning_rate * g, params, grads
        )
        return params, {"loss": loss}

    def attack_success_rate(self, params, batch, target_shift: int = 1):
        """Backdoor metric (Table III): triggered images classified as y+1."""
        logits = self.logits(params, batch["x"])
        target = (batch["y"] + target_shift) % self.num_classes
        return jnp.mean((jnp.argmax(logits, -1) == target).astype(jnp.float32))


# ---------------------------------------------------------------------------
# LSTM task
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LSTMTask:
    vocab: int = 90
    embed_dim: int = 8
    hidden: int = 256
    num_layers: int = 2
    learning_rate: float = 0.3

    def init(self, key) -> Dict:
        ks = jax.random.split(key, 2 + self.num_layers)
        params = {
            "embed": jax.random.normal(ks[0], (self.vocab, self.embed_dim)) * 0.1,
            "out": jax.random.normal(ks[1], (self.hidden, self.vocab))
            / math.sqrt(self.hidden),
            "bout": jnp.zeros((self.vocab,)),
        }
        inp = self.embed_dim
        for l in range(self.num_layers):
            fan = inp + self.hidden
            params[f"lstm{l}"] = {
                "w": jax.random.normal(ks[2 + l], (fan, 4 * self.hidden)) / math.sqrt(fan),
                "b": jnp.zeros((4 * self.hidden,)),
            }
            inp = self.hidden
        return params

    def _lstm_layer(self, p, xs):
        """xs: (T, B, in) -> (T, B, hidden)."""
        B = xs.shape[1]
        h0 = jnp.zeros((B, self.hidden))
        c0 = jnp.zeros((B, self.hidden))

        def step(carry, x):
            h, c = carry
            z = jnp.concatenate([x, h], axis=-1) @ p["w"] + p["b"]
            i, f, g, o = jnp.split(z, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), h

        _, hs = jax.lax.scan(step, (h0, c0), xs)
        return hs

    def logits(self, params, tokens):
        """tokens (B, T) -> (B, T, V)."""
        x = params["embed"][tokens]                       # (B,T,E)
        xs = jnp.moveaxis(x, 1, 0)
        for l in range(self.num_layers):
            xs = self._lstm_layer(params[f"lstm{l}"], xs)
        hs = jnp.moveaxis(xs, 0, 1)
        return hs @ params["out"] + params["bout"]

    def loss(self, params, batch):
        tokens = batch["tokens"]
        logits = self.logits(params, tokens)[:, :-1]
        return softmax_xent(logits, tokens[:, 1:])

    def eval_fn(self, params, batch) -> jnp.ndarray:
        tokens = batch["tokens"]
        logits = self.logits(params, tokens)[:, :-1]
        pred = jnp.argmax(logits, -1)
        return jnp.mean((pred == tokens[:, 1:]).astype(jnp.float32))

    def train_fn(self, params, batch, key):
        loss, grads = jax.value_and_grad(self.loss)(params, batch)
        params = jax.tree_util.tree_map(
            lambda p, g: p - self.learning_rate * g, params, grads
        )
        return params, {"loss": loss}


def make_epoch_train(task):
    """One 'iteration' trains over several minibatches (an epoch, §V.A.1).

    Returns train_fn(params, batch, key) where each leaf of ``batch`` has a
    leading steps axis; single-step training is scanned over it.
    """

    def train(params, batch, key):
        steps = jax.tree_util.tree_leaves(batch)[0].shape[0]
        keys = jax.random.split(key, steps)

        def body(p, xs):
            kb, mb = xs
            p, m = task.train_fn(p, mb, kb)
            return p, m["loss"]

        params, losses = jax.lax.scan(body, params, (keys, batch))
        return params, {"loss": losses[-1]}

    return train


def bench_cnn_task() -> CNNTask:
    """Scaled-down CNN for CPU benches (EXPERIMENTS.md notes the scaling).

    lr 0.05 instead of the full-size task's 0.002: the scaled model needs a
    hotter step, but 0.2 diverges on the class-skewed paper partition (each
    node's epoch yanks the model toward its dominant class and accuracy
    oscillates at chance), which is what kept test_system xfailed.
    """
    return CNNTask(image_size=16, channels=(8, 16), fc_units=64, learning_rate=0.05)


def bench_lstm_task() -> LSTMTask:
    return LSTMTask(hidden=64, num_layers=2, learning_rate=0.3)
