"""Table-I latency model (Eqs. 5-7 + transmission + system-specific terms).

Every FL system in the simulator draws its timing from this model so the
Table-II comparison is apples-to-apples:

  d0 = eta0 * phi0 * beta / f_i          training delay        (Eq. 5)
  d1 = eta1 * phi1 * alpha / f_i         validation delay      (Eq. 6)
  t_tx = phi / B                         one model transfer
  PoW ~ Exp(mean 5 s)                    Block FL consensus    (Section V.A)
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import DagFLConfig


@dataclass
class LatencyModel:
    cfg: DagFLConfig
    freqs: np.ndarray             # (N,) per-node CPU frequency
    pow_mean: float = 5.0         # Section V.A: PoW solves in ~5 s
    block_collect: int = 5        # miner publishes after 5 tx ...
    block_timeout: float = 10.0   # ... or 10 s
    google_cohort: int = 10       # nodes per synchronous round

    @classmethod
    def create(cls, cfg: DagFLConfig, seed: int = 0) -> "LatencyModel":
        rng = np.random.default_rng(seed)
        lo, hi = cfg.cpu_freq_range
        return cls(cfg=cfg, freqs=rng.uniform(lo, hi, cfg.num_nodes))

    # --- Eq. (5)-(7) ------------------------------------------------------
    def _train_cycles(self) -> float:
        c = self.cfg
        return c.train_density * c.minibatch_size_bits * c.beta

    def _validate_cycles(self) -> float:
        c = self.cfg
        return c.validate_density * c.valset_size_bits * c.alpha

    def d0(self, node: int) -> float:
        return self._train_cycles() / self.freqs[node]

    def d1(self, node: int) -> float:
        return self._validate_cycles() / self.freqs[node]

    def h(self, node: int) -> float:
        return self.d0(node) + self.d1(node)

    def h_all(self) -> np.ndarray:
        """(N,) per-node Eq. (7) iteration delay h_i = d0_i + d1_i.

        The vector the continuous-time engine schedules completion events
        from (``repro.net.events.simulate_insystem_tips``): heterogeneous
        ``freqs`` make the low-frequency tail the §IV stragglers.
        Divides before summing so ``h_all()[i]`` is bitwise ``h(i)``.
        """
        return (self._train_cycles() / self.freqs
                + self._validate_cycles() / self.freqs)

    def tx_time(self) -> float:
        return self.cfg.tx_size_bits / self.cfg.bandwidth

    # --- per-system iteration delays ---------------------------------------
    def dagfl_iteration(self, node: int, lazy: bool = False) -> float:
        """Validate alpha tips + train + publish (models already local)."""
        train = 0.0 if lazy else self.d0(node)
        return self.d1(node) + train + self.tx_time()

    def google_iteration(self, node: int, lazy: bool = False) -> float:
        """Download global + train + upload (no validation burden)."""
        train = 0.0 if lazy else self.d0(node)
        return 2 * self.tx_time() + train

    def async_iteration(self, node: int, lazy: bool = False) -> float:
        train = 0.0 if lazy else self.d0(node)
        return 2 * self.tx_time() + train

    def block_iteration(self, node: int, lazy: bool = False) -> float:
        """Node-side only; miner adds collection wait + PoW + block bcast."""
        train = 0.0 if lazy else self.d0(node)
        return 2 * self.tx_time() + train

    def pow_time(self, rng: np.random.Generator) -> float:
        return float(rng.exponential(self.pow_mean))
