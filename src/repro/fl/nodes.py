"""Node population for the simulator: local data + behavior.

Behaviors (Section V.A.1):
  normal    — trains honestly.
  lazy      — skips training, republishes an existing model (reward farming).
  poisoning — local labels/tokens randomized (wrong data).
  backdoor  — CNN only: 5x5-ish white square trigger, label shifted +1;
              backdoor nodes also run the JOINT attack — they bias tip
              selection toward other backdoor nodes' transactions (§V.A.4).

Nodes are task-agnostic: local data is a dict of row-aligned arrays
({"x","y"} for CNN, {"tokens"} for the LSTM task).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.data.synthetic import (
    CharCorpus,
    MnistLike,
    NUM_CLASSES,
    VOCAB,
    add_backdoor_trigger,
    char_partition,
    paper_partition,
)

BEHAVIORS = ("normal", "lazy", "poisoning", "backdoor")


@dataclass
class SimNode:
    node_id: int
    behavior: str
    train: Dict[str, np.ndarray]
    test: Dict[str, np.ndarray]
    rng: np.random.Generator

    def _rows(self, d: Dict[str, np.ndarray]) -> int:
        return len(next(iter(d.values())))

    def minibatch(self, size: int) -> Dict[str, np.ndarray]:
        n = self._rows(self.train)
        idx = self.rng.integers(0, n, size)
        return {k: v[idx] for k, v in self.train.items()}

    def epoch(self, steps: int, size: int) -> Dict[str, np.ndarray]:
        """``steps`` stacked minibatches — one paper 'iteration' of training."""
        n = self._rows(self.train)
        idx = self.rng.integers(0, n, (steps, size))
        return {k: v[idx] for k, v in self.train.items()}

    def val_batch(self, size: int) -> Dict[str, np.ndarray]:
        n = self._rows(self.test)
        idx = self.rng.integers(0, n, size)          # with replacement: fixed shape
        return {k: v[idx] for k, v in self.test.items()}


def _assign_behaviors(num_nodes, abnormal, num_abnormal, rng):
    ids = set(rng.choice(num_nodes, size=num_abnormal, replace=False).tolist())
    return ["normal" if i not in ids else abnormal for i in range(num_nodes)]


def build_population(
    gen: MnistLike,
    num_nodes: int,
    abnormal: str = "normal",
    num_abnormal: int = 0,
    shard_size: int = 40,
    uniform_per_node: int = 40,
    test_frac: float = 0.25,
    backdoor_frac: float = 0.5,
    seed: int = 0,
) -> List[SimNode]:
    """CNN task: the paper's exact non-IID partition + behavior assignment."""
    data = paper_partition(gen, num_nodes, shard_size, uniform_per_node, seed=seed)
    rng = np.random.default_rng(seed + 7)
    behaviors = _assign_behaviors(num_nodes, abnormal, num_abnormal, rng)

    nodes = []
    for i in range(num_nodes):
        ds = data[i]
        n_test = max(8, int(len(ds.y) * test_frac))
        perm = rng.permutation(len(ds.y))
        te, tr = perm[:n_test], perm[n_test:]
        x_tr, y_tr = ds.x[tr].copy(), ds.y[tr].copy()
        behavior = behaviors[i]

        if behavior == "poisoning":
            y_tr = rng.integers(0, NUM_CLASSES, len(y_tr)).astype(y_tr.dtype)
        elif behavior == "backdoor":
            n_bd = int(len(y_tr) * backdoor_frac)
            pick = rng.choice(len(y_tr), n_bd, replace=False)
            sq = max(3, x_tr.shape[1] // 6)
            x_tr[pick] = add_backdoor_trigger(x_tr[pick], square=sq)
            y_tr[pick] = (y_tr[pick] + 1) % NUM_CLASSES

        nodes.append(
            SimNode(
                node_id=i,
                behavior=behavior,
                train={"x": x_tr, "y": y_tr},
                test={"x": ds.x[te], "y": ds.y[te]},
                rng=np.random.default_rng(seed * 1000 + i),
            )
        )
    return nodes


def build_char_population(
    corpus: CharCorpus,
    num_nodes: int,
    abnormal: str = "normal",
    num_abnormal: int = 0,
    lines_per_node: int = 64,
    test_frac: float = 0.25,
    seed: int = 0,
) -> List[SimNode]:
    """LSTM task: role-partitioned lines (backdoor not applicable — §V.A.1)."""
    assert abnormal != "backdoor", "paper runs backdoor nodes only on the CNN task"
    data = char_partition(corpus, num_nodes, lines_per_node, seed=seed)
    rng = np.random.default_rng(seed + 7)
    behaviors = _assign_behaviors(num_nodes, abnormal, num_abnormal, rng)

    nodes = []
    for i in range(num_nodes):
        lines = data[i]
        n_test = max(4, int(len(lines) * test_frac))
        perm = rng.permutation(len(lines))
        te, tr = perm[:n_test], perm[n_test:]
        tr_lines = lines[tr].copy()
        if behaviors[i] == "poisoning":
            tr_lines = rng.integers(0, VOCAB, tr_lines.shape).astype(tr_lines.dtype)
        nodes.append(
            SimNode(
                node_id=i,
                behavior=behaviors[i],
                train={"tokens": tr_lines},
                test={"tokens": lines[te]},
                rng=np.random.default_rng(seed * 1000 + i),
            )
        )
    return nodes


def backdoor_eval_set(gen: MnistLike, rng: np.random.Generator, n: int = 256):
    """Triggered clean images; attack succeeds if prediction = y+1 (§V.A.3)."""
    ds = gen.balanced(rng, n)
    sq = max(3, ds.x.shape[1] // 6)
    return {"x": add_backdoor_trigger(ds.x, square=sq), "y": ds.y}
