"""The FL systems of Section V, sharing one task/population/latency model.

* DAG-FL          — the paper's system (core consensus on a shared ledger).
* DAG-FL gossip   — same consensus, but each node works against its own DAG
                    replica synced by anti-entropy gossip over an overlay
                    (repro.net); the §III.A architecture under an imperfect
                    network. With an ideal wire it recovers plain DAG-FL.
* Google FL       — synchronous rounds of 10, FederatedAveraging [1].
* Asynchronous FL — server mixes each upload into the global model [7].
* Block FL        — 5 miner groups, candidate blocks (5 tx or 10 s), PoW [3].

Timing comes from the Table-I ``LatencyModel``; iteration starts follow the
paper's Poisson arrivals ("one node on average ready per second"). Google FL
serializes its cohort's transfers over the shared 100 Mbps medium, which is
what makes its rounds the slowest (Table II).
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DagFLConfig
from repro.core import Controller, make_dagfl_iteration
from repro.core.consensus import commit_prepared, make_dagfl_stages
from repro.core.anomaly import contribution_rates
from repro.fl.latency import LatencyModel
from repro.fl.nodes import SimNode
from repro.fl.tasks import make_epoch_train
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo_lib
from repro.net.bank import BankGossipConfig
from repro.obs import ObsConfig
from repro.obs import trace as obs_trace


@dataclass
class SimConfig:
    iterations: int = 400
    eval_every: int = 25
    minibatch: int = 32
    steps_per_iter: int = 4       # minibatches per 'iteration' (one local epoch)
    val_size: int = 64            # node-local validation batch (fixed shape)
    seed: int = 0
    async_mix: float = 0.5        # [7]-style server mixing coefficient
    block_margin: float = 0.2     # miner drops tx if acc < global_acc - margin
                                  # (loose: catches poisoned models, not the
                                  #  normal non-IID accuracy dip)
    backdoor_joint_bias: float = 3.0


@dataclass
class SimResult:
    system: str
    iters: np.ndarray
    times: np.ndarray
    accs: np.ndarray
    avg_latency: float            # mean per-iteration latency (Table II)
    final_params: Any
    extras: Dict = field(default_factory=dict)

    def acc_at(self, iteration: int) -> float:
        if len(self.iters) == 0:
            return 0.0
        i = np.searchsorted(self.iters, iteration, side="right") - 1
        return float(self.accs[max(i, 0)])


def _poisson_starts(rng, rate: float, n: int) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / rate, n))


def _jb(batch: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
    return {k: jnp.asarray(v) for k, v in batch.items()}


def _counter_snapshot(dag) -> Dict[str, np.ndarray]:
    """Raw cumulative counters (Table IV) at a point in time."""
    return dict(
        contribution_m0=np.asarray(dag.contributing_m0),
        contribution_m1=np.asarray(dag.contributing_m1),
        published=np.asarray(dag.published_per_node),
    )


def _late_contributions(dag, mid_snapshot: Dict, extras: Dict) -> None:
    """Second-half contribution rates from a mid-run counter snapshot.

    The paper's Table IV runs 10000 s; at bench scale the first half is
    pre-convergence fog where validation cannot yet separate abnormal models.
    """
    if not mid_snapshot:
        return
    pub_late = np.asarray(dag.published_per_node) - mid_snapshot["published"]
    for m in (0, 1):
        c_late = (
            np.asarray(getattr(dag, f"contributing_m{m}"))
            - mid_snapshot[f"contribution_m{m}"]
        )
        extras[f"late_contribution_m{m}"] = c_late / np.maximum(pub_late, 1)
    extras["late_published"] = pub_late


# ---------------------------------------------------------------------------
# DAG-FL: one event-driven Algorithm-2 loop, two ledger backends
# ---------------------------------------------------------------------------
#
# All jit wrappers live at module level (cached): a benchmark sweep that
# constructs a fresh backend/task per run used to re-trace prepare + commit
# every time; now equal configs and tasks (frozen dataclasses) share one
# trace.


@functools.lru_cache(maxsize=None)
def _jit_of(fn):
    """jit cache keyed by function identity — every backend instance using
    the same commit body shares one traced executable."""
    return jax.jit(fn)


def _identity_train(params, batch, key):
    """Lazy-node 'training' (§V.A): republish the aggregated model as-is."""
    return params, {}


def _build_stage_jits(dcfg, task, weighted):
    prep_normal, commit_fn = make_dagfl_stages(
        dcfg, task.eval_fn, make_epoch_train(task), weighted
    )
    prep_lazy, _ = make_dagfl_stages(dcfg, task.eval_fn, _identity_train, weighted)
    return jax.jit(prep_normal), jax.jit(prep_lazy), commit_fn


_stage_jits_cached = functools.lru_cache(maxsize=None)(_build_stage_jits)


def _stage_jits(dcfg, task, weighted):
    """(jitted prepare, jitted lazy prepare, commit body) for a run.

    ``DagFLConfig`` and the paper tasks are frozen dataclasses, so sweeps
    that rebuild an equal task per run hit the cache and stop re-tracing
    stages 1-3; an unhashable ad-hoc task just falls back to a fresh trace.
    """
    try:
        return _stage_jits_cached(dcfg, task, weighted)
    except TypeError:
        return _build_stage_jits(dcfg, task, weighted)


class _SharedLedger:
    """One instantly-consistent global DAG — the paper's idealized runtime."""

    name = "dagfl"

    def __init__(self, state, commit_fn):
        self.dag, self.bank = state.dag, state.bank
        self._commit = _jit_of(commit_fn)

    def view(self, node_id):
        return self.dag

    def advance(self, t):
        pass

    def commit(self, node_id, t1, prepared):
        self.dag, self.bank = self._commit(
            self.dag, self.bank, node_id, jnp.float32(t1), prepared
        )

    def union_dag(self):
        return self.dag

    def observe(self, done, t1, union):
        pass

    def extras(self, union):
        return {}


def _run_dagfl_events(task, nodes, dcfg, sim, global_val, weighted, make_backend):
    """Event-driven driver shared by ``run_dagfl`` and ``run_dagfl_gossip``:
    prepare (stages 1-3) at start time t0, commit (stage 4) at completion
    t1 = t0 + h — in-flight iterations overlap, so tips accumulate to the
    Eq.-4 equilibrium instead of being consumed serially. The backend
    decides what ledger state a node sees (global vs its own replica);
    keeping one copy of the loop is what guarantees the gossip system's
    ideal-wire limit stays exactly equivalent to the shared ledger."""
    rng = np.random.default_rng(sim.seed)
    lat = LatencyModel.create(dcfg, sim.seed)
    gv = _jb(global_val)
    N = len(nodes)

    ctrl = Controller(dcfg, task.eval_fn)
    params0 = task.init(jax.random.PRNGKey(sim.seed))
    state = ctrl.genesis(params0, gv)

    prep_normal, prep_lazy, commit_fn = _stage_jits(dcfg, task, weighted)
    backend = make_backend(state, commit_fn)

    if sim.iterations == 0:
        # no Poisson starts -> no commits: report the genesis state instead
        # of reaching the trailing eval with an unbound completion time
        union = backend.union_dag()
        extras = {
            "contribution_m0": np.asarray(contribution_rates(union, 0)),
            "contribution_m1": np.asarray(contribution_rates(union, 1)),
            "published": np.asarray(union.published_per_node),
            "behaviors": [n.behavior for n in nodes],
            "dag": union,
        }
        extras.update(backend.extras(union))
        empty = np.zeros((0,))
        return SimResult(backend.name, empty, empty, empty, 0.0, params0, extras)

    # joint backdoor attack: backdoor nodes up-weight backdoor publishers
    is_bd = np.array([n.behavior == "backdoor" for n in nodes] + [False])
    bd_bias = jnp.asarray(np.where(is_bd, sim.backdoor_joint_bias, 0.0), jnp.float32)
    zero_bias = jnp.zeros_like(bd_bias)

    starts = _poisson_starts(rng, dcfg.arrival_rate, sim.iterations)
    pending = []        # heap of (t1, seq, node_id, Prepared)
    curve, lats = [], []
    done = 0
    mid_snapshot = {}

    def _commit_one(t1, nid, prepared):
        nonlocal done
        backend.advance(t1)
        backend.commit(nid, t1, prepared)
        done += 1
        if done == sim.iterations // 2 and not mid_snapshot:
            mid_snapshot.update(_counter_snapshot(backend.union_dag()))

    def _check(t1):
        nonlocal state
        union = backend.union_dag()
        state.dag, state.bank = union, backend.bank
        state = ctrl.check(state, jax.random.PRNGKey(done), float(t1) + 1e-3, gv)
        curve.append((done, t1, state.best_accuracy))
        backend.observe(done, t1, union)

    for i, t0 in enumerate(starts):
        while pending and pending[0][0] <= t0:
            t1, _, nid, prepared = heapq.heappop(pending)
            _commit_one(t1, nid, prepared)
            if done % sim.eval_every == 0:
                _check(t1)
        backend.advance(t0)
        node = nodes[rng.integers(0, N)]
        lazy = node.behavior == "lazy"
        t1 = t0 + lat.dagfl_iteration(node.node_id, lazy=lazy)
        # telemetry hook: backends with an event trace record the iteration
        # span (PUBLISH at t0, duration t1 - t0) — a host-side note, free
        on_start = getattr(backend, "on_start", None)
        if on_start is not None:
            on_start(node.node_id, t0, t1)
        fn = prep_lazy if lazy else prep_normal
        bias = bd_bias if node.behavior == "backdoor" else zero_bias
        # defense hook: backends carrying fault state fold their rejection
        # credit into tip selection — log(1.0) = 0 for clean senders, so
        # without rejections this adds an exact zero and the trajectory is
        # untouched
        fb = getattr(backend, "fault_bias", lambda: None)()
        if fb is not None:
            bias = bias + fb
        prepared = fn(
            backend.view(node.node_id),
            backend.bank,
            jnp.float32(t0),
            jax.random.PRNGKey(sim.seed * 100003 + i),
            _jb(node.epoch(sim.steps_per_iter, sim.minibatch)),
            _jb(node.val_batch(sim.val_size)),
            bias,
        )
        heapq.heappush(pending, (t1, i, node.node_id, prepared))
        lats.append(t1 - t0)
    while pending:
        t1, _, nid, prepared = heapq.heappop(pending)
        _commit_one(t1, nid, prepared)
    _check(t1)

    union = state.dag
    extras = {
        "contribution_m0": np.asarray(contribution_rates(union, 0)),
        "contribution_m1": np.asarray(contribution_rates(union, 1)),
        "published": np.asarray(union.published_per_node),
        "behaviors": [n.behavior for n in nodes],
        "dag": union,
    }
    extras.update(backend.extras(union))
    _late_contributions(union, mid_snapshot, extras)
    it_arr, t_arr, a_arr = map(np.asarray, zip(*curve))
    return SimResult(
        backend.name, it_arr, t_arr, a_arr, float(np.mean(lats)),
        state.target_model if state.target_model is not None else params0, extras,
    )


def run_dagfl(
    task,
    nodes: List[SimNode],
    dcfg: DagFLConfig,
    sim: SimConfig,
    global_val: Dict[str, np.ndarray],
    weighted: bool = False,
) -> SimResult:
    return _run_dagfl_events(
        task, nodes, dcfg, sim, global_val, weighted,
        lambda state, commit_fn: _SharedLedger(state, commit_fn),
    )


# ---------------------------------------------------------------------------
# DAG-FL over a gossip overlay (repro.net)
# ---------------------------------------------------------------------------


def _gossip_commit(dag, bank, node_id, t_publish, prepared, seq):
    """Stage-4 commit against a node's LOCAL replica, at a global row.

    The same ``commit_prepared`` body as the shared ledger, addressed by
    ``replica.global_row`` instead of the replica-local count, so every
    replica stores this transaction at the same slot and ``dag.merge`` can
    reconcile by identity.
    """
    slot, new_count = replica_lib.global_row(dag, seq)
    return commit_prepared(
        dag, bank, node_id, t_publish, prepared, slot=slot, new_count=new_count
    )


class _GossipLedger:
    """Per-node replicas over a gossip overlay (repro.net)."""

    name = "dagfl_gossip"

    def __init__(self, state, topology, gossip, partition, mesh=None,
                 bank_gossip=None, obs=None, faults=None, serve=None):
        self.net = gossip_lib.GossipNetwork(
            state.dag, state.bank, topology, gossip, partition, mesh=mesh,
            bank_cfg=bank_gossip, obs_cfg=obs, faults_cfg=faults,
            serve_cfg=serve,
        )
        self.capacity = int(state.dag.publisher.shape[0])
        self.seq = int(state.dag.count)       # genesis consumed sequence 0
        self._commit = _jit_of(_gossip_commit)
        self.approvals_issued = 0
        self.divergence = []
        self.bank_lag = []

    @property
    def bank(self):
        return self.net.bank

    def view(self, node_id):
        # with the bank gossiped this is the node's USABLE view: rows whose
        # model chunks have not arrived are masked out, so Algorithm-2 tip
        # selection — and hence approvals — waits for the payload
        return self.net.read_view(node_id)

    def advance(self, t):
        self.net.advance(t)

    def on_start(self, node_id, t0, t1):
        # iteration span for the event trace (no-op without telemetry);
        # routes through the device ring under ObsConfig.device_spans
        self.net.trace_span(t0, obs_trace.KIND_PUBLISH, node_id, node_id,
                            t1 - t0)

    def commit(self, node_id, t1, prepared):
        dag_i = self.net.read(node_id)
        # distinct-approval accounting: a credit is "issued" only when this
        # node was not already an approver of the row in its own replica —
        # the same predicate publish_at's crossing scan applies, so in the
        # ideal-wire limit issued == what survives the union exactly
        rows = np.asarray(prepared.chosen_rows)
        appr = np.asarray(dag_i.approvers)
        self.approvals_issued += int(
            sum(1 for r in rows if r >= 0 and not appr[r, node_id])
        )
        # wire compression (repro.kernels.delta_codec): encode the commit
        # against the slot's pre-overwrite content, store the DEQUANTIZED
        # wire values (lossy error enters training exactly once, here) and
        # digest the ENCODED pytree so the spoof defense verifies the bytes
        # that actually cross the link. Identity codecs skip all of it —
        # the PR-7 commit path, bitwise.
        slot = self.seq % self.capacity
        codec = (self.net.bank_cfg.codec
                 if self.net.bank_cfg is not None else None)
        if codec is not None and not codec.is_identity:
            base = jax.tree_util.tree_map(lambda b: b[slot], self.net.bank)
            enc = codec.encode(prepared.new_params, base)
            prepared = prepared._replace(
                new_params=codec.decode(enc, base)
            )
        else:
            enc = prepared.new_params
        dag_i, bank = self._commit(
            dag_i, self.net.bank, node_id, jnp.float32(t1), prepared,
            jnp.int32(self.seq),
        )
        self.net.write(node_id, dag_i, bank)
        # transport accounting: the committer holds its own payload's
        # chunks; the ring-reused slot's old content leaves everyone else
        self.net.bank_commit(node_id, slot, enc)
        self.net.trace_span(t1, obs_trace.KIND_COMMIT, node_id, node_id,
                            float(self.seq))
        self.seq += 1

    def union_dag(self):
        return self.net.union()

    def fault_bias(self):
        """(N+1,) log-credit tip-selection bias from digest rejections.

        ``anomaly.rejection_credit`` over the fault layer's cumulative
        rejection matrix: a clean sender's credit is exactly 1.0 (zero
        bias — the honest trajectory is unperturbed), a quarantined
        spoofer's collapses toward the floor, down-weighting its tips in
        Algorithm-2 selection the same way the §VI.B credit extension
        does. The trailing slot covers publisher -1 (genesis). ``None``
        without a fault-state carry."""
        credit = self.net.rejection_credit()
        if credit is None:
            return None
        return jnp.log(jnp.concatenate([
            jnp.asarray(credit, jnp.float32), jnp.ones((1,), jnp.float32)
        ]))

    def observe(self, done, t1, union):
        self.divergence.append(
            (done, float(t1), int(self.net.missing_rows(union).max()))
        )
        if self.net.bank_cfg is not None:
            self.bank_lag.append(
                (done, float(t1), int(self.net.missing_chunks().max()))
            )

    def extras(self, union):
        out = {}
        if self.net.bank_cfg is not None:
            out = {
                # payload transport: chunks still owed vs what the run paid
                "bank_missing_final": self.net.missing_chunks(),
                "bank_bytes_sent": self.net.bytes_sent(),
                "bank_lag_curve": np.asarray(self.bank_lag, dtype=np.float64),
            }
        if self.net.obs_cfg is not None:
            # drained telemetry: metric series, trace, dispatch breakdown
            out["obs"] = self.net.obs_report()
        if self.net.faults_cfg is not None:
            # adversary post-mortem: roles, rejections, quarantine, ASR
            out["fault_report"] = self.net.fault_report()
        sr = self.net.serve_report()
        if sr is not None:
            # inference-load summary: per-node throughput counters plus
            # staleness-at-serve percentiles (repro.net.serve.report)
            out["serve_report"] = sr
        return out | {
            "replicas": self.net.replicas,
            "sync_rounds": self.net.rounds_run,
            "device_calls": self.net.device_calls,
            "dispatch_counts": dict(self.net.dispatch_counts),
            "events_processed": self.net.events_processed,
            "synced_final": self.net.synced(),
            "missing_rows_final": self.net.missing_rows(union),
            # approval deficit: distinct credits issued by committers vs
            # what survives the union — with the exact approver-set merge
            # the only loss channel left is ring eviction
            "approvals_issued": self.approvals_issued,
            "approvals_in_union": int(
                np.asarray(jnp.sum(union.approval_count * (union.publisher >= 0)))
            ),
            "divergence_curve": np.asarray(self.divergence, dtype=np.float64),
        }


def run_dagfl_gossip(
    task,
    nodes: List[SimNode],
    dcfg: DagFLConfig,
    sim: SimConfig,
    global_val: Dict[str, np.ndarray],
    weighted: bool = False,
    topology: Optional[topo_lib.Topology] = None,
    gossip: Optional[gossip_lib.GossipConfig] = None,
    partition: Optional[gossip_lib.PartitionSchedule] = None,
    mesh=None,
    bank_gossip: Optional[BankGossipConfig] = None,
    engine: Optional[str] = None,
    obs: Optional[ObsConfig] = None,
    faults=None,
    serve=None,
) -> SimResult:
    """DAG-FL where each node runs Algorithm 2 against its own DAG replica.

    ``prepare`` (stages 1-3) reads the node's LOCAL view at iteration start;
    ``commit`` (stage 4) publishes locally; anti-entropy sync ticks are
    interleaved into the event timeline (``GossipNetwork.advance``). The
    external agent E evaluates the union of all replicas — with an ideal
    wire (``sync_period <= 0``, drop 0, connected overlay) this reduces
    exactly to ``run_dagfl``; with finite sync periods, losses, or a
    partition schedule, tip staleness, duplicate approvals across stale
    views, and partition/heal convergence become measurable in ``extras``.
    ``mesh`` (repro.net.mesh) shards the replica set's receiver axis over
    the mesh's "nodes" axis — bitwise the same simulation, run across
    devices.

    ``bank_gossip`` (repro.net.bank) makes MODEL PAYLOAD transport explicit:
    chunk availability gossips alongside the rows, each transfer is charged
    against the overlay's Table-I per-link bandwidth
    (``Topology.bandwidth``), and a node's view only shows transactions
    whose model chunks have arrived — Algorithm-2 approvals wait for the
    payload. With unlimited per-link capacity this is BITWISE the
    ``bank_gossip=None`` run for every round impl and mesh (the chunk step
    is deterministic and leaves the PRNG stream untouched); with Table-I
    budgets, time-to-model-availability (``extras["bank_lag_curve"]``) and
    the byte bill (``extras["bank_bytes_sent"]``) become measurable.

    ``engine`` overrides the transport clock (``GossipConfig.engine``):
    "ticks" is the quantized stride model (the default, bitwise what it
    was); "events" runs the continuous-time engine (``repro.net.events``)
    — sync messages cross each link at its ACTUAL latency and bank chunks
    drain at whole-chunk completion instants. With a uniform per-edge
    delay equal to the sync period the two engines are bitwise identical
    (CI-enforced); heterogeneous latencies make the difference measurable.

    ``obs`` (``repro.obs.ObsConfig``) turns on device-resident telemetry:
    metric accumulators and an event trace ring ride the jitted sync loops
    as pure reads, drained into ``extras["obs"]`` (an ``ObsReport`` —
    Chrome-trace / JSONL export via ``repro.obs.export``). Collection
    never perturbs the trajectory: the obs-on run is bitwise the obs-off
    run (CI-enforced).

    ``faults`` (``repro.net.faults.FaultConfig``) injects Byzantine roles
    into the sync transport — crash/churn windows, eclipse adjacency
    rewrites, selective forwarding, payload spoofing, sybil approval
    inflation — with digest verification + quarantine as the defense.
    ``faults=None`` (and an all-honest config) leaves every path bitwise
    what it was; adversarial runs surface ``extras["fault_report"]`` and
    fold rejection credit into tip selection (``fault_bias``).

    ``serve`` (``repro.net.serve.ServeConfig``) adds per-node Poisson
    inference load to the continuous-time engine: requests arrive at each
    node, batch onto fixed slots, and are answered from the node's
    availability-GATED view — so staleness-at-serve-time is the
    transport's doing. ``serve=None`` and any ``rate<=0`` config leave
    every path bitwise what it was (CI-enforced); serving runs surface
    ``extras["serve_report"]`` (per-node throughput + staleness
    percentiles). Requires ``engine="events"``.
    """
    if topology is None:
        topology = topo_lib.full(len(nodes))
    if gossip is None:
        gossip = gossip_lib.GossipConfig(sync_period=1.0, seed=sim.seed)
    if engine is not None:
        gossip = dataclasses.replace(gossip, engine=engine)
    return _run_dagfl_events(
        task, nodes, dcfg, sim, global_val, weighted,
        lambda state, commit_fn: _GossipLedger(
            state, topology, gossip, partition, mesh=mesh,
            bank_gossip=bank_gossip, obs=obs, faults=faults, serve=serve,
        ),
    )


# ---------------------------------------------------------------------------
# Google FL (synchronous rounds)
# ---------------------------------------------------------------------------


def run_google(
    task, nodes: List[SimNode], dcfg: DagFLConfig, sim: SimConfig,
    global_val: Dict[str, np.ndarray],
) -> SimResult:
    rng = np.random.default_rng(sim.seed)
    lat = LatencyModel.create(dcfg, sim.seed)
    gv = _jb(global_val)
    N, cohort = len(nodes), lat.google_cohort
    params = task.init(jax.random.PRNGKey(sim.seed))
    train = jax.jit(make_epoch_train(task))
    evalf = jax.jit(task.eval_fn)

    t, done, curve, lats = 0.0, 0, [], []
    while done < sim.iterations:
        sel = rng.choice(N, size=cohort, replace=False)
        # shared-medium: cohort downloads then uploads serialize (2*c*tx);
        # training runs in parallel (max d0)
        d0s = [0.0 if nodes[s].behavior == "lazy" else lat.d0(s) for s in sel]
        round_time = 2 * cohort * lat.tx_time() + max(d0s)
        locals_ = []
        for s in sel:
            node = nodes[s]
            if node.behavior == "lazy":
                locals_.append(params)                    # re-uploads the global
            else:
                p, _ = train(params, _jb(node.epoch(sim.steps_per_iter, sim.minibatch)),
                             jax.random.PRNGKey(done + s))
                locals_.append(p)
        params = jax.tree_util.tree_map(
            lambda *xs: sum(x.astype(jnp.float32) for x in xs) / len(xs), *locals_
        )
        t += round_time
        done += cohort
        lats.extend([round_time] * cohort)               # every member waits the round
        if (done // cohort) % max(sim.eval_every // cohort, 1) == 0 or done >= sim.iterations:
            curve.append((done, t, float(evalf(params, gv))))

    it_arr, t_arr, a_arr = map(np.asarray, zip(*curve))
    return SimResult("google", it_arr, t_arr, a_arr, float(np.mean(lats)), params)


# ---------------------------------------------------------------------------
# Asynchronous FL (server-side mixing, Xie et al. [7])
# ---------------------------------------------------------------------------


def run_async(
    task, nodes: List[SimNode], dcfg: DagFLConfig, sim: SimConfig,
    global_val: Dict[str, np.ndarray],
) -> SimResult:
    rng = np.random.default_rng(sim.seed)
    lat = LatencyModel.create(dcfg, sim.seed)
    gv = _jb(global_val)
    N = len(nodes)
    params = task.init(jax.random.PRNGKey(sim.seed))
    train = jax.jit(make_epoch_train(task))
    evalf = jax.jit(task.eval_fn)
    mix = sim.async_mix

    starts = _poisson_starts(rng, dcfg.arrival_rate, sim.iterations)
    curve, lats = [], []
    for i, t0 in enumerate(starts):
        node = nodes[rng.integers(0, N)]
        lazy = node.behavior == "lazy"
        t1 = t0 + lat.async_iteration(node.node_id, lazy=lazy)
        if lazy:
            local = params
        else:
            local, _ = train(params, _jb(node.epoch(sim.steps_per_iter, sim.minibatch)),
                             jax.random.PRNGKey(sim.seed * 7919 + i))
        params = jax.tree_util.tree_map(
            lambda g, l: ((1 - mix) * g.astype(jnp.float32) + mix * l.astype(jnp.float32)).astype(g.dtype),
            params, local,
        )
        lats.append(t1 - t0)
        if (i + 1) % sim.eval_every == 0 or i == sim.iterations - 1:
            curve.append((i + 1, t1, float(evalf(params, gv))))

    it_arr, t_arr, a_arr = map(np.asarray, zip(*curve))
    return SimResult("async", it_arr, t_arr, a_arr, float(np.mean(lats)), params)


# ---------------------------------------------------------------------------
# Block FL (miners + PoW, Kim et al. [3])
# ---------------------------------------------------------------------------


def run_block(
    task, nodes: List[SimNode], dcfg: DagFLConfig, sim: SimConfig,
    global_val: Dict[str, np.ndarray], num_miners: int = 5,
) -> SimResult:
    rng = np.random.default_rng(sim.seed)
    lat = LatencyModel.create(dcfg, sim.seed)
    gv = _jb(global_val)
    N = len(nodes)
    params = task.init(jax.random.PRNGKey(sim.seed))
    train = jax.jit(make_epoch_train(task))
    evalf = jax.jit(task.eval_fn)

    miner_of = {i: i % num_miners for i in range(N)}
    collected: List[List[Any]] = [[] for _ in range(num_miners)]
    first_ts: List[Optional[float]] = [None] * num_miners
    pow_until: List[float] = [0.0] * num_miners          # busy mining until t
    global_acc = float(evalf(params, gv))

    starts = _poisson_starts(rng, dcfg.arrival_rate, sim.iterations)
    curve, lats, dropped = [], [], 0
    for i, t0 in enumerate(starts):
        node = nodes[rng.integers(0, N)]
        m = miner_of[node.node_id]
        lazy = node.behavior == "lazy"
        t1 = t0 + lat.block_iteration(node.node_id, lazy=lazy)
        lats.append(t1 - t0)
        if lazy:
            local = params
        else:
            local, _ = train(params, _jb(node.epoch(sim.steps_per_iter, sim.minibatch)),
                             jax.random.PRNGKey(sim.seed * 104729 + i))

        if t1 < pow_until[m]:
            dropped += 1                                  # miner busy mining: tx lost
        else:
            # miner validates with the full test set (Section V.A.1)
            acc = float(evalf(local, gv))
            if acc >= global_acc - sim.block_margin:
                collected[m].append(local)
                if first_ts[m] is None:
                    first_ts[m] = t1
            # block trigger: 5 tx or 10 s since first
            if collected[m] and (
                len(collected[m]) >= lat.block_collect
                or t1 - (first_ts[m] or t1) >= lat.block_timeout
            ):
                mine = lat.pow_time(rng)
                pow_until[m] = t1 + mine
                # the block extends the chain: previous global is a member of
                # the average (keeps small blocks from thrashing the model)
                stacked = [params] + collected[m]
                params = jax.tree_util.tree_map(
                    lambda *xs: sum(x.astype(jnp.float32) for x in xs) / len(xs), *stacked
                )
                global_acc = float(evalf(params, gv))
                collected[m], first_ts[m] = [], None

        if (i + 1) % sim.eval_every == 0 or i == sim.iterations - 1:
            curve.append((i + 1, t1, global_acc))

    it_arr, t_arr, a_arr = map(np.asarray, zip(*curve))
    return SimResult(
        "block", it_arr, t_arr, a_arr, float(np.mean(lats)), params,
        {"dropped": dropped},
    )


SYSTEMS: Dict[str, Callable] = {
    "dagfl": run_dagfl,
    "dagfl_gossip": run_dagfl_gossip,
    "google": run_google,
    "async": run_async,
    "block": run_block,
}
