"""The four FL systems of Section V, sharing one task/population/latency model.

* DAG-FL          — the paper's system (core consensus on a shared ledger).
* Google FL       — synchronous rounds of 10, FederatedAveraging [1].
* Asynchronous FL — server mixes each upload into the global model [7].
* Block FL        — 5 miner groups, candidate blocks (5 tx or 10 s), PoW [3].

Timing comes from the Table-I ``LatencyModel``; iteration starts follow the
paper's Poisson arrivals ("one node on average ready per second"). Google FL
serializes its cohort's transfers over the shared 100 Mbps medium, which is
what makes its rounds the slowest (Table II).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DagFLConfig
from repro.core import Controller, make_dagfl_iteration
from repro.core.consensus import make_dagfl_stages
from repro.core.anomaly import contribution_rates
from repro.fl.latency import LatencyModel
from repro.fl.nodes import SimNode
from repro.fl.tasks import make_epoch_train


@dataclass
class SimConfig:
    iterations: int = 400
    eval_every: int = 25
    minibatch: int = 32
    steps_per_iter: int = 4       # minibatches per 'iteration' (one local epoch)
    val_size: int = 64            # node-local validation batch (fixed shape)
    seed: int = 0
    async_mix: float = 0.5        # [7]-style server mixing coefficient
    block_margin: float = 0.2     # miner drops tx if acc < global_acc - margin
                                  # (loose: catches poisoned models, not the
                                  #  normal non-IID accuracy dip)
    backdoor_joint_bias: float = 3.0


@dataclass
class SimResult:
    system: str
    iters: np.ndarray
    times: np.ndarray
    accs: np.ndarray
    avg_latency: float            # mean per-iteration latency (Table II)
    final_params: Any
    extras: Dict = field(default_factory=dict)

    def acc_at(self, iteration: int) -> float:
        if len(self.iters) == 0:
            return 0.0
        i = np.searchsorted(self.iters, iteration, side="right") - 1
        return float(self.accs[max(i, 0)])


def _poisson_starts(rng, rate: float, n: int) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / rate, n))


def _jb(batch: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
    return {k: jnp.asarray(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# DAG-FL
# ---------------------------------------------------------------------------


def run_dagfl(
    task,
    nodes: List[SimNode],
    dcfg: DagFLConfig,
    sim: SimConfig,
    global_val: Dict[str, np.ndarray],
    weighted: bool = False,
) -> SimResult:
    rng = np.random.default_rng(sim.seed)
    lat = LatencyModel.create(dcfg, sim.seed)
    gv = _jb(global_val)
    N = len(nodes)

    ctrl = Controller(dcfg, task.eval_fn)
    params0 = task.init(jax.random.PRNGKey(sim.seed))
    state = ctrl.genesis(params0, gv)
    dag, bank = state.dag, state.bank

    identity_train = lambda p, b, k: (p, {})
    epoch_train = make_epoch_train(task)
    prep_normal, commit = make_dagfl_stages(dcfg, task.eval_fn, epoch_train, weighted)
    prep_lazy, _ = make_dagfl_stages(dcfg, task.eval_fn, identity_train, weighted)
    prep_normal, prep_lazy = jax.jit(prep_normal), jax.jit(prep_lazy)
    commit = jax.jit(commit)

    # joint backdoor attack: backdoor nodes up-weight backdoor publishers
    is_bd = np.array([n.behavior == "backdoor" for n in nodes] + [False])
    bd_bias = jnp.asarray(np.where(is_bd, sim.backdoor_joint_bias, 0.0), jnp.float32)
    zero_bias = jnp.zeros_like(bd_bias)

    # event-driven: prepare (stages 1-3) at start time t0, commit (stage 4)
    # at completion t1 = t0 + h — in-flight iterations overlap, so tips
    # accumulate to the Eq.-4 equilibrium instead of being consumed serially.
    starts = _poisson_starts(rng, dcfg.arrival_rate, sim.iterations)
    pending = []        # heap of (t1, seq, node_id, Prepared)
    curve, lats = [], []
    done = 0
    mid_snapshot = {}
    def _maybe_snapshot():
        if done == sim.iterations // 2 and not mid_snapshot:
            mid_snapshot.update(
                contribution_m0=np.asarray(contribution_rates(dag, 0)) * 0 + np.asarray(dag.contributing_m0),
                contribution_m1=np.asarray(dag.contributing_m1),
                published=np.asarray(dag.published_per_node),
            )
    for i, t0 in enumerate(starts):
        while pending and pending[0][0] <= t0:
            t1, _, nid, prepared = heapq.heappop(pending)
            dag, bank = commit(dag, bank, nid, jnp.float32(t1), prepared)
            done += 1
            _maybe_snapshot()
            if done % sim.eval_every == 0:
                state.dag, state.bank = dag, bank
                state = ctrl.check(state, jax.random.PRNGKey(done), float(t1) + 1e-3, gv)
                curve.append((done, t1, state.best_accuracy))
        node = nodes[rng.integers(0, N)]
        lazy = node.behavior == "lazy"
        t1 = t0 + lat.dagfl_iteration(node.node_id, lazy=lazy)
        fn = prep_lazy if lazy else prep_normal
        bias = bd_bias if node.behavior == "backdoor" else zero_bias
        prepared = fn(
            dag,
            bank,
            jnp.float32(t0),
            jax.random.PRNGKey(sim.seed * 100003 + i),
            _jb(node.epoch(sim.steps_per_iter, sim.minibatch)),
            _jb(node.val_batch(sim.val_size)),
            bias,
        )
        heapq.heappush(pending, (t1, i, node.node_id, prepared))
        lats.append(t1 - t0)
    while pending:
        t1, _, nid, prepared = heapq.heappop(pending)
        dag, bank = commit(dag, bank, nid, jnp.float32(t1), prepared)
        done += 1
        _maybe_snapshot()
    state.dag, state.bank = dag, bank
    state = ctrl.check(state, jax.random.PRNGKey(done), float(t1) + 1e-3, gv)
    curve.append((done, t1, state.best_accuracy))

    state.dag, state.bank = dag, bank
    extras = {
        "contribution_m0": np.asarray(contribution_rates(dag, 0)),
        "contribution_m1": np.asarray(contribution_rates(dag, 1)),
        "published": np.asarray(dag.published_per_node),
        "behaviors": [n.behavior for n in nodes],
        "dag": dag,
    }
    # late-phase (second half) contribution rates: the paper's Table IV runs
    # 10000 s; at bench scale the first half is pre-convergence fog where
    # validation cannot yet separate abnormal models.
    if mid_snapshot:
        pub_late = np.asarray(dag.published_per_node) - mid_snapshot["published"]
        for m in (0, 1):
            c_late = (
                np.asarray(getattr(dag, f"contributing_m{m}"))
                - mid_snapshot[f"contribution_m{m}"]
            )
            extras[f"late_contribution_m{m}"] = c_late / np.maximum(pub_late, 1)
        extras["late_published"] = pub_late
    it_arr, t_arr, a_arr = map(np.asarray, zip(*curve))
    return SimResult(
        "dagfl", it_arr, t_arr, a_arr, float(np.mean(lats)), state.target_model
        if state.target_model is not None else params0, extras
    )


# ---------------------------------------------------------------------------
# Google FL (synchronous rounds)
# ---------------------------------------------------------------------------


def run_google(
    task, nodes: List[SimNode], dcfg: DagFLConfig, sim: SimConfig,
    global_val: Dict[str, np.ndarray],
) -> SimResult:
    rng = np.random.default_rng(sim.seed)
    lat = LatencyModel.create(dcfg, sim.seed)
    gv = _jb(global_val)
    N, cohort = len(nodes), lat.google_cohort
    params = task.init(jax.random.PRNGKey(sim.seed))
    train = jax.jit(make_epoch_train(task))
    evalf = jax.jit(task.eval_fn)

    t, done, curve, lats = 0.0, 0, [], []
    while done < sim.iterations:
        sel = rng.choice(N, size=cohort, replace=False)
        # shared-medium: cohort downloads then uploads serialize (2*c*tx);
        # training runs in parallel (max d0)
        d0s = [0.0 if nodes[s].behavior == "lazy" else lat.d0(s) for s in sel]
        round_time = 2 * cohort * lat.tx_time() + max(d0s)
        locals_ = []
        for s in sel:
            node = nodes[s]
            if node.behavior == "lazy":
                locals_.append(params)                    # re-uploads the global
            else:
                p, _ = train(params, _jb(node.epoch(sim.steps_per_iter, sim.minibatch)),
                             jax.random.PRNGKey(done + s))
                locals_.append(p)
        params = jax.tree_util.tree_map(
            lambda *xs: sum(x.astype(jnp.float32) for x in xs) / len(xs), *locals_
        )
        t += round_time
        done += cohort
        lats.extend([round_time] * cohort)               # every member waits the round
        if (done // cohort) % max(sim.eval_every // cohort, 1) == 0 or done >= sim.iterations:
            curve.append((done, t, float(evalf(params, gv))))

    it_arr, t_arr, a_arr = map(np.asarray, zip(*curve))
    return SimResult("google", it_arr, t_arr, a_arr, float(np.mean(lats)), params)


# ---------------------------------------------------------------------------
# Asynchronous FL (server-side mixing, Xie et al. [7])
# ---------------------------------------------------------------------------


def run_async(
    task, nodes: List[SimNode], dcfg: DagFLConfig, sim: SimConfig,
    global_val: Dict[str, np.ndarray],
) -> SimResult:
    rng = np.random.default_rng(sim.seed)
    lat = LatencyModel.create(dcfg, sim.seed)
    gv = _jb(global_val)
    N = len(nodes)
    params = task.init(jax.random.PRNGKey(sim.seed))
    train = jax.jit(make_epoch_train(task))
    evalf = jax.jit(task.eval_fn)
    mix = sim.async_mix

    starts = _poisson_starts(rng, dcfg.arrival_rate, sim.iterations)
    curve, lats = [], []
    for i, t0 in enumerate(starts):
        node = nodes[rng.integers(0, N)]
        lazy = node.behavior == "lazy"
        t1 = t0 + lat.async_iteration(node.node_id, lazy=lazy)
        if lazy:
            local = params
        else:
            local, _ = train(params, _jb(node.epoch(sim.steps_per_iter, sim.minibatch)),
                             jax.random.PRNGKey(sim.seed * 7919 + i))
        params = jax.tree_util.tree_map(
            lambda g, l: ((1 - mix) * g.astype(jnp.float32) + mix * l.astype(jnp.float32)).astype(g.dtype),
            params, local,
        )
        lats.append(t1 - t0)
        if (i + 1) % sim.eval_every == 0 or i == sim.iterations - 1:
            curve.append((i + 1, t1, float(evalf(params, gv))))

    it_arr, t_arr, a_arr = map(np.asarray, zip(*curve))
    return SimResult("async", it_arr, t_arr, a_arr, float(np.mean(lats)), params)


# ---------------------------------------------------------------------------
# Block FL (miners + PoW, Kim et al. [3])
# ---------------------------------------------------------------------------


def run_block(
    task, nodes: List[SimNode], dcfg: DagFLConfig, sim: SimConfig,
    global_val: Dict[str, np.ndarray], num_miners: int = 5,
) -> SimResult:
    rng = np.random.default_rng(sim.seed)
    lat = LatencyModel.create(dcfg, sim.seed)
    gv = _jb(global_val)
    N = len(nodes)
    params = task.init(jax.random.PRNGKey(sim.seed))
    train = jax.jit(make_epoch_train(task))
    evalf = jax.jit(task.eval_fn)

    miner_of = {i: i % num_miners for i in range(N)}
    collected: List[List[Any]] = [[] for _ in range(num_miners)]
    first_ts: List[Optional[float]] = [None] * num_miners
    pow_until: List[float] = [0.0] * num_miners          # busy mining until t
    global_acc = float(evalf(params, gv))

    starts = _poisson_starts(rng, dcfg.arrival_rate, sim.iterations)
    curve, lats, dropped = [], [], 0
    for i, t0 in enumerate(starts):
        node = nodes[rng.integers(0, N)]
        m = miner_of[node.node_id]
        lazy = node.behavior == "lazy"
        t1 = t0 + lat.block_iteration(node.node_id, lazy=lazy)
        lats.append(t1 - t0)
        if lazy:
            local = params
        else:
            local, _ = train(params, _jb(node.epoch(sim.steps_per_iter, sim.minibatch)),
                             jax.random.PRNGKey(sim.seed * 104729 + i))

        if t1 < pow_until[m]:
            dropped += 1                                  # miner busy mining: tx lost
        else:
            # miner validates with the full test set (Section V.A.1)
            acc = float(evalf(local, gv))
            if acc >= global_acc - sim.block_margin:
                collected[m].append(local)
                if first_ts[m] is None:
                    first_ts[m] = t1
            # block trigger: 5 tx or 10 s since first
            if collected[m] and (
                len(collected[m]) >= lat.block_collect
                or t1 - (first_ts[m] or t1) >= lat.block_timeout
            ):
                mine = lat.pow_time(rng)
                pow_until[m] = t1 + mine
                # the block extends the chain: previous global is a member of
                # the average (keeps small blocks from thrashing the model)
                stacked = [params] + collected[m]
                params = jax.tree_util.tree_map(
                    lambda *xs: sum(x.astype(jnp.float32) for x in xs) / len(xs), *stacked
                )
                global_acc = float(evalf(params, gv))
                collected[m], first_ts[m] = [], None

        if (i + 1) % sim.eval_every == 0 or i == sim.iterations - 1:
            curve.append((i + 1, t1, global_acc))

    it_arr, t_arr, a_arr = map(np.asarray, zip(*curve))
    return SimResult(
        "block", it_arr, t_arr, a_arr, float(np.mean(lats)), params,
        {"dropped": dropped},
    )


SYSTEMS: Dict[str, Callable] = {
    "dagfl": run_dagfl,
    "google": run_google,
    "async": run_async,
    "block": run_block,
}
