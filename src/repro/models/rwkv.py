"""RWKV-6 "Finch" blocks (arXiv:2404.05892): attention-free time mix with
DATA-DEPENDENT per-channel decay + squared-ReLU channel mix.

The WKV recurrence per head (state S in R^{hd_k x hd_v}):

    y_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) @ S_{t-1} + k_t^T v_t,   w_t = exp(-exp(w0 + lora(x_t)))

Implemented as a ``lax.scan`` over time (the reference RWKV CUDA kernel is
also sequential); the TPU adaptation keeps the (hd_k, hd_v) state resident
across the scan instead of re-reading HBM. Decode carries
(tm_shift, cm_shift, S) per layer.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, norm_apply, norm_init


class RWKVState(NamedTuple):
    tm_shift: jnp.ndarray   # (B, d)   last input to time-mix
    cm_shift: jnp.ndarray   # (B, d)   last input to channel-mix
    wkv: jnp.ndarray        # (B, H, hd, hd) recurrent state (f32)


def rwkv_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def rwkv_block_init(key, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    d = cfg.d_model
    H, hd = rwkv_heads(cfg), cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    lora = 64
    return {
        "ln_tm": norm_init("layernorm", d, dtype),
        "ln_cm": norm_init("layernorm", d, dtype),
        # static token-shift lerp coefficients for r,k,v,g and decay input
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(dtype),
        "w_r": dense_init(ks[1], d, d, dtype),
        "w_k": dense_init(ks[2], d, d, dtype),
        "w_v": dense_init(ks[3], d, d, dtype),
        "w_g": dense_init(ks[4], d, d, dtype),
        "w_o": dense_init(ks[5], d, d, dtype),
        # data-dependent decay: w0 + tanh(x @ A) @ B  (per-channel)
        "decay_w0": jnp.full((d,), -1.0, dtype),
        "decay_A": dense_init(ks[6], d, lora, dtype),
        "decay_B": (dense_init(ks[7], lora, d, dtype) * 0.1),
        "bonus_u": (jax.random.uniform(ks[8], (H, hd)) * 0.5).astype(dtype),
        "gn_scale": jnp.ones((H, hd), dtype),   # per-head group norm
        "gn_bias": jnp.zeros((H, hd), dtype),
        # channel mix
        "cm_mu": (jax.random.uniform(ks[9], (2, d)) * 0.5 + 0.25).astype(dtype),
        "cm_k": dense_init(ks[10], d, cfg.d_ff, dtype),
        "cm_v": dense_init(ks[11], cfg.d_ff, d, dtype),
        "cm_r": dense_init(ks[0], d, d, dtype),
    }


def _shift(x: jnp.ndarray, first: jnp.ndarray) -> jnp.ndarray:
    """(B, T, d) -> previous token (B, T, d); position 0 gets ``first``."""
    prev = jnp.concatenate([first[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def wkv_scan(r, k, v, logw, u, state):
    """Sequential WKV (reference / decode path).

    r,k,v,logw: (B, T, H, hd) (logw = log decay <= 0); u: (H, hd);
    state: (B, H, hd, hd) f32. Returns (y (B,T,H,hd), new_state).
    """
    rT = jnp.moveaxis(r, 1, 0).astype(jnp.float32)
    kT = jnp.moveaxis(k, 1, 0).astype(jnp.float32)
    vT = jnp.moveaxis(v, 1, 0).astype(jnp.float32)
    wT = jnp.exp(jnp.moveaxis(logw, 1, 0).astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                       # (B, H, hd)
        kv = kt[..., :, None] * vt[..., None, :]   # (B, H, hd, hd)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + uf[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    state, ys = jax.lax.scan(step, state.astype(jnp.float32), (rT, kT, vT, wT))
    return jnp.moveaxis(ys, 0, 1), state


WKV_CHUNK = 32


def wkv_chunked(r, k, v, logw, u, state, chunk: int = WKV_CHUNK):
    """Chunk-parallel WKV — the TPU-native formulation (DESIGN.md §3).

    Within a chunk all pairwise decay exponents cum_{t-1} - cum_s (s < t) are
    <= 0, so the (C, C, hd) decay tensor is numerically safe; across chunks a
    single (hd_k, hd_v) state is carried. Replaces the T-step sequential scan
    (which puts 3 collectives and a tiny matmul in every HLO loop iteration)
    with T/C chunk steps of dense (C,C,hd) einsums that feed the MXU.

    Exactly equals ``wkv_scan`` (tests/test_rwkv_mamba.py).
    """
    B, T, H, hd = r.shape
    assert T % chunk == 0, (T, chunk)
    C = chunk
    nc = T // C

    def resh(x):
        return jnp.moveaxis(
            x.reshape(B, nc, C, H, hd).astype(jnp.float32), 1, 0
        )                                              # (nc, B, C, H, hd)

    rc, kc, vc, lwc = map(resh, (r, k, v, logw))
    uf = u.astype(jnp.float32)

    def chunk_step(S, inp):
        rb, kb, vb, lw = inp                           # (B, C, H, hd)
        cum = jnp.cumsum(lw, axis=1)                   # inclusive  (B,C,H,hd)
        cum_prev = cum - lw                            # exclusive
        # intra-chunk: W[t,s] = exp(cum_prev[t] - cum[s]) for s < t  (<= 0)
        expo = cum_prev[:, :, None] - cum[:, None, :, :, :]   # (B,C,C,H,hd)
        mask = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])[None, :, :, None, None]
        W = jnp.where(mask, jnp.exp(expo), 0.0)
        scores = jnp.einsum("bthd,bshd,btshd->bths", rb, kb, W)
        bonus = jnp.einsum("bthd,bthd,hd->bth", rb, kb, uf)
        y = jnp.einsum("bths,bshd->bthd", scores, vb)
        y = y + bonus[..., None] * vb
        # inter-chunk: decayed state read
        rdec = rb * jnp.exp(cum_prev)                  # (B,C,H,hd)
        y = y + jnp.einsum("bthk,bhkv->bthv", rdec, S)
        # state update: S' = exp(cum_C) * S + sum_s exp(cum_C - cum_s) k_s v_s
        total = cum[:, -1]                             # (B,H,hd)
        kdec = kb * jnp.exp(total[:, None] - cum)      # (B,C,H,hd), expo <= 0
        S = jnp.exp(total)[..., None] * S + jnp.einsum("bshk,bshv->bhkv", kdec, vb)
        return S, y

    state, ys = jax.lax.scan(chunk_step, state.astype(jnp.float32), (rc, kc, vc, lwc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, hd)
    return y, state


def time_mix(cfg: ModelConfig, p: dict, x: jnp.ndarray, shift_in: jnp.ndarray, wkv_state):
    """x: (B, T, d). Returns (out, new_shift (B,d), new_wkv_state)."""
    B, T, d = x.shape
    H, hd = rwkv_heads(cfg), cfg.rwkv_head_dim
    xx = _shift(x, shift_in)
    mu = p["mu"]
    xr = x + (xx - x) * mu[0]
    xk = x + (xx - x) * mu[1]
    xv = x + (xx - x) * mu[2]
    xg = x + (xx - x) * mu[3]
    xw = x + (xx - x) * mu[4]

    r = (xr @ p["w_r"]).reshape(B, T, H, hd)
    k = (xk @ p["w_k"]).reshape(B, T, H, hd)
    v = (xv @ p["w_v"]).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ p["w_g"])

    # data-dependent decay in (0,1): w = exp(-exp(dd)) — the Finch
    # contribution; kept in log space (logw = -exp(dd) <= 0) for stability
    dd = p["decay_w0"] + jnp.tanh(xw @ p["decay_A"]) @ p["decay_B"]
    logw = -jnp.exp(jnp.minimum(dd.astype(jnp.float32), 10.0)).reshape(B, T, H, hd)

    if T > 1 and T % WKV_CHUNK == 0:
        y, new_state = wkv_chunked(r, k, v, logw, p["bonus_u"], wkv_state)
    else:
        y, new_state = wkv_scan(r, k, v, logw, p["bonus_u"], wkv_state)

    # per-head group norm
    yf = y.astype(jnp.float32)
    mean = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    y = (yf - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y * p["gn_scale"].astype(jnp.float32) + p["gn_bias"].astype(jnp.float32)
    y = y.reshape(B, T, d).astype(x.dtype) * g
    out = y @ p["w_o"]
    return out, x[:, -1, :], new_state


def channel_mix(cfg: ModelConfig, p: dict, x: jnp.ndarray, shift_in: jnp.ndarray):
    xx = _shift(x, shift_in)
    xk = x + (xx - x) * p["cm_mu"][0]
    xr = x + (xx - x) * p["cm_mu"][1]
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    out = jax.nn.sigmoid(xr @ p["cm_r"]) * (k @ p["cm_v"])
    return out, x[:, -1, :]


def rwkv_block_apply(
    cfg: ModelConfig, p: dict, x: jnp.ndarray, state: RWKVState
) -> Tuple[jnp.ndarray, RWKVState]:
    h = norm_apply("layernorm", p["ln_tm"], x)
    tm_out, tm_shift, wkv = time_mix(cfg, p, h, state.tm_shift, state.wkv)
    x = x + tm_out
    h = norm_apply("layernorm", p["ln_cm"], x)
    cm_out, cm_shift = channel_mix(cfg, p, h, state.cm_shift)
    x = x + cm_out
    return x, RWKVState(tm_shift, cm_shift, wkv)


def rwkv_empty_state(cfg: ModelConfig, batch: int, dtype) -> RWKVState:
    H, hd = rwkv_heads(cfg), cfg.rwkv_head_dim
    return RWKVState(
        tm_shift=jnp.zeros((batch, cfg.d_model), dtype),
        cm_shift=jnp.zeros((batch, cfg.d_model), dtype),
        wkv=jnp.zeros((batch, H, hd, hd), jnp.float32),
    )
