"""Mixture-of-Experts block: shared + routed experts, top-k routing.

Two dispatch implementations:

* ``dense``  — every expert runs on every token, gates mask the combine.
               O(T*E*d*ff) compute: only sane at smoke scale (E <= 4) and as
               the oracle the sorted path is tested against.
* ``sorted`` — MaxText/MegaBlocks-style: sort token-expert pairs by expert,
               capacity-bucket into an (E, C, d) buffer, one grouped einsum
               per projection, gather+segment-sum combine. O(k*T*d*ff).
               This is the production path; the distribution layer shards the
               expert dimension over the ``model`` mesh axis (expert
               parallelism) so the scatter/gather becomes the MoE all-to-all.

Router: softmax-after-top-k (DeepSeek style), plus the switch-transformer
load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, mlp_apply, mlp_init


def moe_init(key, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    kr, ki, kg, ko, ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)

    def stack(k, fan_in, fan_out):
        w = jax.random.truncated_normal(k, -2.0, 2.0, (E, fan_in, fan_out)) * scale
        return w.astype(dtype)

    p = {
        "router": dense_init(kr, d, E, dtype),
        "wi": stack(ki, d, ff),
        "wo": stack(ko, ff, d) * math.sqrt(d) / math.sqrt(ff),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["wg"] = stack(kg, d, ff)
    if cfg.num_shared_experts:
        import dataclasses

        shared_cfg = dataclasses.replace(cfg, d_ff=cfg.num_shared_experts * ff)
        p["shared"] = mlp_init(ks, shared_cfg, dtype=dtype)
    return p


def _expert_ffn(cfg: ModelConfig, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (E, C, d) -> (E, C, d), one grouped matmul per projection."""
    h = jnp.einsum("ecd,edf->ecf", x, params["wi"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, params["wg"])) * h
    elif cfg.act == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, params["wg"]), approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, params["wo"])


def route(cfg: ModelConfig, params: dict, x: jnp.ndarray):
    """x: (T, d) -> gates (T, k), expert ids (T, k), aux loss ()."""
    logits = (x @ params["router"]).astype(jnp.float32)     # (T, E)
    k = cfg.experts_per_token
    top_logits, top_idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(top_logits, axis=-1)             # normalize over k

    # switch load-balance aux: E * sum_e load_e * importance_e
    probs = jax.nn.softmax(logits, axis=-1)
    importance = jnp.mean(probs, axis=0)                    # (E,)
    load = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx, cfg.num_experts, dtype=jnp.float32), axis=1),
        axis=0,
    ) / k
    aux = cfg.num_experts * jnp.sum(importance * load)
    return gates.astype(x.dtype), top_idx, aux


def capacity(cfg: ModelConfig, num_tokens: int, factor: float = 1.25) -> int:
    c = int(math.ceil(num_tokens * cfg.experts_per_token / cfg.num_experts * factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly tiles


def moe_apply_dense(cfg: ModelConfig, params: dict, x: jnp.ndarray):
    """Oracle path: all experts on all tokens. x (T, d)."""
    gates, top_idx, aux = route(cfg, params, x)
    combine = jnp.zeros((x.shape[0], cfg.num_experts), x.dtype)
    combine = jnp.sum(
        jax.nn.one_hot(top_idx, cfg.num_experts, dtype=x.dtype) * gates[..., None], axis=1
    )
    h = _expert_ffn(cfg, params, jnp.broadcast_to(x, (cfg.num_experts,) + x.shape))
    y = jnp.einsum("te,etd->td", combine, h)
    return y, aux


def moe_apply_sorted(cfg: ModelConfig, params: dict, x: jnp.ndarray, capacity_factor: float = 1.25):
    """Production path: sort + capacity-bucketed grouped matmul. x (T, d)."""
    T, d = x.shape
    k = cfg.experts_per_token
    E = cfg.num_experts
    C = capacity(cfg, T, capacity_factor)

    gates, top_idx, aux = route(cfg, params, x)             # (T,k)
    flat_e = top_idx.reshape(-1)                            # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)                   # token id per pair
    flat_g = gates.reshape(-1)

    order = jnp.argsort(flat_e)                             # stable sort by expert
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]

    counts = jax.ops.segment_sum(jnp.ones_like(se), se, num_segments=E)
    seg_start = jnp.cumsum(counts) - counts                 # (E,)
    rank = jnp.arange(T * k) - seg_start[se]                # rank within expert
    keep = rank < C                                         # capacity drop
    slot = jnp.where(keep, rank, C)                         # overflow -> slot C

    buf = jnp.zeros((E, C + 1, d), x.dtype)                 # +1 trash slot
    buf = buf.at[se, slot].set(x[st])
    out_buf = _expert_ffn(cfg, params, buf[:, :C])

    y_pairs = jnp.where(
        keep[:, None],
        out_buf[se, jnp.minimum(slot, C - 1)] * sg[:, None],
        0.0,
    )
    y = jax.ops.segment_sum(y_pairs, st, num_segments=T)
    return y, aux


MOE_BLOCK_TOKENS = 32768


def moe_apply_blocked(cfg: ModelConfig, params: dict, x: jnp.ndarray,
                      block: int = MOE_BLOCK_TOKENS):
    """§Perf optimization: scan the sorted dispatch over token blocks.

    The (E, C, d) capacity buffer scales with the token count it serves; at
    train_4k kimi-scale (1M tokens, E=384, k=8) the global buffer is ~150 TB
    — GSPMD spills it as ~0.6 TB/device temp. Routing is per-token, so
    dispatching ``block`` tokens at a time is mathematically identical
    (same router, same capacity *rate*) while shrinking live buffers by
    T/block. Aux loss is averaged over blocks.
    """
    T = x.shape[0]
    if T <= block:
        return moe_apply_sorted(cfg, params, x)
    nb = -(-T // block)
    pad = nb * block - T
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    xb = xp.reshape(nb, block, -1)

    def body(_, xblk):
        y, aux = moe_apply_sorted(cfg, params, xblk)
        return None, (y, aux)

    _, (yb, auxb) = jax.lax.scan(body, None, xb)
    y = yb.reshape(nb * block, -1)[:T]
    return y, jnp.mean(auxb)


# mesh for the shard_map ("expert_parallel") dispatch; set by the launcher.
_SHARD_MAP_MESH = None


def set_shard_map_mesh(mesh) -> None:
    global _SHARD_MAP_MESH
    _SHARD_MAP_MESH = mesh


def moe_apply(cfg: ModelConfig, params: dict, x: jnp.ndarray, impl: str = "sorted"):
    """x: (..., d). Returns (y, aux)."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    if impl == "expert_parallel" and _SHARD_MAP_MESH is not None and len(shape) == 3:
        from repro.models.moe_shard_map import make_moe_shard_map

        y, aux = make_moe_shard_map(cfg, _SHARD_MAP_MESH)(params, x)
        y = y.reshape(-1, shape[-1])
    elif impl == "dense":
        y, aux = moe_apply_dense(cfg, params, flat)
    elif impl == "blocked":
        y, aux = moe_apply_blocked(cfg, params, flat)
    else:
        y, aux = moe_apply_sorted(cfg, params, flat)
    if cfg.num_shared_experts:
        import dataclasses

        shared_cfg = dataclasses.replace(
            cfg, d_ff=cfg.num_shared_experts * (cfg.moe_d_ff or cfg.d_ff)
        )
        y = y + mlp_apply(shared_cfg, params["shared"], flat)
    return y.reshape(shape), aux
