"""Shared building blocks: norms, MLPs, RoPE, initializers.

Pure-functional: params are nested dicts of jnp arrays; every ``*_apply``
is vmappable over a leading params axis (needed by DAG-FL tip validation,
which evaluates a bank of candidate models with one vmap).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, fan_in: int, fan_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (fan_in, fan_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_init(kind: str, d: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparam_layernorm":
        return {}
    raise ValueError(kind)


def norm_apply(kind: str, params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    # nonparam_layernorm (OLMo): no affine params
    return y.astype(x.dtype)


def rms_head_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """QK-norm (Qwen3): RMS-normalise the last (head) dim."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None, dtype=None) -> dict:
    d_ff = d_ff or cfg.d_ff
    dtype = dtype or jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    n_gate = 2 if cfg.act in ("swiglu", "geglu") else 1
    p = {"wo": dense_init(k2, d_ff, cfg.d_model, dtype)}
    p["wi"] = dense_init(k1, cfg.d_model, d_ff, dtype)
    if n_gate == 2:
        p["wg"] = dense_init(k3, cfg.d_model, d_ff, dtype)
    return p


def mlp_apply(cfg: ModelConfig, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = x @ params["wi"]
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * h
    elif cfg.act == "geglu":
        h = jax.nn.gelu(x @ params["wg"], approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]                 # (..., S, 1, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray, mask=None) -> jnp.ndarray:
    """Mean next-token cross entropy. logits (..., V), labels int (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
