"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Prefill/train decompress the latent into per-head K/V (normal activation
cost); decode uses the ABSORBED form — W_UK folds into the query and W_UV
into the output so the per-step cost is O(S * kv_lora_rank) and the cache is
only (c_kv, k_rope): 2*(r + rope_dim) bytes/token/layer instead of
2*H*hd — the MLA memory saving the paper claims.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, norm_apply, norm_init

NEG_INF = -1e30


class MLACache(NamedTuple):
    c_kv: jnp.ndarray       # (B, S, r)      — compressed latent
    k_rope: jnp.ndarray     # (B, S, rope_d) — decoupled rope key (shared head)
    length: jnp.ndarray     # () int32


def mla_init(key, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    d, H = cfg.d_model, cfg.num_heads
    p_dim = cfg.resolved_head_dim()          # qk nope dim
    v_dim = cfg.resolved_v_head_dim()
    r, rq, rd = cfg.kv_lora_rank, cfg.q_lora_rank, cfg.rope_head_dim
    keys = jax.random.split(key, 6)
    params = {
        "wkv_a": dense_init(keys[0], d, r + rd, dtype),
        "kv_norm": norm_init("rmsnorm", r, dtype),
        "wkv_b": dense_init(keys[1], r, H * (p_dim + v_dim), dtype),
        "wo": dense_init(keys[2], H * v_dim, d, dtype),
    }
    if rq:
        params["wq_a"] = dense_init(keys[3], d, rq, dtype)
        params["q_norm"] = norm_init("rmsnorm", rq, dtype)
        params["wq_b"] = dense_init(keys[4], rq, H * (p_dim + rd), dtype)
    else:
        params["wq"] = dense_init(keys[5], d, H * (p_dim + rd), dtype)
    return params


def _queries(cfg: ModelConfig, params: dict, x, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    p_dim, rd = cfg.resolved_head_dim(), cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = norm_apply("rmsnorm", params["q_norm"], x @ params["wq_a"])
        q = cq @ params["wq_b"]
    else:
        q = x @ params["wq"]
    q = q.reshape(B, S, H, p_dim + rd)
    q_nope, q_rope = q[..., :p_dim], q[..., p_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(cfg: ModelConfig, params: dict, x, positions):
    r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    kv_a = x @ params["wkv_a"]
    c_kv = norm_apply("rmsnorm", params["kv_norm"], kv_a[..., :r])
    k_rope = kv_a[..., r:][..., None, :]                  # single shared head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_forward(
    cfg: ModelConfig,
    params: dict,
    x: jnp.ndarray,
    positions: Optional[jnp.ndarray] = None,
    return_cache: bool = False,
    cache_len: int = 0,
) -> Tuple[jnp.ndarray, Optional[MLACache]]:
    B, S, _ = x.shape
    H = cfg.num_heads
    p_dim, v_dim, rd = cfg.resolved_head_dim(), cfg.resolved_v_head_dim(), cfg.rope_head_dim
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    q_nope, q_rope = _queries(cfg, params, x, positions)
    c_kv, k_rope = _latent(cfg, params, x, positions)

    kv = (c_kv @ params["wkv_b"]).reshape(B, S, H, p_dim + v_dim)
    k_nope, v = kv[..., :p_dim], kv[..., p_dim:]

    scale = 1.0 / jnp.sqrt(jnp.float32(p_dim + rd))

    def block_attn(q_nope_b, q_rope_b, offset):
        """One query block vs the full keys: scores O(bq * S)."""
        bq = q_nope_b.shape[1]
        scores = (
            jnp.einsum("bqhp,bkhp->bhqk", q_nope_b, k_nope)
            + jnp.einsum("bqhp,bkp->bhqk", q_rope_b, k_rope)
        ).astype(jnp.float32) * scale
        qpos = offset + jnp.arange(bq)[:, None]
        kpos = jnp.arange(S)[None, :]
        scores = scores + jnp.where(kpos <= qpos, 0.0, NEG_INF)[None, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        return jnp.einsum("bhqk,bkhv->bqhv", probs, v)

    BQ = 1024
    if S <= BQ:
        out = block_attn(q_nope, q_rope, 0)
    else:
        nb = -(-S // BQ)
        pad = nb * BQ - S
        qn = jnp.pad(q_nope, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q_nope
        qr = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q_rope
        qn = jnp.moveaxis(qn.reshape(B, nb, BQ, H, p_dim), 1, 0)
        qr = jnp.moveaxis(qr.reshape(B, nb, BQ, H, rd), 1, 0)

        def body(_, xs):
            i, qnb, qrb = xs
            return None, block_attn(qnb, qrb, i * BQ)

        _, outs = jax.lax.scan(body, None, (jnp.arange(nb), qn, qr))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, nb * BQ, H, v_dim)[:, :S]
    out = out.reshape(B, S, H * v_dim) @ params["wo"]

    cache = None
    if return_cache:
        slots = max(cache_len, S)
        ck, kr = c_kv, k_rope
        if slots > S:
            ck = jnp.pad(c_kv, ((0, 0), (0, slots - S), (0, 0)))
            kr = jnp.pad(k_rope, ((0, 0), (0, slots - S), (0, 0)))
        cache = MLACache(ck, kr, jnp.asarray(S, jnp.int32))
    return out, cache


def mla_empty_cache(cfg: ModelConfig, batch: int, max_len: int, dtype, length: int = 0) -> MLACache:
    c = jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype)
    kr = jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype)
    return MLACache(c, kr, jnp.asarray(length, jnp.int32))


def mla_decode_step(
    cfg: ModelConfig,
    params: dict,
    x: jnp.ndarray,              # (B, 1, D)
    cache: MLACache,
) -> Tuple[jnp.ndarray, MLACache]:
    B = x.shape[0]
    H = cfg.num_heads
    p_dim, v_dim, rd = cfg.resolved_head_dim(), cfg.resolved_v_head_dim(), cfg.rope_head_dim
    r = cfg.kv_lora_rank
    pos = cache.length
    positions = jnp.broadcast_to(pos, (B, 1))

    q_nope, q_rope = _queries(cfg, params, x, positions)   # (B,1,H,*)
    c_new, kr_new = _latent(cfg, params, x, positions)     # (B,1,r), (B,1,rd)

    slots = cache.c_kv.shape[1]
    slot = jnp.minimum(pos, slots - 1)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, slot, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, slot, axis=1)

    w_b = params["wkv_b"].reshape(r, H, p_dim + v_dim)
    w_uk, w_uv = w_b[..., :p_dim], w_b[..., p_dim:]

    # absorbed: q_lat[b,h,r] = sum_p q_nope[b,h,p] * w_uk[r,h,p]
    q_lat = jnp.einsum("bqhp,rhp->bqhr", q_nope, w_uk)
    scale = 1.0 / jnp.sqrt(jnp.float32(p_dim + rd))
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv)
        + jnp.einsum("bqhp,bsp->bhqs", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    valid = jnp.arange(slots) <= pos
    scores = scores + jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)

    ctx_lat = jnp.einsum("bhqs,bsr->bqhr", probs, c_kv)
    out = jnp.einsum("bqhr,rhv->bqhv", ctx_lat, w_uv)
    out = out.reshape(B, 1, H * v_dim) @ params["wo"]
    return out, MLACache(c_kv, k_rope, pos + 1)
