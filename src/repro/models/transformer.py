"""Unified decoder-only model covering every assigned family.

families:
  dense / audio / vlm  — transformer blocks (GQA/MQA/MLA attention + MLP)
  moe                  — transformer blocks with routed-expert FFN
  rwkv                 — RWKV6 blocks (attention-free)
  hybrid               — Mamba2 blocks + ONE shared attention block every k

Layer stacks are homogeneous and scanned (``lax.scan`` over stacked params)
so 61-layer/1T-param graphs stay compact for the dry-run compiler; DeepSeek's
leading dense layer lives in an unscanned prefix. ``audio``/``vlm`` accept
stubbed frontend embeddings (precomputed frames/patches per the assignment)
that a learned projector prepends to the token sequence.

Everything is functional: ``forward(params, tokens)`` vmaps over a leading
params axis, which is exactly how DAG-FL tip validation evaluates a bank of
candidate models in one XLA program.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models.attention import KVCache
from repro.models.layers import (
    dense_init,
    embed_init,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    softmax_xent,
)

# ---------------------------------------------------------------------------
# per-block init / apply for transformer-ish families
# ---------------------------------------------------------------------------


def _tf_block_init(key, cfg: ModelConfig, dense_mlp: bool, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": norm_init(cfg.norm, cfg.d_model, dtype),
        "ln2": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.attention == "mla":
        p["attn"] = mla_lib.mla_init(k1, cfg, dtype)
    else:
        p["attn"] = attn_lib.attn_init(k1, cfg, dtype)
    if cfg.is_moe() and not dense_mlp:
        p["moe"] = moe_lib.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k3, cfg, dtype=dtype)
    return p


def _tf_block_apply(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    mode: str,                     # "train" | "prefill" | "decode"
    cache,                         # layer cache or None
    cache_len: int,
    dense_mlp: bool,
):
    h = norm_apply(cfg.norm, p["ln1"], x)
    new_cache = None
    if cfg.attention == "mla":
        if mode == "decode":
            a, new_cache = mla_lib.mla_decode_step(cfg, p["attn"], h, cache)
        else:
            a, new_cache = mla_lib.mla_forward(
                cfg, p["attn"], h, positions,
                return_cache=(mode == "prefill"), cache_len=cache_len,
            )
    else:
        if mode == "decode":
            a, new_cache = attn_lib.attn_decode_step(cfg, p["attn"], h, cache)
        else:
            a, new_cache = attn_lib.attn_forward(
                cfg,
                p["attn"],
                h,
                positions,
                return_cache=(mode == "prefill"),
                cache_len=cache_len,
            )
    x = x + a
    h = norm_apply(cfg.norm, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        m, aux = moe_lib.moe_apply(cfg, p["moe"], h, impl=cfg.moe_impl)
    else:
        m = mlp_apply(cfg, p["mlp"], h)
    return x + m, new_cache, aux


# ---------------------------------------------------------------------------
# hybrid (Zamba2) blocks
# ---------------------------------------------------------------------------


def _shared_attn_init(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": attn_lib.attn_init(k1, cfg, dtype),
        "ln2": norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg, dtype=dtype),
    }


def _shared_attn_apply(cfg, p, x, positions, mode, cache, cache_len):
    h = norm_apply(cfg.norm, p["ln1"], x)
    if mode == "decode":
        a, new_cache = attn_lib.attn_decode_step(cfg, p["attn"], h, cache)
    else:
        a, new_cache = attn_lib.attn_forward(
            cfg, p["attn"], h, positions, return_cache=(mode == "prefill"), cache_len=cache_len
        )
    x = x + a
    h = norm_apply(cfg.norm, p["ln2"], x)
    return x + mlp_apply(cfg, p["mlp"], h), new_cache


def _hybrid_layer_init(key, cfg: ModelConfig, dtype) -> dict:
    return {
        "ln": norm_init(cfg.norm, cfg.d_model, dtype),
        "mixer": mamba_lib.mamba_init(key, cfg, dtype),
    }


# ---------------------------------------------------------------------------
# the Model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------- init ------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
            "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)
        if cfg.frontend_tokens:
            params["frontend_proj"] = dense_init(keys[2], cfg.frontend_dim, cfg.d_model, dtype)

        n_stack = cfg.num_layers - cfg.first_dense_layers
        if cfg.family == "rwkv":
            lkeys = jax.random.split(keys[3], cfg.num_layers)
            params["layers"] = jax.vmap(lambda k: rwkv_lib.rwkv_block_init(k, cfg, dtype))(lkeys)
            params["embed_norm"] = norm_init("layernorm", cfg.d_model, dtype)
        elif cfg.family == "hybrid":
            lkeys = jax.random.split(keys[3], cfg.num_layers)
            params["layers"] = jax.vmap(lambda k: _hybrid_layer_init(k, cfg, dtype))(lkeys)
            params["shared_attn"] = _shared_attn_init(keys[4], cfg, dtype)
        else:
            if cfg.first_dense_layers:
                pkeys = jax.random.split(keys[5], cfg.first_dense_layers)
                params["prefix"] = [
                    _tf_block_init(pk, cfg, dense_mlp=True, dtype=dtype) for pk in pkeys
                ]
            lkeys = jax.random.split(keys[3], n_stack)
            params["layers"] = jax.vmap(
                lambda k: _tf_block_init(k, cfg, dense_mlp=False, dtype=dtype)
            )(lkeys)
        return params

    # ---------------- embeddings / head -----------------------------------
    def _embed(self, params, tokens, frontend):
        x = params["embed"][tokens]
        if self.cfg.frontend_tokens:
            assert frontend is not None, "audio/vlm archs need frontend embeddings"
            fe = frontend.astype(x.dtype) @ params["frontend_proj"]
            x = jnp.concatenate([fe, x], axis=1)
        return x

    def _head(self, params, x):
        x = norm_apply(self.cfg.norm, params["final_norm"], x)
        if self.cfg.tie_embeddings:
            return x @ params["embed"].T
        return x @ params["lm_head"]

    # ---------------- full-sequence passes ---------------------------------
    def _run_layers(self, params, x, positions, mode: str, cache, cache_len: int):
        """Dispatch per family; returns (x, new_cache, aux)."""
        cfg = self.cfg
        if cfg.family == "rwkv":
            return self._run_rwkv(params, x, mode, cache)
        if cfg.family == "hybrid":
            return self._run_hybrid(params, x, positions, mode, cache, cache_len)
        return self._run_tf(params, x, positions, mode, cache, cache_len)

    # -- transformer / moe stack
    def _run_tf(self, params, x, positions, mode, cache, cache_len):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        new_prefix = []
        for i in range(cfg.first_dense_layers):
            c = cache["prefix"][i] if cache is not None else None
            x, nc, aux = _tf_block_apply(
                cfg, params["prefix"][i], x, positions, mode, c, cache_len, dense_mlp=True
            )
            new_prefix.append(nc)
            aux_total = aux_total + aux

        if mode == "decode":
            def body(carry, xs):
                h, auxs = carry
                lp, lc = xs
                h, nc, aux = _tf_block_apply(cfg, lp, h, positions, mode, lc, cache_len, False)
                return (h, auxs + aux), nc

            (x, aux_total), new_stack = jax.lax.scan(
                body, (x, aux_total), (params["layers"], cache["stack"])
            )
        else:
            def body(carry, lp):
                h, auxs = carry
                h, nc, aux = _tf_block_apply(cfg, lp, h, positions, mode, None, cache_len, False)
                return (h, auxs + aux), nc

            if mode == "train":
                body = jax.checkpoint(body, prevent_cse=False)
            (x, aux_total), new_stack = jax.lax.scan(body, (x, aux_total), params["layers"])

        new_cache = None
        if mode in ("prefill", "decode"):
            new_cache = {"prefix": new_prefix, "stack": new_stack}
        return x, new_cache, aux_total

    # -- rwkv stack
    def _run_rwkv(self, params, x, mode, states):
        cfg = self.cfg
        x = norm_apply("layernorm", params["embed_norm"], x)

        def body(h, xs):
            lp, st = xs
            h, new_st = rwkv_lib.rwkv_block_apply(cfg, lp, h, st)
            return h, new_st

        if mode == "train":
            body = jax.checkpoint(body, prevent_cse=False)
        if states is None:
            B = x.shape[0]
            states = self._rwkv_states(B, stacked=True)
        x, new_states = jax.lax.scan(body, x, (params["layers"], states))
        new_cache = new_states if mode in ("prefill", "decode") else None
        return x, new_cache, jnp.zeros((), jnp.float32)

    def _rwkv_states(self, batch, stacked=True):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        st = rwkv_lib.rwkv_empty_state(cfg, batch, dtype)
        if stacked:
            st = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), st
            )
        return st

    # -- hybrid (mamba + shared attention) stack
    def _run_hybrid(self, params, x, positions, mode, cache, cache_len):
        cfg = self.cfg
        every = cfg.shared_attn_every
        n_apps = cfg.num_layers // every if every else 0
        B = x.shape[0]
        dtype = jnp.dtype(cfg.dtype)

        if cache is None:
            mstates = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape),
                mamba_lib.mamba_empty_state(cfg, B, dtype),
            )
            acaches = None
        else:
            mstates, acaches = cache["mamba"], cache["attn"]

        if acaches is None and mode != "train" and n_apps:
            slots = cache_len or x.shape[1]
            one = attn_lib.empty_cache(cfg, B, slots, dtype)
            acaches = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (n_apps,) + a.shape), one
            )

        shared = params["shared_attn"]

        def body(carry, xs):
            h, ac = carry
            idx, lp, mst = xs
            h2, new_mst = mamba_lib.mamba_apply(
                cfg, lp["mixer"], norm_apply(cfg.norm, lp["ln"], h), mst
            )
            h = h + h2
            if every:
                def with_attn(h, ac):
                    app = idx // every
                    if mode == "train":
                        h2, _ = _shared_attn_apply(cfg, shared, h, positions, mode, None, cache_len)
                        return h2, ac
                    layer_cache = jax.tree_util.tree_map(lambda a: a[app], ac)
                    h2, nc = _shared_attn_apply(
                        cfg, shared, h, positions, mode, layer_cache, cache_len
                    )
                    ac = jax.tree_util.tree_map(
                        lambda a, n: jax.lax.dynamic_update_index_in_dim(a, n, app, 0),
                        ac,
                        nc,
                    )
                    return h2, ac

                apply_attn = (idx % every) == (every - 1)
                h, ac = jax.lax.cond(apply_attn, with_attn, lambda h, ac: (h, ac), h, ac)
            return (h, ac), new_mst

        if mode == "train":
            body = jax.checkpoint(body, prevent_cse=False)
        idxs = jnp.arange(cfg.num_layers)
        if acaches is None:  # train mode placeholder so cond branches match
            acaches = jnp.zeros((), jnp.float32)
        (x, acaches), new_mstates = jax.lax.scan(
            body, (x, acaches), (idxs, params["layers"], mstates)
        )
        new_cache = None
        if mode in ("prefill", "decode"):
            new_cache = {"mamba": new_mstates, "attn": acaches}
        return x, new_cache, jnp.zeros((), jnp.float32)

    # ---------------- public API -------------------------------------------
    def forward(self, params, tokens, frontend=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-sequence logits (train path). Returns (logits, aux_loss)."""
        x = self._embed(params, tokens, frontend)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, _, aux = self._run_layers(params, x, positions, "train", None, 0)
        return self._head(params, x), aux

    def prefill(self, params, tokens, frontend=None, cache_len: int = 0):
        """Build the serving cache; returns (last-position logits, cache)."""
        x = self._embed(params, tokens, frontend)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, cache, _ = self._run_layers(params, x, positions, "prefill", None, cache_len or S)
        return self._head(params, x[:, -1:, :]), cache

    def decode_step(self, params, token, cache):
        """token: (B, 1) int32. Returns (logits (B,1,V), new cache)."""
        x = params["embed"][token]
        positions = None  # per-layer caches carry their own positions
        x, new_cache, _ = self._run_layers(params, x, positions, "decode", cache, 0)
        return self._head(params, x), new_cache

    def init_cache(self, batch: int, max_len: int, length: int = 0):
        """Cache stand-in for decode; ``length`` tokens considered present."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        if cfg.family == "rwkv":
            return self._rwkv_states(batch, stacked=True)
        if cfg.family == "hybrid":
            n_apps = cfg.num_layers // cfg.shared_attn_every
            one = attn_lib.empty_cache(cfg, batch, max_len, dtype, length)
            return {
                "mamba": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape),
                    mamba_lib.mamba_empty_state(cfg, batch, dtype),
                ),
                "attn": jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (n_apps,) + a.shape), one
                ),
            }
        n_stack = cfg.num_layers - cfg.first_dense_layers
        if cfg.attention == "mla":
            one = mla_lib.mla_empty_cache(cfg, batch, max_len, dtype, length)
        else:
            one = attn_lib.empty_cache(cfg, batch, max_len, dtype, length)
        stack = jax.tree_util.tree_map(lambda a: jnp.broadcast_to(a, (n_stack,) + a.shape), one)
        prefix = [
            jax.tree_util.tree_map(lambda a: a, one) for _ in range(cfg.first_dense_layers)
        ]
        return {"prefix": prefix, "stack": stack}

    # ---------------- training --------------------------------------------
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        tokens = batch["tokens"]
        labels = batch["labels"]
        frontend = batch.get("frontend")
        logits, aux = self.forward(params, tokens, frontend)
        F = self.cfg.frontend_tokens
        if F:
            # position F-1+j predicts text token j
            logits = logits[:, F - 1 : F - 1 + tokens.shape[1], :]
            labels = tokens
        else:
            logits = logits[:, :-1, :]
            labels = labels[:, 1:]
        xent = softmax_xent(logits, labels)
        total = xent + self.cfg.router_aux_loss * aux
        return total, {"xent": xent, "aux": aux}

    def train_step(self, train_cfg, params, opt_state, batch, lr):
        from repro.optim import make_optimizer

        _, update = make_optimizer(train_cfg)

        def loss_fn(p):
            total, metrics = self.loss(p, batch)
            return total, metrics

        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = update(grads, opt_state, params, lr)
        metrics = dict(metrics, loss=total)
        return params, opt_state, metrics


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
