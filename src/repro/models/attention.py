"""GQA/MQA attention with full or sliding-window masking, QK-norm, QKV bias.

Three entry points:
  * ``attn_forward``      — train/prefill over a whole sequence (optionally
                            returning the KV cache),
  * ``attn_decode_step``  — one new token against a cache,
  * the cache helpers     — full cache (S slots) or ring-buffer window cache.

Layouts: activations (B, S, D); q/k/v (B, S, H, hd); caches (B, S, KV, hd).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_head_norm

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, S_cache, KV, hd)
    v: jnp.ndarray          # (B, S_cache, KV, hd)
    length: jnp.ndarray     # () int32 — tokens written so far (global position)


def attn_init(key, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim()
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    d = cfg.d_model
    p = {
        "wq": dense_init(kq, d, cfg.num_heads * hd, dtype),
        "wk": dense_init(kk, d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(kv, d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.num_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(cfg: ModelConfig, params: dict, x: jnp.ndarray):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim()
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_norm"])
        k = rms_head_norm(k, params["k_norm"])
    return q, k, v


def causal_mask(S: int, window: int = 0, dtype=jnp.float32) -> jnp.ndarray:
    """(S, S) additive mask; window>0 => sliding-window causal."""
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = j <= i
    if window:
        ok = ok & (j > i - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


# q blocks larger than this are processed by the scanned (flash-style) path,
# bounding score memory to (B, H, CHUNK_Q, S) instead of (B, H, S, S).
CHUNK_Q = 1024


def _grouped_scores(q5, k):
    """q5 (B,Sq,KV,G,hd) x k (B,Sk,KV,hd) -> (B,KV,G,Sq,Sk) f32 (no repeat)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q5, k).astype(jnp.float32)


def sdpa(q, k, v, q_offset, S_total, window: int = 0) -> jnp.ndarray:
    """Grouped-query attention for one query block.

    q (B,Sq,H,hd), k/v (B,Sk,KV,hd); queries at absolute positions
    q_offset..q_offset+Sq-1 of a length-S_total causal sequence.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q5 = q.reshape(B, Sq, KV, G, hd)
    scores = _grouped_scores(q5, k) / jnp.sqrt(jnp.float32(hd))
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    ok = kpos <= qpos
    if window:
        ok = ok & (kpos > qpos - window)
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, hd)


def chunked_sdpa(q, k, v, window: int = 0, block_q: int = CHUNK_Q) -> jnp.ndarray:
    """Flash-style scan over query blocks: score memory O(bq * S)."""
    B, S, H, hd = q.shape
    if S <= block_q:
        return sdpa(q, k, v, 0, S, window)
    nb = -(-S // block_q)
    pad = nb * block_q - S
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qb = qp.reshape(B, nb, block_q, H, hd)

    def body(_, xs):
        blk_idx, q_blk = xs
        out = sdpa(q_blk, k, v, blk_idx * block_q, S, window)
        return None, out

    _, outs = jax.lax.scan(body, None, (jnp.arange(nb), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nb * block_q, H, hd)
    return out[:, :S]


def attn_forward(
    cfg: ModelConfig,
    params: dict,
    x: jnp.ndarray,
    positions: Optional[jnp.ndarray] = None,
    return_cache: bool = False,
    cache_len: int = 0,
) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Train/prefill path. Returns (out (B,S,D), cache?)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(cfg, params, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    window = cfg.window_size if cfg.attention == "sliding_window" else 0
    out = chunked_sdpa(q, k, v, window)
    out = out.reshape(B, S, -1) @ params["wo"]

    cache = None
    if return_cache:
        slots = cache_len or S
        if window and slots > window:
            slots = window
        if window and S > slots:
            # ring-buffer layout: global position p lives at slot p % slots
            tail_k = jax.lax.dynamic_slice_in_dim(k, S - slots, slots, axis=1)
            tail_v = jax.lax.dynamic_slice_in_dim(v, S - slots, slots, axis=1)
            ck = jnp.roll(tail_k, S % slots, axis=1)
            cv = jnp.roll(tail_v, S % slots, axis=1)
        else:
            assert slots >= S, f"full-attn cache needs >= {S} slots, got {slots}"
            ck = jnp.zeros((B, slots) + k.shape[2:], k.dtype)
            cv = jnp.zeros_like(ck)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=1)
        cache = KVCache(ck, cv, jnp.asarray(S, jnp.int32))
    return out, cache


def empty_cache(cfg: ModelConfig, batch: int, max_len: int, dtype, length: int = 0) -> KVCache:
    """Cache with ``max_len`` logical context; ring-buffer sized when windowed.

    ``length`` = number of tokens considered already present (the decode
    dry-run uses length = seq_len - 1: one step appends the seq_len-th token).
    """
    hd = cfg.resolved_head_dim()
    slots = max_len
    if cfg.attention == "sliding_window":
        slots = min(max_len, cfg.window_size)
    k = jnp.zeros((batch, slots, cfg.num_kv_heads, hd), dtype)
    return KVCache(k, jnp.zeros_like(k), jnp.asarray(length, jnp.int32))


def attn_decode_step(
    cfg: ModelConfig,
    params: dict,
    x: jnp.ndarray,              # (B, 1, D)
    cache: KVCache,
) -> Tuple[jnp.ndarray, KVCache]:
    """One token against the cache. Ring buffer when sliding-window."""
    B = x.shape[0]
    pos = cache.length                                  # global position
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k, v = _project_qkv(cfg, params, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    slots = cache.k.shape[1]
    slot = jnp.mod(pos, slots) if cfg.attention == "sliding_window" else jnp.minimum(pos, slots - 1)
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)

    # positions of each cache slot (for validity mask)
    idx = jnp.arange(slots)
    if cfg.attention == "sliding_window":
        # slot s holds global position: the latest p <= pos with p % slots == s
        slot_pos = pos - jnp.mod(pos - idx, slots)
        valid = (slot_pos >= 0) & (slot_pos >= pos - slots + 1)
    else:
        valid = idx <= pos
    hd = q.shape[-1]
    KV = ck.shape[2]
    G = cfg.num_heads // KV
    qg = q.reshape(B, KV, G, hd)                 # squeeze the length-1 q dim
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, ck).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = scores + jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cv)
    out = out.reshape(B, 1, -1) @ params["wo"]
    return out, KVCache(ck, cv, pos + 1)
