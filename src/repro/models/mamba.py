"""Mamba2 (SSD) mixer for the Zamba2 hybrid (arXiv:2411.15242).

Scalar-per-head A, grouped B/C (ngroups=1), causal conv(4), gated RMSNorm
before out-projection. Projections are SEPARATE matrices (w_z, w_x, w_B,
w_C, w_dt) so tensor-parallel sharding can put the head-structured dims
(din, H) on the ``model`` mesh axis while the small B/C/state matrices stay
replicated — the TPU-native layout (DESIGN.md §3).

The selective-state recurrence runs as a ``lax.scan`` over time (state
(H, d_head, N) stays VMEM-resident across steps); decode is the single-step
recurrence carrying (conv buffer, ssd state).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


class MambaState(NamedTuple):
    conv_x: jnp.ndarray   # (B, K-1, din) conv history for x
    conv_B: jnp.ndarray   # (B, K-1, N)
    conv_C: jnp.ndarray   # (B, K-1, N)
    ssd: jnp.ndarray      # (B, H, d_head, N) f32 recurrent state


def mamba_dims(cfg: ModelConfig):
    din = cfg.ssm_expand * cfg.d_model
    heads = cfg.ssm_heads
    d_head = din // heads
    N = cfg.ssm_state
    return din, heads, d_head, N


def mamba_init(key, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    din, H, d_head, N = mamba_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "w_z": dense_init(ks[0], d, din, dtype),
        "w_x": dense_init(ks[1], d, din, dtype),
        "w_B": dense_init(ks[2], d, N, dtype),
        "w_C": dense_init(ks[3], d, N, dtype),
        "w_dt": dense_init(ks[4], d, H, dtype),
        "conv_x": (jax.random.normal(ks[5], (4, din)) * 0.1).astype(dtype),
        "conv_xb": jnp.zeros((din,), dtype),
        "conv_B": (jax.random.normal(ks[6], (4, N)) * 0.1).astype(dtype),
        "conv_Bb": jnp.zeros((N,), dtype),
        "conv_C": (jax.random.normal(ks[5], (4, N)) * 0.1).astype(dtype),
        "conv_Cb": jnp.zeros((N,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "gn_scale": jnp.ones((din,), dtype),            # gated RMSNorm
        "out_proj": dense_init(ks[2], din, d, dtype),
    }


def _causal_conv(seq: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, history: jnp.ndarray):
    """Depthwise causal conv. seq (B,T,C), w (K,C), history (B,K-1,C)."""
    K = w.shape[0]
    padded = jnp.concatenate([history, seq], axis=1)     # (B, T+K-1, C)
    out = sum(padded[:, i : i + seq.shape[1], :] * w[i] for i in range(K))
    new_hist = padded[:, -(K - 1) :, :]
    return jax.nn.silu(out + b), new_hist


SSD_CHUNK = 64


def ssd_chunked(xh, Bm, Cm, dt, A, state, chunk: int = SSD_CHUNK):
    """Chunked SSD (the Mamba2 paper's algorithm, TPU-adapted).

    Scalar-per-head decay makes the intra-chunk pairwise matrix (B,H,C,C) —
    no head_dim blowup. Exactly equals ``ssd_scan`` (tests).
    """
    B, T, H, P = xh.shape
    N = Bm.shape[-1]
    assert T % chunk == 0, (T, chunk)
    C = chunk
    nc = T // C

    def resh(x, last):
        return jnp.moveaxis(x.reshape((B, nc, C) + last).astype(jnp.float32), 1, 0)

    xc = resh(xh, (H, P))
    bc = resh(Bm, (N,))
    cc = resh(Cm, (N,))
    dc = resh(dt, (H,))
    Af = A.astype(jnp.float32)

    def chunk_step(S, inp):
        xb, bb, cb, db = inp                     # (B,C,H,P),(B,C,N),(B,C,N),(B,C,H)
        a = Af[None, None, :] * db               # (B,C,H) <= 0
        cum = jnp.cumsum(a, axis=1)              # inclusive
        # intra: y_t = sum_{s<=t} exp(cum_t - cum_s) dt_s (C_t . B_s) x_s
        expo = cum[:, :, None, :] - cum[:, None, :, :]        # (B,C,C,H)
        mask = (jnp.arange(C)[:, None] >= jnp.arange(C)[None, :])[None, :, :, None]
        decay = jnp.where(mask, jnp.exp(expo), 0.0)
        cb_dot_bb = jnp.einsum("btn,bsn->bts", cb, bb)        # (B,C,C)
        M = cb_dot_bb[..., None] * decay * db[:, None, :, :]  # (B,t,s,H)
        y = jnp.einsum("btsh,bshp->bthp", M, xb)
        # inter: y_t += exp(cum_t) * C_t . S
        rd = jnp.exp(cum)                                     # (B,C,H)
        y = y + rd[..., None] * jnp.einsum("btn,bhpn->bthp", cb, S)
        # state: S' = exp(cum_C) S + sum_s exp(cum_C - cum_s) dt_s x_s B_s
        total = cum[:, -1]                                    # (B,H)
        xdec = xb * (jnp.exp(total[:, None] - cum) * db)[..., None]
        S = jnp.exp(total)[..., None, None] * S + jnp.einsum(
            "bshp,bsn->bhpn", xdec, bb
        )
        return S, y

    state, ys = jax.lax.scan(chunk_step, state.astype(jnp.float32), (xc, bc, cc, dc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P)
    return y, state


def ssd_scan(xh, Bm, Cm, dt, A, state):
    """xh (B,T,H,P), Bm/Cm (B,T,N), dt (B,T,H), A (H,), state (B,H,P,N) f32.
    Returns y (B,T,H,P), new_state."""
    xT = jnp.moveaxis(xh, 1, 0).astype(jnp.float32)
    BT = jnp.moveaxis(Bm, 1, 0).astype(jnp.float32)
    CT = jnp.moveaxis(Cm, 1, 0).astype(jnp.float32)
    dT = jnp.moveaxis(dt, 1, 0).astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(S, inp):
        xt, bt, ct, dtt = inp                            # (B,H,P),(B,N),(B,N),(B,H)
        decay = jnp.exp(Af[None, :] * dtt)               # (B,H)
        upd = (dtt[..., None, None] * xt[..., :, None]) * bt[:, None, None, :]
        S = decay[..., None, None] * S + upd             # (B,H,P,N)
        y = jnp.einsum("bhpn,bn->bhp", S, ct)
        return S, y

    state, ys = jax.lax.scan(step, state.astype(jnp.float32), (xT, BT, CT, dT))
    return jnp.moveaxis(ys, 0, 1), state


def mamba_apply(
    cfg: ModelConfig, p: dict, x: jnp.ndarray, state: MambaState
) -> Tuple[jnp.ndarray, MambaState]:
    """x: (B, T, d) -> (B, T, d). Sequential over T; T=1 is the decode step."""
    B, T, d = x.shape
    din, H, d_head, N = mamba_dims(cfg)
    z = x @ p["w_z"]                                     # (B,T,din)
    xs = x @ p["w_x"]
    Bs = x @ p["w_B"]
    Cs = x @ p["w_C"]
    dt = x @ p["w_dt"]                                   # (B,T,H)

    xs, new_cx = _causal_conv(xs, p["conv_x"], p["conv_xb"], state.conv_x)
    Bs, new_cb = _causal_conv(Bs, p["conv_B"], p["conv_Bb"], state.conv_B)
    Cs, new_cc = _causal_conv(Cs, p["conv_C"], p["conv_Cb"], state.conv_C)
    xh = xs.reshape(B, T, H, d_head)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"])

    if T > 1 and T % SSD_CHUNK == 0:
        y, new_ssd = ssd_chunked(xh, Bs, Cs, dt, A, state.ssd)
    else:
        y, new_ssd = ssd_scan(xh, Bs, Cs, dt, A, state.ssd)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, din)

    # gated RMSNorm (Mamba2): norm(y * silu(z)) * scale
    g = y * jax.nn.silu(z.astype(jnp.float32))
    g = g * jax.lax.rsqrt(jnp.mean(g * g, axis=-1, keepdims=True) + 1e-6)
    g = (g * p["gn_scale"].astype(jnp.float32)).astype(x.dtype)
    return g @ p["out_proj"], MambaState(new_cx, new_cb, new_cc, new_ssd)


def mamba_empty_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    din, H, d_head, N = mamba_dims(cfg)
    return MambaState(
        conv_x=jnp.zeros((batch, 3, din), dtype),
        conv_B=jnp.zeros((batch, 3, N), dtype),
        conv_C=jnp.zeros((batch, 3, N), dtype),
        ssd=jnp.zeros((batch, H, d_head, N), jnp.float32),
    )
