"""Expert-parallel MoE dispatch via shard_map + all_to_all (§Perf).

Why: under plain pjit, the sort-based dispatch's scatter has data-dependent
indices, so GSPMD falls back to replicate-and-mask — every device
materializes the full (E, C, d) buffer and all-reduces it (measured:
~47 TB/device/step on deepseek train_4k). The canonical fix is explicit
expert parallelism: tokens stay sharded, each device routes its own tokens,
ONE all_to_all over the ``model`` axis moves token rows to their expert's
shard, experts compute locally, one all_to_all returns them. Per-device
traffic: ~2 * k * T_local * d bytes — the textbook MoE a2a volume.

Used by the --opt dry-run profile for the pod-granularity MoE archs; expert
weights are replicated over ``data`` and sharded over ``model`` (fits: kimi
2.1 GB/device, deepseek 0.5 GB/device).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.moe import _expert_ffn, route


def _bucket_by(ids: jnp.ndarray, values: jnp.ndarray, num_buckets: int,
               capacity: int):
    """Scatter rows into (num_buckets, capacity, ...) by ``ids`` (ragged,
    capacity-dropped). Returns (buffer, keep mask, slot per row)."""
    n = ids.shape[0]
    order = jnp.argsort(ids)
    sid = ids[order]
    counts = jax.ops.segment_sum(jnp.ones_like(sid), sid, num_segments=num_buckets)
    start = jnp.cumsum(counts) - counts
    rank = jnp.arange(n) - start[sid]
    keep = rank < capacity
    slot = jnp.where(keep, rank, capacity)
    buf = jnp.zeros((num_buckets, capacity + 1) + values.shape[1:], values.dtype)
    buf = buf.at[sid, slot].set(values[order])
    return buf[:, :capacity], order, keep, sid, slot


def make_moe_shard_map(cfg: ModelConfig, mesh, capacity_factor: float = 2.0):
    """Returns moe_fn(params, x) with x (B, S, d); B%data==0, S%model==0."""
    n_model = mesh.shape["model"]
    E = cfg.num_experts
    assert E % n_model == 0
    E_loc = E // n_model

    def local_moe(params, x):
        """Runs per device inside shard_map; x (b_loc, s_loc, d)."""
        d = x.shape[-1]
        flat = x.reshape(-1, d)
        T = flat.shape[0]
        k = cfg.experts_per_token
        gates, top_idx, aux = route(cfg, params, flat)

        # --- route to destination model-shards --------------------------
        flat_e = top_idx.reshape(-1)                     # (T*k,) global expert
        flat_t = jnp.repeat(jnp.arange(T), k)
        flat_g = gates.reshape(-1)
        dest = flat_e // E_loc                           # model shard id
        cap_send = int(math.ceil(T * k / n_model * capacity_factor))
        cap_send = -(-cap_send // 8) * 8

        payload = jnp.concatenate(
            [flat[flat_t],
             (flat_e + 1)[:, None].astype(flat.dtype),   # +1: 0 = padding row
             flat_g[:, None].astype(flat.dtype)], axis=1)
        send, order, keep, sid, slot = _bucket_by(dest, payload, n_model, cap_send)

        # --- the MoE all-to-all ------------------------------------------
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                                  tiled=True)            # (n_model*cap, d+2)

        rx = recv.reshape(-1, d + 2)
        r_tok = rx[:, :d]
        r_raw = rx[:, d].astype(jnp.int32)
        r_valid = r_raw > 0                              # 0 = padding row
        r_e_local = jnp.where(r_valid, (r_raw - 1) % E_loc, E_loc)  # E_loc = trash
        r_gate = rx[:, d + 1]

        # --- local expert compute (padding rows land in bucket E_loc) -----
        cap_e = int(math.ceil(rx.shape[0] / E_loc * 1.5))
        cap_e = -(-cap_e // 8) * 8
        ebuf, eorder, ekeep, esid, eslot = _bucket_by(
            r_e_local, r_tok, E_loc + 1, cap_e)
        eout = _expert_ffn(cfg, params, ebuf[:E_loc])    # local (E_loc, cap, d)
        eout = jnp.concatenate(
            [eout, jnp.zeros((1,) + eout.shape[1:], eout.dtype)], axis=0)
        back = jnp.zeros((rx.shape[0], d), flat.dtype)
        back = back.at[eorder].set(
            jnp.where(ekeep[:, None], eout[esid, jnp.minimum(eslot, cap_e - 1)], 0.0)
        )
        back = back * (r_gate * r_valid.astype(r_gate.dtype))[:, None]

        # --- return trip --------------------------------------------------
        ret = jax.lax.all_to_all(
            back.reshape(n_model, cap_send, d), "model",
            split_axis=0, concat_axis=0, tiled=True,
        ).reshape(n_model, cap_send, d)

        # undo the send bucketing: row (sid, slot) came from flat_t[order]
        y_pairs = jnp.where(keep[:, None], ret[sid, jnp.minimum(slot, cap_send - 1)], 0.0)
        contrib = jnp.zeros((T * cfg.experts_per_token, d), flat.dtype)
        contrib = contrib.at[order].set(y_pairs)
        y = jax.ops.segment_sum(contrib, flat_t, num_segments=T)
        aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(x.shape), aux

    # weights: experts sharded over model, replicated over data
    wspec = {
        "router": P(None, None),
        "wi": P("model", None, None),
        "wo": P("model", None, None),
    }
    if cfg.act in ("swiglu", "geglu"):
        wspec["wg"] = P("model", None, None)

    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    all_axes = data_axes + ("model",)
    data_axes = data_axes if len(data_axes) > 1 else data_axes[0]

    fn = jax.shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(wspec, P(data_axes, "model", None)),
        out_specs=(P(data_axes, "model", None), P()),
        check_vma=False,
    )

    def moe_fn(params, x):
        routed = {k: v for k, v in params.items() if k in wspec}
        y, aux = fn(routed, x)
        return y, aux

    return moe_fn
