"""Batching utilities + the token pipeline for the assigned LLM archs."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class MinibatchSampler:
    """Uniform with-replacement minibatches from a node-local dataset."""

    def __init__(self, x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0):
        self.x, self.y, self.batch = x, y, batch
        self.rng = np.random.default_rng(seed)

    def next(self) -> Dict[str, np.ndarray]:
        idx = self.rng.integers(0, len(self.y), self.batch)
        return {"x": self.x[idx], "y": self.y[idx]}


class TokenSampler:
    """Synthetic token stream for LLM local training (dry-run scale tests)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.rng = np.random.default_rng(seed)

    def next(self) -> Dict[str, np.ndarray]:
        # Zipf-ish marginal so the loss has structure to learn
        z = self.rng.zipf(1.3, size=(self.batch, self.seq))
        toks = (z % self.vocab).astype(np.int32)
        return {"tokens": toks, "labels": toks}


def lines_to_batches(lines: np.ndarray, batch: int, seed: int = 0) -> Iterator[Dict]:
    rng = np.random.default_rng(seed)
    while True:
        idx = rng.integers(0, len(lines), batch)
        yield {"tokens": lines[idx]}
