from repro.data.pipeline import MinibatchSampler, TokenSampler, lines_to_batches
from repro.data.synthetic import (
    CharCorpus,
    ImageDataset,
    MnistLike,
    NUM_CLASSES,
    VOCAB,
    add_backdoor_trigger,
    char_partition,
    paper_partition,
)

__all__ = [
    "MinibatchSampler",
    "TokenSampler",
    "lines_to_batches",
    "CharCorpus",
    "ImageDataset",
    "MnistLike",
    "NUM_CLASSES",
    "VOCAB",
    "add_backdoor_trigger",
    "char_partition",
    "paper_partition",
]
