"""Offline synthetic datasets with the paper's shapes and non-IID structure.

The container has no internet, so MNIST / Shakespeare are replaced by
deterministic generators that preserve what the experiments actually use:

* ``mnist_like``  — 10-class 28x28x1 images: smooth class prototypes +
  per-sample noise + random shifts. Linearly separable-ish but not trivially
  so; a 2-layer CNN reaches high accuracy in a few hundred steps, mirroring
  the paper's MNIST curves (EXPERIMENTS.md flags the absolute-number caveat).
* ``char_corpus`` — "Shakespeare-like" character stream from per-role Markov
  chains over a 90-char alphabet; 80-char lines, highly unbalanced roles
  (the paper's non-IID source).

Both are pure-numpy, seeded, and sized by arguments so tests run small.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

NUM_CLASSES = 10
VOCAB = 90  # printable chars


# ---------------------------------------------------------------------------
# image task
# ---------------------------------------------------------------------------


def _prototypes(rng: np.random.Generator, image_size: int) -> np.ndarray:
    """Smooth per-class patterns: sum of a few random 2-D cosines."""
    protos = np.zeros((NUM_CLASSES, image_size, image_size), np.float32)
    yy, xx = np.mgrid[0:image_size, 0:image_size].astype(np.float32) / image_size
    for c in range(NUM_CLASSES):
        for _ in range(4):
            fx, fy = rng.uniform(0.5, 3.0, 2)
            px, py = rng.uniform(0, 2 * np.pi, 2)
            protos[c] += np.cos(2 * np.pi * fx * xx + px) * np.cos(2 * np.pi * fy * yy + py)
        protos[c] /= np.max(np.abs(protos[c]))
    return protos


@dataclass
class ImageDataset:
    x: np.ndarray   # (N, H, W, 1) float32 in [0, 1]
    y: np.ndarray   # (N,) int32

    def __len__(self):
        return len(self.y)


class MnistLike:
    """Deterministic generator; samples are reproducible given (seed, split)."""

    def __init__(self, image_size: int = 28, seed: int = 0, noise: float = 0.3):
        self.image_size = image_size
        self.noise = noise
        self.protos = _prototypes(np.random.default_rng(seed), image_size)

    def sample(self, rng: np.random.Generator, labels: np.ndarray) -> ImageDataset:
        n = len(labels)
        s = self.image_size
        base = self.protos[labels]                          # (n, s, s)
        shift = rng.integers(-2, 3, size=(n, 2))
        imgs = np.empty_like(base)
        for i in range(n):                                  # small n per shard
            imgs[i] = np.roll(base[i], tuple(shift[i]), axis=(0, 1))
        imgs = imgs + rng.normal(0, self.noise, imgs.shape).astype(np.float32)
        imgs = (imgs - imgs.min()) / (imgs.max() - imgs.min() + 1e-9)
        return ImageDataset(imgs[..., None].astype(np.float32), labels.astype(np.int32))

    def balanced(self, rng: np.random.Generator, n: int) -> ImageDataset:
        labels = rng.integers(0, NUM_CLASSES, n)
        return self.sample(rng, labels)


def add_backdoor_trigger(x: np.ndarray, square: int = 5) -> np.ndarray:
    """Paper §V.A: white square in the upper-left corner."""
    out = x.copy()
    out[:, :square, :square, :] = 1.0
    return out


# ---------------------------------------------------------------------------
# the paper's exact non-IID partition (Section V.A.1)
# ---------------------------------------------------------------------------


def paper_partition(
    gen: MnistLike,
    num_nodes: int = 100,
    shard_size: int = 200,
    uniform_per_node: int = 200,
    seed: int = 1,
) -> List[ImageDataset]:
    """2/3 of the train set sorted by label -> 200 shards of ``shard_size``,
    2 shards per node; the remaining 1/3 spread uniformly.

    Each node ends up with most samples of two digits + a uniform sprinkle.
    """
    rng = np.random.default_rng(seed)
    shards_per_node = 2
    total_shards = num_nodes * shards_per_node
    # sorted-by-label shard labels: shard i is entirely digit (i * 10 // total)
    reps = -(-total_shards // NUM_CLASSES)  # ceil
    shard_digit = np.repeat(np.arange(NUM_CLASSES), reps)[:total_shards]
    rng.shuffle(shard_digit)

    nodes = []
    for i in range(num_nodes):
        labels = []
        for s in range(shards_per_node):
            digit = shard_digit[i * shards_per_node + s]
            labels.append(np.full(shard_size, digit, np.int64))
        labels.append(rng.integers(0, NUM_CLASSES, uniform_per_node))
        labels = np.concatenate(labels)
        nodes.append(gen.sample(rng, labels))
    return nodes


# ---------------------------------------------------------------------------
# char-LM task
# ---------------------------------------------------------------------------


class CharCorpus:
    """Role-conditioned Markov text: each role has its own transition matrix
    biased toward a role-specific subset of the alphabet (non-IID source)."""

    def __init__(self, num_roles: int = 30, seed: int = 0, order_bias: float = 6.0):
        rng = np.random.default_rng(seed)
        base = rng.dirichlet(np.ones(VOCAB) * 0.3, size=VOCAB).astype(np.float64)
        self.mats = []
        for r in range(num_roles):
            fav = rng.choice(VOCAB, size=12, replace=False)
            m = base.copy()
            m[:, fav] *= order_bias
            m /= m.sum(axis=1, keepdims=True)
            self.mats.append(m.astype(np.float64))
        self.num_roles = num_roles

    def lines(self, rng: np.random.Generator, role: int, n_lines: int, line_len: int = 80):
        m = self.mats[role % self.num_roles]
        out = np.empty((n_lines, line_len), np.int32)
        for i in range(n_lines):
            c = rng.integers(0, VOCAB)
            for t in range(line_len):
                out[i, t] = c
                c = rng.choice(VOCAB, p=m[c])
        return out


def char_partition(
    corpus: CharCorpus, num_nodes: int, lines_per_node: int, seed: int = 2
) -> List[np.ndarray]:
    """Random role per node (paper: roles randomly assigned to 100 nodes)."""
    rng = np.random.default_rng(seed)
    roles = rng.integers(0, corpus.num_roles, num_nodes)
    return [
        corpus.lines(np.random.default_rng(seed + 100 + i), int(roles[i]), lines_per_node)
        for i in range(num_nodes)
    ]
