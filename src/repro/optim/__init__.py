from repro.optim.optimizers import (
    OptState,
    adam_init,
    init_optimizer,
    make_optimizer,
    sgd_init,
)
from repro.optim.schedules import constant_lr, cosine_lr, warmup_cosine

__all__ = [
    "OptState",
    "adam_init",
    "init_optimizer",
    "make_optimizer",
    "sgd_init",
    "constant_lr",
    "cosine_lr",
    "warmup_cosine",
]
