"""Minimal, pytree-generic optimizers (no external deps).

``make_optimizer(train_cfg)`` returns ``(init_fn, update_fn)`` with
``update_fn(grads, state, params, lr) -> (new_params, new_state)``.
The paper's nodes run plain SGD (lr 0.002 CNN / 0.3 LSTM); momentum and Adam
exist for the larger architectures' local training.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment / momentum (pytree or None)
    nu: Any          # second moment (pytree or None)


def _zeros_like_f32(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd_init(params) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), None, None)


def momentum_init(params) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), None)


def adam_init(params) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params), _zeros_like_f32(params))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def make_optimizer(cfg: TrainConfig) -> Tuple[Callable, Callable]:
    wd = cfg.weight_decay

    def apply_wd(p, g):
        if wd:
            return g + wd * p.astype(jnp.float32)
        return g

    if cfg.optimizer == "sgd":
        init = sgd_init

        def update(grads, state, params, lr):
            if cfg.grad_clip:
                grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
            new_params = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32) - lr * apply_wd(p, g.astype(jnp.float32))).astype(p.dtype),
                params,
                grads,
            )
            return new_params, OptState(state.step + 1, None, None)

        return init, update

    if cfg.optimizer == "momentum":
        init = momentum_init

        def update(grads, state, params, lr):
            if cfg.grad_clip:
                grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
            mu = jax.tree_util.tree_map(
                lambda m, g: cfg.momentum * m + g.astype(jnp.float32), state.mu, grads
            )
            new_params = jax.tree_util.tree_map(
                lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, mu
            )
            return new_params, OptState(state.step + 1, mu, None)

        return init, update

    if cfg.optimizer == "adam":
        init = adam_init
        b1, b2, eps = 0.9, 0.95, 1e-8

        def update(grads, state, params, lr):
            if cfg.grad_clip:
                grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
            step = state.step + 1
            mu = jax.tree_util.tree_map(
                lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
            )
            nu = jax.tree_util.tree_map(
                lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                state.nu,
                grads,
            )
            bc1 = 1 - b1 ** step.astype(jnp.float32)
            bc2 = 1 - b2 ** step.astype(jnp.float32)

            def upd(p, m, n):
                d = (m / bc1) / (jnp.sqrt(n / bc2) + eps)
                return (p.astype(jnp.float32) - lr * (d + wd * p.astype(jnp.float32))).astype(p.dtype)

            new_params = jax.tree_util.tree_map(upd, params, mu, nu)
            return new_params, OptState(step, mu, nu)

        return init, update

    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")


def init_optimizer(cfg: TrainConfig, params) -> OptState:
    init, _ = make_optimizer(cfg)
    return init(params)
