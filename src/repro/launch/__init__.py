# NOTE: do not import repro.launch.dryrun here — it sets XLA_FLAGS at import
# time and must only be imported as the __main__ entry point.
from repro.launch import hlo_analysis, mesh, steps

__all__ = ["hlo_analysis", "mesh", "steps"]
