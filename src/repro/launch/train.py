"""DAG-FL training driver — the end-to-end production path.

    python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 50 --nodes 4

Runs the jitted ``dagfl_train_step`` (selection -> Eq.-1 aggregation ->
local train -> cross-validation scoring -> frontier publish) on whatever
mesh the host provides (1 CPU device here; the same code lowers on the
16x16 / 2x16x16 production meshes — see repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import get_arch, list_archs
from repro.configs.base import DagFLConfig, ModelConfig, TrainConfig
from repro.data.pipeline import TokenSampler
from repro.models import build_model
from repro.sharding import fl_step as fl_lib


def small_100m() -> ModelConfig:
    """~100M-param dense config for the end-to-end example driver."""
    import dataclasses

    return dataclasses.replace(
        get_arch("qwen3-0.6b"),
        name="qwen3-100m",
        num_layers=8,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        head_dim=64,
        d_ff=1536,
        vocab_size=32768,
        dtype="float32",
    )


def run(
    cfg: ModelConfig,
    steps: int = 50,
    nodes: int = 4,
    batch_per_node: int = 4,
    seq_len: int = 128,
    lr: float = 3e-3,
    seed: int = 0,
    log_every: int = 10,
    checkpoint: str = "",
):
    model = build_model(cfg)
    tcfg = TrainConfig(optimizer="sgd", learning_rate=lr)
    dcfg = DagFLConfig(num_nodes=nodes, alpha=min(4, nodes), k=2, tau_max=1e9)
    step_fn = jax.jit(
        fl_lib.make_dagfl_train_step(model, cfg, tcfg, dcfg, nodes)
    )

    key = jax.random.PRNGKey(seed)
    init_keys = jax.random.split(key, nodes)
    stacked = jax.vmap(model.init)(init_keys)
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(stacked)) // nodes
    print(f"arch={cfg.name} params/node={n_params/1e6:.1f}M nodes={nodes} "
          f"batch/node={batch_per_node} seq={seq_len}")

    frontier = fl_lib.init_frontier(nodes)
    samplers = [
        TokenSampler(cfg.vocab_size, batch_per_node, seq_len, seed=seed + i)
        for i in range(nodes)
    ]
    val = TokenSampler(cfg.vocab_size, 1, min(seq_len, 512), seed=seed + 999)
    val_tokens = jnp.stack([jnp.asarray(val.next()["tokens"][0]) for _ in range(nodes)])
    val_batch = {"tokens": val_tokens[:, None, :]}

    t0 = time.time()
    for step in range(steps):
        toks = np.stack([s.next()["tokens"] for s in samplers])   # (N, b, S)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
        if cfg.frontend_tokens:
            batch["frontend"] = jnp.zeros(
                (nodes, batch_per_node, cfg.frontend_tokens, cfg.frontend_dim)
            )
            val_batch.setdefault(
                "frontend",
                jnp.zeros((nodes, 1, cfg.frontend_tokens, cfg.frontend_dim)),
            )
        stacked, frontier, metrics = step_fn(
            stacked, frontier, batch, val_batch, jax.random.PRNGKey(seed * 7 + step)
        )
        if (step + 1) % log_every == 0 or step == 0:
            dt = (time.time() - t0) / (step + 1)
            print(f"step {step+1:4d}  mean_val_acc={float(metrics['mean_val_acc']):.4f}  "
                  f"sel_entropy={float(metrics['selection_entropy']):.3f}  "
                  f"{dt:.2f}s/step")
    if checkpoint:
        save_pytree(checkpoint, {"params": stacked, "frontier": frontier},
                    meta={"arch": cfg.name, "steps": steps})
        print(f"checkpoint -> {checkpoint}.npz")
    return stacked, frontier


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs() + ["100m"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    if args.arch == "100m":
        cfg = small_100m()
    else:
        cfg = get_arch(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
    run(cfg, args.steps, args.nodes, args.batch_per_node, args.seq_len,
        args.lr, checkpoint=args.checkpoint)


if __name__ == "__main__":
    main()
