"""Step builders + input specs for every (arch x shape x mesh) combination.

``plan(arch, shape, mesh, fl_mode)`` returns a ``StepPlan``:
  fn            — the jittable step function,
  args          — ShapeDtypeStruct stand-ins for every input (no allocation),
  in_specs      — PartitionSpec pytree matching ``args``,
  out_specs     — PartitionSpecs for outputs (params/caches keep their spec).

Shapes follow the assignment block:
  train_4k    -> dagfl_train_step (FL archs) / train_step (pod-granularity)
  prefill_32k -> prefill building the serving cache
  decode_32k  -> decode_step: ONE token against a seq_len cache
  long_500k   -> decode_step at 524288 (sub-quadratic variants only)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import DagFLConfig, ModelConfig, ShapeSpec, TrainConfig
from repro.configs.registry import POD_GRANULARITY
from repro.models import build_model
from repro.optim import make_optimizer
from repro.sharding import fl_step as fl_lib
from repro.sharding.specs import batch_specs, cache_specs, param_specs

VAL_SEQ = 512      # per-node validation tokens for DAG-FL scoring
VAL_BATCH = 1

# §Perf optimization profile (dryrun --opt). Baseline stays the default.
OPT_PROFILE = {
    "moe_impl": "expert_parallel",   # shard_map all-to-all dispatch
    "microbatches": 2,         # grad accumulation halves the remat stash
    "agg_dtype": "bfloat16",   # halves Eq.-1 aggregation collective bytes
    "val_seq": 128,            # scoring budget (phi_1 knob of the paper)
    # replicas smaller than this run ONE FL NODE PER DEVICE (no tensor
    # parallelism): kills the per-layer TP all-reduces that dominate small
    # archs' collective term, and runs DAG-FL at 256-node scale.
    "node_per_device_max_bytes": 4e9,
}


@dataclass
class StepPlan:
    name: str
    fn: Callable
    args: tuple
    in_specs: tuple
    out_specs: Any
    model_cfg: ModelConfig
    notes: str = ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _eval_params(model, cfg):
    """Parameter ShapeDtypeStructs without allocating."""
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def _data_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _stack_shapes(tree, n):
    return jax.tree_util.tree_map(
        lambda l: _sds((n,) + tuple(l.shape), l.dtype), tree
    )


def _prefix_specs(tree_specs, prefix):
    return jax.tree_util.tree_map(
        lambda s: P(*((prefix,) + tuple(s))), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _frontend_sds(cfg: ModelConfig, lead: tuple):
    if not cfg.frontend_tokens:
        return None
    return _sds(lead + (cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)


# ---------------------------------------------------------------------------
# train_4k
# ---------------------------------------------------------------------------


def plan_train(cfg: ModelConfig, shape: ShapeSpec, mesh, fl_mode: Optional[str] = None,
               opt: bool = False) -> StepPlan:
    val_seq = VAL_SEQ
    microbatches = 1
    agg_dtype = jnp.float32
    if opt:
        cfg = dataclasses.replace(cfg, moe_impl=OPT_PROFILE["moe_impl"])
        val_seq = OPT_PROFILE["val_seq"]
        microbatches = OPT_PROFILE["microbatches"]
        agg_dtype = jnp.dtype(OPT_PROFILE["agg_dtype"])
        if cfg.is_moe():
            from repro.models.moe import set_shard_map_mesh

            set_shard_map_mesh(mesh)
    model = build_model(cfg)
    tcfg = TrainConfig(optimizer="sgd", learning_rate=1e-3, remat=True)
    dcfg = DagFLConfig()
    fl = fl_mode if fl_mode is not None else (
        "pod" if cfg.name in POD_GRANULARITY else "node"
    )
    params_sds = _eval_params(model, cfg)

    replica_bytes = cfg.param_count() * 2
    node_per_device = (
        opt
        and fl == "node"
        and replica_bytes <= OPT_PROFILE["node_per_device_max_bytes"]
        and shape.global_batch % mesh.size == 0
    )

    if fl == "node" or (fl == "pod" and "pod" in mesh.axis_names):
        # ----- DAG-FL step: node-stacked replicas over the data/pod axes ---
        if node_per_device:
            # §Perf: one node per device — no tensor parallelism at all
            N = mesh.size
            node_axes = tuple(mesh.axis_names)
        elif fl == "node":
            N = _data_size(mesh)
            node_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        else:
            N = mesh.shape["pod"]
            node_axes = ("pod",)
        node_axes = node_axes if len(node_axes) > 1 else node_axes[0]
        per_node = shape.global_batch // N
        assert per_node >= 1, f"batch {shape.global_batch} < nodes {N}"
        if node_per_device:
            microbatches = 1          # batch/node is already minimal

        step = fl_lib.make_dagfl_train_step(
            model, cfg, tcfg, dcfg, N,
            microbatches=microbatches, agg_dtype=agg_dtype,
            ring_window=(8 if node_per_device else 0),
        )
        stacked_params = _stack_shapes(params_sds, N)
        frontier = jax.eval_shape(lambda: fl_lib.init_frontier(N))
        batch = {
            "tokens": _sds((N, per_node, shape.seq_len), jnp.int32),
            "labels": _sds((N, per_node, shape.seq_len), jnp.int32),
        }
        fe = _frontend_sds(cfg, (N, per_node))
        if fe is not None:
            batch["frontend"] = fe
        val = {"tokens": _sds((N, VAL_BATCH, val_seq), jnp.int32)}
        vfe = _frontend_sds(cfg, (N, VAL_BATCH))
        if vfe is not None:
            val["frontend"] = vfe
        key = _sds((2,), jnp.uint32)

        if node_per_device:
            # replica fully local: inner dims replicated (= per-device)
            p_specs = jax.tree_util.tree_map(
                lambda l: P(*((None,) * l.ndim)), params_sds
            )
        else:
            inner_mode = "model" if fl == "node" else "plain"
            p_specs = param_specs(cfg, params_sds, mesh, mode=inner_mode)
        p_specs = _prefix_specs(p_specs, node_axes)
        f_specs = jax.tree_util.tree_map(lambda l: P(), frontier)
        b_specs = {
            k: P(*((node_axes,) + (None,) * (v.ndim - 1))) for k, v in batch.items()
        }
        v_specs = {
            k: P(*((node_axes,) + (None,) * (v.ndim - 1))) for k, v in val.items()
        }
        args = (stacked_params, frontier, batch, val, key)
        in_specs = (p_specs, f_specs, b_specs, v_specs, P(None))
        out_specs = (p_specs, f_specs, jax.tree_util.tree_map(lambda _: P(), {
            "mean_val_acc": 0, "selection_entropy": 0}))
        return StepPlan(
            f"dagfl_train[{fl}]", step, args, in_specs, out_specs, cfg,
            notes=f"N={N} per_node_batch={per_node}",
        )

    # ----- plain train step (pod-granularity arch on a single pod) --------
    _, update = make_optimizer(tcfg)

    def train_step(params, batch, key):
        def loss_fn(p):
            total, metrics = model.loss(p, batch)
            return total, metrics
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        from repro.optim.optimizers import OptState
        new_params, _ = update(grads, OptState(jnp.zeros((), jnp.int32), None, None),
                               params, tcfg.learning_rate)
        return new_params, dict(metrics, loss=total)

    batch = {
        "tokens": _sds((shape.global_batch, shape.seq_len), jnp.int32),
        "labels": _sds((shape.global_batch, shape.seq_len), jnp.int32),
    }
    fe = _frontend_sds(cfg, (shape.global_batch,))
    if fe is not None:
        batch["frontend"] = fe
    key = _sds((2,), jnp.uint32)
    p_specs = param_specs(cfg, params_sds, mesh, mode="plain")
    b_specs = batch_specs(mesh, batch)
    args = (params_sds, batch, key)
    in_specs = (p_specs, b_specs, P(None))
    out_specs = (p_specs, jax.tree_util.tree_map(lambda _: P(), {
        "xent": 0, "aux": 0, "loss": 0}))
    return StepPlan("train", train_step, args, in_specs, out_specs, cfg)


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def plan_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh) -> StepPlan:
    model = build_model(cfg)
    params_sds = _eval_params(model, cfg)

    def prefill(params, tokens, frontend=None):
        return model.prefill(params, tokens, frontend,
                             cache_len=shape.seq_len + cfg.frontend_tokens)

    tokens = _sds((shape.global_batch, shape.seq_len - cfg.frontend_tokens), jnp.int32)
    fe = _frontend_sds(cfg, (shape.global_batch,))
    p_specs = param_specs(cfg, params_sds, mesh, mode="plain")
    t_specs = P(*(("data",) if shape.global_batch % _data_size(mesh) == 0 else (None,))
                + (None,))
    # out: (logits, cache)
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len + cfg.frontend_tokens)
    )
    c_specs = cache_specs(cfg, mesh, cache_sds)
    out_specs = (P(None, None, "model"), c_specs)
    args = (params_sds, tokens) + ((fe,) if fe is not None else ())
    in_specs = (p_specs, t_specs) + ((P(None, None, None),) if fe is not None else ())
    return StepPlan("prefill", prefill, args, in_specs, out_specs, cfg)


def plan_decode(cfg: ModelConfig, shape: ShapeSpec, mesh) -> StepPlan:
    model = build_model(cfg)
    params_sds = _eval_params(model, cfg)

    def decode(params, token, cache):
        return model.decode_step(params, token, cache)

    B = shape.global_batch
    token = _sds((B, 1), jnp.int32)
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len, length=shape.seq_len - 1)
    )
    p_specs = param_specs(cfg, params_sds, mesh, mode="plain")
    c_specs = cache_specs(cfg, mesh, cache_sds)
    t_spec = P(("data" if B % _data_size(mesh) == 0 and B > 1 else None), None)
    out_specs = (P(None, None, "model"), c_specs)
    args = (params_sds, token, cache_sds)
    in_specs = (p_specs, t_spec, c_specs)
    return StepPlan("decode", decode, args, in_specs, out_specs, cfg,
                    notes=f"cache_len={shape.seq_len}")


def plan_for(cfg: ModelConfig, shape: ShapeSpec, mesh, fl_mode=None, opt: bool = False) -> StepPlan:
    if shape.kind == "train":
        return plan_train(cfg, shape, mesh, fl_mode, opt=opt)
    if shape.kind == "prefill":
        return plan_prefill(cfg, shape, mesh)
    return plan_decode(cfg, shape, mesh)
