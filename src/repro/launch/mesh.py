"""Mesh construction for the production deployment.

Single pod : (data=16, model=16)            = 256 chips of TPU v5e
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Small host-device mesh for sharding tests (run in a subprocess with
    --xla_force_host_platform_device_count set)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axis names that act as the data/FL-node dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
