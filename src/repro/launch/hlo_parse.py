"""Trip-count-aware HLO module analyzer.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (verified on this
jax build), which silently drops ~L x the flops/bytes/collective traffic of
scan-over-layers models. This module parses the optimized HLO text into
computations, finds while-loop trip counts from their condition computations,
and aggregates, per computation and transitively:

  * dot flops (2 * result_elems * contracted_elems),
  * HBM bytes (operand + result shape bytes of top-level ops, skipping
    no-traffic ops and fusion-internal ops),
  * collective payload bytes by kind.

Aggregate(entry) = own cost + sum(while trip * aggregate(body)) + called comps.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "u4": 1, "s4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# tuple shapes may contain /*index=N*/ comments (with '='); tuples never nest
# parens, so "first closing paren" delimits them correctly.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "broadcast", "reshape",
}

# Ops whose operand/result traffic we count toward HBM bytes. CPU HLO leaves
# elementwise chains unfused that a TPU build would fuse into neighbours, so
# pure elementwise ops are treated as free (fused); what remains is weight /
# activation traffic of contractions, data movement ops, and loop carries —
# a deliberate approximation of a well-fused TPU program (EXPERIMENTS.md).
_TRAFFIC_OPS = {
    "dot", "convolution", "fusion", "call", "custom-call", "reduce",
    "reduce-window", "sort", "scatter", "gather", "select-and-scatter",
    "dynamic-slice", "dynamic-update-slice", "copy", "concatenate",
    "transpose", "slice", "pad", "map",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _dims(shape_str):
        total += _DTYPE_BYTES[dt] * int(math.prod(dims)) if dims else _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    shape: str
    kind: str
    rest: str
    operands: List[str]


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_n: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, v in other.coll_n.items():
            self.coll_n[k] = self.coll_n.get(k, 0.0) + v * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


_OPERAND_NAME_RE = re.compile(r"%?([\w\.\-]+)")


def _parse_operands(argstr: str) -> List[str]:
    """First-level comma-split of the call arg list (stop at closing paren)."""
    depth = 0
    out, cur = [], []
    for ch in argstr:
        if ch == "(":
            depth += 1
            cur.append(ch)
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    names = []
    for tok in out:
        m = _OPERAND_NAME_RE.match(tok.strip())
        if m:
            names.append(m.group(1))
    return names


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.strip().endswith("{"):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, kind, rest = m.groups()
        op = Op(name, shape, kind, rest, _parse_operands(rest))
        cur.ops.append(op)
        cur.shapes[name] = shape
    return comps


_ATTR_COMP_RE = re.compile(r"(?:body|to_apply|calls)=\{?%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Largest integer constant in the condition computation (scan bound)."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for op in comp.ops:
        if op.kind == "constant":
            m = _CONST_RE.search(op.shape + " constant(" + op.rest)
        else:
            m = None
        m2 = _CONST_RE.search(" ".join([op.rest]))
        for mm in (m, m2):
            if mm:
                best = max(best, int(mm.group(1)))
    return best


def _dot_flops(comp: Computation, op: Op) -> float:
    result_elems = 0
    for dt, dims in _dims(op.shape):
        result_elems += int(math.prod(dims)) if dims else 1
    lhs_shape = comp.shapes.get(op.operands[0], "") if op.operands else ""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contracted = 1
    if m and lhs_shape:
        idxs = [int(i) for i in m.group(1).split(",") if i]
        ds = _dims(lhs_shape)
        if ds:
            dims = ds[0][1]
            for i in idxs:
                if i < len(dims):
                    contracted *= dims[i]
    return 2.0 * result_elems * contracted


def _op_bytes(comp: Computation, op: Op) -> float:
    total = _shape_bytes(op.shape)
    for o in op.operands:
        s = comp.shapes.get(o)
        if s:
            total += _shape_bytes(s)
    return float(total)


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)|^(\d+)\)")


def _fusion_bytes(comps: Dict[str, Computation], comp: Computation, op: Op) -> float:
    """HBM traffic of a fusion op, slice-aware.

    A fusion whose interior dynamic-slices a big stacked operand (the scan
    residual pattern: read chunk i of f32[128,...]) only touches the slice;
    likewise dynamic-update-slice writes only the update window. Counting
    whole operand shapes would overcount ~trip_count x. Parameters consumed
    by a dynamic-slice count as the slice size; a root dynamic-update-slice
    counts as its update size.
    """
    cm = _ATTR_COMP_RE.search(op.rest)
    called = comps.get(cm.group(1)) if cm else None
    if called is None:
        return _op_bytes(comp, op)

    # parameter index -> name, and slice-consumption map
    param_by_idx: Dict[int, str] = {}
    for o in called.ops:
        if o.kind == "parameter":
            m = re.search(r"parameter\((\d+)\)|\((\d+)\)", o.rest)
            if not m:
                # rest is like "0)" after the opening paren split
                m = re.match(r"(\d+)\)", o.rest)
            idx = None
            if m:
                idx = int(next(g for g in m.groups() if g is not None))
            if idx is not None:
                param_by_idx[idx] = o.name

    sliced_bytes: Dict[str, float] = {}
    dus_updated: Dict[str, float] = {}
    for o in called.ops:
        if o.kind == "dynamic-slice" and o.operands:
            tgt = o.operands[0]
            sliced_bytes[tgt] = sliced_bytes.get(tgt, 0.0) + _shape_bytes(o.shape)
        elif o.kind == "dynamic-update-slice" and len(o.operands) > 1:
            tgt = o.operands[0]
            upd = _shape_bytes(called.shapes.get(o.operands[1], ""))
            # read-modify-write of the window only
            dus_updated[tgt] = dus_updated.get(tgt, 0.0) + 2.0 * upd

    total = 0.0
    # operands: positional order matches parameter indices
    for i, oname in enumerate(op.operands):
        s = comp.shapes.get(oname)
        full = _shape_bytes(s) if s else 0
        pname = param_by_idx.get(i)
        if pname is not None and pname in sliced_bytes:
            total += min(sliced_bytes[pname], full)
        elif pname is not None and pname in dus_updated:
            total += min(dus_updated[pname], full)
        else:
            total += full

    # result: if the root is a dynamic-update-slice the output aliases the
    # big buffer — only the window is written.
    root = called.ops[-1] if called.ops else None
    res = _shape_bytes(op.shape)
    if root is not None and root.kind == "dynamic-update-slice" and len(root.operands) > 1:
        res = min(res, _shape_bytes(called.shapes.get(root.operands[1], "")) or res)
    return float(total + res)


def analyze(text: str) -> Cost:
    comps = parse_module(text)
    entry = None
    for name, c in comps.items():
        if re.match(r"^main", name) or entry is None:
            if re.match(r"^main", name):
                entry = name
    if entry is None and comps:
        entry = next(iter(comps))

    memo: Dict[str, Cost] = {}

    def cost_of(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name in stack:
            return Cost()
        comp = comps.get(name)
        if comp is None:
            return Cost()
        c = Cost()
        for op in comp.ops:
            if op.kind == "while":
                cm = _ATTR_COMP_RE.search(op.rest)
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trips = int(tm.group(1))
                else:
                    cd = _COND_RE.search(op.rest)
                    trips = _trip_count(comps, cd.group(1)) if cd else 1
                if cm:
                    c.add(cost_of(cm.group(1), stack + (name,)), mult=max(trips, 1))
                # carry traffic per iteration is already counted by the body's
                # dynamic-slice/update ops; count the carry tuple once only.
                c.bytes += _shape_bytes(op.shape)
                continue
            if op.kind in ("fusion", "call", "custom-call", "map", "reduce",
                           "reduce-window", "sort", "scatter", "select-and-scatter"):
                # traffic of the fusion/call itself (slice-aware for fusions)
                if op.kind == "fusion":
                    c.bytes += _fusion_bytes(comps, comp, op)
                else:
                    c.bytes += _op_bytes(comp, op)
                # flops inside the called computation (fusions: count dots)
                cm = _ATTR_COMP_RE.search(op.rest)
                if cm:
                    sub = cost_of(cm.group(1), stack + (name,))
                    c.flops += sub.flops
                    for k, v in sub.coll.items():
                        c.coll[k] = c.coll.get(k, 0.0) + v
                continue
            if op.kind == "conditional":
                for cm in re.finditer(r"%?([\w\.\-]+)", op.rest):
                    pass  # branches counted once below via calls= attr if present
                c.bytes += _op_bytes(comp, op)
                continue
            is_coll = None
            for k in _COLLECTIVES:
                if op.kind == k or op.kind.startswith(k + "-start"):
                    is_coll = k
                    break
            if is_coll:
                b = _shape_bytes(op.shape)
                c.coll[is_coll] = c.coll.get(is_coll, 0.0) + b
                c.coll_n[is_coll] = c.coll_n.get(is_coll, 0.0) + 1
                c.bytes += _op_bytes(comp, op)
                continue
            if op.kind in _NO_TRAFFIC:
                continue
            if op.kind in ("dot", "convolution"):
                c.flops += _dot_flops(comp, op)
            if op.kind in ("dynamic-slice", "slice", "gather"):
                c.bytes += 2.0 * _shape_bytes(op.shape)      # read + write window
            elif op.kind == "dynamic-update-slice" and len(op.operands) > 1:
                upd = _shape_bytes(comp.shapes.get(op.operands[1], "")) or _shape_bytes(op.shape)
                c.bytes += 3.0 * upd                          # rmw window + update
            elif op.kind in _TRAFFIC_OPS:
                c.bytes += _op_bytes(comp, op)
        memo[name] = c
        return c

    # fusion computations are reachable only via their fusion op (handled
    # above); while bodies via while ops — so costing the entry suffices.
    return cost_of(entry) if entry else Cost()
