"""Roofline-term extraction from a lowered/compiled step.

cost_analysis() gives HLO FLOPs and bytes accessed; collective bytes are NOT
in cost_analysis, so we parse the (optimized) HLO text and sum operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops. Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> bytes. Tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        parts = [
            f"{k}:{self.count_by_kind[k]}x/{self.bytes_by_kind[k]/1e9:.3f}GB"
            for k in sorted(self.bytes_by_kind)
        ]
        return " ".join(parts) if parts else "none"


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum OUTPUT shape bytes of every collective op in the HLO.

    Uses the result shape (the `lhs = shape op(...)` form), which bounds the
    per-device payload for gather-like ops; all-reduce moves ~2x in a ring
    but we report shape bytes and fold ring factors into the roofline term.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^[%\w\.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op.startswith(c + "."):
                kind = c
                break
        if kind is None:
            continue
        b = _shape_bytes(shape_str)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective bytes
    num_devices: int
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    def row(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
        }


def analyze_compiled(compiled, num_devices: int) -> Dict:
    """Extract {flops, bytes, collective bytes, memory} from a compiled step.

    Primary source: the trip-count-aware HLO parser (hlo_parse) — XLA's
    cost_analysis() counts while bodies once, dropping ~num_layers x of a
    scanned model's cost (verified; see EXPERIMENTS.md §Dry-run). The raw
    cost_analysis numbers are kept as `xla_*` cross-check fields.
    """
    from repro.launch import hlo_parse

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    parsed = hlo_parse.analyze(hlo)
    flops = max(parsed.flops, xla_flops)
    bytes_accessed = max(parsed.bytes, xla_bytes)
    coll = CollectiveStats(
        bytes_by_kind={k: int(v) for k, v in parsed.coll.items()},
        count_by_kind={k: int(v) for k, v in parsed.coll_n.items()},
    )
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", 0),
        }
    except Exception:
        pass
    roof = Roofline(
        flops=flops, hbm_bytes=bytes_accessed, coll_bytes=float(coll.total_bytes),
        num_devices=num_devices,
    )
    return {
        "flops": flops,                 # per-device (SPMD module), trip-corrected
        "bytes": bytes_accessed,
        "collectives": coll,
        "memory": mem,
        "roofline": roof,
        "xla_flops": xla_flops,
        "xla_bytes": xla_bytes,
    }


def model_flops(cfg, tokens: int, training: bool = True) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n = cfg.active_param_count()
    per_tok = (6 if training else 2) * n
    return per_tok * tokens
