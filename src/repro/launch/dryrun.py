import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, print memory/cost analysis, and emit the roofline terms.

MUST be run as a module entry point (device count is locked at first jax
init, hence the XLA_FLAGS lines above before any other import):

    python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --csv out.csv
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import SHAPES, get_arch, list_archs, long_context_variant
from repro.configs.registry import POD_GRANULARITY
from repro.launch.hlo_analysis import analyze_compiled, model_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import plan_for


def should_skip(arch: str, shape_name: str) -> str:
    """Returns a skip reason or '' (DESIGN.md §6 policy)."""
    return ""   # every assigned arch runs every shape (long_500k via SW/SSM)


def run_one(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True, opt: bool = False):
    cfg = get_arch(arch)
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size

    plan = plan_for(cfg, shape, mesh, opt=opt)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            plan.fn,
            in_shardings=jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s), plan.in_specs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
            ),
            out_shardings=jax.tree_util.tree_map(
                lambda s: jax.sharding.NamedSharding(mesh, s), plan.out_specs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
            ),
        )
        lowered = jitted.lower(*plan.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    info = analyze_compiled(compiled, n_dev)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = model_flops(cfg, tokens, training=(shape.kind == "train"))
    # the SPMD module is per-device: totals are x n_dev
    hlo_flops_total = info["flops"] * n_dev
    useful = mf / hlo_flops_total if hlo_flops_total else float("nan")

    row = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "opt": opt,
        "step": plan.name,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device_flops": info["flops"],
        "per_device_bytes": info["bytes"],
        "per_device_coll_bytes": info["collectives"].total_bytes,
        "collectives": info["collectives"].summary(),
        "model_flops": mf,
        "useful_flops_ratio": useful,
        "xla_flops_uncorrected": info["xla_flops"],
        "memory": info["memory"],
    }
    roof = info["roofline"]
    row.update({k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in roof.row().items()})

    if verbose:
        mem = info["memory"]
        print(f"== {arch} x {shape_name} [{row['mesh']}] step={plan.name} {plan.notes}")
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"   memory_analysis: args={mem.get('argument_bytes',0)/1e9:.2f}GB "
              f"temp={mem.get('temp_bytes',0)/1e9:.2f}GB "
              f"peak={mem.get('peak_bytes',0)/1e9:.2f}GB (per device)")
        print(f"   flops/dev={row['per_device_flops']:.3e} (xla uncorrected {row['xla_flops_uncorrected']:.2e}) "
              f"bytes/dev={row['per_device_bytes']:.3e}")
        print(f"   collectives: {row['collectives']}")
        print(f"   roofline: compute={row['t_compute_s']}s memory={row['t_memory_s']}s "
              f"collective={row['t_collective_s']}s dominant={row['dominant']}")
        print(f"   MODEL_FLOPS={mf:.3e} useful/HLO={useful:.3f}")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jsonl", help="append result rows to this JSONL file")
    ap.add_argument("--opt", action="store_true", help="apply the §Perf optimization profile")
    args = ap.parse_args()

    combos = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                combos.append((a, s, m))

    failures = []
    for a, s, m in combos:
        try:
            row = run_one(a, s, m, opt=args.opt)
            if args.jsonl:
                srow = {k: v for k, v in row.items() if k != "memory"}
                srow["peak_bytes"] = row["memory"].get("peak_bytes", 0)
                srow["arg_bytes"] = row["memory"].get("argument_bytes", 0)
                with open(args.jsonl, "a") as f:
                    f.write(json.dumps(srow) + "\n")
        except Exception as e:
            failures.append((a, s, m, repr(e)))
            print(f"FAILED {a} x {s} multi_pod={m}: {e}")
            traceback.print_exc()

    print(f"\n{len(combos) - len(failures)}/{len(combos)} combos compiled")
    if failures:
        for f in failures:
            print("  FAIL:", f)
        sys.exit(1)


if __name__ == "__main__":
    main()
