"""Serving launcher: continuous-batching-lite over the prefill/decode paths.

    python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 8 --max-new 16

A fixed-size slot pool holds per-request decode state; arriving requests are
prefilled into free slots, all active slots decode in lockstep (one jitted
decode_step per tick, the batched-serving analogue of the decode_32k dry-run
shape), finished requests free their slot. This is the serving counterpart
of launch/train.py (deliverable b: "serve a small model with batched
requests").
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.models import build_model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class SlotServer:
    """Fixed B decode slots; per-slot KV caches live in one batched cache."""

    def __init__(self, cfg, params, slots: int, max_len: int):
        self.cfg, self.params = cfg, params
        self.model = build_model(cfg)
        self.slots = slots
        self.max_len = max_len
        self.cache = self.model.init_cache(slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(
            lambda p, t: self.model.prefill(p, t, None, cache_len=max_len)
        )

    def _write_slot(self, slot: int, cache_one, last_tok: int):
        """Copy a freshly prefilled single-request cache into slot ``slot``."""
        def put(dst, src):
            # caches are stacked (L, B, ...); batch axis = 1
            return dst.at[:, slot].set(src[:, 0]) if dst.ndim >= 2 else dst
        self.cache = jax.tree_util.tree_map(put, self.cache, cache_one)
        self.tokens = self.tokens.at[slot, 0].set(last_tok)

    def admit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.active[s] is None:
                logits, cache_one = self._prefill(self.params, req.prompt[None, :])
                tok = int(jnp.argmax(logits[0, -1]))
                req.out.append(tok)
                self._write_slot(s, cache_one, tok)
                self.active[s] = req
                return True
        return False

    def tick(self):
        """One lockstep decode over all slots (inactive slots decode garbage
        that is simply ignored — the production pattern)."""
        if not any(self.active):
            return
        logits, self.cache = self._decode(self.params, self.tokens, self.cache)
        nxt = jnp.argmax(logits[:, 0, :], axis=-1)
        self.tokens = nxt[:, None].astype(jnp.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            if len(req.out) >= req.max_new:
                req.done = True
                self.active[s] = None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.max_new + cfg.frontend_tokens + 2

    server = SlotServer(cfg, params, args.slots, max_len)
    queue = [
        Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                args.max_new)
        for i in range(args.requests)
    ]
    finished: List[Request] = []

    t0 = time.time()
    pending = list(queue)
    ticks = 0
    while pending or any(server.active):
        while pending and server.admit(pending[0]):
            pending.pop(0)
        server.tick()
        ticks += 1
        finished.extend(r for r in queue if r.done and r not in finished)
        if ticks > 10000:
            break
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in queue)
    print(f"arch={cfg.name} served {len(queue)} requests / {total_tokens} tokens "
          f"in {dt:.2f}s over {ticks} ticks ({total_tokens/dt:.1f} tok/s incl. compile)")
    for r in queue[:3]:
        print(f"  req {r.rid}: {r.out[: args.max_new]}")


if __name__ == "__main__":
    main()
