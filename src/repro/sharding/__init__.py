from repro.sharding import fl_step, specs
from repro.sharding.fl_step import Frontier, init_frontier, make_dagfl_train_step
from repro.sharding.specs import batch_specs, cache_specs, param_specs

__all__ = [
    "fl_step", "specs", "Frontier", "init_frontier", "make_dagfl_train_step",
    "batch_specs", "cache_specs", "param_specs",
]
