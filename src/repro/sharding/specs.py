"""Per-arch sharding rules: leaf key-path -> PartitionSpec.

Conventions (DESIGN.md §3):
  * ``model`` axis — tensor parallel (head/ff/expert dims).
  * ``data``(+``pod``) axes — FL-node axis for node-stacked params, or the
    FSDP-ish second weight dim for pod-granularity archs, plus the batch dim
    of activations.
  * Every rule degrades to replication when a dim is not divisible by the
    axis size (e.g. Gemma's single KV head), letting GSPMD choose.

``param_specs(cfg, params, mesh, mode)`` walks the pytree:
  mode="fl"    — leading node axis on every leaf -> data axes, inner dims
                 per rules (model axis only).
  mode="plain" — no node axis; big weights 2-D sharded (data x model).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axsize(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(mesh, axes, dim: int) -> bool:
    return dim % _axsize(mesh, axes) == 0


def _maybe(mesh, axes, dim: int):
    """axes if divisible else None (replicate)."""
    return axes if axes and _fits(mesh, axes, dim) else None


# ---------------------------------------------------------------------------
# rules keyed by parameter name
# ---------------------------------------------------------------------------

# name -> (model_dim_index, transpose_style)
# "col": shard LAST dim on model; "row": shard FIRST (non-layer) dim on model
_COL = {
    "wq", "wk", "wv", "wi", "wg", "w_r", "w_k", "w_v", "w_g", "cm_k", "cm_r",
    "w_z", "w_x", "w_dt", "decay_B", "wq_a", "wq_b", "wkv_b", "frontend_proj",
    "lm_head", "fc", "out",
}
_ROW = {"wo", "w_o", "cm_v", "out_proj"}
_VEC_MODEL = {"bq", "conv_xb", "gn_scale", "decay_w0"}
_REPL = {
    "router", "wkv_a", "w_B", "w_C", "conv_B", "conv_Bb", "conv_C", "conv_Cb",
    "decay_A", "scale", "bias", "q_norm", "k_norm", "bk", "bv", "A_log", "D",
    "dt_bias", "mu", "cm_mu", "bfc", "bout", "b1", "b2",
}
_2D_ROWDATA = {"wq", "wk", "wv", "wi", "wg", "wq_a", "wq_b", "wkv_b"}  # (d, out)


def leaf_spec(cfg: ModelConfig, path: Tuple[str, ...], leaf, mesh, mode: str) -> P:
    """Spec for one (unstacked) leaf given its key path."""
    names = [p for p in path]
    name = names[-1]
    shape = leaf.shape
    dims = len(shape)
    spec = [None] * dims
    plain2d = mode == "plain"

    def set_if(idx, axes):
        if 0 <= idx < dims and axes is not None and _fits(mesh, axes, shape[idx]):
            spec[idx] = axes

    if name in ("embed",):
        set_if(0, "model")
        if plain2d:
            set_if(1, "data")
    elif "moe" in names and name in ("wi", "wg", "wo"):
        set_if(0, "model")                       # expert parallel
        if plain2d:
            # (E, d, ff) / (E, ff, d): shard the d dim over data
            d_idx = 1 if name in ("wi", "wg") else 2
            set_if(d_idx, "data")
    elif name in ("conv_x",):
        set_if(1, "model")
    elif name in ("bonus_u", "gn_scale", "gn_bias") and dims >= 2:
        set_if(0, "model")                       # (H, hd)
    elif name in _COL:
        set_if(dims - 1, "model")
        if plain2d and dims >= 2 and name in _2D_ROWDATA:
            set_if(dims - 2, "data")
    elif name in _ROW:
        set_if(dims - 2, "model")
        if plain2d:
            set_if(dims - 1, "data")
    elif name in _VEC_MODEL:
        set_if(dims - 1, "model")
    # everything else (incl. _REPL) stays replicated
    return P(*spec)


def param_specs(cfg: ModelConfig, params: Any, mesh, mode: str = "plain") -> Any:
    """PartitionSpec pytree matching ``params`` (no node prefix — callers add
    a leading FL-node axis with steps._prefix_specs when stacking replicas).

    mode="plain": 2-D weight sharding (data x model, FSDP-ish).
    mode="model": model-axis rules only (FL replicas: data axis is the node
                  dimension, so inner dims must not use it).
    Leaves under "layers" carry a leading stacked-layer dim (never sharded).
    """

    def visit(path, leaf):
        names = tuple(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in path
        )
        strip = 1 if "layers" in names else 0

        class _Fake:
            shape = leaf.shape[strip:]

        base = leaf_spec(cfg, names, _Fake, mesh, mode)
        prefix = (None,) * strip
        return P(*(prefix + tuple(base)))

    return jax.tree_util.tree_map_with_path(visit, params)


def batch_specs(mesh, batch: Any, fl: bool = False) -> Any:
    """tokens/labels (B, S) or (N, b, S); frontend adds trailing dims."""
    data_ax = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    data_ax = data_ax if len(data_ax) > 1 else (data_ax[0] if data_ax else None)

    def visit(leaf):
        dims = leaf.ndim
        if leaf.shape[0] % _axsize(mesh, data_ax) == 0:
            return P(*((data_ax,) + (None,) * (dims - 1)))
        return P(*((None,) * dims))

    return jax.tree_util.tree_map(visit, batch)


def cache_specs(cfg: ModelConfig, mesh, cache: Any) -> Any:
    """KV caches: batch dim -> data axes; heads (or head_dim / latent) ->
    model; batch-1 long-context decode shards the SEQUENCE dim over data."""
    data_ax = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    data_ax = data_ax if len(data_ax) > 1 else (data_ax[0] if data_ax else None)
    n_data = _axsize(mesh, data_ax)
    n_model = mesh.shape["model"]

    def visit(path, leaf):
        shape = leaf.shape
        dims = len(shape)
        if dims <= 1:
            return P(*((None,) * dims))
        spec = [None] * dims
        # layout conventions (see models/*): leading stacked-layer dim, then B
        # attn KVCache k/v: (L, B, S, KV, hd); mla: (L, B, S, r); rwkv wkv:
        # (L, B, H, hd, hd); mamba ssd: (L, B, H, P, N); conv: (L, B, K-1, C)
        b_idx = 1 if dims >= 3 else 0
        if shape[b_idx] % n_data == 0 and shape[b_idx] > 1:
            spec[b_idx] = data_ax
        elif dims >= 4 and shape[b_idx + 1] % n_data == 0:
            spec[b_idx + 1] = data_ax          # batch-1: shard sequence/heads
        # model axis: try the head-ish dims from the end
        for idx in range(dims - 2, b_idx, -1):
            if spec[idx] is None and shape[idx] % n_model == 0 and shape[idx] >= n_model:
                spec[idx] = "model"
                break
        else:
            if spec[dims - 1] is None and shape[dims - 1] % n_model == 0 and shape[dims - 1] >= n_model:
                spec[dims - 1] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(visit, cache)


def to_shardings(mesh, specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def replica_specs(tree: Any, axis: str = "nodes") -> Any:
    """Leading-axis sharding for node-stacked replica pytrees.

    The gossip ``ReplicaSet`` (repro.net.replica) stacks N per-node
    ``DagState`` replicas along every leaf's LEADING axis; partitioning that
    receiver axis over a mesh axis (default ``"nodes"``, see
    ``repro.net.mesh``) is what scales replica memory and sync FLOPs past
    one device. Inner dims are replicated — per-replica ledger rows are tiny
    compared to the receiver axis, and the fused sync round wants whole rows
    local to the receiver's shard.
    """
    return jax.tree_util.tree_map(lambda _: P(axis), tree)
