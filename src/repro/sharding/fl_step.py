"""Distributed DAG-FL: one Algorithm-2 iteration per node, whole-mesh SPMD.

Node i lives at data-axis position i; its model replica is row i of the
node-stacked params (sharded P(data, ...model rules)). One ``dagfl_train_step``
does, entirely in-graph:

  1. tip selection   — per-node gumbel sample of alpha fresh peers, top-k by
                       the score matrix from the previous round (stage 1+3),
  2. Eq.-1 aggregation — out_i = sum_j C_ij w_j, a collective matmul over the
                       data axis (impl: "einsum" baseline | "gather" ring),
  3. local training  — vmapped grad over the node axis (data x model parallel),
  4. validation      — score matrix S[j, i] = acc(model j on node i's val
                       tokens). KEY TPU ADAPTATION: instead of moving alpha
                       models to each validator (GBs), the tiny val batches
                       are all-gathered and every node scores ITS OWN model
                       on all shards — same information, ~10^4x less traffic
                       (DESIGN.md §3),
  5. frontier update — approvals/publish times (replicated metadata).

The asynchronous semantics of the paper are preserved at the protocol level
(staleness gates, tip approvals); the pod executes rounds synchronously —
the simulator (repro.fl) covers true asynchrony at paper scale.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DagFLConfig, ModelConfig, TrainConfig
from repro.models.layers import softmax_xent


class Frontier(NamedTuple):
    """Frontier DAG metadata (replicated, O(N^2) scalars)."""

    scores: jnp.ndarray          # (N, N) f32: S[j, i] = acc(model j, val i)
    publish_time: jnp.ndarray    # (N,) f32
    approval_count: jnp.ndarray  # (N,) int32 — approvals since last publish
    total_published: jnp.ndarray # (N,) int32
    total_contributing: jnp.ndarray  # (N,) int32 (> 0 approvals when republished)
    now: jnp.ndarray             # () f32


def init_frontier(num_nodes: int) -> Frontier:
    return Frontier(
        scores=jnp.zeros((num_nodes, num_nodes), jnp.float32),
        publish_time=jnp.zeros((num_nodes,), jnp.float32),
        approval_count=jnp.zeros((num_nodes,), jnp.int32),
        total_published=jnp.zeros((num_nodes,), jnp.int32),
        total_contributing=jnp.zeros((num_nodes,), jnp.int32),
        now=jnp.zeros((), jnp.float32),
    )


def select_peers(
    frontier: Frontier, key, alpha: int, k: int, tau_max: float
) -> jnp.ndarray:
    """Stage 1+3 vectorised over nodes: returns row-normalised C (N, N)."""
    N = frontier.scores.shape[0]
    alpha = max(1, min(alpha, N - 1))    # pod-granularity: N can be 2
    k = max(1, min(k, alpha))
    fresh = (frontier.now - frontier.publish_time) <= tau_max      # (N,)
    gumbel = -jnp.log(-jnp.log(
        jax.random.uniform(key, (N, N), minval=1e-9, maxval=1.0)))
    eligible = fresh[None, :] & ~jnp.eye(N, dtype=bool)
    sample_score = jnp.where(eligible, gumbel, -jnp.inf)
    _, cand = jax.lax.top_k(sample_score, alpha)                   # (N, alpha)

    # validate candidates with last round's accuracy scores: S[j, i]
    acc_of = frontier.scores.T                                     # (N_i, N_j)
    cand_acc = jnp.take_along_axis(acc_of, cand, axis=1)           # (N, alpha)
    cand_ok = jnp.take_along_axis(
        jnp.broadcast_to(eligible, (N, N)), cand, axis=1
    )
    cand_acc = jnp.where(cand_ok, cand_acc, -jnp.inf)
    top_acc, pos = jax.lax.top_k(cand_acc, k)                      # (N, k)
    chosen = jnp.take_along_axis(cand, pos, axis=1)                # (N, k)
    valid = jnp.isfinite(top_acc)

    onehot = jax.nn.one_hot(chosen, N, dtype=jnp.float32)          # (N, k, N)
    C = jnp.sum(onehot * valid[..., None], axis=1)
    # fall back to self when a node found no usable tip (round 0)
    none = jnp.sum(C, axis=1) < 0.5
    C = C + jnp.eye(N) * none[:, None]
    return C / jnp.maximum(jnp.sum(C, axis=1, keepdims=True), 1e-9)


def aggregate(C: jnp.ndarray, stacked: Any, impl: str = "einsum",
              dtype=jnp.float32) -> Any:
    """Eq. (1): out_i = sum_j C_ij w_j over the node (data) axis.

    ``dtype``: accumulation dtype of the collective matmul. bf16 halves the
    aggregation's collective payload (§Perf); k<=8 terms keep the rounding
    error ~1e-2 relative, well under SGD noise.
    """
    if impl == "einsum":
        def avg(leaf):
            flat = leaf.reshape(leaf.shape[0], -1).astype(dtype)
            out = C.astype(dtype) @ flat
            return out.reshape(leaf.shape).astype(leaf.dtype)
        return jax.tree_util.tree_map(avg, stacked)
    raise ValueError(impl)


def make_dagfl_train_step(
    model,
    cfg: ModelConfig,
    tcfg: TrainConfig,
    dcfg: DagFLConfig,
    num_nodes: int,
    agg_impl: str = "einsum",
    microbatches: int = 1,
    agg_dtype=jnp.float32,
    ring_window: int = 0,
):
    """Returns step(stacked_params, frontier, batch, val_tokens, key).

    §Perf knobs: ``microbatches`` scans the local train over sub-batches with
    gradient accumulation (divides the remat activation stash);
    ``agg_dtype=bf16`` halves the Eq.-1 aggregation collective payload.
    """

    def node_loss(params, batch):
        total, _ = model.loss(params, batch)
        return total

    def node_accuracy(params, tokens, frontend=None):
        logits, _ = model.forward(params, tokens, frontend)
        F = cfg.frontend_tokens
        if F:
            logits = logits[:, F - 1 : F - 1 + tokens.shape[1], :]
            labels = tokens
        else:
            logits, labels = logits[:, :-1], tokens[:, 1:]
        pred = jnp.argmax(logits, axis=-1)
        return jnp.mean((pred == labels).astype(jnp.float32))

    def ring_select_and_aggregate(frontier, key, stacked_params):
        """§Perf 'neighborhood tip sampling' (ring_window = W > 0).

        §II.B lets nodes pick tips 'according to some algorithms or just
        randomly'; restricting each node's candidate set to its W ring
        neighbours makes both the score exchange and the Eq.-1 aggregation
        expressible as W static rolls over the node axis -> W
        collective-permutes of one replica each (W*P traffic/device instead
        of the dense matmul's N*P all-gather).
        """
        N, W = num_nodes, ring_window
        fresh = (frontier.now - frontier.publish_time) <= dcfg.tau_max  # (N,)
        # candidate scores: cand_acc[i, d] = acc of node (i-d) on val_i,
        # read from the previous round's windowed score matrix (N, W)
        cand_acc = frontier.scores[:, :W]
        ok = jnp.stack([jnp.roll(fresh, d, axis=0) for d in range(1, W + 1)], 1)
        gumbel = -jnp.log(-jnp.log(
            jax.random.uniform(key, (N, W), minval=1e-9, maxval=1.0)))
        sample = jnp.where(ok, gumbel, -jnp.inf)
        _, cand = jax.lax.top_k(sample, min(dcfg.alpha, W))
        acc_sel = jnp.take_along_axis(cand_acc, cand, axis=1)
        acc_sel = jnp.where(
            jnp.take_along_axis(ok, cand, axis=1), acc_sel, -jnp.inf)
        top_acc, pos = jax.lax.top_k(acc_sel, dcfg.k)
        chosen = jnp.take_along_axis(cand, pos, axis=1)       # (N, k) offsets-1
        valid = jnp.isfinite(top_acc)
        gates = jnp.sum(
            jax.nn.one_hot(chosen, W, dtype=jnp.float32) * valid[..., None], 1
        )                                                      # (N, W)
        none = jnp.sum(gates, 1) < 0.5
        norm = jnp.maximum(jnp.sum(gates, 1, keepdims=True), 1e-9)
        gates = gates / norm

        def agg(leaf):
            out = jnp.where(
                none.reshape((N,) + (1,) * (leaf.ndim - 1)),
                leaf.astype(agg_dtype), jnp.zeros((), agg_dtype))
            for d in range(1, W + 1):
                g = gates[:, d - 1].reshape((N,) + (1,) * (leaf.ndim - 1))
                out = out + g.astype(agg_dtype) * jnp.roll(
                    leaf.astype(agg_dtype), d, axis=0)
            return out.astype(leaf.dtype)

        # approval counts: node j approved once per selector picking offset d
        approvals = jnp.zeros((N,), jnp.int32)
        sel = (gates > 0).astype(jnp.int32)
        for d in range(1, W + 1):
            approvals = approvals + jnp.roll(sel[:, d - 1], -d, axis=0)
        return jax.tree_util.tree_map(agg, stacked_params), approvals

    def ring_scores(new_params, val_batch):
        """scores[i, d-1] = acc(model_{i-d} on val_i), via W val-shard rolls."""
        W = ring_window
        vt = val_batch["tokens"]
        vf = val_batch.get("frontend")
        cols = []
        for d in range(1, W + 1):
            vt_d = jnp.roll(vt, -d, axis=0)       # node j sees val_{j+d}
            vf_d = jnp.roll(vf, -d, axis=0) if vf is not None else None

            def one(params, tokens_j, frontend_j=None):
                t = tokens_j[0]
                f = frontend_j[0] if frontend_j is not None else None
                return node_accuracy(params, t[None], f[None] if f is not None else None)

            if vf_d is not None:
                s = jax.vmap(one)(new_params, vt_d, vf_d)
            else:
                s = jax.vmap(one)(new_params, vt_d)
            cols.append(jnp.roll(s, d, axis=0))   # selector i reads (i-d)
        return jnp.stack(cols, axis=1)            # (N, W)

    def step(stacked_params, frontier: Frontier, batch, val_batch, key):
        k_sel, k_train = jax.random.split(key)
        now = frontier.now + 1.0

        if ring_window:
            agg_params, ring_approvals = ring_select_and_aggregate(
                frontier, k_sel, stacked_params)
            C = None
        else:
            # --- stages 1+3a: selection matrix from frontier --------------
            C = select_peers(frontier, k_sel, dcfg.alpha, dcfg.k, dcfg.tau_max)
            # --- stage 3b: Eq.-1 aggregation (collective over data axis) --
            agg_params = aggregate(C, stacked_params, agg_impl, dtype=agg_dtype)

        # --- stage 3c: local training (vmapped over the node axis) --------
        def sgd(params, grads):
            return jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - tcfg.learning_rate * g.astype(jnp.float32)
                              ).astype(p.dtype),
                params, grads,
            )

        def local_train(params, node_batch):
            if microbatches == 1:
                return sgd(params, jax.grad(node_loss)(params, node_batch))
            mbs = jax.tree_util.tree_map(
                lambda a: a.reshape(
                    (microbatches, a.shape[0] // microbatches) + a.shape[1:]
                ),
                node_batch,
            )

            def body(acc, mb):
                g = jax.grad(node_loss)(params, mb)
                return jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g
                ), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            gsum, _ = jax.lax.scan(body, zeros, mbs)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
            return sgd(params, grads)

        new_params = jax.vmap(local_train)(agg_params, batch)

        # --- stage 2/4: validation scores, data-moves-not-models ----------
        if ring_window:
            ring = ring_scores(new_params, val_batch)       # (N, W)
            scores = jnp.zeros_like(frontier.scores)
            scores = scores.at[:, : ring.shape[1]].set(ring)
            approvals = ring_approvals
            mean_acc = jnp.mean(ring)
            sel_entropy = jnp.zeros(())
        else:
            # val_batch["tokens"]: (N, vb, S_val) — each node scores its own
            # new model on every node's val shard: S[j, i]
            vt = val_batch["tokens"]
            vf = val_batch.get("frontend")

            def score_own(params):
                def on_shard(tokens_i, frontend_i=None):
                    return node_accuracy(params, tokens_i, frontend_i)
                if vf is not None:
                    return jax.vmap(on_shard)(vt, vf)
                return jax.vmap(on_shard)(vt)

            scores = jax.vmap(score_own)(new_params)        # (N_j, N_i)
            approvals = jnp.sum(C > 0, axis=0).astype(jnp.int32)
            mean_acc = jnp.mean(jnp.diagonal(scores))
            sel_entropy = -jnp.sum(
                jnp.where(C > 0, C * jnp.log(C + 1e-9), 0.0)
            ) / num_nodes

        # --- stage 4: publish (frontier metadata update) -------------------
        contributed = (frontier.approval_count + approvals) > 0
        new_frontier = Frontier(
            scores=scores,
            publish_time=jnp.full_like(frontier.publish_time, now),
            approval_count=jnp.zeros_like(frontier.approval_count),
            total_published=frontier.total_published + 1,
            total_contributing=frontier.total_contributing
            + contributed.astype(jnp.int32),
            now=now,
        )
        metrics = {
            "mean_val_acc": mean_acc,
            "selection_entropy": sel_entropy,
        }
        return new_params, new_frontier, metrics

    return step
