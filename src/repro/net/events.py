"""Continuous-time event engine: the overlay without tick quantization.

The §IV deployment model is asynchronous by construction — nodes finish
Eq. (5)-(7) iterations on their own clocks (Poisson arrivals, per-node
``h_i``), and messages cross each wireless link after that link's own
latency. Up to PR 4 the simulator approximated all of that on a quantized
global tick: a link with latency ℓ fired every ``ceil(ℓ / sync_period)``
ticks, so a 0.3 s link waited for the 1 s tick and a 3.7 s link was rounded
to 4 s. This module replaces the quantization with a device-resident
discrete-event simulation:

  queue    a fixed-capacity event queue stored as stacked arrays
           ``(time, kind, src, dst, seq)`` with a validity mask
           (``EventQueue``) — no heap, no data-dependent shapes, one
           pytree in the jitted loop's carry;
  pop      the queue head is a masked lexicographic argmin over
           ``(time, kind, seq)`` — ``repro.kernels.event_pop`` (Pallas
           kernel + ``ref.event_pop_ref`` oracle, the ``gossip_merge``
           reduction mold with min in place of max);
  advance  ONE jitted ``lax.while_loop`` pops the head, gathers every
           event firing at the same instant, processes the batch, and
           reschedules — the whole horizon is a single dispatch. Each
           delivery edge fires at most ``max_ticks_per_advance`` times per
           window; an overflowing backlog is elided (the edge jumps past
           the horizon) exactly as the tick driver fast-forwards, keeping
           the degenerate limit bitwise for any window size.

Event kinds (lexicographic tie order = intra-instant processing order,
mirroring the tick driver: rows merge, then payloads settle, then
completions land, then new iterations read):

  ``KIND_DELIVER``  anti-entropy delivery on a directed edge. Each edge
                    delivers every ``delivery_intervals`` seconds — the
                    link's ``Topology.latency`` (zero-latency links fall
                    back to the protocol's ``sync_period`` cadence) — and
                    reschedules itself; simultaneous deliveries merge as
                    ONE fused round (``gossip._apply_round``), which is
                    what makes the degenerate limit exact (below).
  ``KIND_DRAIN``    bank chunk-drain completion (``repro.net.bank``): a
                    link whose byte budget ran out mid-slot finishes its
                    next whole chunk at ``t + remaining / rate`` instead
                    of waiting for the next tick — bandwidth accrues
                    continuously (``(t - last_serviced) * B/8``), so a
                    strided-out link no longer wastes its idle ticks.
  ``KIND_PUBLISH``  iteration completion: the node publishes a transaction
                    approving the tips it reserved at start (the §IV
                    in-system simulation, ``simulate_insystem_tips``).
  ``KIND_START``    iteration start: a Poisson arrival picks a node, the
                    node samples k tips from its LOCAL replica view and
                    begins ``h_i`` seconds of Eq. (5)-(7) work.

Degenerate-limit equivalence (CI-enforced, ``tests/test_net_events.py`` +
``benchmarks/gossip_propagation.py --smoke``): with a uniform deterministic
per-edge delay equal to the sync period, deliveries fire in lockstep
batches at exactly the tick times, the engine splits its PRNG key once per
batch exactly as the tick scan splits once per tick, and the merge
sequence — dags, bank state, and key alike — is BITWISE the
``engine="ticks"`` fused path. Precision domain: the event clock lives on
device in float32 (``EventQueue.time`` accumulates ``qt + interval`` per
fire) while the tick driver's clock accumulates in host float64, so the
bitwise claim requires the common delay to accumulate exactly in float32 —
dyadic values (0.25, 0.5, 1.0, 2.0, ...); a delay like 0.1 drifts one
rounding step per fire and the two engines eventually disagree on how many
rounds fit a window. Heterogeneous latencies then depart from the
tick model in the honest direction: fast links deliver early, slow links at
their true cadence, and drains recover the bandwidth the stride model
forfeited.

``GossipNetwork(engine="events")`` (``repro.net.gossip``) swaps its
``advance`` onto this engine; ``simulate_insystem_tips`` closes the loop
with §IV by measuring the Eq. (4) tip equilibrium *inside* the full gossip
system (``benchmarks/stability_tips.py`` compares it against the closed
form and the standalone numpy simulation).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dag as dag_lib
from repro.core import stability as stability_lib
from repro.core.dag import DagState
from repro.kernels import chunk_transfer as chunk_kernel
from repro.kernels.event_pop import event_pop
from repro.net import bank as bank_lib
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net.topology import Topology, partition_matrix

KIND_DELIVER = 0   # anti-entropy delivery on edge (src -> dst)
KIND_DRAIN = 1     # bank chunk-drain completion on edge (src -> dst)
KIND_PUBLISH = 2   # iteration completion: dst publishes its transaction
KIND_START = 3     # iteration start: a node reserves tips, begins h_i work
KIND_INFER = 4     # inference-serving slot on a node (arrival / completion);
                   # sorts AFTER every transport kind at an equal instant, so
                   # same-instant requests serve the post-merge view


class EventQueue(NamedTuple):
    """Fixed-capacity event queue as stacked arrays (one jittable pytree).

    Invalid slots carry ``time = +inf`` so the head reduction never has to
    special-case them; ``seq`` is a unique per-slot tie-break (insertion
    order), which makes the pop deterministic even at exact time/kind ties.
    ``time`` is float32 (it lives on device inside the jitted loop) — see
    the module docstring for what that means for the degenerate-limit
    bitwise claim.
    """

    time: jnp.ndarray    # (Q,) f32, +inf on invalid slots
    kind: jnp.ndarray    # (Q,) i32
    src: jnp.ndarray     # (Q,) i32 sender (edge events) / acting node
    dst: jnp.ndarray     # (Q,) i32 receiver (edge events) / acting node
    seq: jnp.ndarray     # (Q,) i32 unique tie-break
    valid: jnp.ndarray   # (Q,) bool


def delivery_intervals(top: Topology, sync_period: float) -> np.ndarray:
    """(N, N) f32 inter-delivery interval per directed edge.

    The continuous-time replacement for ``gossip.stride_matrix``: an edge
    delivers every ``latency`` seconds — its actual wire time, not the
    tick-grid round-up ``ceil(latency / period) * period`` — with
    zero-latency links falling back to the protocol's ``sync_period``
    cadence (an instantaneous wire still only exchanges state as often as
    the anti-entropy protocol initiates). +inf off-link.
    """
    lat = np.where(np.isfinite(top.latency), top.latency, 0.0)
    iv = np.where(lat > 0, lat, float(sync_period))
    return np.where(top.adjacency, iv, np.inf).astype(np.float32)


def make_edge_queue(top: Topology, sync_period: float,
                    drain_slots: bool = False):
    """Build the perpetual edge-event slots for an overlay.

    One ``KIND_DELIVER`` slot per directed edge, first firing one interval
    in (matching the tick engine, whose first tick runs at one period) and
    rescheduling itself forever — edge slots recycle in place, so the queue
    can never overflow. ``drain_slots=True`` adds one (initially invalid)
    ``KIND_DRAIN`` slot per directed edge for bank gossip. An edgeless
    overlay gets a single invalid slot so reductions stay well-formed.

    Returns ``(EventQueue, slot_interval (Q,) f32)`` — the per-slot
    delivery cadence (0 on non-delivery slots).
    """
    iv = delivery_intervals(top, sync_period)
    dst, src = np.nonzero(top.adjacency)        # receiver i hears sender j
    e = len(dst)
    if e == 0:
        dst = src = np.zeros(1, np.int64)
        times = np.full(1, np.inf, np.float32)
        kinds = np.zeros(1, np.int32)
        valid = np.zeros(1, bool)
        interval = np.full(1, np.inf, np.float32)
    else:
        times = iv[dst, src].astype(np.float32)
        kinds = np.zeros(e, np.int32)
        valid = np.ones(e, bool)
        interval = times.copy()
        if drain_slots:
            dst = np.concatenate([dst, dst])
            src = np.concatenate([src, src])
            times = np.concatenate([times, np.full(e, np.inf, np.float32)])
            kinds = np.concatenate([kinds, np.full(e, KIND_DRAIN, np.int32)])
            valid = np.concatenate([valid, np.zeros(e, bool)])
            interval = np.concatenate([interval, np.zeros(e, np.float32)])
    queue = EventQueue(
        time=jnp.asarray(times, jnp.float32),
        kind=jnp.asarray(kinds, jnp.int32),
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        seq=jnp.arange(len(times), dtype=jnp.int32),
        valid=jnp.asarray(valid),
    )
    return queue, jnp.asarray(interval, jnp.float32)


def _edge_mask(n: int, qdst, qsrc, mask) -> jnp.ndarray:
    """(N, N) bool — scatter queue-slot mask onto directed-edge coordinates."""
    hits = jnp.zeros((n, n), jnp.int32).at[qdst, qsrc].add(
        mask.astype(jnp.int32)
    )
    return hits > 0


def _queue_head_due(qtime, qvalid, horizon):
    return jnp.min(jnp.where(qvalid, qtime, jnp.inf)) <= horizon


def _partition_mask(t, part_mask, part_t0, part_t1):
    """(N, N) bool — the partition's edge suppression at instant ``t``
    (active on ``t_start <= t < t_end``, matching ``PartitionSchedule``)."""
    pact = (t >= part_t0) & (t < part_t1)
    return jnp.where(pact, part_mask, True)


def _deliver_round(dags, qt, fires, key, t, qv, qkind, qsrc, qdst, islot,
                   horizon, fire_cap, part_mask, part_t0, part_t1, drop,
                   nbr_idx, nbr_valid, impl):
    """One fused anti-entropy round over every delivery firing at instant
    ``t`` — THE shared block all three event drivers run, so the key-split
    order (one per batch), the partition-window rule, and the reschedule
    arithmetic that the degenerate-limit bitwise equivalence depends on
    live in one place.

    Reschedule: a fired edge moves one interval out; an edge that has
    already fired ``fire_cap`` times within this advance window instead
    jumps to its first fire time strictly past ``horizon`` — bitwise the
    tick driver's ``max_ticks_per_advance`` fast-forward, which SKIPS
    (never replays) a backlog that outruns the cap, so the degenerate
    uniform-delay limit stays bitwise the tick path for any window size.

    Returns ``(dags, qt, fires, key, deliver, live, pm)`` — the edge masks
    so bank callers can service the same exchanges.
    """
    n = dags.publisher.shape[0]
    batch = qv & (qt == t) & (qkind == KIND_DELIVER)
    deliver = _edge_mask(n, qdst, qsrc, batch)
    pm = _partition_mask(t, part_mask, part_t0, part_t1)
    key, sub = jax.random.split(key)
    u = jax.random.uniform(sub, (n, n))
    live = deliver & pm & (u >= drop)
    dags = gossip_lib._apply_round(dags, live, nbr_idx, nbr_valid, impl)
    fires = fires + batch.astype(jnp.int32)
    elide = fires >= fire_cap
    skip = (jnp.floor((horizon - qt) / islot) + 1.0) * islot
    qt = jnp.where(batch, qt + jnp.where(elide, skip, islot), qt)
    return dags, qt, fires, key, deliver, live, pm


# ---------------------------------------------------------------------------
# Engine A: GossipNetwork advance — deliveries (+ bank drains) to a horizon
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _advance_events_jit(impl: str, obs=None, faults=None, serve=None):
    """Event-driven ``advance``: one ``lax.while_loop`` over delivery batches.

    Each iteration pops the queue head (``repro.kernels.event_pop``),
    gathers every delivery firing at that instant, and runs the shared
    ``_deliver_round`` block — one PRNG split per batch, exactly as the
    tick scan splits once per tick, and per-edge fire caps that elide an
    overflowing backlog exactly as the tick driver fast-forwards — so the
    degenerate uniform-delay limit is bitwise the tick path, key included,
    for any advance window.

    ``obs`` (an ``repro.obs.ObsConfig``) threads the telemetry collectors
    through the loop carry, sampled once per event batch at the batch
    instant — a pure read, so the dags/key trajectory is bitwise the
    ``obs=None`` program, whose body below is the untouched code.
    ``faults`` (a ``repro.net.faults.FaultConfig``) swaps in the
    fault-injected body — ``faults=None`` keeps the untouched program
    below. ``serve`` (pre-mapped through ``repro.net.serve.serve_key``)
    swaps in the inference-serving body with KIND_INFER slots live;
    ``serve=None`` keeps the literal serve-free program below.
    """
    if serve is not None:
        from repro.net import serve as serve_lib   # deferred: serve imports this module
        return serve_lib._advance_events_serve_jit(impl, serve, obs, faults)
    if faults is not None:
        from repro.net import faults as faults_lib   # deferred: faults imports this module
        return faults_lib._advance_events_faults_jit(impl, faults, obs)

    if obs is None:
        def advance(dags, qtime, qvalid, qkind, qsrc, qdst, qseq, islot, key,
                    horizon, limit, fire_cap, part_mask, part_t0, part_t1,
                    drop, nbr_idx, nbr_valid):

            def cond(carry):
                _dags, qt, qv, _fires, _key, done = carry
                return _queue_head_due(qt, qv, horizon) & (done < limit)

            def body(carry):
                dags, qt, qv, fires, key, done = carry
                idx, _found = event_pop(qt, qkind, qseq, qv)
                t = qt[idx]
                dags, qt, fires, key, _dlv, _live, _pm = _deliver_round(
                    dags, qt, fires, key, t, qv, qkind, qsrc, qdst, islot,
                    horizon, fire_cap, part_mask, part_t0, part_t1, drop,
                    nbr_idx, nbr_valid, impl,
                )
                return dags, qt, qv, fires, key, done + 1

            dags, qt, qv, _fires, key, done = jax.lax.while_loop(
                cond, body,
                (dags, qtime, qvalid, jnp.zeros_like(qseq), key, jnp.int32(0)),
            )
            return dags, qt, qv, key, done

        return jax.jit(advance)

    from repro import obs as obs_lib   # deferred: repro.obs imports repro.net

    def advance(dags, qtime, qvalid, qkind, qsrc, qdst, qseq, islot, key,
                horizon, limit, fire_cap, part_mask, part_t0, part_t1, drop,
                nbr_idx, nbr_valid, metrics, ring):

        def cond(carry):
            _dags, qt, qv = carry[0], carry[1], carry[2]
            done = carry[7]
            return _queue_head_due(qt, qv, horizon) & (done < limit)

        def body(carry):
            dags, qt, qv, fires, key, metrics, ring, done = carry
            idx, _found = event_pop(qt, qkind, qseq, qv)
            t = qt[idx]
            old = dags
            dags, qt, fires, key, _dlv, live, _pm = _deliver_round(
                dags, qt, fires, key, t, qv, qkind, qsrc, qdst, islot,
                horizon, fire_cap, part_mask, part_t0, part_t1, drop,
                nbr_idx, nbr_valid, impl,
            )
            metrics, ring = obs_lib.observe_round(
                obs, metrics, ring, t, old, dags, live_edges=live
            )
            return dags, qt, qv, fires, key, metrics, ring, done + 1

        dags, qt, qv, _fires, key, metrics, ring, done = jax.lax.while_loop(
            cond, body,
            (dags, qtime, qvalid, jnp.zeros_like(qseq), key, metrics, ring,
             jnp.int32(0)),
        )
        return dags, qt, qv, key, done, metrics, ring

    return jax.jit(advance)


@functools.lru_cache(maxsize=None)
def _advance_events_bank_jit(impl: str, bank_impl, obs=None, faults=None,
                             codec=None, serve=None):
    """Event-driven ``advance`` with the model bank gossiped.

    The row half of a batch is the shared ``_deliver_round`` (fire caps and
    all); the bank half services every edge whose delivery or drain fired,
    with a budget
    accrued CONTINUOUSLY since the edge's last service
    (``(t - last_serviced) * B/8`` — the tick model's per-fire quantum is
    the uniform-interval special case, so the unlimited-capacity degenerate
    limit stays bitwise the tick path). A serviced link with work left over
    arms its drain slot at the instant its next whole chunk completes; a
    link partitioned away retries one chunk-time later without resetting
    the rolled-over credit. ``obs`` threads the telemetry carry exactly as
    in ``_advance_events_jit`` (``obs=None`` keeps the untouched program);
    bank batches additionally sample chunk lag / byte totals and record a
    DRAIN trace span per link that moved payload. ``faults`` swaps in the
    fault-injected body (``faults=None`` keeps the untouched program
    below). ``codec`` (pre-mapped through ``delta_codec.codec_key``)
    scales ``chunk_bytes`` to the encoded wire size — pricing, the byte
    meter, AND the drain-instant arithmetic all see the compressed
    granule, so compressed chunks complete earlier in continuous time;
    ``codec=None`` keeps the literal raw-chunk program. ``serve``
    (pre-mapped through ``repro.net.serve.serve_key``) swaps in the
    inference-serving body with KIND_INFER slots live — requests served
    from the availability-GATED view; ``serve=None`` keeps the literal
    serve-free program below.
    """
    if serve is not None:
        from repro.net import serve as serve_lib
        return serve_lib._advance_events_bank_serve_jit(
            impl, bank_impl, serve, obs, faults, codec
        )
    if faults is not None:
        from repro.net import faults as faults_lib
        return faults_lib._advance_events_bank_faults_jit(
            impl, bank_impl, faults, obs, codec
        )

    if obs is not None:
        from repro import obs as obs_lib

    def advance(dags, have, credit, sent, last_srv, digest, qtime, qvalid,
                qkind, qsrc, qdst, qseq, islot, key, horizon, limit,
                fire_cap, part_mask, part_t0, part_t1, drop, nbr_idx,
                nbr_valid, bw_bytes, chunk_bytes, *obs_carry):
        if codec is not None:
            chunk_bytes = chunk_bytes * codec.wire_ratio()
        n = dags.publisher.shape[0]

        def cond(carry):
            qt, qv, done = carry[4], carry[5], carry[7]
            return _queue_head_due(qt, qv, horizon) & (done < limit)

        def body(carry):
            if obs is not None:
                (dags, bstate, last_srv, key, qt, qv, fires, done,
                 metrics, ring) = carry
                old_dags, old_sent, old_have = dags, bstate.sent, bstate.have
            else:
                dags, bstate, last_srv, key, qt, qv, fires, done = carry
            idx, _found = event_pop(qt, qkind, qseq, qv)
            t = qt[idx]
            batch = qv & (qt == t)
            is_drn = qkind == KIND_DRAIN
            drain = _edge_mask(n, qdst, qsrc, batch & is_drn)

            # drain-only batches (whole-chunk completions between delivery
            # instants) skip the anti-entropy round AND its PRNG split — a
            # drain moves payload bytes, not rows. Deliveries always take
            # the round branch, so the degenerate unlimited-capacity limit
            # (where drains never arm) is untouched.
            def _with_round(op):
                return _deliver_round(
                    *op, t, qv, qkind, qsrc, qdst, islot, horizon, fire_cap,
                    part_mask, part_t0, part_t1, drop, nbr_idx, nbr_valid,
                    impl,
                )

            def _no_round(op):
                dags, qt, fires, key = op
                off = jnp.zeros((n, n), bool)
                pm = _partition_mask(t, part_mask, part_t0, part_t1)
                return dags, qt, fires, key, off, off, pm

            dags, qt, fires, key, deliver, live, pm = jax.lax.cond(
                jnp.any(batch & (qkind == KIND_DELIVER)),
                _with_round, _no_round, (dags, qt, fires, key),
            )
            # bank service: surviving deliveries carry chunks in the same
            # exchange; drains are transfer continuations (partition-gated,
            # not loss-gated). Budget = continuous accrual since last fire.
            svc = live | (drain & pm)
            sched = deliver | drain
            accr = jnp.where(svc, (t - last_srv) * bw_bytes, 0.0)
            sat = chunk_kernel.chunk_dedup(bstate.have, digest, impl=bank_impl)
            bstate, pending = bank_lib.chunk_step(
                dags, bstate, digest, sat, sat, svc, accr, chunk_bytes,
                return_pending=True,
            )
            # a fired-but-suppressed exchange wastes its window (idle
            # bandwidth is never banked) — the accrual clock resets either way
            last_srv = jnp.where(sched, t, last_srv)
            # drain slots: serviced edges re-arm from `pending` at the next
            # whole-chunk completion; suppressed fired drains retry later.
            # Strict progress: f32 accrual residue can leave `credit` within
            # one ulp-of-t's worth of bytes of a whole chunk, making the
            # completion instant round back to t itself — the drain would
            # re-arm at its own time and livelock the advance against
            # max_events_per_advance, starving every event behind it. Clamp
            # each re-arm to the next representable instant (a no-op for any
            # re-arm that already lands strictly past t).
            rate = jnp.maximum(bw_bytes, 1e-9)
            t_next = jnp.nextafter(t, jnp.float32(jnp.inf))
            e_next = jnp.maximum(
                t + (chunk_bytes - bstate.credit) / rate, t_next
            )[qdst, qsrc]
            e_retry = jnp.maximum(t + chunk_bytes / rate, t_next)[qdst, qsrc]
            e_svc = svc[qdst, qsrc]
            e_pend = pending[qdst, qsrc]
            qv = jnp.where(is_drn & e_svc, e_pend, qv)
            qt = jnp.where(is_drn & e_svc,
                           jnp.where(e_pend, e_next, jnp.inf), qt)
            qt = jnp.where(batch & is_drn & ~e_svc, e_retry, qt)
            if obs is not None:
                metrics2, ring2 = obs_lib.observe_round(
                    obs, metrics, ring, t, old_dags, dags, live_edges=live,
                    bytes_delta=bstate.sent - old_sent, bstate=bstate,
                    digest=digest, bank_impl=bank_impl, old_have=old_have,
                )
                return (dags, bstate, last_srv, key, qt, qv, fires, done + 1,
                        metrics2, ring2)
            return dags, bstate, last_srv, key, qt, qv, fires, done + 1

        init = (dags, bank_lib.BankState(have=have, credit=credit, sent=sent),
                last_srv, key, qtime, qvalid, jnp.zeros_like(qseq),
                jnp.int32(0)) + tuple(obs_carry)
        out = jax.lax.while_loop(cond, body, init)
        dags, bstate, last_srv, key, qt, qv, _fires, done = out[:8]
        return (dags, bstate, last_srv, key, qt, qv, done) + out[8:]

    return jax.jit(advance)


# ---------------------------------------------------------------------------
# Engine B: the §IV in-system simulation — Eq. (4) inside the full overlay
# ---------------------------------------------------------------------------


class InSystemTrace(NamedTuple):
    """Trace of the in-system tip process (one sample per publish event).

    ``tips`` counts tips of the UNION view (the paper's omniscient external
    agent E) under the same ``tip_mask`` rule Algorithm 2 samples from;
    ``staleness`` is the worst per-replica row lag behind that union at the
    same instants — the quantity that inflates the tip count past Eq. (4)
    when gossip is slow. ``union`` is the final union ledger (per-node
    publish counters live on it); ``overflow`` counts dropped work (queue
    or trace capacity) and is asserted zero by the tests/benches.
    """

    times: np.ndarray       # (P,) f64 publish instants
    tips: np.ndarray        # (P,) f64 union tip count after each publish
    staleness: np.ndarray   # (P,) f64 max rows any replica lags the union
    published: int          # transactions published (excl. genesis)
    overflow: int
    union: DagState
    trace: Optional[dict] = None   # drained PUBLISH/COMMIT device records
                                   # (``record_trace=True`` runs only)
    trace_dropped: int = 0

    def tail_mean(self, frac: float = 0.5) -> float:
        return stability_lib.tail_mean(self.tips, frac)

    def to_report(self):
        """Fold this bespoke trace into the shared ``repro.obs`` format.

        Returns an ``ObsReport`` whose series are the per-publish
        ``t``/``tips``/``staleness`` samples and whose trace is the
        device-recorded PUBLISH/COMMIT record set (empty without
        ``record_trace``) — so ``metrics_jsonl_lines`` /
        ``chrome_trace`` / ``write_*`` work on tip-sim runs unchanged.
        ``tail_mean`` stays the stability acceptance metric; this is the
        export path only.
        """
        from repro.obs.export import ObsReport
        pub = np.asarray(self.union.publisher)
        occ = pub >= 0
        # genesis is published by the virtual node id N, so the max
        # occupied publisher id IS the node count
        n = int(pub[occ].max()) if occ.any() else 0
        trace = self.trace if self.trace is not None else {
            "t": np.zeros((0,), np.float64),
            "kind": np.zeros((0,), np.int32),
            "src": np.zeros((0,), np.int32),
            "dst": np.zeros((0,), np.int32),
            "arg": np.zeros((0,), np.float64),
        }
        return ObsReport(
            num_nodes=n,
            engine="insystem",
            rounds=int(self.published),
            series={
                "t": np.asarray(self.times, np.float64),
                "tips": np.asarray(self.tips, np.float64),
                "staleness": np.asarray(self.staleness, np.float64),
            },
            rows_merged=np.zeros((n,), np.int64),
            link_bytes=np.zeros((n, n), np.float64),
            samples_dropped=int(self.overflow),
            trace=trace,
            trace_dropped=int(self.trace_dropped),
            final={"published": float(self.published)},
        )


@functools.lru_cache(maxsize=None)
def _tip_sim_jit(impl: str, k: int, e_slots: int, p_slots: int,
                 record_trace: bool = False):
    """The in-system §IV driver: one jitted while_loop over ALL event kinds.

    Deliveries batch exactly as in engine A; a START samples a node
    (uniform, the paper's global Poisson arrival), reserves k tips from
    that node's LOCAL replica view (gumbel top-k, in-flight iterations may
    overlap — the overlap Eq. (4) absorbs), and schedules its PUBLISH
    ``h_i`` seconds out in a recycled pending slot; a PUBLISH lands the
    transaction at the globally-sequenced row of the publisher's replica,
    credits the reserved approvals, and samples the union tip count.

    ``record_trace`` threads a ``repro.obs.trace.TraceRing`` through the
    carry and emits the publisher's spans FROM INSIDE the jitted loop —
    one KIND_PUBLISH record when a START reserves its tips (arg = the
    node's ``h_i`` duration) and one KIND_COMMIT when the PUBLISH lands
    (arg = global sequence) — the device-side counterpart of the host
    ``trace_host`` spans. False (the default, its own cache entry) keeps
    the literal trace-free program.
    """
    start_slot = e_slots + p_slots
    if record_trace:
        from repro.obs import trace as obs_trace

    def _self_edge(n, node):
        ids = jnp.arange(n, dtype=jnp.int32)
        return (ids[:, None] == node) & (ids[None, :] == node)

    def run(dags, qtime, qvalid, qkind, qsrc, qdst, qseq, islot, pend, h,
            rate, tau_max, horizon, limit, drop, nbr_idx, nbr_valid,
            part_mask, part_t0, part_t1, key, trace_t, trace_tips,
            trace_stale, *obs_carry):
        n = dags.publisher.shape[0]
        tcap = trace_t.shape[0]
        key, k0 = jax.random.split(key)
        qtime = qtime.at[start_slot].set(jax.random.exponential(k0) / rate)

        def cond(carry):
            qt, qv, done = carry[1], carry[2], carry[-1]
            return _queue_head_due(qt, qv, horizon) & (done < limit)

        def body(carry):
            (dags, qt, qv, qd, pend, key, seqc, tt, ttips, tst, cur, ovf,
             *rest) = carry
            done = rest[-1]
            rest = tuple(rest[:-1])
            idx, _found = event_pop(qt, qkind, qseq, qv)
            t = qt[idx]
            knd = qkind[idx]

            def do_deliver(op):
                (dags, qt, qv, qd, pend, key, seqc, tt, ttips, tst, cur,
                 ovf, *rest) = op
                # fire_cap = imax: the tip sim never elides (it has no tick
                # twin to stay bitwise with; the horizon is one advance)
                dags, qt, _f, key, _dlv, _live, _pm = _deliver_round(
                    dags, qt, jnp.zeros_like(qseq), key, t, qv, qkind, qsrc,
                    qd, islot, horizon, jnp.int32(jnp.iinfo(jnp.int32).max),
                    part_mask, part_t0, part_t1, drop, nbr_idx, nbr_valid,
                    impl,
                )
                return (dags, qt, qv, qd, pend, key, seqc, tt, ttips, tst,
                        cur, ovf, *rest)

            def do_publish(op):
                (dags, qt, qv, qd, pend, key, seqc, tt, ttips, tst, cur,
                 ovf, *rest) = op
                node = qd[idx]
                dag_i = jax.tree_util.tree_map(lambda x: x[node], dags)
                row, new_count = replica_lib.global_row(dag_i, seqc)
                dag_i = dag_lib.publish_at(
                    dag_i, row, new_count, node, t, pend[idx],
                    jnp.float32(0.5), jnp.float32(0.0), row,
                )
                dags = jax.tree_util.tree_map(
                    lambda x, v: x.at[node].set(v), dags, dag_i
                )
                qv = qv.at[idx].set(False)
                qt = qt.at[idx].set(jnp.inf)
                union = replica_lib.merge_all(dags)
                tips = dag_lib.num_tips(union, t, tau_max)
                stale = jnp.max(replica_lib.missing_vs_union(dags, union))
                slot = jnp.minimum(cur, tcap - 1)
                tt = tt.at[slot].set(t)
                ttips = ttips.at[slot].set(tips.astype(jnp.float32))
                tst = tst.at[slot].set(stale.astype(jnp.float32))
                ovf = ovf + (cur >= tcap).astype(jnp.int32)
                cur = jnp.minimum(cur + 1, tcap)
                if record_trace:
                    (ring,) = rest
                    ring = obs_trace.append_edges(
                        ring, t, obs_trace.KIND_COMMIT, _self_edge(n, node),
                        seqc.astype(jnp.float32),
                    )
                    rest = (ring,)
                return (dags, qt, qv, qd, pend, key, seqc + 1, tt, ttips,
                        tst, cur, ovf, *rest)

            def do_start(op):
                (dags, qt, qv, qd, pend, key, seqc, tt, ttips, tst, cur,
                 ovf, *rest) = op
                key, kn, ks, ka = jax.random.split(key, 4)
                node = jax.random.randint(kn, (), 0, n)
                dag_i = jax.tree_util.tree_map(lambda x: x[node], dags)
                rows, _nv = dag_lib.select_tips(dag_i, ks, k, t, tau_max)
                pv = jax.lax.dynamic_slice_in_dim(qv, e_slots, p_slots)
                free = jnp.argmin(pv)                 # first invalid slot
                has = ~pv[free]
                slot = (e_slots + free).astype(jnp.int32)
                qv = qv.at[slot].set(qv[slot] | has)
                qt = qt.at[slot].set(jnp.where(has, t + h[node], qt[slot]))
                qd = qd.at[slot].set(jnp.where(has, node, qd[slot]))
                pend = pend.at[slot].set(jnp.where(has, rows, pend[slot]))
                qt = qt.at[start_slot].set(
                    t + jax.random.exponential(ka) / rate
                )
                ovf = ovf + (~has).astype(jnp.int32)
                if record_trace:
                    # an iteration dropped for want of a pending slot never
                    # publishes — no span for it either
                    (ring,) = rest
                    ring = obs_trace.append_edges(
                        ring, t, obs_trace.KIND_PUBLISH,
                        _self_edge(n, node) & has, h[node],
                    )
                    rest = (ring,)
                return (dags, qt, qv, qd, pend, key, seqc, tt, ttips, tst,
                        cur, ovf, *rest)

            branch = jnp.where(
                knd == KIND_DELIVER, 0,
                jnp.where(knd == KIND_PUBLISH, 1, 2),
            )
            op = (dags, qt, qv, qd, pend, key, seqc, tt, ttips, tst, cur,
                  ovf) + rest
            out = jax.lax.switch(branch, [do_deliver, do_publish, do_start], op)
            return tuple(out) + (done + 1,)

        init = (dags, qtime, qvalid, qdst, pend, key, jnp.int32(1),
                trace_t, trace_tips, trace_stale, jnp.int32(0),
                jnp.int32(0)) + tuple(obs_carry) + (jnp.int32(0),)
        out = jax.lax.while_loop(cond, body, init)
        (dags, _qt, _qv, _qd, _pend, _key, seqc, tt, ttips, tst, cur,
         ovf) = out[:12]
        done = out[-1]
        return (dags, tt, ttips, tst, cur, ovf, seqc, done) + out[12:-1]

    return jax.jit(run)


def simulate_insystem_tips(
    top: Topology,
    h,                              # per-node Eq. (7) delay: (N,) or scalar
    arrival_rate: float,            # lambda — global Poisson iteration rate
    k: int,                         # approvals per transaction
    tau_max: float,
    horizon: float,
    capacity: int = 256,
    seed: int = 0,
    sync_period: float = 1.0,       # cadence fallback for zero-latency links
    impl: str = "fused",
    partition=None,                 # Optional[gossip.PartitionSchedule]
    max_pending: int = 64,
    trace_cap: Optional[int] = None,
    record_trace: bool = False,
) -> InSystemTrace:
    """Measure the Eq. (4) tip process INSIDE the full gossip system.

    The standalone ``core.stability.simulate_tip_count`` runs the paper's
    M/G/inf tangle on one global tip set; this runs the same arrival/
    completion process against per-node DAG replicas synced by the
    continuous-time engine — nodes reserve tips from their own (possibly
    stale) views and publish into their own replicas, so gossip staleness,
    duplicate approvals, and partitions become visible in the measured
    equilibrium. With a well-connected overlay and delivery intervals well
    under ``h`` the tail mean reproduces ``stability.equilibrium_tips``
    (the bench-grid acceptance, ``benchmarks/stability_tips.py``); slow
    overlays inflate it (``examples/async_stragglers.py``).

    ``record_trace=True`` additionally threads a device-resident
    ``repro.obs.trace.TraceRing`` through the jitted loop and drains it
    into ``InSystemTrace.trace`` (one PUBLISH span per started
    iteration, one COMMIT per landed transaction) — the shared
    ``repro.obs`` record format ``InSystemTrace.to_report()`` exports.
    The measured series is bitwise-unchanged either way (pinned by
    ``tests/test_hist.py``).
    """
    if sync_period <= 0:
        raise ValueError("in-system tip sim needs a positive sync_period")
    n = top.num_nodes
    h = jnp.asarray(np.broadcast_to(np.asarray(h, np.float32), (n,)))
    dag = dag_lib.empty_dag(capacity, k, n + 1)
    dag = dag_lib.publish(
        dag, jnp.asarray(n, jnp.int32), jnp.float32(0.0),
        jnp.full((k,), dag_lib.NO_TX, jnp.int32),
        jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(0, jnp.int32),
    )
    dags = jax.tree_util.tree_map(lambda x: jnp.repeat(x[None], n, axis=0), dag)

    base, islot_e = make_edge_queue(top, sync_period)
    e = int(base.time.shape[0])
    p = int(max_pending)
    qtime = jnp.concatenate([base.time, jnp.full((p + 1,), jnp.inf, jnp.float32)])
    qkind = jnp.concatenate([
        base.kind,
        jnp.full((p,), KIND_PUBLISH, jnp.int32),
        jnp.full((1,), KIND_START, jnp.int32),
    ])
    qsrc = jnp.concatenate([base.src, jnp.zeros((p + 1,), jnp.int32)])
    qdst = jnp.concatenate([base.dst, jnp.zeros((p + 1,), jnp.int32)])
    qseq = jnp.arange(e + p + 1, dtype=jnp.int32)
    qvalid = jnp.concatenate(
        [base.valid, jnp.zeros((p,), bool), jnp.ones((1,), bool)]
    )
    islot = jnp.concatenate([islot_e, jnp.zeros((p + 1,), jnp.float32)])
    pend = jnp.full((e + p + 1, k), dag_lib.NO_TX, jnp.int32)

    if trace_cap is None:
        trace_cap = int(horizon * arrival_rate * 3) + 64
    trace_t = jnp.zeros((trace_cap,), jnp.float32)
    trace_tips = jnp.zeros((trace_cap,), jnp.float32)
    trace_stale = jnp.zeros((trace_cap,), jnp.float32)

    iv = delivery_intervals(top, sync_period)
    deliveries = float((horizon / iv[top.adjacency]).sum()) if top.adjacency.any() else 0.0
    limit = int(min(deliveries + 4.0 * horizon * arrival_rate + p + 1024,
                    2.0 ** 31 - 1))
    if partition is not None:
        part_mask = jnp.asarray(partition_matrix(partition.assignment))
        pt0, pt1 = float(partition.t_start), float(partition.t_end)
    else:
        part_mask = jnp.ones((n, n), bool)
        pt0, pt1 = float("inf"), float("-inf")

    nbr_idx, nbr_valid = gossip_lib._neighbor_table_cached(
        np.asarray(top.adjacency, bool).tobytes(), n
    )
    obs_carry = ()
    if record_trace:
        from repro.obs import trace as obs_trace
        ring0 = obs_trace.init_trace(2 * trace_cap + 8)
        obs_carry = (ring0,)
    out = _tip_sim_jit(impl, k, e, p, record_trace=record_trace)(
        dags, qtime, qvalid, qkind, qsrc, qdst, qseq, islot, pend, h,
        jnp.float32(arrival_rate), jnp.float32(tau_max), jnp.float32(horizon),
        jnp.int32(limit), jnp.asarray(top.drop), nbr_idx, nbr_valid,
        part_mask, jnp.float32(pt0), jnp.float32(pt1),
        jax.random.PRNGKey(seed), trace_t, trace_tips, trace_stale,
        *obs_carry,
    )
    dags, tt, ttips, tst, cur, ovf, seqc, _done = out[:8]
    span_trace, span_dropped = None, 0
    if record_trace:
        from repro.obs import trace as obs_trace
        ring = out[8]
        span_trace = obs_trace.drain(ring)
        span_dropped = int(ring.dropped)
    cur = int(cur)
    return InSystemTrace(
        times=np.asarray(tt, np.float64)[:cur],
        tips=np.asarray(ttips, np.float64)[:cur],
        staleness=np.asarray(tst, np.float64)[:cur],
        published=int(seqc) - 1,
        overflow=int(ovf),
        union=replica_lib.merge_all_jit(dags),
        trace=span_trace,
        trace_dropped=span_dropped,
    )
