"""Poisson inference load served from availability-gated bank views.

The paper's deployment story (§III, Algorithm 2) is that on-device nodes
keep *using* their local model while consensus proceeds asynchronously —
training never blocks serving, and serving never waits for global sync.
Up to PR 8 the simulator only trained: ``launch/serve.py`` batches
requests against a static checkpoint, disconnected from the gossip /
bank / event machinery. This module closes that loop on the continuous-
time event engine (``repro.net.events``):

  arrivals   each node receives inference requests as an independent
             Poisson process at ``ServeConfig.rate`` requests/s. Inter-
             arrival gaps are sampled from a dedicated key branch
             (``fold_in(PRNGKey(seed), salt)`` folded per (node, arrival
             count) — the salted-fold_in discipline ``repro.net.faults``
             uses), so the training PRNG stream sees the EXACT same split
             sequence as a serve-free run.
  service    a fixed-slot batching model per node, the ``SlotServer``
             shape from ``launch/serve.py`` flattened to counters: an
             idle node admits up to ``slots`` queued requests as one
             batch and completes them ``service_time`` seconds later
             (one lockstep decode pass); requests arriving past
             ``queue_cap`` waiting are counted dropped, never silently
             lost.
  staleness  at every batch-admit instant the node's AVAILABILITY-GATED
             view is measured against the union ledger: a request sees
             only rows whose model chunks have physically arrived
             (``bank.rows_available`` over the live presence bitmaps),
             so staleness-at-serve-time is the transport's doing — slow
             Table-I links, partitions, and quarantined links all show
             up in the served-model lag, not in a simulated penalty.

Event mechanics: ``extend_queue`` appends 2N perpetual ``KIND_INFER``
slots to the edge queue — N arrival slots (self-rescheduling, like
delivery edges) and N batch-completion slots (armed at admit, disarmed
at completion). INFER sorts after every transport kind at an equal
instant, so a same-instant delivery batch pops first and the request is
served from the *post-merge* view. INFER batches never split the main
PRNG key — the serve layer draws only from its own fold_in branch.

Degenerate limit (the obs=None / faults=None / codec=None pattern):
``serve_key`` maps ``None`` and any ``rate <= 0`` config to ``None``,
under which the engines compile their LITERAL pre-serve programs — the
PR-8 trajectory, replicas / bank state / PRNG key alike, is preserved
bitwise by construction (pinned in ``tests/test_serve.py`` and the
``--smoke`` tripwire).

Entry points: ``GossipNetwork(serve_cfg=ServeConfig(...))`` →
``serve_report()``; ``run_dagfl_gossip(serve=...)`` →
``extras["serve_report"]``; ``benchmarks/serve_load.py`` sweeps Table-I
link classes; ``docs/SERVING.md`` documents the semantics.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import chunk_transfer as chunk_kernel
from repro.kernels.event_pop import event_pop
from repro.net import bank as bank_lib
from repro.net import events as events_lib
from repro.net import replica as replica_lib

# fold_in salt for the serve key branch: arrival gaps derive from
# fold_in(fold_in(fold_in(PRNGKey(seed), _SALT_SERVE), node), count) —
# never from the training stream (events.py splits are untouched)
_SALT_SERVE = 13


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static, hashable inference-load knobs (a jit-factory cache key).

    ``rate``             Poisson request arrivals per node per second.
                         ``rate <= 0`` degenerates to no serving at all —
                         ``serve_key`` maps it to ``None`` so the engines
                         compile the literal serve-free program.
    ``slots``            batch slots per node (the ``SlotServer`` shape):
                         an idle node admits up to this many queued
                         requests as one lockstep batch.
    ``service_time``     seconds one batch takes (prefill + decode for
                         the whole lockstep batch).
    ``queue_cap``        waiting requests a node buffers; arrivals past
                         it are counted in ``ServeState.dropped``.
    ``sample_capacity``  staleness-at-admit samples kept (first-K, the
                         repo's no-wraparound capacity discipline).
    ``salt``             fold_in salt for the serve key branch.
    """

    rate: float = 1.0
    slots: int = 4
    service_time: float = 0.05
    queue_cap: int = 64
    sample_capacity: int = 4096
    salt: int = _SALT_SERVE


def serve_key(cfg: Optional[ServeConfig]) -> Optional[ServeConfig]:
    """The static jit-factory key: ``None`` for every config that serves
    nothing, so a ``rate=0.0`` network compiles the IDENTICAL pre-serve
    program (the ``delta_codec.codec_key`` pattern — off is not a branch
    inside the jitted body, off is a different, literal program)."""
    if cfg is None or cfg.rate <= 0:
        return None
    return cfg


def validate_serve(cfg: ServeConfig, engine: str, mesh=None) -> None:
    """Reject configs the event machinery cannot honor (effective — i.e.
    post-``serve_key`` — configs only; ``None``/rate-0 is valid anywhere
    because it changes nothing)."""
    if engine != "events":
        raise ValueError(
            "serve_cfg needs the continuous-time engine — construct with "
            "GossipConfig(engine='events') (Poisson arrivals have no tick "
            "grid to quantize onto)"
        )
    if mesh is not None:
        raise NotImplementedError(
            "inference serving is single-device for now — the serve "
            "counters are not mesh-sharded (see ROADMAP open items)"
        )
    if cfg.slots < 1:
        raise ValueError("ServeConfig.slots must be >= 1")
    if cfg.queue_cap < 1:
        raise ValueError("ServeConfig.queue_cap must be >= 1")
    if cfg.service_time <= 0:
        raise ValueError("ServeConfig.service_time must be > 0")


class ServeState(NamedTuple):
    """Per-node serving counters + the staleness-at-admit sample buffer
    (one small pytree riding the event loop's carry, like ``MetricsState``).

    Counters are (N,) int32; the sample buffer keeps the FIRST K admit
    instants (capacity ``ServeConfig.sample_capacity``) with overflow
    counted in ``sdropped`` — the ``repro.obs`` discipline, never a wrap.
    """

    queued: jnp.ndarray     # (N,) i32 requests waiting
    inflight: jnp.ndarray   # (N,) i32 requests in the current batch
    served: jnp.ndarray     # (N,) i32 requests completed
    arrivals: jnp.ndarray   # (N,) i32 requests arrived (also the PRNG counter)
    dropped: jnp.ndarray    # (N,) i32 arrivals past queue_cap
    batches: jnp.ndarray    # (N,) i32 batches admitted
    st: jnp.ndarray         # (K,) f32 admit instants
    snode: jnp.ndarray      # (K,) i32 admitting node
    sstale: jnp.ndarray     # (K,) i32 gated staleness at admit
    cursor: jnp.ndarray     # ()   i32 samples attempted (monotone)
    sdropped: jnp.ndarray   # ()   i32 samples past capacity


def init_serve_state(num_nodes: int, cfg: ServeConfig) -> ServeState:
    n, k = int(num_nodes), int(cfg.sample_capacity)
    z = jnp.zeros((n,), jnp.int32)
    return ServeState(
        queued=z, inflight=z, served=z, arrivals=z, dropped=z, batches=z,
        st=jnp.zeros((k,), jnp.float32),
        snode=jnp.full((k,), -1, jnp.int32),
        sstale=jnp.full((k,), -1, jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        sdropped=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Arrival PRNG: a dedicated fold_in branch, reproducible per (seed, node)
# ---------------------------------------------------------------------------


def serve_base_key(seed: int, cfg: ServeConfig):
    """The serve layer's key branch root. Derived from the same seed the
    network uses but salted off it — the training stream never sees a
    serve-dependent split."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), cfg.salt)


def arrival_key(base, node, count):
    """Key for one node's ``count``-th inter-arrival gap. Pure function of
    (seed, node, count): arrivals replay exactly, on device or host, with
    no sequential RNG state anywhere."""
    return jax.random.fold_in(jax.random.fold_in(base, node), count)


def interarrival_gap(base, node, count, rate):
    """() f32 — the exponential gap BEFORE arrival ``count`` at ``node``."""
    return jax.random.exponential(arrival_key(base, node, count)) / rate


def _next_gaps(base, counts, rate):
    """(N,) f32 — each node's next gap given its per-node arrival counts."""
    n = counts.shape[0]
    keys = jax.vmap(arrival_key, in_axes=(None, 0, 0))(
        base, jnp.arange(n, dtype=jnp.int32), counts.astype(jnp.int32)
    )
    return jax.vmap(jax.random.exponential)(keys) / jnp.float32(rate)


def arrival_times(seed: int, cfg: ServeConfig, node: int,
                  horizon: float) -> np.ndarray:
    """Host-side replay of one node's arrival instants up to ``horizon``
    (the f32 accumulation the engine performs). Test/analysis helper —
    the property tests pin the engine's counters against it."""
    base = serve_base_key(seed, cfg)
    t = np.float32(0.0)
    out, count = [], 0
    while True:
        gap = np.float32(interarrival_gap(
            base, jnp.int32(node), jnp.int32(count), jnp.float32(cfg.rate)
        ))
        t = np.float32(t + gap)
        if float(t) > horizon:
            return np.asarray(out, np.float64)
        out.append(float(t))
        count += 1


# ---------------------------------------------------------------------------
# Queue extension: 2N perpetual KIND_INFER slots
# ---------------------------------------------------------------------------


def extend_queue(queue: events_lib.EventQueue, islot, num_nodes: int,
                 cfg: ServeConfig, seed: int):
    """Append the serve slots to an edge queue built by ``make_edge_queue``.

    Slot ``infer_base + i`` is node i's ARRIVAL slot (valid, first firing
    at the count-0 exponential gap, self-rescheduling forever like a
    delivery edge); slot ``infer_base + N + i`` is node i's batch
    COMPLETION slot (invalid until a batch admits, like a drain slot).
    Returns ``(EventQueue, islot, infer_base)``. Only called when serve is
    effective — a serve-free network's queue is untouched, which is what
    keeps the degenerate limit the literal PR-8 program.
    """
    n = int(num_nodes)
    base = serve_base_key(seed, cfg)
    first = _next_gaps(base, jnp.zeros((n,), jnp.int32), cfg.rate)
    infer_base = int(queue.time.shape[0])
    ids = jnp.arange(n, dtype=jnp.int32)
    ext = events_lib.EventQueue(
        time=jnp.concatenate([
            queue.time, first.astype(jnp.float32),
            jnp.full((n,), jnp.inf, jnp.float32),
        ]),
        kind=jnp.concatenate([
            queue.kind, jnp.full((2 * n,), events_lib.KIND_INFER, jnp.int32),
        ]),
        src=jnp.concatenate([queue.src, ids, ids]),
        dst=jnp.concatenate([queue.dst, ids, ids]),
        seq=jnp.arange(infer_base + 2 * n, dtype=jnp.int32),
        valid=jnp.concatenate([
            queue.valid, jnp.ones((n,), bool), jnp.zeros((n,), bool),
        ]),
    )
    islot = jnp.concatenate([islot, jnp.zeros((2 * n,), jnp.float32)])
    return ext, islot, infer_base


# ---------------------------------------------------------------------------
# The INFER batch step (runs inside the jitted event loops)
# ---------------------------------------------------------------------------


def gated_staleness(dags, sat=None) -> jnp.ndarray:
    """(N,) i32 — rows each node's USABLE view lacks vs the union ledger.

    Without a bank (``sat=None``) this is plain replica staleness
    (``missing_vs_union``). With the availability bitmaps it first masks
    rows whose chunks have not arrived (``bank.gate_views``) — the
    staleness a served request actually experiences: a row whose metadata
    gossiped ahead of its payload is NOT usable yet, so it still counts
    as missing.
    """
    union = replica_lib.merge_all(dags)
    if sat is None:
        return replica_lib.missing_vs_union(dags, union)
    return replica_lib.missing_vs_union(
        bank_lib.gate_views(dags, sat), union
    )


def infer_step(cfg: ServeConfig, sstate: ServeState, t, qt, qv, qkind, qseq,
               infer_base, serve_base, stale_now):
    """Process every KIND_INFER event firing at instant ``t``.

    Order inside the instant (all fused, one pass): completions land
    (inflight → served, server idles), arrivals enqueue (or drop past
    ``queue_cap``), then every idle node with waiting work admits a batch
    of up to ``slots`` — so a completion and an arrival at the same
    instant chain into an immediate re-admit, the self-healing property
    that keeps a loaded server busy. Admission samples the node's gated
    staleness ``stale_now`` into the first-K buffer.

    Reschedules: a fired arrival slot moves to the node's next
    exponential gap (keyed by the post-increment arrival count);
    completion slots of touched nodes arm at ``t + service_time`` when a
    batch admitted, disarm otherwise. Draws only from ``serve_base`` —
    the main key is neither passed in nor split.

    Returns ``(sstate, qt, qv, admitted (N,) bool, batch_now (N,) i32)``.
    """
    n = stale_now.shape[0]
    is_inf = qkind == events_lib.KIND_INFER
    fired = qv & (qt == t) & is_inf
    arr_slot = is_inf & (qseq < infer_base + n)
    node_of = jnp.clip(
        jnp.where(arr_slot, qseq - infer_base, qseq - infer_base - n),
        0, n - 1,
    )
    zeros_b = jnp.zeros((n,), bool)
    arr_fire = zeros_b.at[node_of].max(fired & arr_slot)
    cmp_fire = zeros_b.at[node_of].max(fired & ~arr_slot)

    # completions first: the batch finishes, the server idles
    served = sstate.served + jnp.where(cmp_fire, sstate.inflight, 0)
    inflight = jnp.where(cmp_fire, 0, sstate.inflight)
    # arrivals: count every one (the count also indexes the PRNG branch),
    # enqueue while there is room, drop past the cap
    arrivals = sstate.arrivals + arr_fire.astype(jnp.int32)
    room = sstate.queued < cfg.queue_cap
    queued = sstate.queued + (arr_fire & room).astype(jnp.int32)
    dropped = sstate.dropped + (arr_fire & ~room).astype(jnp.int32)
    # admission: idle + backlog -> start a batch NOW (same instant)
    can = (inflight == 0) & (queued > 0)
    batch_now = jnp.where(can, jnp.minimum(queued, cfg.slots), 0)
    inflight = inflight + batch_now
    queued = queued - batch_now
    batches = sstate.batches + can.astype(jnp.int32)

    # staleness-at-admit samples: prefix-sum slot assignment, first-K,
    # mode="drop" past capacity (the repro.obs scatter discipline)
    cap = sstate.st.shape[0]
    fi = can.astype(jnp.int32)
    pos = jnp.cumsum(fi) - fi
    idx = sstate.cursor + pos
    slot = jnp.where(can & (idx < cap), idx, cap)
    tvec = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (n,))
    st = sstate.st.at[slot].set(tvec, mode="drop")
    snode = sstate.snode.at[slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop"
    )
    sstale = sstate.sstale.at[slot].set(
        stale_now.astype(jnp.int32), mode="drop"
    )
    cursor = sstate.cursor + jnp.sum(fi)
    sdropped = sstate.sdropped + jnp.sum(fi * (idx >= cap).astype(jnp.int32))

    # reschedule fired arrival slots at the next per-(node, count) gap
    next_arr = t + _next_gaps(serve_base, arrivals, cfg.rate)
    qt = jnp.where(fired & arr_slot, next_arr[node_of], qt)
    # completion slots: arm at t + service_time when a batch admitted,
    # disarm when the node went idle; untouched nodes keep their schedule
    touched = cmp_fire | can
    e_cmp = is_inf & ~arr_slot & touched[node_of]
    qv = jnp.where(e_cmp, can[node_of], qv)
    qt = jnp.where(
        e_cmp,
        jnp.where(can[node_of], t + jnp.float32(cfg.service_time), jnp.inf),
        qt,
    )
    out = ServeState(
        queued=queued, inflight=inflight, served=served, arrivals=arrivals,
        dropped=dropped, batches=batches, st=st, snode=snode, sstale=sstale,
        cursor=cursor, sdropped=sdropped,
    )
    return out, qt, qv, can, batch_now


# ---------------------------------------------------------------------------
# Event-engine advance factories with the serve slots live
# ---------------------------------------------------------------------------


def _deliver_fn(impl: str, faults):
    """The shared delivery-batch block, faulted or not, with a uniform
    positional signature (dags, qt, fires, key, t, qv, qkind, qsrc, qdst,
    islot, horizon, fire_cap, part_mask, part_t0, part_t1, drop, nbr_idx,
    nbr_valid) -> (dags, qt, fires, key, deliver, live, pm)."""
    if faults is None:
        return lambda *a: events_lib._deliver_round(*a, impl)
    from repro.net import faults as faults_lib
    masks = faults_lib._role_masks(faults)
    return lambda *a: faults_lib._deliver_round_faults(
        faults, masks, impl, *a
    )


@functools.lru_cache(maxsize=None)
def _advance_events_serve_jit(impl: str, serve: ServeConfig, obs=None,
                              faults=None):
    """Bankless event advance with inference load (``serve`` effective).

    The loop body branches on the POPPED HEAD's kind: transport kinds run
    the shared delivery block exactly as the serve-free program (one main-
    key split per delivery batch); an INFER head runs ``infer_step``
    against plain replica staleness (no bank to gate on) and never
    touches the main key. INFER sorts after DELIVER at an equal instant,
    so same-instant requests are served post-merge. Returns a dict
    (dags / qt / qv / key / done / sstate [/ metrics / ring]).
    """
    deliver = _deliver_fn(impl, faults)
    if obs is not None:
        from repro import obs as obs_lib

    def advance(dags, qtime, qvalid, qkind, qsrc, qdst, qseq, islot, key,
                horizon, limit, fire_cap, part_mask, part_t0, part_t1,
                drop, nbr_idx, nbr_valid, sstate, serve_base, infer_base,
                *obs_carry):
        n = dags.publisher.shape[0]

        def cond(carry):
            qt, qv, done = carry[1], carry[2], carry[5]
            return events_lib._queue_head_due(qt, qv, horizon) & (done < limit)

        def body(carry):
            if obs is not None:
                dags, qt, qv, fires, key, done, sstate, metrics, ring = carry
                old_sstate = sstate
            else:
                dags, qt, qv, fires, key, done, sstate = carry
            idx, _found = event_pop(qt, qkind, qseq, qv)
            t = qt[idx]
            knd = qkind[idx]
            old = dags

            def do_net(op):
                dags, qt, qv, fires, key, sstate = op
                dags, qt, fires, key, _dlv, live, _pm = deliver(
                    dags, qt, fires, key, t, qv, qkind, qsrc, qdst, islot,
                    horizon, fire_cap, part_mask, part_t0, part_t1, drop,
                    nbr_idx, nbr_valid,
                )
                return (dags, qt, qv, fires, key, sstate, live,
                        jnp.zeros((n,), bool), jnp.zeros((n,), jnp.int32),
                        jnp.full((), -1, jnp.int32))

            def do_infer(op):
                dags, qt, qv, fires, key, sstate = op
                stale = gated_staleness(dags)
                sstate, qt, qv, admitted, batch_now = infer_step(
                    serve, sstate, t, qt, qv, qkind, qseq, infer_base,
                    serve_base, stale,
                )
                s_now = jnp.max(jnp.where(admitted, stale, -1)).astype(
                    jnp.int32
                )
                return (dags, qt, qv, fires, key, sstate,
                        jnp.zeros((n, n), bool), admitted, batch_now, s_now)

            (dags, qt, qv, fires, key, sstate, live, admitted, batch_now,
             s_now) = jax.lax.cond(
                knd == events_lib.KIND_INFER, do_infer, do_net,
                (dags, qt, qv, fires, key, sstate),
            )
            if obs is not None:
                kw = {}
                if obs.hist is not None:
                    # per-request histograms: the stale vector only weighs
                    # in when a batch admitted (an INFER head, which left
                    # dags untouched), so recomputing it post-cond reads
                    # exactly what infer_step saw
                    kw = dict(
                        serve_stale_node=gated_staleness(dags),
                        serve_arrived=sstate.arrivals - old_sstate.arrivals,
                        serve_enq=(sstate.queued - old_sstate.queued
                                   + batch_now),
                        serve_queued=sstate.queued,
                    )
                metrics, ring = obs_lib.observe_round(
                    obs, metrics, ring, t, old, dags, live_edges=live,
                    serve_counts=sstate.served, serve_stale=s_now,
                    infer_nodes=admitted, infer_arg=batch_now, **kw,
                )
                return (dags, qt, qv, fires, key, done + 1, sstate,
                        metrics, ring)
            return dags, qt, qv, fires, key, done + 1, sstate

        init = (dags, qtime, qvalid, jnp.zeros_like(qseq), key,
                jnp.int32(0), sstate) + tuple(obs_carry)
        out = jax.lax.while_loop(cond, body, init)
        res = {"dags": out[0], "qt": out[1], "qv": out[2], "key": out[4],
               "done": out[5], "sstate": out[6]}
        if obs is not None:
            res["metrics"], res["ring"] = out[7], out[8]
        return res

    return jax.jit(advance)


@functools.lru_cache(maxsize=None)
def _advance_events_bank_serve_jit(impl: str, bank_impl,
                                   serve: ServeConfig, obs=None,
                                   faults=None, codec=None):
    """Bank event advance with inference load (``serve`` effective).

    Transport heads run the bank batch EXACTLY as the serve-free program
    (shared delivery block, continuous budget accrual, drain re-arm;
    faulted variants swap in the fault-aware chunk service with the same
    spoof-key derivation); an INFER head computes the live availability
    reduction (``chunk_dedup``) and serves against the GATED view — rows
    whose chunks have not arrived count as missing, so staleness-at-serve
    is physical. ``codec`` scales ``chunk_bytes`` to encoded wire size as
    everywhere else. Returns a dict (dags / bstate [/ fstate] / last_srv /
    key / qt / qv / done / sstate [/ metrics / ring]).
    """
    deliver = _deliver_fn(impl, faults)
    if faults is not None:
        from repro.net import faults as faults_lib
        masks = faults_lib._role_masks(faults)
    if obs is not None:
        from repro import obs as obs_lib
    f = 1 if faults is not None else 0

    def advance(*all_args):
        if faults is not None:
            (dags, have, credit, sent, fstate0, last_srv, digest, qtime,
             qvalid, qkind, qsrc, qdst, qseq, islot, key, horizon, limit,
             fire_cap, part_mask, part_t0, part_t1, drop, nbr_idx,
             nbr_valid, bw_bytes, chunk_bytes, sstate0, serve_base,
             infer_base, *obs_carry) = all_args
        else:
            (dags, have, credit, sent, last_srv, digest, qtime, qvalid,
             qkind, qsrc, qdst, qseq, islot, key, horizon, limit, fire_cap,
             part_mask, part_t0, part_t1, drop, nbr_idx, nbr_valid,
             bw_bytes, chunk_bytes, sstate0, serve_base, infer_base,
             *obs_carry) = all_args
        if codec is not None:
            chunk_bytes = chunk_bytes * codec.wire_ratio()
        n = dags.publisher.shape[0]

        def cond(carry):
            qt, qv, done = carry[4 + f], carry[5 + f], carry[7 + f]
            return events_lib._queue_head_due(qt, qv, horizon) & (done < limit)

        def body(carry):
            it = list(carry)
            dags, bstate = it[0], it[1]
            if faults is not None:
                fstate = it[2]
            last_srv, key, qt, qv, fires, done, sstate = it[2 + f:9 + f]
            if obs is not None:
                metrics, ring = it[9 + f], it[10 + f]
                old_dags, old_sent = dags, bstate.sent
                old_have, old_sstate = bstate.have, sstate
                if faults is not None:
                    old_rej = fstate.rejects
            idx, _found = event_pop(qt, qkind, qseq, qv)
            t = qt[idx]
            knd = qkind[idx]

            def do_net(op):
                if faults is not None:
                    dags, bstate, fstate, last_srv, key, qt, qv, fires, \
                        sstate = op
                else:
                    dags, bstate, last_srv, key, qt, qv, fires, sstate = op
                batch = qv & (qt == t)
                is_drn = qkind == events_lib.KIND_DRAIN
                drain = events_lib._edge_mask(n, qdst, qsrc, batch & is_drn)

                def _with_round(op2):
                    return deliver(
                        *op2, t, qv, qkind, qsrc, qdst, islot, horizon,
                        fire_cap, part_mask, part_t0, part_t1, drop,
                        nbr_idx, nbr_valid,
                    )

                def _no_round(op2):
                    dags, qt, fires, key = op2
                    off = jnp.zeros((n, n), bool)
                    pm = events_lib._partition_mask(
                        t, part_mask, part_t0, part_t1
                    )
                    return dags, qt, fires, key, off, off, pm

                dags, qt, fires, key, deliver_m, live, pm = jax.lax.cond(
                    jnp.any(batch & (qkind == events_lib.KIND_DELIVER)),
                    _with_round, _no_round, (dags, qt, fires, key),
                )
                svc = live | (drain & pm)
                sched = deliver_m | drain
                accr = jnp.where(svc, (t - last_srv) * bw_bytes, 0.0)
                if faults is not None:
                    skey = jax.random.fold_in(
                        jax.random.fold_in(key, faults_lib._SALT_SPOOF),
                        done,
                    )
                    bstate2, fstate2, pending = (
                        faults_lib._fault_chunk_service(
                            dags, bstate, fstate, digest, svc, accr,
                            chunk_bytes, skey, faults, masks, bank_impl,
                        )
                    )
                else:
                    sat = chunk_kernel.chunk_dedup(
                        bstate.have, digest, impl=bank_impl
                    )
                    bstate2, pending = bank_lib.chunk_step(
                        dags, bstate, digest, sat, sat, svc, accr,
                        chunk_bytes, return_pending=True,
                    )
                last_srv = jnp.where(sched, t, last_srv)
                # strict-progress clamp: see the serve-free drain re-arm in
                # events.py — an f32 credit residue can round the completion
                # instant back to t and livelock the advance
                rate_b = jnp.maximum(bw_bytes, 1e-9)
                t_next = jnp.nextafter(t, jnp.float32(jnp.inf))
                e_next = jnp.maximum(
                    t + (chunk_bytes - bstate2.credit) / rate_b, t_next
                )[qdst, qsrc]
                e_retry = jnp.maximum(
                    t + chunk_bytes / rate_b, t_next
                )[qdst, qsrc]
                e_svc = svc[qdst, qsrc]
                e_pend = pending[qdst, qsrc]
                qv = jnp.where(is_drn & e_svc, e_pend, qv)
                qt = jnp.where(is_drn & e_svc,
                               jnp.where(e_pend, e_next, jnp.inf), qt)
                qt = jnp.where(batch & is_drn & ~e_svc, e_retry, qt)
                out = (dags, bstate2)
                out = out + ((fstate2,) if faults is not None else ())
                return out + (last_srv, key, qt, qv, fires, sstate, live,
                              jnp.zeros((n,), bool),
                              jnp.zeros((n,), jnp.int32),
                              jnp.full((), -1, jnp.int32))

            def do_infer(op):
                if faults is not None:
                    dags, bstate, fstate, last_srv, key, qt, qv, fires, \
                        sstate = op
                else:
                    dags, bstate, last_srv, key, qt, qv, fires, sstate = op
                sat = chunk_kernel.chunk_dedup(
                    bstate.have, digest, impl=bank_impl
                )
                stale = gated_staleness(dags, sat)
                sstate, qt, qv, admitted, batch_now = infer_step(
                    serve, sstate, t, qt, qv, qkind, qseq, infer_base,
                    serve_base, stale,
                )
                s_now = jnp.max(jnp.where(admitted, stale, -1)).astype(
                    jnp.int32
                )
                out = (dags, bstate)
                out = out + ((fstate,) if faults is not None else ())
                return out + (last_srv, key, qt, qv, fires, sstate,
                              jnp.zeros((n, n), bool), admitted, batch_now,
                              s_now)

            op = (dags, bstate)
            op = op + ((fstate,) if faults is not None else ())
            op = op + (last_srv, key, qt, qv, fires, sstate)
            res = jax.lax.cond(
                knd == events_lib.KIND_INFER, do_infer, do_net, op
            )
            dags, bstate = res[0], res[1]
            if faults is not None:
                fstate = res[2]
            (last_srv, key, qt, qv, fires, sstate, live, admitted,
             batch_now, s_now) = res[2 + f:]
            new = (dags, bstate)
            new = new + ((fstate,) if faults is not None else ())
            new = new + (last_srv, key, qt, qv, fires, done + 1, sstate)
            if obs is not None:
                kw = {}
                if faults is not None:
                    kw = dict(rejects=fstate.rejects,
                              rejects_delta=fstate.rejects - old_rej,
                              quarantine_after=faults.quarantine_after)
                if obs.hist is not None:
                    # see the bankless variant: only INFER heads give the
                    # stale vector weight, and they leave dags/bstate
                    # untouched, so the post-cond recompute is what
                    # infer_step gated on
                    sat_h = chunk_kernel.chunk_dedup(
                        bstate.have, digest, impl=bank_impl
                    )
                    kw.update(
                        serve_stale_node=gated_staleness(dags, sat_h),
                        serve_arrived=sstate.arrivals - old_sstate.arrivals,
                        serve_enq=(sstate.queued - old_sstate.queued
                                   + batch_now),
                        serve_queued=sstate.queued,
                    )
                metrics, ring = obs_lib.observe_round(
                    obs, metrics, ring, t, old_dags, dags, live_edges=live,
                    bytes_delta=bstate.sent - old_sent, bstate=bstate,
                    digest=digest, bank_impl=bank_impl, old_have=old_have,
                    serve_counts=sstate.served, serve_stale=s_now,
                    infer_nodes=admitted, infer_arg=batch_now, **kw,
                )
                new = new + (metrics, ring)
            return new

        init = (dags, bank_lib.BankState(have=have, credit=credit,
                                         sent=sent))
        init = init + ((fstate0,) if faults is not None else ())
        init = init + (last_srv, key, qtime, qvalid, jnp.zeros_like(qseq),
                       jnp.int32(0), sstate0) + tuple(obs_carry)
        out = jax.lax.while_loop(cond, body, init)
        res = {"dags": out[0], "bstate": out[1]}
        if faults is not None:
            res["fstate"] = out[2]
        res["last_srv"], res["key"] = out[2 + f], out[3 + f]
        res["qt"], res["qv"] = out[4 + f], out[5 + f]
        res["done"], res["sstate"] = out[7 + f], out[8 + f]
        if obs is not None:
            res["metrics"], res["ring"] = out[9 + f], out[10 + f]
        return res

    return jax.jit(advance)


# ---------------------------------------------------------------------------
# Host-side report
# ---------------------------------------------------------------------------


def report(sstate: ServeState, cfg: ServeConfig) -> dict:
    """Drain the serve counters into a host-side dict (all numpy/python).

    ``staleness_p50`` / ``staleness_p99`` are percentiles over the
    staleness-at-admit samples actually kept (NaN with zero batches);
    per-node arrays carry the full served / arrived / dropped / batch
    accounting so benches can derive throughput per node.
    """
    served = np.asarray(sstate.served, np.int64)
    k = int(min(int(sstate.cursor), sstate.sstale.shape[0]))
    stale = np.asarray(sstate.sstale, np.int64)[:k]
    out = {
        "rate": float(cfg.rate),
        "slots": int(cfg.slots),
        "service_time": float(cfg.service_time),
        "requests_served": served,
        "served_total": int(served.sum()),
        "arrivals": np.asarray(sstate.arrivals, np.int64),
        "arrived_total": int(np.asarray(sstate.arrivals, np.int64).sum()),
        "queued": np.asarray(sstate.queued, np.int64),
        "inflight": np.asarray(sstate.inflight, np.int64),
        "dropped": np.asarray(sstate.dropped, np.int64),
        "dropped_total": int(np.asarray(sstate.dropped, np.int64).sum()),
        "batches": np.asarray(sstate.batches, np.int64),
        "samples": k,
        "samples_dropped": int(sstate.sdropped),
        "staleness_t": np.asarray(sstate.st, np.float64)[:k],
        "staleness_node": np.asarray(sstate.snode, np.int64)[:k],
        "staleness_samples": stale,
        "staleness_p50": (float(np.percentile(stale, 50)) if k
                          else float("nan")),
        "staleness_p99": (float(np.percentile(stale, 99)) if k
                          else float("nan")),
        "staleness_max": int(stale.max()) if k else 0,
    }
    return out
