"""Anti-entropy gossip over the overlay: device-resident, tick-batched sync.

A sync tick folds every node's active neighbors into its local replica with
the ``dag.merge`` row rule. Two interchangeable round implementations:

  ``impl="fused"``   the fast path — per-row winner selection over ALL
                     senders in one masked reduction
                     (``repro.kernels.gossip_merge``; Pallas on TPU, its
                     pure-lax oracle elsewhere) followed by one payload
                     gather (``dag.merge_select``). O(log N) reduction
                     depth, no N² ``DagState`` intermediates.
  ``impl="scan"``    the PR-1 reference — ``vmap`` over receivers of a
                     ``lax.scan`` of sequential two-replica merges. Kept as
                     the bitwise ground truth (``tests/test_gossip_merge``)
                     and the benchmark baseline.

Dispatch batching: ``advance(t)`` no longer issues one jitted call per tick.
It precomputes the (tick index, partition-active) schedule for the whole
window host-side and runs ONE jitted ``lax.scan`` over it (PRNG keys split
inside the scan, so a batched window is bitwise the sequential ticks), and
``converge()`` runs the whole fixpoint iteration in ONE jitted
``lax.while_loop`` whose predicate (replicas synced / progress stalled) is
evaluated on device. ``GossipNetwork.device_calls`` counts dispatches so
benchmarks can report the batching win.

Per-edge behavior (unchanged semantics):

  message loss   each directed message is dropped i.i.d. with the link's
                 drop probability (``Topology.drop``);
  link latency   a link with latency ℓ fires only every
                 ``ceil(ℓ / sync_period)`` ticks — slow links sync less
                 often (transfer time quantized to the tick grid);
  partitions     a ``PartitionSchedule`` suppresses cross-component edges
                 for t ∈ [t_start, t_end), then heals.

``GossipNetwork`` is the host-side driver the simulator talks to: it owns
the replica set, the tick clock, and the schedule bookkeeping; all jitted
entry points live at module level (cached per ``impl``), so constructing
many networks in a benchmark sweep re-traces nothing.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dag as dag_lib
from repro.core.dag import DagState
from repro.kernels import gossip_merge as gossip_kernel
from repro.net import replica as replica_lib
from repro.net.topology import Topology, partition_matrix


@dataclass(frozen=True)
class PartitionSchedule:
    """Split the overlay into components for [t_start, t_end), then heal.

    ``assignment`` is an (N,) array of component labels; while active, only
    edges within a component deliver (§III.A under imperfect networks — the
    measurable question is how fast replicas reconverge after healing).
    """

    assignment: np.ndarray
    t_start: float
    t_end: float

    def active(self, t: float) -> bool:
        return self.t_start <= t < self.t_end


@dataclass(frozen=True)
class GossipConfig:
    """Anti-entropy knobs.

    ``sync_period <= 0`` means an ideal wire: every ``advance`` runs ticks
    until the replicas reach fixpoint — the shared-ledger limit used as the
    baseline (and by the acceptance test against ``run_dagfl``).
    ``max_ticks_per_advance`` bounds work when one advance window spans many
    periods; elided ticks are no-ops once the state has reached fixpoint
    (loss-free links), and with loss they only truncate redundant retries.
    ``impl`` picks the round implementation: "fused" (kernel reduction;
    Pallas on TPU, pure-lax elsewhere), "scan" (PR-1 reference fold), or the
    explicit backends "pallas" / "lax".
    """

    sync_period: float = 1.0
    seed: int = 0
    max_ticks_per_advance: int = 64
    impl: str = "fused"


# ---------------------------------------------------------------------------
# Shared device-side pieces (module-level: traced once per impl, not per
# GossipNetwork instance)
# ---------------------------------------------------------------------------


def trees_equal(a, b) -> jnp.ndarray:
    """() bool — leaf-wise exact equality of two pytrees (same treedef).

    Shared by the converge fixpoint predicate and host-side stall checks;
    module-level so repeated ``GossipNetwork`` construction re-traces
    nothing.
    """
    flags = [
        jnp.all(x == y)
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    ]
    return jnp.all(jnp.stack(flags))


trees_equal_jit = jax.jit(trees_equal)


def _sample_edges(key, tick, part_mask, adj, drop, stride):
    """(N, N) bool active-edge mask for one tick."""
    live = adj & (jnp.mod(tick, stride) == 0) & part_mask
    u = jax.random.uniform(key, adj.shape)
    return live & (u >= drop)


def _neighbor_table(adjacency: np.ndarray):
    """Static per-receiver candidate lists from the overlay adjacency.

    Returns ``(nbr_idx (R, D) int32, nbr_valid (R, D) bool)`` where D is the
    max degree + 1: each row lists the receiver itself plus its neighbors,
    padded (``nbr_valid`` false). Every sampled edge mask is a subset of the
    adjacency, so the table is computed ONCE host-side and the per-tick
    winner reduction runs over D candidates instead of all R senders —
    O(R * D * cap) work, the term that makes the fused round beat the
    sequential fold on sparse overlays.
    """
    adj = np.asarray(adjacency, bool)
    r = adj.shape[0]
    m = adj | np.eye(r, dtype=bool)
    deg = int(m.sum(axis=1).max())
    order = np.argsort(~m, axis=1, kind="stable")[:, :deg].astype(np.int32)
    valid = np.take_along_axis(m, order, axis=1)
    return order, valid


@functools.lru_cache(maxsize=64)
def _neighbor_table_cached(mask_bytes: bytes, r: int):
    m = np.frombuffer(mask_bytes, bool).reshape(r, r)
    nbr_idx, nbr_valid = _neighbor_table(m)
    return jnp.asarray(nbr_idx), jnp.asarray(nbr_valid)


def _round_scan(dags: DagState, edge_active: jnp.ndarray) -> DagState:
    """PR-1 reference round: vmap over receivers of a scan over senders."""

    def receive(dag_i, active_row):
        def body(carry, xs):
            dag_j, act = xs
            merged = dag_lib.merge(carry, dag_j)
            kept = jax.tree_util.tree_map(
                lambda m, c: jnp.where(act, m, c), merged, carry
            )
            return kept, None

        out, _ = jax.lax.scan(body, dag_i, (dags, active_row))
        return out

    return jax.vmap(receive)(dags, edge_active)


def _round_fused(
    dags: DagState, edge_active: jnp.ndarray,
    nbr_idx: jnp.ndarray, nbr_valid: jnp.ndarray, impl: str,
) -> DagState:
    """Fast path: one winner reduction + one payload gather per tick.

    "pallas" runs the dense blocked kernel over the full (receivers x cap)
    grid (the TPU shape; interpreted elsewhere); "lax" — the default off-TPU
    — gathers each receiver's candidate list and reduces over the max degree
    instead of the whole sender axis.
    """
    if impl == "fused":
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    n = edge_active.shape[0]
    if impl == "pallas":
        mask = edge_active | jnp.eye(n, dtype=bool)  # the receiver is a candidate
        src, ac = gossip_kernel.gossip_winner_pallas(
            dags.publish_time, dags.publisher, dags.approval_count, mask,
            interpret=jax.default_backend() != "tpu",
        )
        return dag_lib.merge_select(dags, src, ac, mask=mask)
    if impl != "lax":
        raise ValueError(f"unknown gossip round impl: {impl!r}")
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    act = jnp.take_along_axis(edge_active, nbr_idx, axis=1) | (nbr_idx == rows)
    act = act & nbr_valid
    src, ac = gossip_kernel.gossip_winner_nbr(
        dags.publish_time, dags.publisher, dags.approval_count, nbr_idx, act
    )
    return dag_lib.merge_select(dags, src, ac, nbr_idx=nbr_idx, nbr_act=act)


def _apply_round(
    dags: DagState, edge_active: jnp.ndarray,
    nbr_idx: jnp.ndarray, nbr_valid: jnp.ndarray, impl: str,
) -> DagState:
    if impl == "scan":
        return _round_scan(dags, edge_active)
    return _round_fused(dags, edge_active, nbr_idx, nbr_valid, impl)


@functools.lru_cache(maxsize=None)
def _round_jit(impl: str):
    return jax.jit(functools.partial(_apply_round, impl=impl))


def make_gossip_round(impl: str = "fused"):
    """(dags, edge_active) -> dags anti-entropy round (one jitted call).

    ``edge_active[i, j]`` = receiver i hears sender j this tick. Merge is
    commutative/associative, so folding senders in index order is as good as
    any delivery order — which is also why the non-"scan" impls may replace
    the fold with a masked winner reduction (bitwise-equal, tested). The
    fused impls derive the candidate table from the concrete ``edge_active``
    (cached), so this entry point wants concrete masks; jitted drivers
    (``GossipNetwork``) precompute the table from the static adjacency
    instead.
    """
    if impl == "scan":
        round_scan = _round_jit(impl)
        return lambda dags, edge_active: round_scan(dags, edge_active, None, None)

    def round_fn(dags, edge_active):
        m = np.asarray(edge_active, bool)
        nbr_idx, nbr_valid = _neighbor_table_cached(m.tobytes(), m.shape[0])
        return _round_jit(impl)(dags, edge_active, nbr_idx, nbr_valid)

    return round_fn


@functools.lru_cache(maxsize=None)
def _advance_jit(impl: str):
    """One jitted lax.scan running a whole advance window of sync ticks.

    The PRNG key is split inside the scan exactly like the sequential
    per-tick path did host-side, so a batched window is bitwise-identical to
    running its ticks one call at a time. Retraces once per distinct window
    length (a handful of lengths occur in practice).
    """

    def advance(dags, key, ticks, part_active, adj, drop, stride, part_mask,
                nbr_idx, nbr_valid):
        def body(carry, xs):
            dags, key = carry
            tick, pact = xs
            key, sub = jax.random.split(key)
            pm = jnp.where(pact, part_mask, True)
            edges = _sample_edges(sub, tick, pm, adj, drop, stride)
            return (_apply_round(dags, edges, nbr_idx, nbr_valid, impl), key), None

        (dags, key), _ = jax.lax.scan(body, (dags, key), (ticks, part_active))
        return dags, key

    return jax.jit(advance)


@functools.lru_cache(maxsize=None)
def _converge_jit(impl: str):
    """Device-resident fixpoint flush: ONE jitted lax.while_loop.

    The predicate — not yet synced, tick budget left, progress not stalled
    for a full stride cycle — runs on device, replacing the host loop that
    dispatched a sync round, an equality check, and a synced check per tick.
    """

    def converge(dags, key, tick, part_mask, adj, drop, stride, limit, stall_limit,
                 nbr_idx, nbr_valid):
        def cond(carry):
            dags, _key, _tick, stalled, done = carry
            return (
                ~replica_lib.replicas_synced(dags)
                & (done < limit)
                & (stalled < stall_limit)
            )

        def body(carry):
            dags, key, tick, stalled, done = carry
            key, sub = jax.random.split(key)
            edges = _sample_edges(sub, tick, part_mask, adj, drop, stride)
            new = _apply_round(dags, edges, nbr_idx, nbr_valid, impl)
            stalled = jnp.where(trees_equal(new, dags), stalled + 1, 0)
            return (new, key, tick + 1, stalled, done + 1)

        dags, key, tick, _, done = jax.lax.while_loop(
            cond, body,
            (dags, key, tick, jnp.int32(0), jnp.int32(0)),
        )
        return dags, key, tick, done, replica_lib.replicas_synced(dags)

    return jax.jit(converge)


def stride_matrix(top: Topology, sync_period: float, use_strides: bool = True) -> np.ndarray:
    """(N, N) int32 tick stride per link: a link with latency ℓ fires every
    ``ceil(ℓ / sync_period)`` ticks. ``use_strides=False`` (the ideal wire,
    ``sync_period <= 0``) delivers on every tick regardless of latency.
    Clipped to 2**30 so pathological latency/period ratios stay int32-safe
    (such links effectively never fire instead of overflowing to garbage)."""
    n = top.num_nodes
    if not use_strides:
        return np.ones((n, n), np.int32)
    period = max(float(sync_period), 1e-9)
    finite_lat = np.where(np.isfinite(top.latency), top.latency, 0.0)
    stride = np.where(
        top.adjacency, np.maximum(1.0, np.ceil(finite_lat / period)), 1.0
    )
    return np.minimum(stride, 2.0 ** 30).astype(np.int32)


class GossipNetwork:
    """Host-side overlay driver: replicas + tick clock + schedule batching."""

    def __init__(
        self,
        dag: DagState,
        bank: Any,
        top: Topology,
        cfg: GossipConfig = GossipConfig(),
        partition: Optional[PartitionSchedule] = None,
    ):
        n = top.num_nodes
        self.topology = top
        self.cfg = cfg
        self.partition = partition
        self.replicas = replica_lib.init_replicas(dag, bank, n)
        stride = stride_matrix(top, cfg.sync_period, use_strides=cfg.sync_period > 0)
        self._max_stride = (
            int(stride[top.adjacency].max()) if top.adjacency.any() else 1
        )
        self._adj = jnp.asarray(top.adjacency)
        self._drop = jnp.asarray(top.drop)
        self._stride = jnp.asarray(stride)
        nbr_idx, nbr_valid = _neighbor_table(top.adjacency)
        self._nbr_idx = jnp.asarray(nbr_idx)
        self._nbr_valid = jnp.asarray(nbr_valid)
        self._key = jax.random.PRNGKey(cfg.seed)
        self._all_mask = jnp.ones((n, n), bool)
        self._part_mask = (
            jnp.asarray(partition_matrix(partition.assignment))
            if partition is not None else self._all_mask
        )
        self.tick = 0                # global tick index (drives strides)
        self.rounds_run = 0          # ticks actually executed
        self.device_calls = 0        # jitted sync dispatches issued
        period = cfg.sync_period
        self._next_tick_t = period if period > 0 else 0.0

    # --- replica access ----------------------------------------------------

    @property
    def bank(self):
        return self.replicas.bank

    def read(self, i) -> DagState:
        return replica_lib.read_replica(self.replicas, i)

    def write(self, i, dag: DagState, bank=None) -> None:
        self.replicas = replica_lib.write_replica(self.replicas, i, dag)
        if bank is not None:
            self.replicas = self.replicas._replace(bank=bank)

    def union(self) -> DagState:
        return replica_lib.merge_all_jit(self.replicas.dags)

    def synced(self) -> bool:
        return bool(replica_lib.replicas_synced_jit(self.replicas.dags))

    def missing_rows(self, union: Optional[DagState] = None) -> np.ndarray:
        """(N,) rows each replica lacks vs the union view (0 = converged).
        Pass a precomputed ``union()`` to avoid re-folding the replicas."""
        if union is None:
            union = self.union()
        return np.asarray(
            replica_lib.missing_vs_union_jit(self.replicas.dags, union)
        )

    # --- the clock ---------------------------------------------------------

    def _mask_at(self, t: float):
        if self.partition is not None and self.partition.active(t):
            return self._part_mask
        return self._all_mask

    def _run_ticks(self, ticks, part_active) -> None:
        """Execute a batch of sync ticks as ONE jitted device call."""
        dags, self._key = _advance_jit(self.cfg.impl)(
            self.replicas.dags, self._key,
            jnp.asarray(ticks, jnp.int32), jnp.asarray(part_active, bool),
            self._adj, self._drop, self._stride, self._part_mask,
            self._nbr_idx, self._nbr_valid,
        )
        self.replicas = self.replicas._replace(dags=dags)
        self.tick += len(ticks)
        self.rounds_run += len(ticks)
        self.device_calls += 1

    def _tick_once(self, t: float) -> None:
        """One sync tick at simulation time ``t`` (a batch of one — the
        reference granularity the batched ``advance`` is tested against)."""
        pact = self.partition is not None and self.partition.active(t)
        self._run_ticks([self.tick], [pact])

    def advance(self, t: float) -> None:
        """Run every sync tick scheduled at or before simulation time ``t``
        as one batched dispatch."""
        if self.cfg.sync_period <= 0:
            self.converge(at_time=t)
            return
        ticks, pacts = [], []
        nt = self._next_tick_t
        while nt <= t and len(ticks) < self.cfg.max_ticks_per_advance:
            ticks.append(self.tick + len(ticks))
            pacts.append(self.partition is not None and self.partition.active(nt))
            nt += self.cfg.sync_period
        if ticks:
            self._run_ticks(ticks, pacts)
        self._next_tick_t = nt
        if self._next_tick_t <= t:     # window overflowed the cap: fast-forward
            periods_behind = int((t - self._next_tick_t) // self.cfg.sync_period) + 1
            self.tick += periods_behind
            self._next_tick_t += periods_behind * self.cfg.sync_period

    def converge(self, at_time: float = float("inf")) -> bool:
        """Tick until the replicas reach fixpoint (ideal-wire flush / heal).

        ONE jitted ``lax.while_loop`` with an on-device predicate, bounded
        by ``num_nodes * max_stride`` ticks: the hop diameter is at most
        num_nodes - 1, and a stride-s link needs up to s ticks before it
        fires (stride capped at 64 here so pathological latency ratios
        cannot make the flush unbounded). A full stride cycle of unchanged
        state is a fixpoint (partition active or overlay disconnected — no
        further tick can make progress). Returns whether full sync was
        reached — it cannot be while a partition is active or the overlay
        is disconnected.
        """
        limit = self.topology.num_nodes * min(self._max_stride, 64)
        stall_limit = min(self._max_stride, 64)
        dags, self._key, tick, done, synced = _converge_jit(self.cfg.impl)(
            self.replicas.dags, self._key, jnp.asarray(self.tick, jnp.int32),
            self._mask_at(at_time), self._adj, self._drop, self._stride,
            limit, stall_limit, self._nbr_idx, self._nbr_valid,
        )
        self.replicas = self.replicas._replace(dags=dags)
        self.tick = int(tick)
        self.rounds_run += int(done)
        self.device_calls += 1
        return bool(synced)
