"""Anti-entropy gossip over the overlay: device-resident, tick-batched sync.

A sync tick folds every node's active neighbors into its local replica with
the ``dag.merge`` row rule. Two interchangeable round implementations:

  ``impl="fused"``   the fast path — per-row winner selection over ALL
                     senders in one masked reduction
                     (``repro.kernels.gossip_merge``; Pallas on TPU, its
                     pure-lax oracle elsewhere) followed by one payload
                     gather (``dag.merge_select``). O(log N) reduction
                     depth, no N² ``DagState`` intermediates.
  ``impl="scan"``    the PR-1 reference — ``vmap`` over receivers of a
                     ``lax.scan`` of sequential two-replica merges. Kept as
                     the bitwise ground truth (``tests/test_gossip_merge``)
                     and the benchmark baseline.

Dispatch batching: ``advance(t)`` no longer issues one jitted call per tick.
It precomputes the (tick index, partition-active) schedule for the whole
window host-side and runs ONE jitted ``lax.scan`` over it (PRNG keys split
inside the scan, so a batched window is bitwise the sequential ticks), and
``converge()`` runs the whole fixpoint iteration in ONE jitted
``lax.while_loop`` whose predicate (replicas synced / progress stalled) is
evaluated on device. Every state-advancing device call routes through the
``GossipNetwork._dispatch`` funnel — tick advance, event advance, the bank
variants, converge, and commit accounting alike — so ``device_calls`` is
the complete dispatch count benchmarks report (``dispatch_counts`` keeps
the per-entry-point breakdown).

Telemetry: constructed with ``obs_cfg=repro.obs.ObsConfig(...)``, the
jitted loops thread device-resident collectors (metric accumulators + an
event trace ring, ``repro.obs``) through their carries and the network
grows ``obs_report()`` / ``trace_host()``. Collection is a pure read —
same PRNG splits, bitwise-identical trajectory — and ``obs_cfg=None``
(the default) keeps every jitted program literally unchanged; both claims
are property-tested in ``tests/test_obs.py``.

Per-edge behavior (unchanged semantics):

  message loss   each directed message is dropped i.i.d. with the link's
                 drop probability (``Topology.drop``);
  link latency   a link with latency ℓ fires only every
                 ``ceil(ℓ / sync_period)`` ticks — slow links sync less
                 often (transfer time quantized to the tick grid);
  partitions     a ``PartitionSchedule`` suppresses cross-component edges
                 for t ∈ [t_start, t_end), then heals.

Mesh sharding: constructed with ``mesh=...`` (see ``repro.net.mesh``),
``GossipNetwork`` partitions the replica set's leading receiver axis over
the mesh's ``"nodes"`` axis and swaps the round body for a ``shard_map``:
each shard all-gathers the sender rows once (the round's one collective),
winner-reduces its own receiver block, and writes back only that block.
The tick-batched ``advance`` scan and the ``converge`` while-loop stay
device-resident and are traced once per (impl, mesh). ``mesh=None``
preserves the single-device paths bitwise, and the sharded round is
bitwise-equal to them (property-tested in ``tests/test_net_mesh.py``).

Bank gossip: constructed with ``bank_cfg=BankGossipConfig(...)``
(``repro.net.bank``), every tick also moves MODEL PAYLOAD availability:
after the row merge, each node pulls the content-addressed chunks of rows
it can see but cannot yet use, charged against the link's Table-I byte
budget (``Topology.bandwidth``; partial-chunk credit rolls over across
ticks). The transport state (presence bitmaps + link credit) rides the
same scan carry; under a mesh the tick all-gathers availability BITMAPS,
never payload bytes. The chunk step is deterministic — no PRNG — so with
unlimited capacity the whole trajectory is bitwise the ``bank_cfg=None``
path (the CI-enforced equivalence); ``converge()`` then also waits for
referenced chunks to arrive, with its tick bound extended by the slowest
link's slot-drain time. ``bank_cfg=None`` (default) is exactly the PR-3
driver. With ``bank_cfg.codec`` set (``repro.kernels.delta_codec``),
chunks are priced at their ENCODED byte size — the codec rides the jit
factories as another static key, and every ratio-1.0 codec maps to the
literal uncompressed program (``docs/WIRE_FORMAT.md``).

Continuous time: constructed with ``GossipConfig(engine="events")``,
``advance`` runs the ``repro.net.events`` engine instead of the tick scan —
per-edge deliveries fire at the link's ACTUAL latency (a 0.3 s link no
longer waits for the 1 s tick; a 3.7 s link is no longer rounded to 4),
and with the bank gossiped, chunk drains complete at whole-chunk instants
with continuously-accrued budget. ``engine="ticks"`` (the default) keeps
every path here bitwise what it was, and the degenerate uniform-delay
limit of the event engine is bitwise the tick path (CI-enforced; requires
a float32-exact period — see ``repro.net.events`` on the f32 event clock).
``converge()`` is the engine-independent anti-entropy fixpoint flush (the
tick while-loop — a flush has no timeline to quantize).

``GossipNetwork`` is the host-side driver the simulator talks to: it owns
the replica set, the tick clock, and the schedule bookkeeping; all jitted
entry points live at module level (cached per ``impl`` x ``mesh``
x bank backend), so constructing many networks in a benchmark sweep
re-traces nothing.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import dag as dag_lib
from repro.core.dag import DagState
from repro.kernels import chunk_transfer as chunk_kernel
from repro.kernels import delta_codec as codec_lib
from repro.kernels import gossip_merge as gossip_kernel
from repro.net import bank as bank_lib
from repro.net import mesh as mesh_lib
from repro.net import replica as replica_lib
from repro.net.bank import BankGossipConfig, BankState
from repro.net.topology import Topology, neighbor_table, partition_matrix


@dataclass(frozen=True)
class PartitionSchedule:
    """Split the overlay into components for [t_start, t_end), then heal.

    ``assignment`` is an (N,) array of component labels; while active, only
    edges within a component deliver (§III.A under imperfect networks — the
    measurable question is how fast replicas reconverge after healing).
    """

    assignment: np.ndarray
    t_start: float
    t_end: float

    def active(self, t: float) -> bool:
        return self.t_start <= t < self.t_end


@dataclass(frozen=True)
class GossipConfig:
    """Anti-entropy knobs.

    ``sync_period <= 0`` means an ideal wire: every ``advance`` runs ticks
    until the replicas reach fixpoint — the shared-ledger limit used as the
    baseline (and by the acceptance test against ``run_dagfl``).
    ``max_ticks_per_advance`` bounds work when one advance window spans many
    periods; elided ticks are no-ops once the state has reached fixpoint
    (loss-free links), and with loss they only truncate redundant retries.
    ``impl`` picks the round implementation: "fused" (kernel reduction;
    Pallas on TPU, pure-lax elsewhere), "scan" (PR-1 reference fold), or the
    explicit backends "pallas" / "lax".

    ``engine`` picks the transport clock: "ticks" (the quantized stride
    model — every path bitwise what it was) or "events" (the continuous-time
    engine, ``repro.net.events``: per-edge deliveries at the link's actual
    latency, bank chunk-drains at whole-chunk completion instants, one
    jitted while_loop per advance). Under "events",
    ``max_ticks_per_advance`` caps how often each delivery edge fires per
    advance window — a backlog beyond the cap is ELIDED (the edge's
    schedule jumps past the window), bitwise the tick engine's
    fast-forward, so the degenerate-limit equivalence holds for any window
    size; ``max_events_per_advance`` bounds one dispatch's event batches,
    and a window truncated by it resumes on the next ``advance`` call.
    """

    sync_period: float = 1.0
    seed: int = 0
    max_ticks_per_advance: int = 64
    impl: str = "fused"
    engine: str = "ticks"
    max_events_per_advance: int = 8192


# ---------------------------------------------------------------------------
# Shared device-side pieces (module-level: traced once per impl, not per
# GossipNetwork instance)
# ---------------------------------------------------------------------------


def trees_equal(a, b) -> jnp.ndarray:
    """() bool — leaf-wise exact equality of two pytrees (same treedef).

    Shared by the converge fixpoint predicate and host-side stall checks;
    module-level so repeated ``GossipNetwork`` construction re-traces
    nothing.
    """
    flags = [
        jnp.all(x == y)
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    ]
    return jnp.all(jnp.stack(flags))


trees_equal_jit = jax.jit(trees_equal)


def _sample_edges(key, tick, part_mask, adj, drop, stride):
    """(N, N) bool active-edge mask for one tick."""
    live = adj & (jnp.mod(tick, stride) == 0) & part_mask
    u = jax.random.uniform(key, adj.shape)
    return live & (u >= drop)


@functools.lru_cache(maxsize=64)
def _neighbor_table_cached(mask_bytes: bytes, r: int):
    m = np.frombuffer(mask_bytes, bool).reshape(r, r)
    nbr_idx, nbr_valid = neighbor_table(m)
    return jnp.asarray(nbr_idx), jnp.asarray(nbr_valid)


def _round_scan(
    dags: DagState, edge_active: jnp.ndarray, senders: DagState = None
) -> DagState:
    """PR-1 reference round: vmap over receivers of a scan over senders.

    ``senders`` defaults to ``dags``; a mesh shard passes its local receiver
    block as ``dags`` and the all-gathered sender axis as ``senders``.
    """
    senders = dags if senders is None else senders

    def receive(dag_i, active_row):
        def body(carry, xs):
            dag_j, act = xs
            merged = dag_lib.merge(carry, dag_j)
            kept = jax.tree_util.tree_map(
                lambda m, c: jnp.where(act, m, c), merged, carry
            )
            return kept, None

        out, _ = jax.lax.scan(body, dag_i, (senders, active_row))
        return out

    return jax.vmap(receive)(dags, edge_active)


def _round_fused(
    dags: DagState, edge_active: jnp.ndarray,
    nbr_idx: jnp.ndarray, nbr_valid: jnp.ndarray, impl: str,
    senders: DagState = None, row_offset=None,
) -> DagState:
    """Fast path: one winner reduction + one payload gather per tick.

    "pallas" runs the dense blocked kernel over the full (receivers x cap)
    grid (the TPU shape; interpreted elsewhere); "lax" — the default off-TPU
    — gathers each receiver's candidate list and reduces over the max degree
    instead of the whole sender axis.

    THE round body, single-device and sharded alike: a mesh shard passes its
    receiver block as ``dags`` with the all-gathered sender axis as
    ``senders`` and the block's global start index as ``row_offset``
    (``edge_active``/``nbr_idx``/``nbr_valid`` then hold just the block's
    rows); the defaults are the identity block — every receiver, offset 0.
    """
    if impl == "fused":
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    senders = dags if senders is None else senders
    rb = dags.publisher.shape[0]
    rows = jnp.arange(rb, dtype=jnp.int32)
    if row_offset is not None:
        rows = rows + row_offset
    if impl == "pallas":
        # the receiver is a candidate
        mask = jnp.asarray(edge_active).at[jnp.arange(rb), rows].set(True)
        src, _ = gossip_kernel.gossip_winner_pallas(
            senders.publish_time, senders.publisher, senders.approval_count,
            mask, interpret=jax.default_backend() != "tpu",
            row_offset=0 if row_offset is None else row_offset,
        )
        return dag_lib.merge_select(senders, src, mask=mask)
    if impl != "lax":
        raise ValueError(f"unknown gossip round impl: {impl!r}")
    act = jnp.take_along_axis(edge_active, nbr_idx, axis=1) | (nbr_idx == rows[:, None])
    act = act & nbr_valid
    src, _ = gossip_kernel.gossip_winner_nbr(
        senders.publish_time, senders.publisher, senders.approval_count,
        nbr_idx, act, row_ids=None if row_offset is None else rows,
    )
    return dag_lib.merge_select(senders, src, nbr_idx=nbr_idx, nbr_act=act)


def _apply_round(
    dags: DagState, edge_active: jnp.ndarray,
    nbr_idx: jnp.ndarray, nbr_valid: jnp.ndarray, impl: str,
) -> DagState:
    if impl == "scan":
        return _round_scan(dags, edge_active)
    return _round_fused(dags, edge_active, nbr_idx, nbr_valid, impl)


@functools.lru_cache(maxsize=None)
def _round_jit(impl: str):
    return jax.jit(functools.partial(_apply_round, impl=impl))


# ---------------------------------------------------------------------------
# Mesh-sharded round: per-shard winner reduction + one collective row gather
# ---------------------------------------------------------------------------


def _shard_round_block(
    dags: DagState, edge_active: jnp.ndarray,
    nbr_idx: jnp.ndarray, nbr_valid: jnp.ndarray, impl: str,
) -> DagState:
    """One shard's share of a sync tick (runs under ``shard_map``).

    ``dags`` holds this shard's contiguous receiver block (R/shards rows of
    the stacked replica set); ``edge_active`` and the candidate table arrive
    replicated. The shard all-gathers the sender rows ONCE — the round's one
    collective; merge payload rows are small next to the model bank, which
    stays shared — then runs the SAME round body as the single-device path
    (``_round_fused``/``_round_scan``) restricted to its own receiver block
    (global ids ``off + arange``, so self-tie-preference and payload gathers
    keep addressing the gathered sender axis), and returns only its block.
    Bitwise-equal to the single-device round by construction: one shared
    body, identical candidate lists, masks, and reduction arithmetic per
    receiver row.
    """
    rb = dags.publisher.shape[0]
    off = jax.lax.axis_index(mesh_lib.NODES_AXIS) * rb
    senders = jax.tree_util.tree_map(
        lambda x: jax.lax.all_gather(x, mesh_lib.NODES_AXIS, axis=0, tiled=True),
        dags,
    )
    edges = jax.lax.dynamic_slice_in_dim(edge_active, off, rb, axis=0)
    if impl == "scan":
        return _round_scan(dags, edges, senders=senders)
    nbr = jax.lax.dynamic_slice_in_dim(nbr_idx, off, rb, axis=0)
    nbrv = jax.lax.dynamic_slice_in_dim(nbr_valid, off, rb, axis=0)
    return _round_fused(
        dags, edges, nbr, nbrv, impl, senders=senders, row_offset=off
    )


@functools.lru_cache(maxsize=None)
def _shard_round(impl: str, mesh):
    """shard_map'd round: receivers split over "nodes", everything else
    replicated (any extra mesh axes — e.g. "model" — replicate too)."""
    return shard_map(
        functools.partial(_shard_round_block, impl=impl),
        mesh=mesh,
        in_specs=(P(mesh_lib.NODES_AXIS), P(), P(), P()),
        out_specs=P(mesh_lib.NODES_AXIS),
        check_rep=False,
    )


@functools.lru_cache(maxsize=None)
def _shard_round_jit(impl: str, mesh):
    return jax.jit(_shard_round(impl, mesh))


def _round_for(impl: str, mesh):
    """(dags, edges, nbr_idx, nbr_valid) -> dags round body per mesh.

    ``mesh=None`` returns the exact single-device body (today's behavior,
    bitwise); a mesh returns the shard_map'd round.
    """
    if mesh is None:
        return functools.partial(_apply_round, impl=impl)
    return _shard_round(impl, mesh)


# ---------------------------------------------------------------------------
# Bank-gossip tick: DAG round + priced chunk transfers (repro.net.bank)
# ---------------------------------------------------------------------------


def _bank_tick_single(dags, bstate, digest, edges, nbr_idx, nbr_valid,
                      cap_bytes, chunk_bytes, impl, bank_impl):
    """One sync tick with the model bank gossiped (single-device body).

    Rows merge first (the unchanged PR-3 round), then the chunk step runs on
    the POST-merge replicas over the SAME sampled edge mask: metadata and
    payload travel the same links in the same tick, so under infinite
    bandwidth availability tracks visibility exactly (see ``repro.net.bank``)
    and the dags trajectory — and the PRNG stream, which the deterministic
    chunk step never touches — is bitwise the bankless path.
    """
    dags = _apply_round(dags, edges, nbr_idx, nbr_valid, impl)
    sat = chunk_kernel.chunk_dedup(bstate.have, digest, impl=bank_impl)
    bstate = bank_lib.chunk_step(
        dags, bstate, digest, sat, sat, edges, cap_bytes, chunk_bytes
    )
    return dags, bstate


def _bank_tick_block(dags, have, credit, sent, digest, edges, nbr_idx,
                     nbr_valid, cap_bytes, chunk_bytes, impl, bank_impl):
    """One shard's share of a bank-gossip tick (runs under ``shard_map``).

    The DAG half is exactly ``_shard_round_block``; the bank half computes
    the dedup reduction for its own receiver block and ALL-GATHERS the
    resulting chunk-availability bitmaps — never payload bytes; the store
    stays shared — so its block's transfer selection sees every sender's
    effective availability, then updates only its block's presence/credit
    rows. Bitwise-equal to the single-device tick: per-receiver arithmetic
    over identical gathered operands.
    """
    rb = dags.publisher.shape[0]
    off = jax.lax.axis_index(mesh_lib.NODES_AXIS) * rb
    dags = _shard_round_block(dags, edges, nbr_idx, nbr_valid, impl)
    bstate = BankState(have=have, credit=credit, sent=sent)
    sat_blk = chunk_kernel.chunk_dedup(have, digest, impl=bank_impl)
    sat_all = jax.lax.all_gather(
        sat_blk, mesh_lib.NODES_AXIS, axis=0, tiled=True
    )
    edges_blk = jax.lax.dynamic_slice_in_dim(edges, off, rb, axis=0)
    cap_blk = jax.lax.dynamic_slice_in_dim(cap_bytes, off, rb, axis=0)
    bstate = bank_lib.chunk_step(
        dags, bstate, digest, sat_all, sat_blk, edges_blk, cap_blk, chunk_bytes
    )
    return dags, bstate.have, bstate.credit, bstate.sent


@functools.lru_cache(maxsize=None)
def _shard_bank_tick(impl: str, bank_impl, mesh):
    p_nodes, p_rep = P(mesh_lib.NODES_AXIS), P()
    return shard_map(
        functools.partial(_bank_tick_block, impl=impl, bank_impl=bank_impl),
        mesh=mesh,
        in_specs=(p_nodes, p_nodes, p_nodes, p_nodes,
                  p_rep, p_rep, p_rep, p_rep, p_rep, p_rep),
        out_specs=(p_nodes, p_nodes, p_nodes, p_nodes),
        check_rep=False,
    )


def _bank_tick_for(impl: str, bank_impl, mesh):
    """(dags, bstate, digest, edges, nbr_idx, nbr_valid, cap, chunk_bytes)
    -> (dags, bstate) tick body; ``mesh=None`` is the single-device tick,
    a mesh routes both halves through one ``shard_map``."""
    if mesh is None:
        return functools.partial(
            _bank_tick_single, impl=impl, bank_impl=bank_impl
        )
    tick = _shard_bank_tick(impl, bank_impl, mesh)

    def run(dags, bstate, digest, edges, nbr_idx, nbr_valid, cap_bytes,
            chunk_bytes):
        dags, have, credit, sent = tick(
            dags, bstate.have, bstate.credit, bstate.sent, digest, edges,
            nbr_idx, nbr_valid, cap_bytes, chunk_bytes,
        )
        return dags, BankState(have=have, credit=credit, sent=sent)

    return run


def _codec_tick(tick, codec):
    """Wrap a bank tick body so every consumer of ``chunk_bytes`` — credit
    pricing, the ``sent`` meter, afford — is charged the codec's ENCODED
    byte size. ``codec=None`` (the ``delta_codec.codec_key`` image of every
    ratio-1.0 codec) returns the tick body UNTOUCHED, so the identity path
    stays the literal uncompressed program."""
    if codec is None:
        return tick
    ratio = codec.wire_ratio()

    def run(dags, bstate, digest, edges, nbr_idx, nbr_valid, cap_bytes,
            chunk_bytes):
        return tick(dags, bstate, digest, edges, nbr_idx, nbr_valid,
                    cap_bytes, chunk_bytes * ratio)

    return run


@functools.lru_cache(maxsize=None)
def _advance_bank_jit(impl: str, bank_impl, mesh=None, obs=None, faults=None,
                      codec=None):
    """Tick-batched advance with the bank gossiped: the same ONE-``lax.scan``
    window as ``_advance_jit`` — same PRNG splits, same edge samples — with
    the transport state threaded through the carry. ``obs`` threads the
    telemetry carry too (``obs=None`` keeps the untouched program); the
    bank run additionally samples chunk lag / byte totals and records a
    DRAIN trace span per link that moved payload. ``faults`` (a
    ``repro.net.faults.FaultConfig``) swaps in the fault-injected body —
    ``faults=None`` keeps the untouched program below. ``codec`` (pre-mapped
    through ``delta_codec.codec_key``) scales ``chunk_bytes`` to the
    encoded wire size inside the body; ``codec=None`` keeps the literal
    raw-chunk program."""
    if faults is not None:
        from repro.net import faults as faults_lib   # deferred: faults imports this module
        return faults_lib._advance_bank_faults_jit(impl, bank_impl, faults,
                                                   obs, codec)
    tick = _codec_tick(_bank_tick_for(impl, bank_impl, mesh), codec)

    if obs is None:
        def advance(dags, bstate, digest, key, ticks, part_active, adj, drop,
                    stride, part_mask, nbr_idx, nbr_valid, cap_bytes,
                    chunk_bytes):
            def body(carry, xs):
                dags, bstate, key = carry
                tick_i, pact = xs
                key, sub = jax.random.split(key)
                pm = jnp.where(pact, part_mask, True)
                edges = _sample_edges(sub, tick_i, pm, adj, drop, stride)
                dags, bstate = tick(dags, bstate, digest, edges, nbr_idx,
                                    nbr_valid, cap_bytes, chunk_bytes)
                return (dags, bstate, key), None

            (dags, bstate, key), _ = jax.lax.scan(
                body, (dags, bstate, key), (ticks, part_active)
            )
            return dags, bstate, key

        return jax.jit(advance)

    from repro import obs as obs_lib

    def advance(dags, bstate, digest, key, ticks, part_active, adj, drop,
                stride, part_mask, nbr_idx, nbr_valid, cap_bytes, chunk_bytes,
                metrics, ring, period):
        def body(carry, xs):
            dags, bstate, key, metrics, ring = carry
            tick_i, pact = xs
            key, sub = jax.random.split(key)
            pm = jnp.where(pact, part_mask, True)
            edges = _sample_edges(sub, tick_i, pm, adj, drop, stride)
            new, newb = tick(dags, bstate, digest, edges, nbr_idx,
                             nbr_valid, cap_bytes, chunk_bytes)
            t = (tick_i.astype(jnp.float32) + 1.0) * period
            metrics, ring = obs_lib.observe_round(
                obs, metrics, ring, t, dags, new, live_edges=edges,
                bytes_delta=newb.sent - bstate.sent, bstate=newb,
                digest=digest, bank_impl=bank_impl, old_have=bstate.have,
            )
            return (new, newb, key, metrics, ring), None

        (dags, bstate, key, metrics, ring), _ = jax.lax.scan(
            body, (dags, bstate, key, metrics, ring), (ticks, part_active)
        )
        return dags, bstate, key, metrics, ring

    return jax.jit(advance)


@functools.lru_cache(maxsize=None)
def _converge_bank_jit(impl: str, bank_impl, mesh=None, obs=None, faults=None,
                       codec=None):
    """Fixpoint flush with the bank gossiped: one ``lax.while_loop`` whose
    predicate also demands every replica's referenced chunks have ARRIVED —
    rows synced is no longer enough when payloads lag — and whose stall
    check watches the transport state too (credit accrual on a pending link
    is progress; a full stride cycle with nothing moving is a fixpoint).
    ``obs`` threads the telemetry carry (``obs=None`` keeps the untouched
    program); ``faults`` swaps in the fault-injected body (``faults=None``
    keeps the untouched program below); ``codec`` prices chunks at encoded
    bytes (``codec=None`` keeps the literal raw-chunk program)."""
    if faults is not None:
        from repro.net import faults as faults_lib
        return faults_lib._converge_bank_faults_jit(impl, bank_impl, faults,
                                                    obs, codec)
    tick = _codec_tick(_bank_tick_for(impl, bank_impl, mesh), codec)

    def synced(dags, bstate, digest):
        return replica_lib.replicas_synced(dags) & (
            jnp.max(bank_lib.missing_chunks(dags, bstate, digest,
                                            impl=bank_impl)) == 0
        )

    if obs is None:
        def converge(dags, bstate, digest, key, tick0, part_mask, adj, drop,
                     stride, limit, stall_limit, nbr_idx, nbr_valid,
                     cap_bytes, chunk_bytes):
            def cond(carry):
                dags, bstate, _key, _tick, stalled, done = carry
                return (
                    ~synced(dags, bstate, digest)
                    & (done < limit)
                    & (stalled < stall_limit)
                )

            def body(carry):
                dags, bstate, key, tick_i, stalled, done = carry
                key, sub = jax.random.split(key)
                edges = _sample_edges(sub, tick_i, part_mask, adj, drop, stride)
                new, newb = tick(dags, bstate, digest, edges, nbr_idx,
                                 nbr_valid, cap_bytes, chunk_bytes)
                still = trees_equal((new, newb), (dags, bstate))
                stalled = jnp.where(still, stalled + 1, 0)
                return (new, newb, key, tick_i + 1, stalled, done + 1)

            dags, bstate, key, tick_i, _, done = jax.lax.while_loop(
                cond, body,
                (dags, bstate, key, tick0, jnp.int32(0), jnp.int32(0)),
            )
            return (dags, bstate, key, tick_i, done,
                    synced(dags, bstate, digest))

        return jax.jit(converge)

    from repro import obs as obs_lib

    def converge(dags, bstate, digest, key, tick0, part_mask, adj, drop,
                 stride, limit, stall_limit, nbr_idx, nbr_valid, cap_bytes,
                 chunk_bytes, metrics, ring, period):
        def cond(carry):
            dags, bstate, _key, _tick, stalled, done = carry[:6]
            return (
                ~synced(dags, bstate, digest)
                & (done < limit)
                & (stalled < stall_limit)
            )

        def body(carry):
            dags, bstate, key, tick_i, stalled, done, metrics, ring = carry
            key, sub = jax.random.split(key)
            edges = _sample_edges(sub, tick_i, part_mask, adj, drop, stride)
            new, newb = tick(dags, bstate, digest, edges, nbr_idx, nbr_valid,
                             cap_bytes, chunk_bytes)
            still = trees_equal((new, newb), (dags, bstate))
            stalled = jnp.where(still, stalled + 1, 0)
            t = (tick_i.astype(jnp.float32) + 1.0) * period
            metrics, ring = obs_lib.observe_round(
                obs, metrics, ring, t, dags, new, live_edges=edges,
                bytes_delta=newb.sent - bstate.sent, bstate=newb,
                digest=digest, bank_impl=bank_impl, old_have=bstate.have,
            )
            return (new, newb, key, tick_i + 1, stalled, done + 1,
                    metrics, ring)

        dags, bstate, key, tick_i, _, done, metrics, ring = (
            jax.lax.while_loop(
                cond, body,
                (dags, bstate, key, tick0, jnp.int32(0), jnp.int32(0),
                 metrics, ring),
            )
        )
        return (dags, bstate, key, tick_i, done,
                synced(dags, bstate, digest), metrics, ring)

    return jax.jit(converge)


def make_gossip_round(impl: str = "fused", mesh=None):
    """(dags, edge_active) -> dags anti-entropy round (one jitted call).

    ``edge_active[i, j]`` = receiver i hears sender j this tick. Merge is
    commutative/associative, so folding senders in index order is as good as
    any delivery order — which is also why the non-"scan" impls may replace
    the fold with a masked winner reduction (bitwise-equal, tested). The
    fused impls derive the candidate table from the concrete ``edge_active``
    (cached), so this entry point wants concrete masks; jitted drivers
    (``GossipNetwork``) precompute the table from the static adjacency
    instead. With ``mesh`` the stacked replicas are placed receiver-sharded
    and the round runs as the shard_map body (``_shard_round``).
    """
    if mesh is None:
        if impl == "scan":
            round_scan = _round_jit(impl)
            return lambda dags, edge_active: round_scan(
                dags, edge_active, None, None
            )

        def round_fn(dags, edge_active):
            m = np.asarray(edge_active, bool)
            nbr_idx, nbr_valid = _neighbor_table_cached(m.tobytes(), m.shape[0])
            return _round_jit(impl)(dags, edge_active, nbr_idx, nbr_valid)

        return round_fn

    def round_fn(dags, edge_active):
        m = np.asarray(edge_active, bool)
        mesh_lib.validate_replica_mesh(m.shape[0], mesh)
        nbr_idx, nbr_valid = _neighbor_table_cached(m.tobytes(), m.shape[0])
        dags = mesh_lib.shard_replicas(dags, mesh)
        return _shard_round_jit(impl, mesh)(
            dags, jnp.asarray(m), nbr_idx, nbr_valid
        )

    return round_fn


@functools.lru_cache(maxsize=None)
def _advance_jit(impl: str, mesh=None, obs=None, faults=None):
    """One jitted lax.scan running a whole advance window of sync ticks.

    The PRNG key is split inside the scan exactly like the sequential
    per-tick path did host-side, so a batched window is bitwise-identical to
    running its ticks one call at a time. Retraces once per distinct window
    length (a handful of lengths occur in practice) and once per mesh shape
    — under a mesh the scan body routes through the shard_map'd round
    (edge sampling stays a replicated global computation, so the sampled
    masks are bitwise the single-device ones).

    ``obs`` (an ``repro.obs.ObsConfig``) threads the telemetry collectors
    through the scan carry — a pure read sampled after each round, so the
    dags/key trajectory is bitwise the ``obs=None`` program, whose body
    below is literally the untouched code. ``faults`` (a
    ``repro.net.faults.FaultConfig``) swaps in the fault-injected body —
    ``faults=None`` keeps the untouched program below.
    """
    if faults is not None:
        from repro.net import faults as faults_lib
        return faults_lib._advance_faults_jit(impl, faults, obs)
    apply_round = _round_for(impl, mesh)

    if obs is None:
        def advance(dags, key, ticks, part_active, adj, drop, stride,
                    part_mask, nbr_idx, nbr_valid):
            def body(carry, xs):
                dags, key = carry
                tick, pact = xs
                key, sub = jax.random.split(key)
                pm = jnp.where(pact, part_mask, True)
                edges = _sample_edges(sub, tick, pm, adj, drop, stride)
                return (apply_round(dags, edges, nbr_idx, nbr_valid), key), None

            (dags, key), _ = jax.lax.scan(
                body, (dags, key), (ticks, part_active)
            )
            return dags, key

        return jax.jit(advance)

    from repro import obs as obs_lib   # deferred: repro.obs imports repro.net

    def advance(dags, key, ticks, part_active, adj, drop, stride, part_mask,
                nbr_idx, nbr_valid, metrics, ring, period):
        def body(carry, xs):
            dags, key, metrics, ring = carry
            tick, pact = xs
            key, sub = jax.random.split(key)
            pm = jnp.where(pact, part_mask, True)
            edges = _sample_edges(sub, tick, pm, adj, drop, stride)
            new = apply_round(dags, edges, nbr_idx, nbr_valid)
            t = (tick.astype(jnp.float32) + 1.0) * period
            metrics, ring = obs_lib.observe_round(
                obs, metrics, ring, t, dags, new, live_edges=edges
            )
            return (new, key, metrics, ring), None

        (dags, key, metrics, ring), _ = jax.lax.scan(
            body, (dags, key, metrics, ring), (ticks, part_active)
        )
        return dags, key, metrics, ring

    return jax.jit(advance)


@functools.lru_cache(maxsize=None)
def _converge_jit(impl: str, mesh=None, obs=None, faults=None):
    """Device-resident fixpoint flush: ONE jitted lax.while_loop.

    The predicate — not yet synced, tick budget left, progress not stalled
    for a full stride cycle — runs on device, replacing the host loop that
    dispatched a sync round, an equality check, and a synced check per tick.
    Under a mesh the loop body routes through the shard_map'd round; the
    predicate's reductions are global (GSPMD inserts the collectives).
    ``obs`` threads the telemetry carry exactly as in ``_advance_jit``
    (``obs=None`` keeps the untouched program; a flush has no timeline, so
    its samples sit at the tick arithmetic's ``(tick + 1) * period``).
    ``faults`` swaps in the fault-injected body (``faults=None`` keeps the
    untouched program below).
    """
    if faults is not None:
        from repro.net import faults as faults_lib
        return faults_lib._converge_faults_jit(impl, faults, obs)
    apply_round = _round_for(impl, mesh)

    if obs is None:
        def converge(dags, key, tick, part_mask, adj, drop, stride, limit,
                     stall_limit, nbr_idx, nbr_valid):
            def cond(carry):
                dags, _key, _tick, stalled, done = carry
                return (
                    ~replica_lib.replicas_synced(dags)
                    & (done < limit)
                    & (stalled < stall_limit)
                )

            def body(carry):
                dags, key, tick, stalled, done = carry
                key, sub = jax.random.split(key)
                edges = _sample_edges(sub, tick, part_mask, adj, drop, stride)
                new = apply_round(dags, edges, nbr_idx, nbr_valid)
                stalled = jnp.where(trees_equal(new, dags), stalled + 1, 0)
                return (new, key, tick + 1, stalled, done + 1)

            dags, key, tick, _, done = jax.lax.while_loop(
                cond, body,
                (dags, key, tick, jnp.int32(0), jnp.int32(0)),
            )
            return dags, key, tick, done, replica_lib.replicas_synced(dags)

        return jax.jit(converge)

    from repro import obs as obs_lib

    def converge(dags, key, tick, part_mask, adj, drop, stride, limit,
                 stall_limit, nbr_idx, nbr_valid, metrics, ring, period):
        def cond(carry):
            dags, _key, _tick, stalled, done = carry[:5]
            return (
                ~replica_lib.replicas_synced(dags)
                & (done < limit)
                & (stalled < stall_limit)
            )

        def body(carry):
            dags, key, tick, stalled, done, metrics, ring = carry
            key, sub = jax.random.split(key)
            edges = _sample_edges(sub, tick, part_mask, adj, drop, stride)
            new = apply_round(dags, edges, nbr_idx, nbr_valid)
            stalled = jnp.where(trees_equal(new, dags), stalled + 1, 0)
            t = (tick.astype(jnp.float32) + 1.0) * period
            metrics, ring = obs_lib.observe_round(
                obs, metrics, ring, t, dags, new, live_edges=edges
            )
            return (new, key, tick + 1, stalled, done + 1, metrics, ring)

        dags, key, tick, _, done, metrics, ring = jax.lax.while_loop(
            cond, body,
            (dags, key, tick, jnp.int32(0), jnp.int32(0), metrics, ring),
        )
        return (dags, key, tick, done, replica_lib.replicas_synced(dags),
                metrics, ring)

    return jax.jit(converge)


# commit accounting shares one trace across every network instance
_bank_commit_jit = jax.jit(bank_lib.commit_chunks)


@functools.lru_cache(maxsize=None)
def _trace_one_jit(n: int):
    """Jitted single-record append into the device trace ring.

    The record (t, kind, src, dst, arg) goes through the SAME
    ``TraceRing.append_edges`` prefix-sum path the in-loop collectors use
    — a one-hot (N, N) mask in the [receiver, sender] layout selects the
    slot — so host-initiated spans (PUBLISH/COMMIT under
    ``ObsConfig.device_spans``) share the ring's capacity/overflow
    discipline with the device-recorded kinds.
    """
    def append(ring, t, kind, src, dst, arg):
        from repro.obs import trace as obs_trace
        ids = jnp.arange(n, dtype=jnp.int32)
        mask = (ids[:, None] == dst) & (ids[None, :] == src)
        return obs_trace.append_edges(ring, t, kind, mask, arg)

    return jax.jit(append)


def stride_matrix(top: Topology, sync_period: float, use_strides: bool = True) -> np.ndarray:
    """(N, N) int32 tick stride per link: a link with latency ℓ fires every
    ``ceil(ℓ / sync_period)`` ticks. ``use_strides=False`` (the ideal wire,
    ``sync_period <= 0``) delivers on every tick regardless of latency.
    Clipped to 2**30 so pathological latency/period ratios stay int32-safe
    (such links effectively never fire instead of overflowing to garbage)."""
    n = top.num_nodes
    if not use_strides:
        return np.ones((n, n), np.int32)
    period = max(float(sync_period), 1e-9)
    finite_lat = np.where(np.isfinite(top.latency), top.latency, 0.0)
    stride = np.where(
        top.adjacency, np.maximum(1.0, np.ceil(finite_lat / period)), 1.0
    )
    return np.minimum(stride, 2.0 ** 30).astype(np.int32)


class GossipNetwork:
    """Host-side overlay driver: replicas + tick clock + schedule batching."""

    def __init__(
        self,
        dag: DagState,
        bank: Any,
        top: Topology,
        cfg: GossipConfig = GossipConfig(),
        partition: Optional[PartitionSchedule] = None,
        mesh=None,
        bank_cfg: Optional[BankGossipConfig] = None,
        obs_cfg=None,
        faults_cfg=None,
        serve_cfg=None,
    ):
        n = top.num_nodes
        self.topology = top
        self.cfg = cfg
        self.partition = partition
        self.mesh = mesh
        self.bank_cfg = bank_cfg
        self.obs_cfg = obs_cfg
        self.faults_cfg = faults_cfg
        self._fstate = None
        if faults_cfg is not None:
            from repro.net import faults as faults_lib
            if mesh is not None:
                raise NotImplementedError(
                    "fault injection is single-device for now — the role "
                    "masks and FaultState are not mesh-sharded (see ROADMAP "
                    "open items)"
                )
            faults_lib.validate_faults(faults_cfg, n, bank=bank_cfg is not None)
        # init_replicas validates the mesh and shards the receiver axis
        self.replicas = replica_lib.init_replicas(dag, bank, n, mesh=mesh)
        if bank_cfg is not None:
            c = bank_cfg.chunks_per_slot
            slots = jax.tree_util.tree_leaves(bank)[0].shape[0]
            slot_b = (bank_lib.slot_nbytes(bank) if bank_cfg.slot_bytes is None
                      else float(bank_cfg.slot_bytes))
            self._chunk_bytes = jnp.float32(max(slot_b / c, 1e-9))
            # the static codec key for the bank jit factories: None for
            # every codec that prices like raw bytes, so the identity
            # path keeps the literal uncompressed programs
            self._codec = codec_lib.codec_key(bank_cfg.codec)
            self._digest = jax.jit(
                bank_lib.bank_digests, static_argnames="chunks"
            )(bank, chunks=c)
            bstate = bank_lib.init_bank_state(n, slots, c)
            # per-tick, per-directed-link byte budget: Table-I bits/s over
            # one sync period. sync_period <= 0 is the ideal wire — payload
            # transport is as free as metadata there, whatever `bandwidth`
            # says (the PR-3 limit the equivalence tests pin).
            if cfg.sync_period > 0:
                cap = top.bandwidth / 8.0 * cfg.sync_period
            else:
                cap = np.where(top.adjacency, np.inf, 0.0)
            # converge()'s tick bound must also cover DRAINING payloads: a
            # full slot over the slowest finite link costs this many ticks
            # (0 when every link is ideal or dead — rows alone bound those)
            finite = cap[top.adjacency & np.isfinite(cap) & (cap > 0)]
            self._drain_ticks = (
                int(min(np.ceil(slot_b / float(finite.min())), 256))
                if finite.size else 0
            )
            self._cap_bytes = jnp.asarray(cap, jnp.float32)
            if mesh is not None:
                bstate = mesh_lib.shard_replicas(bstate, mesh)
                self._digest, self._cap_bytes = (
                    mesh_lib.replicate(x, mesh)
                    for x in (self._digest, self._cap_bytes)
                )
            self.replicas = self.replicas._replace(bank_state=bstate)
            if faults_cfg is not None:
                from repro.net import faults as faults_lib
                self._fstate = faults_lib.init_fault_state(n, slots, c)
        stride = stride_matrix(top, cfg.sync_period, use_strides=cfg.sync_period > 0)
        self._max_stride = (
            int(stride[top.adjacency].max()) if top.adjacency.any() else 1
        )
        self._adj = jnp.asarray(top.adjacency)
        self._drop = jnp.asarray(top.drop)
        self._stride = jnp.asarray(stride)
        nbr_idx, nbr_valid = neighbor_table(top.adjacency)
        self._nbr_idx = jnp.asarray(nbr_idx)
        self._nbr_valid = jnp.asarray(nbr_valid)
        self._key = jax.random.PRNGKey(cfg.seed)
        self._all_mask = jnp.ones((n, n), bool)
        self._part_mask = (
            jnp.asarray(partition_matrix(partition.assignment))
            if partition is not None else self._all_mask
        )
        if mesh is not None:
            # overlay-wide arrays replicated so the jitted loops see one
            # committed layout per mesh (the replicas are receiver-sharded
            # by init_replicas above)
            (self._adj, self._drop, self._stride, self._nbr_idx,
             self._nbr_valid, self._all_mask, self._part_mask) = (
                mesh_lib.replicate(x, mesh) for x in (
                    self._adj, self._drop, self._stride, self._nbr_idx,
                    self._nbr_valid, self._all_mask, self._part_mask,
                )
            )
        self.tick = 0                # global tick index (drives strides)
        self.rounds_run = 0          # ticks / event batches actually executed
        self.device_calls = 0        # jitted dispatches issued (_dispatch)
        self.dispatch_counts = {}    # per-entry-point dispatch breakdown
        self.events_processed = 0    # event batches fired (engine="events")
        if obs_cfg is not None:
            # telemetry carries (repro.obs): device-resident, threaded
            # through every jitted loop below as pure reads
            from repro import obs as obs_lib
            self._metrics = obs_lib.init_metrics(n, obs_cfg)
            self._ring = obs_lib.init_trace(obs_cfg.trace_capacity)
            self._obs_period = jnp.float32(max(cfg.sync_period, 0.0))
            self._host_events = []        # (t, kind, src, dst, arg) spans
            self._part_logged = [False, False]
            if mesh is not None:
                self._metrics = mesh_lib.replicate(self._metrics, mesh)
                self._ring = mesh_lib.replicate(self._ring, mesh)
        period = cfg.sync_period
        # wall-clock sample instant per tick — (tick + 1) * period, the
        # telemetry convention; the fault layer's crash windows use it too
        self._period = jnp.float32(max(period, 0.0))
        self._next_tick_t = period if period > 0 else 0.0
        if cfg.engine not in ("ticks", "events"):
            raise ValueError(f"unknown gossip engine: {cfg.engine!r}")
        if cfg.engine == "events":
            if mesh is not None:
                raise NotImplementedError(
                    "engine='events' is single-device for now — the event "
                    "queue is not mesh-sharded (see ROADMAP open items)"
                )
            from repro.net import events as events_lib
            self._equeue, self._eislot = events_lib.make_edge_queue(
                top, period if period > 0 else 1.0,
                drain_slots=bank_cfg is not None,
            )
            if partition is not None:
                self._part_t0 = jnp.float32(partition.t_start)
                self._part_t1 = jnp.float32(partition.t_end)
            else:
                self._part_t0 = jnp.float32(float("inf"))
                self._part_t1 = jnp.float32(float("-inf"))
            if bank_cfg is not None:
                self._last_srv = jnp.zeros((n, n), jnp.float32)
                self._bw_bytes = jnp.asarray(top.bandwidth / 8.0, jnp.float32)
        # inference-serving layer (repro.net.serve): the static key maps
        # None AND rate<=0 to None, under which nothing below runs and the
        # engines compile the literal serve-free programs (the degenerate
        # limit tests/test_serve.py pins bitwise)
        self.serve_cfg = serve_cfg
        self._serve = None
        if serve_cfg is not None:
            from repro.net import serve as serve_lib
            self._serve = serve_lib.serve_key(serve_cfg)
        if self._serve is not None:
            serve_lib.validate_serve(self._serve, cfg.engine, mesh)
            self._equeue, self._eislot, ib = serve_lib.extend_queue(
                self._equeue, self._eislot, n, self._serve, cfg.seed
            )
            self._infer_base = jnp.int32(ib)
            self._sstate = serve_lib.init_serve_state(n, self._serve)
            self._serve_base = serve_lib.serve_base_key(
                cfg.seed, self._serve
            )
        if obs_cfg is not None and obs_cfg.hist is not None:
            # streaming histograms ride inside MetricsState.hist; the
            # propagation latch starts from the ACTUAL initial state and
            # the arrival FIFO is sized by the serve queue (0 without it)
            from repro.obs import hist as hist_lib
            qcap = int(self._serve.queue_cap) if self._serve is not None else 0
            hstate = hist_lib.init_hist(
                obs_cfg.hist, self.replicas.dags, queue_cap=qcap
            )
            if mesh is not None:
                hstate = mesh_lib.replicate(hstate, mesh)
            self._metrics = self._metrics._replace(hist=hstate)

    # --- replica access ----------------------------------------------------

    @property
    def bank(self):
        return self.replicas.bank

    def read(self, i) -> DagState:
        return replica_lib.read_replica(self.replicas, i)

    def write(self, i, dag: DagState, bank=None) -> None:
        self.replicas = replica_lib.write_replica(self.replicas, i, dag)
        if bank is not None:
            self.replicas = self.replicas._replace(bank=bank)

    # --- bank transport (only when constructed with bank_cfg) ---------------

    @property
    def bank_state(self) -> Optional[BankState]:
        return self.replicas.bank_state

    def read_view(self, i) -> DagState:
        """Node i's USABLE view: with the bank gossiped, rows whose model
        chunks have not arrived are masked out (``bank.gate_view``) so
        Algorithm 2 cannot select or approve a payload-less transaction;
        without bank gossip this is exactly ``read`` (the PR-3 view)."""
        dag = replica_lib.read_replica(self.replicas, i)
        if self.bank_cfg is None:
            return dag
        return bank_lib.gate_view_jit(
            dag, self.replicas.bank_state.have[i], self._digest
        )

    def bank_commit(self, node_id, slot, params) -> None:
        """Account a stage-4 commit in the transport state: the committer
        holds the new chunks, every other node's presence bits for the
        (ring-reused) slot reset, and the digest row is re-derived."""
        if self.bank_cfg is None:
            return
        bstate = self.replicas.bank_state
        have, self._digest = self._dispatch(
            "bank_commit", _bank_commit_jit,
            bstate.have, self._digest, params,
            jnp.asarray(slot, jnp.int32), jnp.asarray(node_id, jnp.int32),
        )
        self.replicas = self.replicas._replace(
            bank_state=bstate._replace(have=have)
        )

    def missing_chunks(self) -> np.ndarray:
        """(N,) referenced-but-unavailable chunks per node — the payload lag
        behind row visibility (all zeros without bank gossip)."""
        if self.bank_cfg is None:
            return np.zeros(self.topology.num_nodes, np.int32)
        return np.asarray(bank_lib.missing_chunks_jit(
            self.replicas.dags, self.replicas.bank_state, self._digest,
            impl=self.bank_cfg.impl,
        ))

    def bytes_sent(self) -> float:
        """Total payload bytes delivered so far (the Table-I traffic bill)."""
        if self.bank_cfg is None:
            return 0.0
        return float(jnp.sum(self.replicas.bank_state.sent))

    def union(self) -> DagState:
        return replica_lib.merge_all_jit(self.replicas.dags)

    def synced(self) -> bool:
        """Fully converged: row-identical replicas AND — when the bank is
        gossiped — every referenced model payload delivered (the same
        predicate the bank-aware ``converge`` loop evaluates on device)."""
        rows = bool(replica_lib.replicas_synced_jit(self.replicas.dags))
        if self.bank_cfg is None:
            return rows
        return rows and int(self.missing_chunks().max()) == 0

    def missing_rows(self, union: Optional[DagState] = None) -> np.ndarray:
        """(N,) rows each replica lacks vs the union view (0 = converged).
        Pass a precomputed ``union()`` to avoid re-folding the replicas."""
        if union is None:
            union = self.union()
        return np.asarray(
            replica_lib.missing_vs_union_jit(self.replicas.dags, union)
        )

    # --- telemetry (only when constructed with obs_cfg) ---------------------

    def trace_host(self, t, kind, src, dst, arg=0.0) -> None:
        """Buffer a host-side trace span (PUBLISH/COMMIT/PARTITION — events
        the FL driver already knows host-side, so recording them costs zero
        device dispatches). Merged with the device ring at drain. No-op
        without telemetry."""
        if self.obs_cfg is not None and self.obs_cfg.trace:
            self._host_events.append(
                (float(t), int(kind), int(src), int(dst), float(arg))
            )

    def trace_device(self, t, kind, src, dst, arg=0.0) -> None:
        """Record a host-initiated span through the DEVICE trace ring —
        the ``ObsConfig.device_spans`` path: the same (t, kind, src, dst,
        arg) record ``trace_host`` buffers, appended via
        ``TraceRing.append_edges`` instead (one jitted dispatch; values
        quantize to the ring's f32 wire precision). Pinned against the
        host-recorded path in ``tests/test_hist.py``. No-op without
        telemetry/trace."""
        if self.obs_cfg is None or not self.obs_cfg.trace:
            return
        n = self.topology.num_nodes
        self._ring = self._dispatch(
            "trace_device", _trace_one_jit(n), self._ring,
            jnp.float32(t), jnp.int32(kind), jnp.int32(src),
            jnp.int32(dst), jnp.float32(arg),
        )

    def trace_span(self, t, kind, src, dst, arg=0.0) -> None:
        """PUBLISH/COMMIT entry point for the FL driver: routes to the
        device ring when ``ObsConfig.device_spans`` is set, to the host
        buffer otherwise (the default, free path)."""
        if self.obs_cfg is not None and self.obs_cfg.device_spans:
            self.trace_device(t, kind, src, dst, arg)
        else:
            self.trace_host(t, kind, src, dst, arg)

    def _note_partition(self, t: float) -> None:
        """Record the partition's begin/heal transitions once each, the
        first time the clock reaches them."""
        if self.obs_cfg is None or self.partition is None:
            return
        from repro.obs import trace as obs_trace
        p = self.partition
        if not self._part_logged[0] and t >= p.t_start:
            self._part_logged[0] = True
            self.trace_host(p.t_start, obs_trace.KIND_PARTITION, -1, -1, 1.0)
        if not self._part_logged[1] and t >= p.t_end:
            self._part_logged[1] = True
            self.trace_host(p.t_end, obs_trace.KIND_PARTITION, -1, -1, 0.0)

    def obs_report(self):
        """Drain the in-loop collectors into a host-side ``ObsReport``
        (``repro.obs.export``) — metric series truncated to the samples
        taken, the trace ring merged with buffered host spans, dispatch
        counts, and final-state scalars. ``None`` without telemetry."""
        if self.obs_cfg is None:
            return None
        from repro import obs as obs_lib
        from repro.obs import trace as obs_trace
        m = self._metrics
        taken = int(min(int(m.cursor), m.t.shape[0]))
        series = {
            "t": np.asarray(m.t, np.float64)[:taken],
            "tips": np.asarray(m.tips, np.int64)[:taken],
            "staleness": np.asarray(m.staleness, np.int64)[:taken],
            "rows_delta": np.asarray(m.rows_delta, np.int64)[:taken],
            "chunk_lag": np.asarray(m.chunk_lag, np.int64)[:taken],
            "bytes_total": np.asarray(m.bytes_total, np.float64)[:taken],
            "staleness_node": np.asarray(m.staleness_node, np.int64)[:taken],
            "staleness_link": np.asarray(m.staleness_link, np.int64)[:taken],
            "rejected": np.asarray(m.rejected, np.int64)[:taken],
            "quarantined": np.asarray(m.quarantined, np.int64)[:taken],
            "requests_served": np.asarray(
                m.requests_served, np.int64)[:taken],
            "serve_staleness": np.asarray(
                m.serve_staleness, np.int64)[:taken],
        }
        final = {
            "bytes_sent": self.bytes_sent(),
            "chunk_lag": float(self.missing_chunks().max()),
            "staleness": float(self.missing_rows().max()),
        }
        if self.faults_cfg is not None and self._fstate is not None:
            final["rejected"] = float(np.asarray(self._fstate.rejects).sum())
            final["quarantined"] = float(self.quarantined_links().sum())
        hist = None
        if self.obs_cfg.hist is not None:
            from repro.obs import hist as hist_lib
            hist = hist_lib.report_dict(m.hist, self.obs_cfg.hist)
        return obs_lib.ObsReport(
            num_nodes=self.topology.num_nodes,
            engine=self.cfg.engine,
            rounds=int(m.rounds),
            series=series,
            rows_merged=np.asarray(m.rows_merged, np.int64),
            link_bytes=np.asarray(m.link_bytes, np.float64),
            samples_dropped=int(m.dropped),
            trace=obs_trace.drain(self._ring, self._host_events),
            trace_dropped=int(self._ring.dropped),
            dispatch_counts=dict(self.dispatch_counts),
            final=final,
            hist=hist,
        )

    # --- fault injection (only when constructed with faults_cfg) ------------

    def quarantined_links(self) -> np.ndarray:
        """(N, N) bool — links the digest-verification defense has cut
        (``rejects >= quarantine_after``). All-False without faults or
        without bank gossip (bankless faults carry no rejection state)."""
        n = self.topology.num_nodes
        if self.faults_cfg is None or self._fstate is None:
            return np.zeros((n, n), bool)
        return np.asarray(
            self._fstate.rejects >= self.faults_cfg.quarantine_after
        )

    def rejection_credit(self) -> Optional[np.ndarray]:
        """(N,) per-sender trust from cumulative digest rejections
        (``repro.core.anomaly.rejection_credit``) — 1.0 for clean senders,
        floored near 0 for quarantined spoofers. ``None`` without a
        fault-state carry."""
        if self.faults_cfg is None or self._fstate is None:
            return None
        from repro.core import anomaly
        return np.asarray(anomaly.rejection_credit(self._fstate.rejects))

    def tainted_in_views(self) -> np.ndarray:
        """(N,) corrupted chunks REFERENCED by rows visible in each node's
        gated view — the attack-success numerator: with digest
        verification on this must be identically zero (corrupted payloads
        are rejected before they can set presence bits, so ``gate_view``
        never exposes a row backed by them)."""
        n = self.topology.num_nodes
        out = np.zeros(n, np.int64)
        if (self.faults_cfg is None or self._fstate is None
                or self.bank_cfg is None):
            return out
        tainted = np.asarray(self._fstate.tainted)
        for i in range(n):
            view = self.read_view(i)
            slots = np.asarray(view.model_slot)[np.asarray(view.publisher) >= 0]
            slots = np.unique(slots[slots >= 0])
            out[i] = int(tainted[i, slots, :].sum())
        return out

    def fault_report(self) -> Optional[dict]:
        """Host-side summary of the adversary/defense state: roles, the
        per-link rejection matrix, quarantined-link count, per-node
        tainted-chunk counts, and the attack-success numerator
        (``tainted_in_views``). ``None`` without fault injection."""
        if self.faults_cfg is None:
            return None
        report = {
            "roles": np.asarray(self.faults_cfg.roles, np.int32),
            "verify_digests": self.faults_cfg.verify_digests,
        }
        if self._fstate is not None:
            rejects = np.asarray(self._fstate.rejects)
            report.update(
                rejects=rejects,
                rejected_total=int(rejects.sum()),
                quarantined_links=int(self.quarantined_links().sum()),
                tainted_chunks=np.asarray(
                    self._fstate.tainted.sum(axis=(1, 2))
                ),
                tainted_in_views=self.tainted_in_views(),
                rejection_credit=self.rejection_credit(),
            )
        return report

    # --- the clock ---------------------------------------------------------

    def _mask_at(self, t: float):
        if self.partition is not None and self.partition.active(t):
            return self._part_mask
        return self._all_mask

    def _dispatch(self, label: str, fn, *args):
        """Issue ONE jitted device call through the counting funnel.

        EVERY state-advancing dispatch (tick advance, event advance, bank
        variants, converge, commit accounting) routes through here, so
        ``device_calls`` — what the ``dispatch_batching`` bench reports —
        counts them all instead of the hand-instrumented subset it used to
        see; ``dispatch_counts`` keeps the per-entry-point breakdown. With
        telemetry on, the call is wrapped in a
        ``jax.profiler.TraceAnnotation`` so device profiles name the
        overlay's phases.
        """
        self.device_calls += 1
        self.dispatch_counts[label] = self.dispatch_counts.get(label, 0) + 1
        if self.obs_cfg is not None and self.obs_cfg.annotate:
            with jax.profiler.TraceAnnotation(f"repro.net.{label}"):
                return fn(*args)
        return fn(*args)

    def _run_ticks(self, ticks, part_active) -> None:
        """Execute a batch of sync ticks as ONE jitted device call."""
        fl = self.faults_cfg
        if self.bank_cfg is not None:
            fn = _advance_bank_jit(
                self.cfg.impl, self.bank_cfg.impl, self.mesh, self.obs_cfg,
                fl, self._codec,
            )
            args = (
                self.replicas.dags, self.replicas.bank_state, self._digest,
                self._key,
                jnp.asarray(ticks, jnp.int32), jnp.asarray(part_active, bool),
                self._adj, self._drop, self._stride, self._part_mask,
                self._nbr_idx, self._nbr_valid,
                self._cap_bytes, self._chunk_bytes,
            )
            if fl is not None:
                # the faulted body takes (dags, bstate, FSTATE, digest, ...,
                # period) and returns the FaultState too
                args = (args[:2] + (self._fstate,) + args[2:]
                        + (self._period,))
                if self.obs_cfg is None:
                    dags, bstate, self._fstate, self._key = self._dispatch(
                        "advance_bank", fn, *args
                    )
                else:
                    (dags, bstate, self._fstate, self._key, self._metrics,
                     self._ring) = self._dispatch(
                        "advance_bank", fn, *args, self._metrics, self._ring,
                    )
            elif self.obs_cfg is None:
                dags, bstate, self._key = self._dispatch(
                    "advance_bank", fn, *args
                )
            else:
                dags, bstate, self._key, self._metrics, self._ring = (
                    self._dispatch(
                        "advance_bank", fn, *args,
                        self._metrics, self._ring, self._obs_period,
                    )
                )
            self.replicas = self.replicas._replace(dags=dags, bank_state=bstate)
        else:
            fn = _advance_jit(self.cfg.impl, self.mesh, self.obs_cfg, fl)
            args = (
                self.replicas.dags, self._key,
                jnp.asarray(ticks, jnp.int32), jnp.asarray(part_active, bool),
                self._adj, self._drop, self._stride, self._part_mask,
                self._nbr_idx, self._nbr_valid,
            )
            if fl is not None:
                args = args + (self._period,)
                if self.obs_cfg is None:
                    dags, self._key = self._dispatch("advance", fn, *args)
                else:
                    dags, self._key, self._metrics, self._ring = (
                        self._dispatch(
                            "advance", fn, *args, self._metrics, self._ring,
                        )
                    )
            elif self.obs_cfg is None:
                dags, self._key = self._dispatch("advance", fn, *args)
            else:
                dags, self._key, self._metrics, self._ring = self._dispatch(
                    "advance", fn, *args,
                    self._metrics, self._ring, self._obs_period,
                )
            self.replicas = self.replicas._replace(dags=dags)
        self.tick += len(ticks)
        self.rounds_run += len(ticks)

    def _tick_once(self, t: float) -> None:
        """One sync tick at simulation time ``t`` (a batch of one — the
        reference granularity the batched ``advance`` is tested against)."""
        pact = self.partition is not None and self.partition.active(t)
        self._run_ticks([self.tick], [pact])

    def _advance_events(self, t: float) -> None:
        """Run every continuous-time event at or before ``t`` as ONE jitted
        while-loop dispatch (``repro.net.events``). Delivery slots recycle
        in place, so the queue state simply persists across calls."""
        if self._serve is not None:
            self._advance_events_serve(t)
            return
        from repro.net import events as events_lib

        limit = jnp.int32(self.cfg.max_events_per_advance)
        fire_cap = jnp.int32(self.cfg.max_ticks_per_advance)
        fl = self.faults_cfg
        if self.bank_cfg is not None:
            fn = events_lib._advance_events_bank_jit(
                self.cfg.impl, self.bank_cfg.impl, self.obs_cfg, fl,
                self._codec,
            )
            args = (
                self.replicas.dags, self.replicas.bank_state.have,
                self.replicas.bank_state.credit,
                self.replicas.bank_state.sent, self._last_srv,
                self._digest, self._equeue.time, self._equeue.valid,
                self._equeue.kind, self._equeue.src, self._equeue.dst,
                self._equeue.seq, self._eislot, self._key,
                jnp.float32(t), limit, fire_cap, self._part_mask,
                self._part_t0, self._part_t1, self._drop, self._nbr_idx,
                self._nbr_valid, self._bw_bytes, self._chunk_bytes,
            )
            if fl is not None:
                # the faulted body takes the FaultState after sent and
                # returns it too
                args = args[:4] + (self._fstate,) + args[4:]
                if self.obs_cfg is None:
                    (dags, bstate, self._fstate, self._last_srv, self._key,
                     qt, qv, done) = self._dispatch(
                        "advance_events_bank", fn, *args
                    )
                else:
                    (dags, bstate, self._fstate, self._last_srv, self._key,
                     qt, qv, done, self._metrics, self._ring) = (
                        self._dispatch(
                            "advance_events_bank", fn, *args,
                            self._metrics, self._ring,
                        )
                    )
            elif self.obs_cfg is None:
                dags, bstate, self._last_srv, self._key, qt, qv, done = (
                    self._dispatch("advance_events_bank", fn, *args)
                )
            else:
                (dags, bstate, self._last_srv, self._key, qt, qv, done,
                 self._metrics, self._ring) = self._dispatch(
                    "advance_events_bank", fn, *args,
                    self._metrics, self._ring,
                )
            self.replicas = self.replicas._replace(dags=dags, bank_state=bstate)
        else:
            fn = events_lib._advance_events_jit(self.cfg.impl, self.obs_cfg,
                                                fl)
            args = (
                self.replicas.dags, self._equeue.time, self._equeue.valid,
                self._equeue.kind, self._equeue.src, self._equeue.dst,
                self._equeue.seq, self._eislot, self._key, jnp.float32(t),
                limit, fire_cap, self._part_mask, self._part_t0,
                self._part_t1, self._drop, self._nbr_idx, self._nbr_valid,
            )
            if self.obs_cfg is None:
                dags, qt, qv, self._key, done = self._dispatch(
                    "advance_events", fn, *args
                )
            else:
                dags, qt, qv, self._key, done, self._metrics, self._ring = (
                    self._dispatch(
                        "advance_events", fn, *args,
                        self._metrics, self._ring,
                    )
                )
            self.replicas = self.replicas._replace(dags=dags)
        self._equeue = self._equeue._replace(time=qt, valid=qv)
        self.tick += int(done)
        self.rounds_run += int(done)
        self.events_processed += int(done)

    def _advance_events_serve(self, t: float) -> None:
        """The event advance with the inference-serving slots live
        (``repro.net.serve``): same loop, same transport program, plus
        KIND_INFER batches that never split the main key. The dict result
        avoids a combinatorial tuple-unpack over bank x faults x obs."""
        from repro.net import events as events_lib

        limit = jnp.int32(self.cfg.max_events_per_advance)
        fire_cap = jnp.int32(self.cfg.max_ticks_per_advance)
        fl = self.faults_cfg
        obs_carry = (
            (self._metrics, self._ring) if self.obs_cfg is not None else ()
        )
        if self.bank_cfg is not None:
            fn = events_lib._advance_events_bank_jit(
                self.cfg.impl, self.bank_cfg.impl, self.obs_cfg, fl,
                self._codec, self._serve,
            )
            args = (
                self.replicas.dags, self.replicas.bank_state.have,
                self.replicas.bank_state.credit,
                self.replicas.bank_state.sent, self._last_srv,
                self._digest, self._equeue.time, self._equeue.valid,
                self._equeue.kind, self._equeue.src, self._equeue.dst,
                self._equeue.seq, self._eislot, self._key,
                jnp.float32(t), limit, fire_cap, self._part_mask,
                self._part_t0, self._part_t1, self._drop, self._nbr_idx,
                self._nbr_valid, self._bw_bytes, self._chunk_bytes,
                self._sstate, self._serve_base, self._infer_base,
            )
            if fl is not None:
                args = args[:4] + (self._fstate,) + args[4:]
            out = self._dispatch(
                "advance_events_bank_serve", fn, *args, *obs_carry
            )
            self.replicas = self.replicas._replace(
                dags=out["dags"], bank_state=out["bstate"]
            )
            if fl is not None:
                self._fstate = out["fstate"]
            self._last_srv = out["last_srv"]
        else:
            fn = events_lib._advance_events_jit(
                self.cfg.impl, self.obs_cfg, fl, self._serve
            )
            args = (
                self.replicas.dags, self._equeue.time, self._equeue.valid,
                self._equeue.kind, self._equeue.src, self._equeue.dst,
                self._equeue.seq, self._eislot, self._key, jnp.float32(t),
                limit, fire_cap, self._part_mask, self._part_t0,
                self._part_t1, self._drop, self._nbr_idx, self._nbr_valid,
                self._sstate, self._serve_base, self._infer_base,
            )
            out = self._dispatch(
                "advance_events_serve", fn, *args, *obs_carry
            )
            self.replicas = self.replicas._replace(dags=out["dags"])
        self._key = out["key"]
        self._sstate = out["sstate"]
        if self.obs_cfg is not None:
            self._metrics, self._ring = out["metrics"], out["ring"]
        self._equeue = self._equeue._replace(time=out["qt"], valid=out["qv"])
        done = int(out["done"])
        self.tick += done
        self.rounds_run += done
        self.events_processed += done

    def serve_report(self):
        """Host-side serving summary (``repro.net.serve.report``):
        per-node served/arrivals/dropped counters, throughput inputs, and
        staleness-at-admit percentiles. None when serving is off."""
        if self._serve is None:
            return None
        from repro.net import serve as serve_lib
        return serve_lib.report(self._sstate, self._serve)

    def advance(self, t: float) -> None:
        """Run every sync tick scheduled at or before simulation time ``t``
        as one batched dispatch."""
        self._note_partition(t)
        if self.cfg.sync_period <= 0:
            self.converge(at_time=t)
            return
        if self.cfg.engine == "events":
            self._advance_events(t)
            return
        ticks, pacts = [], []
        nt = self._next_tick_t
        while nt <= t and len(ticks) < self.cfg.max_ticks_per_advance:
            ticks.append(self.tick + len(ticks))
            pacts.append(self.partition is not None and self.partition.active(nt))
            nt += self.cfg.sync_period
        if ticks:
            self._run_ticks(ticks, pacts)
        self._next_tick_t = nt
        if self._next_tick_t <= t:     # window overflowed the cap: fast-forward
            periods_behind = int((t - self._next_tick_t) // self.cfg.sync_period) + 1
            self.tick += periods_behind
            self._next_tick_t += periods_behind * self.cfg.sync_period

    def converge(self, at_time: float = float("inf")) -> bool:
        """Tick until the replicas reach fixpoint (ideal-wire flush / heal).

        ONE jitted ``lax.while_loop`` with an on-device predicate, bounded
        by ``num_nodes * max_stride`` ticks: the hop diameter is at most
        num_nodes - 1, and a stride-s link needs up to s ticks before it
        fires (stride capped at 64 here so pathological latency ratios
        cannot make the flush unbounded). A full stride cycle of unchanged
        state is a fixpoint (partition active or overlay disconnected — no
        further tick can make progress). Returns whether full sync was
        reached — it cannot be while a partition is active or the overlay
        is disconnected.
        """
        self._note_partition(at_time)
        limit = self.topology.num_nodes * min(self._max_stride, 64)
        stall_limit = min(self._max_stride, 64)
        fl = self.faults_cfg
        if self.bank_cfg is not None:
            # rows cross in <= num_nodes strided hops; chunks then drain at
            # the per-link budget — extend the bound, keep the stall check
            limit = (self.topology.num_nodes + self._drain_ticks) * min(
                self._max_stride, 64
            )
            fn = _converge_bank_jit(
                self.cfg.impl, self.bank_cfg.impl, self.mesh, self.obs_cfg,
                fl, self._codec,
            )
            args = (
                self.replicas.dags, self.replicas.bank_state, self._digest,
                self._key, jnp.asarray(self.tick, jnp.int32),
                self._mask_at(at_time), self._adj, self._drop, self._stride,
                limit, stall_limit, self._nbr_idx, self._nbr_valid,
                self._cap_bytes, self._chunk_bytes,
            )
            if fl is not None:
                args = (args[:2] + (self._fstate,) + args[2:]
                        + (self._period,))
                if self.obs_cfg is None:
                    (dags, bstate, self._fstate, self._key, tick, done,
                     synced) = self._dispatch("converge_bank", fn, *args)
                else:
                    (dags, bstate, self._fstate, self._key, tick, done,
                     synced, self._metrics, self._ring) = self._dispatch(
                        "converge_bank", fn, *args,
                        self._metrics, self._ring,
                    )
            elif self.obs_cfg is None:
                dags, bstate, self._key, tick, done, synced = self._dispatch(
                    "converge_bank", fn, *args
                )
            else:
                (dags, bstate, self._key, tick, done, synced,
                 self._metrics, self._ring) = self._dispatch(
                    "converge_bank", fn, *args,
                    self._metrics, self._ring, self._obs_period,
                )
            self.replicas = self.replicas._replace(dags=dags, bank_state=bstate)
        else:
            fn = _converge_jit(self.cfg.impl, self.mesh, self.obs_cfg, fl)
            args = (
                self.replicas.dags, self._key,
                jnp.asarray(self.tick, jnp.int32),
                self._mask_at(at_time), self._adj, self._drop, self._stride,
                limit, stall_limit, self._nbr_idx, self._nbr_valid,
            )
            if fl is not None:
                args = args + (self._period,)
                if self.obs_cfg is None:
                    dags, self._key, tick, done, synced = self._dispatch(
                        "converge", fn, *args
                    )
                else:
                    (dags, self._key, tick, done, synced,
                     self._metrics, self._ring) = self._dispatch(
                        "converge", fn, *args, self._metrics, self._ring,
                    )
            elif self.obs_cfg is None:
                dags, self._key, tick, done, synced = self._dispatch(
                    "converge", fn, *args
                )
            else:
                (dags, self._key, tick, done, synced,
                 self._metrics, self._ring) = self._dispatch(
                    "converge", fn, *args,
                    self._metrics, self._ring, self._obs_period,
                )
            self.replicas = self.replicas._replace(dags=dags)
        self.tick = int(tick)
        self.rounds_run += int(done)
        return bool(synced)
