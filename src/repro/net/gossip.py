"""Anti-entropy gossip over the overlay: one jitted device call per tick.

A sync tick folds every node's active neighbors into its local replica with
``dag.merge`` — vectorized as ``vmap`` over receivers of a ``scan`` over
senders, so the whole round is a single jitted call on the stacked
``ReplicaSet`` (no per-node Python loop over merges). Per-edge behavior:

  message loss   each directed message is dropped i.i.d. with the link's
                 drop probability (``Topology.drop``);
  link latency   a link with latency ℓ fires only every
                 ``ceil(ℓ / sync_period)`` ticks — slow links sync less
                 often (transfer time quantized to the tick grid);
  partitions     a ``PartitionSchedule`` suppresses cross-component edges
                 for t ∈ [t_start, t_end), then heals.

``GossipNetwork`` is the host-side driver the simulator talks to: it owns
the replica set, the tick clock, and the jitted kernels, and interleaves
``advance(t)`` calls with Algorithm-2 prepare/commit events.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dag as dag_lib
from repro.core.dag import DagState
from repro.net import replica as replica_lib
from repro.net.topology import Topology, partition_matrix


@dataclass(frozen=True)
class PartitionSchedule:
    """Split the overlay into components for [t_start, t_end), then heal.

    ``assignment`` is an (N,) array of component labels; while active, only
    edges within a component deliver (§III.A under imperfect networks — the
    measurable question is how fast replicas reconverge after healing).
    """

    assignment: np.ndarray
    t_start: float
    t_end: float

    def active(self, t: float) -> bool:
        return self.t_start <= t < self.t_end


@dataclass(frozen=True)
class GossipConfig:
    """Anti-entropy knobs.

    ``sync_period <= 0`` means an ideal wire: every ``advance`` runs ticks
    until the replicas reach fixpoint — the shared-ledger limit used as the
    baseline (and by the acceptance test against ``run_dagfl``).
    ``max_ticks_per_advance`` bounds work when one advance window spans many
    periods; elided ticks are no-ops once the state has reached fixpoint
    (loss-free links), and with loss they only truncate redundant retries.
    """

    sync_period: float = 1.0
    seed: int = 0
    max_ticks_per_advance: int = 64


def make_gossip_round():
    """Jitted (dags, edge_active) -> dags anti-entropy round.

    ``edge_active[i, j]`` = receiver i hears sender j this tick. Merge is
    commutative/associative, so folding senders in index order is as good as
    any delivery order.
    """

    def gossip_round(dags: DagState, edge_active: jnp.ndarray) -> DagState:
        def receive(dag_i, active_row):
            def body(carry, xs):
                dag_j, act = xs
                merged = dag_lib.merge(carry, dag_j)
                kept = jax.tree_util.tree_map(
                    lambda m, c: jnp.where(act, m, c), merged, carry
                )
                return kept, None

            out, _ = jax.lax.scan(body, dag_i, (dags, active_row))
            return out

        return jax.vmap(receive)(dags, edge_active)

    return jax.jit(gossip_round)


def stride_matrix(top: Topology, sync_period: float, use_strides: bool = True) -> np.ndarray:
    """(N, N) int32 tick stride per link: a link with latency ℓ fires every
    ``ceil(ℓ / sync_period)`` ticks. ``use_strides=False`` (the ideal wire,
    ``sync_period <= 0``) delivers on every tick regardless of latency.
    Clipped to 2**30 so pathological latency/period ratios stay int32-safe
    (such links effectively never fire instead of overflowing to garbage)."""
    n = top.num_nodes
    if not use_strides:
        return np.ones((n, n), np.int32)
    period = max(float(sync_period), 1e-9)
    finite_lat = np.where(np.isfinite(top.latency), top.latency, 0.0)
    stride = np.where(
        top.adjacency, np.maximum(1.0, np.ceil(finite_lat / period)), 1.0
    )
    return np.minimum(stride, 2.0 ** 30).astype(np.int32)


def make_edge_sampler(top: Topology, stride: np.ndarray):
    """Jitted (key, tick, part_mask) -> (N, N) bool active-edge mask."""
    adj = jnp.asarray(top.adjacency)
    drop = jnp.asarray(top.drop)
    stride = jnp.asarray(stride)

    def sample(key, tick, part_mask):
        live = adj & (jnp.mod(tick, stride) == 0) & part_mask
        u = jax.random.uniform(key, adj.shape)
        return live & (u >= drop)

    return jax.jit(sample)


class GossipNetwork:
    """Host-side overlay driver: replicas + tick clock + jitted kernels."""

    def __init__(
        self,
        dag: DagState,
        bank: Any,
        top: Topology,
        cfg: GossipConfig = GossipConfig(),
        partition: Optional[PartitionSchedule] = None,
    ):
        n = top.num_nodes
        self.topology = top
        self.cfg = cfg
        self.partition = partition
        self.replicas = replica_lib.init_replicas(dag, bank, n)
        self._round = make_gossip_round()
        self._stride = stride_matrix(top, cfg.sync_period, use_strides=cfg.sync_period > 0)
        self._max_stride = (
            int(self._stride[top.adjacency].max()) if top.adjacency.any() else 1
        )
        self._sampler = make_edge_sampler(top, self._stride)
        self._synced = jax.jit(replica_lib.replicas_synced)
        self._union = jax.jit(replica_lib.merge_all)
        self._missing = jax.jit(replica_lib.missing_vs_union)
        self._unchanged = jax.jit(
            lambda a, b: jnp.all(jnp.stack([
                jnp.all(x == y)
                for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
            ]))
        )
        self._key = jax.random.PRNGKey(cfg.seed)
        self._all_mask = jnp.ones((n, n), bool)
        self._part_mask = (
            jnp.asarray(partition_matrix(partition.assignment))
            if partition is not None else None
        )
        self.tick = 0                # global tick index (drives strides)
        self.rounds_run = 0          # ticks actually executed
        period = cfg.sync_period
        self._next_tick_t = period if period > 0 else 0.0

    # --- replica access ----------------------------------------------------

    @property
    def bank(self):
        return self.replicas.bank

    def read(self, i) -> DagState:
        return replica_lib.read_replica(self.replicas, i)

    def write(self, i, dag: DagState, bank=None) -> None:
        self.replicas = replica_lib.write_replica(self.replicas, i, dag)
        if bank is not None:
            self.replicas = self.replicas._replace(bank=bank)

    def union(self) -> DagState:
        return self._union(self.replicas.dags)

    def synced(self) -> bool:
        return bool(self._synced(self.replicas.dags))

    def missing_rows(self, union: Optional[DagState] = None) -> np.ndarray:
        """(N,) rows each replica lacks vs the union view (0 = converged).
        Pass a precomputed ``union()`` to avoid re-folding the replicas."""
        if union is None:
            union = self.union()
        return np.asarray(self._missing(self.replicas.dags, union))

    # --- the clock ---------------------------------------------------------

    def _mask_at(self, t: float):
        if self.partition is not None and self.partition.active(t):
            return self._part_mask
        return self._all_mask

    def _tick_once(self, t: float) -> None:
        self._key, sub = jax.random.split(self._key)
        edges = self._sampler(sub, jnp.asarray(self.tick, jnp.int32), self._mask_at(t))
        self.replicas = self.replicas._replace(
            dags=self._round(self.replicas.dags, edges)
        )
        self.tick += 1
        self.rounds_run += 1

    def advance(self, t: float) -> None:
        """Run every sync tick scheduled at or before simulation time ``t``."""
        if self.cfg.sync_period <= 0:
            self.converge(at_time=t)
            return
        ran = 0
        while self._next_tick_t <= t and ran < self.cfg.max_ticks_per_advance:
            self._tick_once(self._next_tick_t)
            self._next_tick_t += self.cfg.sync_period
            ran += 1
        if self._next_tick_t <= t:     # window overflowed the cap: fast-forward
            periods_behind = int((t - self._next_tick_t) // self.cfg.sync_period) + 1
            self.tick += periods_behind
            self._next_tick_t += periods_behind * self.cfg.sync_period

    def converge(self, at_time: float = float("inf")) -> bool:
        """Tick until the replicas reach fixpoint (ideal-wire flush / heal).

        Bounded by ``num_nodes * max_stride`` ticks: the hop diameter is at
        most num_nodes - 1, and a stride-s link needs up to s ticks before
        it fires (stride capped at 64 here so pathological latency ratios
        cannot make the flush unbounded). Returns whether full sync was
        reached — it cannot be while a partition is active or the overlay
        is disconnected.
        """
        limit = self.topology.num_nodes * min(self._max_stride, 64)
        # a full stride cycle of unchanged state is a fixpoint: partition
        # active or overlay disconnected — no further tick can make progress
        stall_limit = min(self._max_stride, 64)
        stalled = 0
        for _ in range(limit):
            if self.synced():
                return True
            before = self.replicas.dags
            self._tick_once(at_time)
            stalled = stalled + 1 if bool(self._unchanged(before, self.replicas.dags)) else 0
            if stalled >= stall_limit:
                break
        return self.synced()
