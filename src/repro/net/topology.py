"""Overlay topologies: neighbor masks plus per-link latency / drop / bandwidth.

Every builder returns a ``Topology`` of dense host-side numpy arrays (the
jitted gossip kernels lift them to device once):

  adjacency  (N, N) bool   symmetric, zero diagonal
  latency    (N, N) f32    seconds per link; +inf off-link
  drop       (N, N) f32    per-message loss probability; 0 off-link
  bandwidth  (N, N) f32    bits/s per link (Table-I B); +inf = ideal wire,
                           0 off-link

Latency, drop, and bandwidth are drawn per *link* (symmetric), so a slow or
lossy edge is slow in both directions — message loss itself is still
sampled per directed message (see ``gossip._sample_edges``), and each
direction of a link spends its own byte budget when the model bank is
gossiped (``repro.net.bank``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

# Table-I prices one model transfer at phi / B with B = 100 Mbit/s; the
# sweep classes below bracket that wireless budget downward (the paper's
# motivating "wireless and resource-limited" devices). Values are bits/s,
# keyed the way benchmarks/examples report them.
TABLE1_LINK_CLASSES = {
    "ideal": float("inf"),          # the PR-3 limit: payloads travel free
    "table1_100mbps": 100e6,        # Table I's B — campus WiFi / wired edge
    "lte_10mbps": 10e6,             # one order down — loaded LTE uplink
    "constrained_1mbps": 1e6,       # IoT-class uplink
}


class Topology(NamedTuple):
    adjacency: np.ndarray       # (N, N) bool
    latency: np.ndarray         # (N, N) f32, +inf where no link
    drop: np.ndarray            # (N, N) f32, 0 where no link
    bandwidth: np.ndarray       # (N, N) f32 bits/s, +inf = ideal, 0 off-link

    @property
    def num_nodes(self) -> int:
        return self.adjacency.shape[0]

    def degree(self) -> np.ndarray:
        return self.adjacency.sum(axis=1)


def _finalize(
    adj: np.ndarray,
    link_latency: float,
    latency_jitter: float,
    drop: float,
    seed: int,
    bandwidth: float = float("inf"),
) -> Topology:
    n = adj.shape[0]
    adj = np.asarray(adj, bool).copy()
    np.fill_diagonal(adj, False)
    adj |= adj.T                                    # undirected overlay
    rng = np.random.default_rng(seed)
    jitter = rng.uniform(0.0, latency_jitter, (n, n)) if latency_jitter else np.zeros((n, n))
    jitter = np.triu(jitter, 1)
    jitter = jitter + jitter.T                      # symmetric per-link draw
    latency = np.where(adj, link_latency + jitter, np.inf).astype(np.float32)
    drop_m = np.where(adj, float(drop), 0.0).astype(np.float32)
    bw = np.where(adj, float(bandwidth), 0.0).astype(np.float32)
    return Topology(adjacency=adj, latency=latency, drop=drop_m, bandwidth=bw)


def ring(n: int, link_latency: float = 0.0, latency_jitter: float = 0.0,
         drop: float = 0.0, seed: int = 0,
         bandwidth: float = float("inf")) -> Topology:
    """Cycle graph: node i ↔ i±1 (mod n). Diameter ⌊n/2⌋ — worst-case
    propagation, the stress topology for staleness experiments."""
    adj = np.zeros((n, n), bool)
    idx = np.arange(n)
    adj[idx, (idx + 1) % n] = True
    return _finalize(adj, link_latency, latency_jitter, drop, seed, bandwidth=bandwidth)


def k_regular(n: int, k: int, link_latency: float = 0.0,
              latency_jitter: float = 0.0, drop: float = 0.0,
              seed: int = 0, bandwidth: float = float("inf")) -> Topology:
    """Circulant k-regular graph: offsets ±1..±k//2, plus the antipode when
    k is odd (requires even n, the standard feasibility condition)."""
    if not 0 < k < n:
        raise ValueError(f"need 0 < k < n, got k={k}, n={n}")
    if (n * k) % 2 != 0:
        raise ValueError(f"no {k}-regular graph on {n} nodes (n*k must be even)")
    adj = np.zeros((n, n), bool)
    idx = np.arange(n)
    for off in range(1, k // 2 + 1):
        adj[idx, (idx + off) % n] = True
        adj[idx, (idx - off) % n] = True
    if k % 2 == 1:
        adj[idx, (idx + n // 2) % n] = True
    return _finalize(adj, link_latency, latency_jitter, drop, seed, bandwidth=bandwidth)


def erdos_renyi(n: int, p: float, link_latency: float = 0.0,
                latency_jitter: float = 0.0, drop: float = 0.0,
                seed: int = 0, bandwidth: float = float("inf")) -> Topology:
    """G(n, p) random overlay. May be disconnected — that is a feature
    (natural partitions); check with ``is_connected`` / ``components``."""
    rng = np.random.default_rng(seed)
    upper = np.triu(rng.uniform(size=(n, n)) < p, 1)
    return _finalize(upper, link_latency, latency_jitter, drop, seed + 1, bandwidth=bandwidth)


def star(n: int, hub: int = 0, link_latency: float = 0.0,
         latency_jitter: float = 0.0, drop: float = 0.0,
         seed: int = 0, bandwidth: float = float("inf")) -> Topology:
    """Hub-and-spoke: every node ↔ ``hub``. Diameter 2, but the hub is a
    single point of failure — partitioning it isolates every spoke."""
    adj = np.zeros((n, n), bool)
    adj[hub, :] = True
    return _finalize(adj, link_latency, latency_jitter, drop, seed, bandwidth=bandwidth)


def full(n: int, link_latency: float = 0.0, latency_jitter: float = 0.0,
         drop: float = 0.0, seed: int = 0,
         bandwidth: float = float("inf")) -> Topology:
    """Complete graph — the shared-ledger limit of the overlay."""
    return _finalize(np.ones((n, n), bool), link_latency, latency_jitter, drop, seed, bandwidth=bandwidth)


def neighbor_table(adjacency: np.ndarray):
    """Static per-receiver candidate lists from an overlay adjacency.

    Returns ``(nbr_idx (N, D) int32, nbr_valid (N, D) bool)`` where D is the
    max degree + 1: each row lists the receiver itself plus its neighbors,
    padded (``nbr_valid`` false). Every sampled per-tick edge mask is a
    subset of the adjacency, so the table is computed ONCE host-side and the
    per-tick winner reduction (``repro.kernels.gossip_merge``) runs over D
    candidates instead of all N senders — O(N * D * cap) work, the term that
    makes the fused round beat the sequential fold on sparse overlays. A
    mesh shard (``repro.net.mesh``) slices its receiver block's rows out of
    the same table.
    """
    adj = np.asarray(adjacency, bool)
    n = adj.shape[0]
    m = adj | np.eye(n, dtype=bool)
    deg = int(m.sum(axis=1).max())
    order = np.argsort(~m, axis=1, kind="stable")[:, :deg].astype(np.int32)
    valid = np.take_along_axis(m, order, axis=1)
    return order, valid


# ---------------------------------------------------------------------------
# Connectivity / partition helpers
# ---------------------------------------------------------------------------


def components(adjacency: np.ndarray) -> np.ndarray:
    """(N,) int component label per node (BFS over the boolean mask)."""
    n = adjacency.shape[0]
    labels = np.full(n, -1, np.int64)
    current = 0
    for start in range(n):
        if labels[start] >= 0:
            continue
        frontier = np.zeros(n, bool)
        frontier[start] = True
        member = frontier.copy()
        while frontier.any():
            frontier = (adjacency[frontier].any(axis=0)) & ~member
            member |= frontier
        labels[member] = current
        current += 1
    return labels


def is_connected(adjacency: np.ndarray) -> bool:
    return int(components(adjacency).max()) == 0


def partition_matrix(assignment: np.ndarray) -> np.ndarray:
    """(N, N) bool mask keeping only intra-component edges."""
    a = np.asarray(assignment)
    return a[:, None] == a[None, :]


def split_halves(n: int) -> np.ndarray:
    """Assignment splitting nodes [0, n//2) from [n//2, n) — the canonical
    two-component partition scenario."""
    return (np.arange(n) >= n // 2).astype(np.int64)


def split_random(n: int, num_parts: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, num_parts, n)


def path_latency_bound(top: Topology, sync_period: float) -> float:
    """Worst-case anti-entropy propagation time over the overlay.

    Each hop costs one sync tick, and a link with latency ℓ only fires every
    ``ceil(ℓ / sync_period)`` ticks (gossip's latency stride), so the
    effective per-edge delay is ``sync_period * max(1, ceil(ℓ / period))``.
    Floyd–Warshall over those weights; the max finite shortest path is the
    weighted diameter — an upper bound on how stale any replica can be in a
    healed, loss-free overlay.
    """
    period = max(sync_period, 1e-9)
    n = top.num_nodes
    w = np.where(
        top.adjacency,
        period * np.maximum(1.0, np.ceil(top.latency / period)),
        np.inf,
    ).astype(np.float64)
    np.fill_diagonal(w, 0.0)
    for k in range(n):
        w = np.minimum(w, w[:, k:k + 1] + w[k:k + 1, :])
    finite = w[np.isfinite(w)]
    return float(finite.max()) if finite.size else float("inf")
