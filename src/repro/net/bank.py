"""Gossiped model bank: content-addressed chunks over a bandwidth budget.

The paper's DAG layer exchanges *models*, not just transaction metadata
(§III.A: each node's local DAG is "updated by communicating with adjacent
nodes"; Table I prices exactly that traffic at phi / B per transfer). Up to
PR 3 the simulator shared one host-side model bank, so a transaction's
payload was usable the instant its DAG row arrived — sync cost was free
where the paper says it dominates. This module makes payload transport a
first-class, *priced* part of the anti-entropy round while keeping the
payload bytes stored once:

  store          the model bank stays ONE content-addressed store (slot i of
                 every leaf is transaction i's model, `repro.core.bank`);
                 replicating N physical banks would multiply memory by N for
                 no informational gain. What is replicated per node is the
                 *presence bitmap*: which chunks of the store this node has
                 actually received.

  chunking       each bank slot is split into ``chunks_per_slot`` equal
                 byte ranges, identified by a content digest
                 (``chunk_digests`` — the per-chunk analogue of
                 ``bank.auth_checksum``). Chunking is ALIGNED: dedup
                 compares chunks at the same offset across slots, so an
                 identical payload (a lazy node republishing the aggregate
                 verbatim) costs zero bytes the second time, while
                 offset-shifted collisions are not modeled.

  transfer       every sync tick, after the DAG merge, each node derives
                 the chunks it still needs (rows visible in its replica
                 whose slots its effective availability — the
                 ``repro.kernels.chunk_transfer`` dedup reduction — does not
                 cover) and pulls them from active neighbors, charged
                 against a per-directed-link byte budget
                 ``bandwidth / 8 * sync_period`` (``Topology.bandwidth``,
                 Table-I B). Whole chunks transfer in canonical order;
                 partial-chunk budget ROLLS OVER across ticks (paused, not
                 lost, while a link is strided out or partitioned away), and
                 idle bandwidth is never banked.

  gating         a transaction is *usable* at a node only once its model
                 chunks have arrived: ``run_dagfl_gossip`` masks unavailable
                 rows out of the node's view (``gate_view``), so Algorithm-2
                 tip selection — and hence approvals — waits for the payload
                 exactly as BlockFL/DAG-AFL style delay analyses assume.

Infinite-bandwidth limit: with ``bandwidth=inf`` every assigned chunk
transfers on the tick its row arrives, availability tracks row visibility
exactly (induction from the committer, which holds its own chunks), and the
whole system is BITWISE the PR-3 path for every round impl — the transfer
step is deterministic and never touches the PRNG stream. Property-tested in
``tests/test_net_bank.py``.

Slot-reuse caveat: the ledger ring reuses slots, and the store always holds
a slot's *latest* content. A commit overwriting slot s resets every other
node's presence bits for s (they held the old content) and re-digests it;
a node still referencing the evicted row will re-fetch — and is gated on —
the new content until merge overwrites the stale row.

Wire compression (``BankGossipConfig.codec``,
``repro.kernels.delta_codec``): with a codec configured the FL driver
encodes every commit before it reaches the store — the store slot holds
the DEQUANTIZED wire values (so quantization error flows into training
exactly once, at commit), ``commit_chunks`` digests the ENCODED pytree
(the spoof defense verifies the bytes that actually cross the link), and
the engines scale ``chunk_bytes`` by ``codec.wire_ratio()`` so pricing,
the ``sent`` meter, and the event engine's drain instants all charge
encoded bytes. ``codec=None`` and the explicit identity codec keep every
jitted program LITERALLY the uncompressed one (``delta_codec.codec_key``),
the same contract the obs/faults static keys honor; pinned bitwise in
``tests/test_delta_codec.py``, formats in ``docs/WIRE_FORMAT.md``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.dag import DagState
from repro.kernels import chunk_transfer as ck
from repro.kernels import delta_codec as codec_lib


@dataclass(frozen=True)
class BankGossipConfig:
    """Knobs for gossiping the model bank.

    ``chunks_per_slot`` — byte ranges per bank slot (the transfer granule).
    ``slot_bytes`` — payload size per slot for pricing; None measures the
    actual bank leaves, while Table-I realism passes ``7e6`` (phi = 7 MB)
    so a bench-scale CNN is charged like the paper's model.
    ``impl`` — dedup reduction backend ("pallas" / "lax"; None auto-picks
    like ``kernels.chunk_transfer.chunk_dedup``).
    ``codec`` — wire compression for commits
    (``repro.kernels.delta_codec.DeltaCodec``); None ships raw f32 chunks
    and keeps the engines' jitted programs literally unchanged.
    """

    chunks_per_slot: int = 4
    slot_bytes: Optional[float] = None
    impl: Optional[str] = None
    codec: Optional["codec_lib.DeltaCodec"] = None


class BankState(NamedTuple):
    """Per-node bank-transport state (leading axis = replica, like ``dags``).

    ``have``   (R, S, C) bool — physical chunk presence per node;
    ``credit`` (R, R) f32 — rolled-over partial-chunk budget per directed
               link (receiver i <- sender j), bytes;
    ``sent``   (R, R) f32 — cumulative bytes delivered per directed link
               (the Table-I traffic the run actually paid for).
    """

    have: jnp.ndarray
    credit: jnp.ndarray
    sent: jnp.ndarray


def slot_nbytes(bank: Any) -> float:
    """Payload bytes of one bank slot (sum over leaves, sans the slot axis)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(bank):
        per = leaf.dtype.itemsize
        for d in leaf.shape[1:]:
            per *= d
        total += per
    return float(total)


def chunk_digests(params: Any, chunks: int) -> jnp.ndarray:
    """(chunks,) f32 content digests of one model payload.

    The payload is conceptually flattened leaf-by-leaf into one byte stream,
    split into ``chunks`` equal ranges, and each range is tagged with a
    fixed pseudo-random projection (the per-chunk analogue of
    ``bank.auth_checksum``): identical content → identical digest, any bit
    flip moves it. Deterministic and shape-independent given equal
    flattened values, which is all content addressing needs here.
    """
    leaves = [l.reshape(-1).astype(jnp.float32)
              for l in jax.tree_util.tree_leaves(params)]
    flat = jnp.concatenate(leaves) if len(leaves) > 1 else leaves[0]
    n = flat.shape[0]
    per = -(-n // chunks)                       # ceil; zero-pad the tail
    flat = jnp.pad(flat, (0, per * chunks - n)).reshape(chunks, per)
    idx = jnp.arange(per, dtype=jnp.float32)
    proj = jnp.cos(idx * 0.618033988749895) + 1e-3 * jnp.sin(idx * 0.318309886)
    return flat @ proj


def bank_digests(bank: Any, chunks: int) -> jnp.ndarray:
    """(S, chunks) f32 — digest table of the whole store (vmap over slots)."""
    return jax.vmap(lambda i: chunk_digests(
        jax.tree_util.tree_map(lambda b: b[i], bank), chunks
    ))(jnp.arange(jax.tree_util.tree_leaves(bank)[0].shape[0]))


def init_bank_state(num_replicas: int, slots: int, chunks: int) -> BankState:
    """Genesis transport state: every node already holds the initial store
    (all replicas start from the same fully-replicated view — the same
    assumption ``init_replicas`` makes for the ledger), no budget in flight,
    zero bytes on the meter."""
    return BankState(
        have=jnp.ones((num_replicas, slots, chunks), bool),
        credit=jnp.zeros((num_replicas, num_replicas), jnp.float32),
        sent=jnp.zeros((num_replicas, num_replicas), jnp.float32),
    )


def commit_chunks(have: jnp.ndarray, digest: jnp.ndarray, params: Any,
                  slot, node_id) -> tuple:
    """Account a stage-4 commit overwriting store ``slot`` with ``params``.

    The committer holds the new content; everyone else's presence bits for
    the slot are reset (they held the ring-evicted payload); the digest row
    is re-derived from the new bytes. ``params`` is only ever digested
    here, so a codec-enabled driver passes the ENCODED wire pytree — the
    digest table then addresses the bytes receivers actually pull.
    Returns ``(have, digest)``.
    """
    chunks = digest.shape[1]
    have = have.at[:, slot, :].set(False).at[node_id, slot, :].set(True)
    return have, digest.at[slot].set(chunk_digests(params, chunks))


# ---------------------------------------------------------------------------
# The per-tick transfer step (runs inside the jitted sync scan)
# ---------------------------------------------------------------------------


def referenced_slots(dags: DagState, slots: int) -> jnp.ndarray:
    """(R, S) bool — store slots referenced by rows visible in each replica."""
    r = dags.publisher.shape[0]
    occ = dags.publisher >= 0
    ms = jnp.maximum(dags.model_slot, 0)
    rows = jnp.arange(r, dtype=jnp.int32)[:, None]
    ref = jnp.zeros((r, slots), bool)
    return ref.at[rows, ms].max(occ)


def chunk_step(
    dags: DagState,            # receiver block's replicas (post-merge)
    bstate: BankState,         # receiver block's transport state
    digest: jnp.ndarray,       # (S, C) f32 store digest table (global)
    sat_all: jnp.ndarray,      # (R, S, C) bool EVERY sender's availability
    sat_blk: jnp.ndarray,      # (Rb, S, C) bool this block's availability
    edges: jnp.ndarray,        # (Rb, R) bool active directed edges
    cap_bytes: jnp.ndarray,    # (Rb, R) f32 per-link budget this tick
    chunk_bytes,               # () f32 transfer granule
    return_pending: bool = False,
):
    """One tick of priced chunk movement for a receiver block.

    Single-device calls pass the full axes (``sat_blk is sat_all``); a mesh
    shard passes its receiver block against the all-gathered availability
    bitmaps — never payloads (``gossip._shard_bank_tick``). Per-receiver
    arithmetic only, so both are bitwise-identical.

    ``return_pending=True`` additionally returns the (Rb, R) bool mask of
    links that still had assigned work after the budget ran out — the
    continuous-time event engine (``repro.net.events``) schedules a
    chunk-drain completion event from it; the default keeps the tick paths
    byte-for-byte what they were.
    """
    rb, s, c = sat_blk.shape
    ref = referenced_slots(dags, s)
    need = (ref[:, :, None] & ~sat_blk).reshape(rb, s * c)
    budget = bstate.credit + jnp.where(edges, cap_bytes, 0.0)
    afford = jnp.clip(
        jnp.floor(budget / chunk_bytes), 0, jnp.iinfo(jnp.int32).max
    ).astype(jnp.int32)
    take, spent_chunks, pending = ck.transfer_select(
        need, sat_all.reshape(-1, s * c), edges, afford
    )
    spent = spent_chunks.astype(jnp.float32) * chunk_bytes
    # rollover: keep residual while work is pending; pause (don't reset) on
    # links that did not fire; never bank idle bandwidth on an active link
    credit = jnp.where(pending, budget - spent,
                       jnp.where(edges, 0.0, bstate.credit))
    out = BankState(
        have=bstate.have | take.reshape(rb, s, c),
        credit=credit,
        sent=bstate.sent + spent,
    )
    if return_pending:
        return out, pending
    return out


# ---------------------------------------------------------------------------
# Availability views (gating + metrics)
# ---------------------------------------------------------------------------


def rows_available(dag: DagState, sat: jnp.ndarray) -> jnp.ndarray:
    """(..., cap) bool — rows whose model chunks have fully arrived.

    ``dag`` may be one replica with ``sat (S, C)`` or the stacked set with
    ``sat (R, S, C)``; empty rows count as available (there is nothing to
    wait for).
    """
    ms = jnp.maximum(dag.model_slot, 0)
    got = jnp.all(jnp.take_along_axis(
        sat, ms[..., None].astype(jnp.int32), axis=-2
    ), axis=-1)
    return (dag.publisher < 0) | got


def gate_view(dag: DagState, have_row: jnp.ndarray, digest: jnp.ndarray) -> DagState:
    """A node's USABLE view: rows whose payload has not arrived are masked
    to empty (publisher and model_slot -1), exactly as if the transaction
    had not been received — Algorithm 2 then neither selects nor approves
    it. With full availability this is the identity (bitwise), which is what
    keeps the infinite-bandwidth limit equal to the ungated PR-3 path.

    Stage-3 fallback caveat: when a node has NO usable tips it continues
    from its most recent *visible* model; masking ``model_slot`` makes a
    payload-less latest row fall back to the store's slot 0 rather than
    read bytes the node never received.
    """
    sat = ck.chunk_dedup(have_row[None], digest)[0]
    avail = rows_available(dag, sat)
    return dag._replace(
        publisher=jnp.where(avail, dag.publisher, -1),
        model_slot=jnp.where(avail, dag.model_slot, -1),
    )


def gate_views(dags: DagState, sat: jnp.ndarray) -> DagState:
    """All nodes' USABLE views at once: the stacked ``gate_view`` given a
    precomputed availability reduction ``sat (R, S, C)`` (the serve path
    already holds one from ``chunk_dedup`` — no re-reduction per node).
    Rows whose payload has not arrived mask to empty exactly as in
    ``gate_view``; with full availability this is the identity."""
    avail = rows_available(dags, sat)
    return dags._replace(
        publisher=jnp.where(avail, dags.publisher, -1),
        model_slot=jnp.where(avail, dags.model_slot, -1),
    )


def missing_chunks(dags: DagState, bstate: BankState,
                   digest: jnp.ndarray, impl: Optional[str] = None) -> jnp.ndarray:
    """(R,) int32 — referenced-but-unavailable chunks per node (0 = every
    visible transaction's model is locally usable)."""
    sat = ck.chunk_dedup(bstate.have, digest, impl=impl)
    ref = referenced_slots(dags, sat.shape[1])
    return jnp.sum((ref[:, :, None] & ~sat).astype(jnp.int32), axis=(1, 2))


missing_chunks_jit = jax.jit(missing_chunks, static_argnames=("impl",))
gate_view_jit = jax.jit(gate_view)
