"""Device-mesh placement for the gossip overlay: shard the receiver axis.

The ``ReplicaSet`` stacks N per-node DAG replicas along one leading receiver
axis (repro.net.replica); this module partitions that axis over the
``"nodes"`` axis of a device mesh so replica memory and per-tick sync FLOPs
scale with the device count instead of capping N on one device (the §III.A
many-node DAG layer actually living on many devices).

The sharded anti-entropy round (repro.net.gossip) is a ``shard_map`` over
the mesh: each shard all-gathers the sender rows once (THE collective of
the round — the fused winner rule made the whole round one masked reduction
plus a row gather, so sharding receivers turns it into a per-shard
reduction over the gathered sender axis), reduces winners for its own
receiver block, and writes back only its block. Any extra mesh axes (e.g. a
``model`` axis in a 2x4 mesh) are unused by gossip and simply replicate.

With the model bank gossiped (``repro.net.bank``), the sharded tick gains a
second, equally skinny collective: each shard dedups its own receivers'
chunk presence and all-gathers the resulting availability BITMAPS — never
payload bytes; the content-addressed store stays shared — then selects its
block's transfers against the gathered sender availability. The per-node
``BankState`` leaves (presence bitmap, link credit, byte meter) all lead
with the receiver axis, so the same ``replica_specs`` placement rule shards
them.

``make_gossip_mesh`` builds the canonical ("nodes", "model") mesh; on CPU
runners the multi-device path needs
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (what the CI
8-device lane pins). ``mesh=None`` everywhere preserves the single-device
paths bitwise.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.specs import replica_specs, to_shardings

NODES_AXIS = "nodes"


def make_gossip_mesh(
    nodes: Optional[int] = None, model: int = 1, devices=None
) -> Mesh:
    """A ("nodes", "model") mesh; gossip shards receivers over "nodes" only.

    ``nodes=None`` spends every visible device on the nodes axis. A 2x4 mesh
    (nodes=2, model=4) and an 8x1 mesh sync identically — the model axis is
    replicated by the gossip layer; it exists so one mesh can serve both the
    sharded overlay and tensor-parallel model work (repro.sharding).
    """
    devices = np.asarray(jax.devices() if devices is None else devices)
    if nodes is None:
        nodes = devices.size // model
    if nodes * model > devices.size:
        raise ValueError(
            f"mesh {nodes}x{model} needs {nodes * model} devices, "
            f"only {devices.size} visible"
        )
    return Mesh(
        devices[: nodes * model].reshape(nodes, model), (NODES_AXIS, "model")
    )


def nodes_axis_size(mesh: Optional[Mesh]) -> int:
    return 1 if mesh is None else int(mesh.shape[NODES_AXIS])


def validate_replica_mesh(num_nodes: int, mesh: Mesh) -> None:
    """The receiver axis must tile exactly over the nodes axis — an uneven
    split would need padded phantom replicas inside every collective; pick
    an overlay size divisible by the nodes axis instead."""
    if NODES_AXIS not in mesh.axis_names:
        raise ValueError(
            f"gossip mesh needs a {NODES_AXIS!r} axis, got {mesh.axis_names}"
        )
    shards = nodes_axis_size(mesh)
    if num_nodes % shards != 0:
        raise ValueError(
            f"num_nodes={num_nodes} not divisible by the {NODES_AXIS!r} "
            f"axis ({shards}); resize the overlay or the mesh"
        )


def replica_sharding(mesh: Mesh, tree: Any) -> Any:
    """NamedSharding pytree: every leaf's leading receiver axis -> nodes."""
    return to_shardings(mesh, replica_specs(tree, NODES_AXIS))


def shard_replicas(dags: Any, mesh: Mesh) -> Any:
    """Place stacked replicas with the receiver axis split over "nodes"."""
    return jax.device_put(dags, replica_sharding(mesh, dags))


def replicate(x: Any, mesh: Mesh) -> Any:
    """Place overlay-wide arrays (adjacency, drop, strides) fully replicated
    so the jitted sync loops see one committed layout per mesh."""
    return jax.device_put(x, NamedSharding(mesh, P()))
