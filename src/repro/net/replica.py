"""Per-node DAG replicas stacked into one vmappable pytree.

``ReplicaSet`` holds R = num_nodes copies of the ledger as a single
``DagState`` whose every leaf grew a leading replica axis — one pytree on
device, not R Python objects — so an anti-entropy round is one fused masked
reduction over the sender axis (see ``repro.net.gossip`` and
``repro.kernels.gossip_merge``) instead of a Python loop over merges. That
leading receiver axis is also the scaling axis: ``init_replicas(mesh=...)``
partitions it over a device mesh's "nodes" axis (``repro.net.mesh``), which
is what lets R grow past one device's memory.

The model bank's PAYLOAD stays stored once: rows are allocated from a
global publish sequence (``publish_local``), so a transaction occupies the
same slot on every replica and its bytes live once in the bank — a
content-addressed model store (replicating N full model banks would
multiply memory by N for no informational gain). What gossip propagates is
row *visibility* (a replica that has not received a row never reads its
bank slot) and — when the network is built with a
``bank.BankGossipConfig`` — per-node chunk *presence*: ``bank_state``
stacks each node's chunk-availability bitmap and in-flight link budgets
along the same leading replica axis, so payload transport is priced on the
Table-I bandwidth model while the store itself is never duplicated
(``repro.net.bank``). ``bank_state`` is None when the bank is not gossiped
— the PR-3 behavior, bitwise.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dag as dag_lib
from repro.core.dag import DagState
from repro.kernels import ref as kernel_ref


class ReplicaSet(NamedTuple):
    dags: DagState      # every leaf has leading axis (R, ...)
    bank: Any           # shared model bank (repro.core.bank pytree)
    bank_state: Any = None   # per-node chunk transport (repro.net.bank
                             # BankState, leading axis R) — None when the
                             # bank is not gossiped

    @property
    def num_replicas(self) -> int:
        return int(self.dags.publisher.shape[0])


def init_replicas(
    dag: DagState, bank: Any, num_replicas: int, mesh=None
) -> ReplicaSet:
    """Every node starts from the same view (the genesis ledger).

    ``mesh`` (repro.net.mesh) places the stacked leaves with the leading
    receiver axis sharded over the mesh's "nodes" axis from the start: the
    broadcast runs jitted with sharded ``out_shardings``, so each device
    materializes only its R/shards receiver block — the whole point of the
    mesh is a stack too big for one device. The bank stays replicated
    either way (it is shared, see above).
    """

    def stack(d):
        return jax.tree_util.tree_map(
            lambda x: jnp.repeat(x[None], num_replicas, axis=0), d
        )

    if mesh is None:
        return ReplicaSet(dags=stack(dag), bank=bank)
    from repro.net import mesh as mesh_lib

    mesh_lib.validate_replica_mesh(num_replicas, mesh)
    stacked_like = jax.eval_shape(stack, dag)
    dags = jax.jit(
        stack, out_shardings=mesh_lib.replica_sharding(mesh, stacked_like)
    )(dag)
    return ReplicaSet(dags=dags, bank=bank)


def read_replica(rs: ReplicaSet, i) -> DagState:
    return jax.tree_util.tree_map(lambda x: x[i], rs.dags)


@functools.partial(jax.jit, donate_argnums=0)
def _write_dags_donated(dags: DagState, i, dag: DagState) -> DagState:
    return jax.tree_util.tree_map(lambda x, v: x.at[i].set(v), dags, dag)


def write_replica(rs: ReplicaSet, i, dag: DagState) -> ReplicaSet:
    """Write replica ``i``'s rows in place.

    The stacked ``dags`` buffers are DONATED to the update, so each commit
    scatters one replica's rows into the existing allocation instead of
    copying the whole (R, cap, ...) pytree — arrays reachable from the
    ``rs`` passed in are invalid afterwards; use the returned set.
    """
    return rs._replace(dags=_write_dags_donated(rs.dags, i, dag))


def global_row(dag: DagState, seq):
    """(row, count watermark) for a globally-sequenced publish — THE row
    addressing rule replicas must share for ``dag.merge`` to reconcile by
    identity. Using the global sequence (not the replica-local ``count``)
    keeps the same transaction at the same slot on every replica; ``count``
    becomes a watermark, the highest sequence this replica has published
    past (merge max-combines it with what gossip brings in)."""
    seq = jnp.asarray(seq, jnp.int32)
    row = jnp.mod(seq, dag_lib.capacity_of(dag))
    return row, jnp.maximum(dag.count, seq + 1)


def publish_local(
    dag: DagState,
    seq,                # () int32 global publish sequence number
    publisher,
    time,
    approvals,
    accuracy,
    auth_tag,
    model_slot,
) -> DagState:
    """Publish into a replica at the globally-allocated row (``global_row``)."""
    row, new_count = global_row(dag, seq)
    return dag_lib.publish_at(
        dag, row, new_count, publisher, time, approvals, accuracy, auth_tag,
        model_slot,
    )


# ---------------------------------------------------------------------------
# Union view + divergence metrics
# ---------------------------------------------------------------------------


def merge_all(dags: DagState) -> DagState:
    """Fold ``dag.merge`` across the replica axis — the union ledger.

    Merge is commutative/associative/idempotent, so the fold order is
    irrelevant; the union is what an omniscient observer (the paper's
    external agent E) would see, and equals the shared-ledger state when the
    overlay is fully synchronized. Implemented as the same fused winner
    reduction the anti-entropy round uses (one receiver hearing every
    replica — the ``Rr=1`` case of ``kernels.ref.gossip_winner_ref``), which
    is bitwise-equal to the sequential fold: the reduction's replica-0 tie
    preference is exactly the fold's first-element preference.
    """
    r = dags.publisher.shape[0]
    mask = jnp.ones((1, r), bool)
    src, _ = kernel_ref.gossip_winner_ref(
        dags.publish_time, dags.publisher, dags.approval_count, mask
    )
    merged = dag_lib.merge_select(dags, src, mask=mask)
    return jax.tree_util.tree_map(lambda x: x[0], merged)


def missing_vs_union(dags: DagState, union: DagState = None) -> jnp.ndarray:
    """(R,) rows each replica has not yet seen relative to the union view —
    0 everywhere iff row visibility has fully converged. Pass a precomputed
    union to avoid re-folding the replicas."""
    if union is None:
        union = merge_all(dags)
    have = (dags.publisher == union.publisher[None]) & (
        dags.publish_time == union.publish_time[None]
    )
    have = have | (union.publisher[None] < 0)
    return jnp.sum((~have).astype(jnp.int32), axis=-1)


def missing_vs_peer(dags: DagState) -> jnp.ndarray:
    """(R, R) rows receiver i has not yet seen of what sender j holds.

    The pairwise form of ``missing_vs_union``: entry (i, j) counts the
    occupied rows of replica j whose identity (publisher, publish_time)
    replica i does not hold at the same global slot — how far i lags j
    specifically, not just the union. The diagonal is zero, a column is
    what the overlay still owes everyone from node j's view, and a row
    pinned high while the rest of its column drains is a receiver being
    starved (eclipse / partition / dead link) — the per-link series
    ``repro.obs`` samples (``staleness_link``). Rows are positionally
    aligned across replicas (``replica.global_row``), the same property
    ``missing_vs_union`` leans on.
    """
    p, t = dags.publisher, dags.publish_time
    have = (p[:, None, :] == p[None, :, :]) & (
        t[:, None, :] == t[None, :, :]
    )
    have = have | (p[None, :, :] < 0)
    return jnp.sum((~have).astype(jnp.int32), axis=-1)


def replicas_synced(dags: DagState) -> jnp.ndarray:
    """() bool — every replica leaf-identical to replica 0."""
    flags = [
        jnp.all(x == x[0:1]) for x in jax.tree_util.tree_leaves(dags)
    ]
    return jnp.all(jnp.stack(flags))


# Module-level jitted entry points: one trace per leaf structure/shape, no
# matter how many GossipNetwork instances a benchmark sweep constructs.
merge_all_jit = jax.jit(merge_all)
missing_vs_union_jit = jax.jit(missing_vs_union)
replicas_synced_jit = jax.jit(replicas_synced)
