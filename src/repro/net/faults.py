"""Adversarial fault injection for the gossip overlay (§V.4 / Table III).

The paper's security argument is qualitative: Byzantine publishers are
starved of approvals by the accuracy-weighted tip selection, and corrupted
model payloads are caught because every transaction's content is
hash-addressed. This module makes those claims *executable*: per-node
adversary ROLES are injected inside the SAME jitted round bodies both
engines run (`repro.net.gossip`'s tick scan/while paths and
`repro.net.events`' delivery batches) — device-resident, so a faulted run
is still one `lax.scan`/`lax.while_loop` dispatch per advance window — and
the defenses the paper assumes (digest verification on receive, re-fetch
from alternate holders, quarantine of misbehaving links) are implemented
against them.

Roles (one per node, static for the run):

``ROLE_HONEST``     the PR-3 node, unchanged.
``ROLE_CRASH``      dark for ``t in [crash_start, crash_end)``: every edge
                    touching the node is cut (fail-stop churn window; the
                    node neither serves nor hears gossip, then recovers).
``ROLE_ECLIPSE``    adjacency rewrite around ``eclipse_target``: the
                    target's links to non-attackers are cut both ways, so
                    its view of the DAG is whatever the attackers relay.
``ROLE_SELECTIVE``  forwards each outgoing edge with probability
                    ``forward_prob`` only (selective forwarding / gray
                    hole) — an availability attack the redundant overlay
                    paths must absorb.
``ROLE_SPOOF``      serves chunk payloads whose bytes do not match the
                    announced content digest (rate ``spoof_rate`` per
                    admitted chunk). Requires bank gossip — metadata rows
                    are self-authenticating, payloads are where spoofing
                    bites.
``ROLE_SYBIL``      forges the full approver bitset on every row of its
                    own replica before gossiping it — the inflation attack
                    the exact approver-set union (PR 7) bounds at N and
                    crossing-gated contribution counters keep out of the
                    §V.2 rates.

Defense side (``verify_digests=True``, the default):

* every admitted chunk is digest-checked on receive
  (``repro.kernels.chunk_transfer.transfer_verify``) and a mismatch is
  dropped BEFORE it can set a presence bit — corrupted payloads never
  reach ``commit_chunks``/``gate_view``;
* a rejecting link zeroes its rolled-over credit (back-off) and charges
  the sender one rejection per bad chunk; at ``quarantine_after``
  cumulative rejections the link is cut for good and the striping in
  ``transfer_select`` re-routes the chunks to alternate holders — bounded
  re-fetch, paid for by the attacker's wasted bytes (spent is charged for
  rejected transfers too);
* cumulative per-sender rejections feed
  ``repro.core.anomaly.rejection_credit`` so the FL driver can bias tip
  selection away from quarantined publishers.

PRNG discipline: fault randomness derives from the round's existing
sub-key via ``jax.random.fold_in`` with fixed salts — the main key stream
sees the exact same split sequence as the un-faulted program, so a config
whose roles are all-HONEST is bitwise the ``faults=None`` path (tested),
and enabling e.g. a crash window does not perturb the drop-loss draws.

``faults=None`` in `GossipNetwork` keeps every existing code path
literally untouched — the jit factories in gossip.py/events.py return
their pre-PR bodies and dispatch here only when a ``FaultConfig`` is
passed (the ``obs=None`` pattern).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dag import DagState
from repro.kernels import chunk_transfer as chunk_kernel
from repro.net import bank as bank_lib
from repro.net import events as events_lib
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib

ROLE_HONEST = 0
ROLE_CRASH = 1
ROLE_ECLIPSE = 2
ROLE_SELECTIVE = 3
ROLE_SPOOF = 4
ROLE_SYBIL = 5

ROLE_NAMES = ("honest", "crash", "eclipse", "selective", "spoof", "sybil")

# fold_in salts: fault draws branch off the round's sub-key without
# advancing the main stream (see module docstring)
_SALT_EDGES = 7
_SALT_SPOOF = 11


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Static, hashable adversary assignment (a jit-factory cache key).

    ``roles``            one ``ROLE_*`` per node (tuple, length N).
    ``crash_start/end``  wall-clock window CRASH nodes are dark. The tick
                         engine evaluates it at the tick's sample instant
                         ``(tick + 1) * sync_period`` (the telemetry
                         convention); on the ideal wire (period <= 0) every
                         tick sits at t = 0, so the window either always or
                         never applies there.
    ``eclipse_target``   the node ECLIPSE attackers isolate (required when
                         any ECLIPSE role is assigned).
    ``forward_prob``     SELECTIVE nodes forward each edge with this
                         probability per round.
    ``spoof_rate``       probability a SPOOF node corrupts an admitted
                         chunk (1.0 = every chunk it serves is garbage).
    ``verify_digests``   the defense switch: True drops corrupted chunks on
                         receive and quarantines repeat offenders; False
                         lets them through (attack-success measurement —
                         ``FaultState.tainted`` then tracks the infection).
    ``quarantine_after`` cumulative rejections at which a (receiver,
                         sender) link is cut permanently.
    """

    roles: Tuple[int, ...]
    crash_start: float = 0.0
    crash_end: float = float("inf")
    eclipse_target: int = -1
    forward_prob: float = 0.5
    spoof_rate: float = 1.0
    verify_digests: bool = True
    quarantine_after: int = 3


class FaultState(NamedTuple):
    """Defense-side carry, threaded through the jitted loops (bank runs
    only — bankless fault paths are stateless edge/row rewrites)."""

    rejects: jnp.ndarray   # (N, N) int32  digest rejections: receiver i charged sender j
    tainted: jnp.ndarray   # (N, S, C) bool corrupted chunks accepted (verify off)


def init_fault_state(n: int, slots: int, chunks: int) -> FaultState:
    return FaultState(
        rejects=jnp.zeros((n, n), jnp.int32),
        tainted=jnp.zeros((n, slots, chunks), jnp.bool_),
    )


def validate_faults(cfg: FaultConfig, n: int, bank: bool) -> None:
    if len(cfg.roles) != n:
        raise ValueError(
            f"FaultConfig.roles has {len(cfg.roles)} entries for {n} nodes"
        )
    bad = [r for r in cfg.roles if r not in range(len(ROLE_NAMES))]
    if bad:
        raise ValueError(f"unknown fault roles: {bad!r}")
    if ROLE_ECLIPSE in cfg.roles and not 0 <= cfg.eclipse_target < n:
        raise ValueError(
            "ROLE_ECLIPSE assigned but eclipse_target is not a valid node"
        )
    if ROLE_SPOOF in cfg.roles and not bank:
        raise ValueError(
            "ROLE_SPOOF corrupts chunk payloads in flight — it requires "
            "bank gossip (construct GossipNetwork with bank_cfg)"
        )
    if cfg.quarantine_after < 1:
        raise ValueError("quarantine_after must be >= 1")


class _RoleMasks(NamedTuple):
    """Static per-role masks baked into the jitted bodies (numpy, traced as
    constants — roles never change mid-run)."""

    crash: np.ndarray         # (N,) bool
    eclipse_keep: np.ndarray  # (N, N) bool — edges the eclipse leaves alive
    selective: np.ndarray     # (N,) bool
    spoof: np.ndarray         # (N,) bool
    sybil: np.ndarray         # (N,) bool
    any_crash: bool
    any_selective: bool
    any_spoof: bool
    any_sybil: bool


@functools.lru_cache(maxsize=None)
def _role_masks(cfg: FaultConfig) -> _RoleMasks:
    roles = np.asarray(cfg.roles, np.int32)
    n = roles.shape[0]
    crash = roles == ROLE_CRASH
    selective = roles == ROLE_SELECTIVE
    spoof = roles == ROLE_SPOOF
    sybil = roles == ROLE_SYBIL
    attackers = roles == ROLE_ECLIPSE
    keep = np.ones((n, n), bool)
    if attackers.any():
        # the target keeps only its links to/from the attackers (and its
        # self-loop): everything it learns is relayed through them
        tgt = int(cfg.eclipse_target)
        allowed = attackers | (np.arange(n) == tgt)
        keep[tgt, :] = allowed
        keep[:, tgt] = allowed
    return _RoleMasks(
        crash=crash, eclipse_keep=keep, selective=selective, spoof=spoof,
        sybil=sybil, any_crash=bool(crash.any()),
        any_selective=bool(selective.any()), any_spoof=bool(spoof.any()),
        any_sybil=bool(sybil.any()),
    )


def fault_edges(cfg: FaultConfig, masks: _RoleMasks, t, fkey, edges):
    """Apply the edge-level attacks to a sampled/live edge mask.

    ``edges[i, j]`` = receiver i hears sender j (the engines' convention).
    Pure suppression — faults only remove deliveries, never add them — so
    an all-HONEST config returns ``edges`` bitwise. ``fkey`` is a
    ``fold_in`` branch of the round's sub-key; only SELECTIVE draws from
    it.
    """
    keep = jnp.asarray(masks.eclipse_keep)
    if masks.any_crash:
        dark = jnp.where(
            (t >= cfg.crash_start) & (t < cfg.crash_end),
            jnp.asarray(masks.crash), False,
        )
        keep = keep & ~dark[:, None] & ~dark[None, :]
    if masks.any_selective:
        u = jax.random.uniform(fkey, edges.shape)
        fwd = ~jnp.asarray(masks.selective)[None, :] | (u < cfg.forward_prob)
        keep = keep & fwd
    return edges & keep


def sybil_inflate(dags: DagState, masks: _RoleMasks) -> DagState:
    """SYBIL nodes forge the full approver bitset on their own rows.

    Runs on the stacked replica set after each round: every row a sybil
    node published *in its own replica* claims every node as an approver
    before the next gossip exchange relays it. The exact approver-set
    union (``core.dag.merge``) caps the damage at N distinct approvers and
    honest replicas' crossing-gated contribution counters never credit the
    forgeries — the attack inflates ``approval_count`` (rows stop looking
    like tips) but not the §V.2 contribution rates.
    """
    if not masks.any_sybil:
        return dags
    r = dags.publisher.shape[0]
    own = dags.publisher == jnp.arange(r, dtype=dags.publisher.dtype)[:, None]
    forge = own & jnp.asarray(masks.sybil)[:, None]
    approvers = dags.approvers | forge[:, :, None]
    return dags._replace(
        approvers=approvers,
        approval_count=jnp.sum(approvers.astype(jnp.int32), axis=-1),
    )


def quarantined(fstate: FaultState, cfg: FaultConfig) -> jnp.ndarray:
    """(N, N) bool — links cut by the rejection counter."""
    return fstate.rejects >= cfg.quarantine_after


def _fault_chunk_service(dags, bstate, fstate, digest, edges, cap_bytes,
                         chunk_bytes, skey, cfg, masks, bank_impl):
    """The fault-aware bank service step (mirrors ``bank.chunk_step``).

    Spoofed payloads are drawn per admitted chunk from ``skey`` (a
    ``fold_in`` branch — the main stream is untouched). With
    ``verify_digests`` the receive path becomes: recompute digests →
    reject mismatches (``transfer_verify``) → commit only verified chunks;
    a rejecting link loses its rolled-over credit (back-off) and repeat
    offenders are quarantined, at which point ``transfer_select``'s
    striping re-routes their chunks to alternate holders on the next
    service — bounded re-fetch with the attacker still billed the spent
    bytes. With verification off the corrupted chunks land and
    ``tainted`` tracks the infection (re-serving a tainted store corrupts
    downstream receivers too).

    Returns ``(bstate, fstate, pending)``.
    """
    r = edges.shape[0]
    s, c = bstate.have.shape[1], bstate.have.shape[2]
    m = s * c
    if cfg.verify_digests:
        edges = edges & ~quarantined(fstate, cfg)
    sat = chunk_kernel.chunk_dedup(bstate.have, digest, impl=bank_impl)
    ref = bank_lib.referenced_slots(dags, s)
    need = (ref[:, :, None] & ~sat).reshape(r, m)
    budget = bstate.credit + jnp.where(edges, cap_bytes, 0.0)
    afford = jnp.clip(
        jnp.floor(budget / chunk_bytes), 0, jnp.iinfo(jnp.int32).max
    ).astype(jnp.int32)
    take, take_link, spent_chunks, pending = chunk_kernel.transfer_select(
        need, sat.reshape(r, m), edges, afford, return_links=True
    )
    # which admitted transfers carry bytes that will not hash to the
    # announced digest: freshly spoofed by the sender, or re-served from a
    # store that accepted garbage earlier (verify-off infection)
    bad = fstate.tainted.reshape(r, m)[None, :, :]
    if masks.any_spoof:
        u = jax.random.uniform(skey, take_link.shape)
        bad = bad | (jnp.asarray(masks.spoof)[None, :, None]
                     & (u < cfg.spoof_rate))
    bad = take_link & bad
    spent = spent_chunks.astype(jnp.float32) * chunk_bytes
    if cfg.verify_digests:
        ok_take, rej = chunk_kernel.transfer_verify(take_link, bad)
        have = bstate.have | ok_take.reshape(r, s, c)
        # rejected bytes still crossed the wire (the attacker's bill);
        # the link's rolled-over budget is dropped as back-off
        credit = jnp.where(
            pending, budget - spent, jnp.where(edges, 0.0, bstate.credit)
        )
        credit = jnp.where(rej > 0, 0.0, credit)
        fstate = fstate._replace(rejects=fstate.rejects + rej)
    else:
        have = bstate.have | take.reshape(r, s, c)
        credit = jnp.where(
            pending, budget - spent, jnp.where(edges, 0.0, bstate.credit)
        )
        fstate = fstate._replace(
            tainted=fstate.tainted | jnp.any(bad, axis=1).reshape(r, s, c)
        )
    bstate = bank_lib.BankState(
        have=have, credit=credit, sent=bstate.sent + spent
    )
    return bstate, fstate, pending


# ---------------------------------------------------------------------------
# Tick engine: faulted variants of gossip.py's four jit factories
# ---------------------------------------------------------------------------


def _faulted_tick(impl, cfg, masks):
    """(dags, sub, tick, pm, adj, drop, stride, nbrs, period) ->
    (dags, edges, t): one faulted bankless tick body."""

    def tick_body(dags, sub, tick, pm, adj, drop, stride, nbr_idx, nbr_valid,
                  period):
        edges = gossip_lib._sample_edges(sub, tick, pm, adj, drop, stride)
        t = (tick.astype(jnp.float32) + 1.0) * period
        edges = fault_edges(
            cfg, masks, t, jax.random.fold_in(sub, _SALT_EDGES), edges
        )
        dags = gossip_lib._apply_round(dags, edges, nbr_idx, nbr_valid, impl)
        dags = sybil_inflate(dags, masks)
        return dags, edges, t

    return tick_body


@functools.lru_cache(maxsize=None)
def _advance_faults_jit(impl: str, faults: FaultConfig, obs=None):
    """Faulted ``_advance_jit``: same ONE-scan window, same PRNG splits —
    the fault layer only rewrites the sampled edge mask (and, for SYBIL,
    the post-round approver bitsets) inside the scan body."""
    masks = _role_masks(faults)
    tick_body = _faulted_tick(impl, faults, masks)

    if obs is None:
        def advance(dags, key, ticks, part_active, adj, drop, stride,
                    part_mask, nbr_idx, nbr_valid, period):
            def body(carry, xs):
                dags, key = carry
                tick, pact = xs
                key, sub = jax.random.split(key)
                pm = jnp.where(pact, part_mask, True)
                dags, _edges, _t = tick_body(
                    dags, sub, tick, pm, adj, drop, stride, nbr_idx,
                    nbr_valid, period,
                )
                return (dags, key), None

            (dags, key), _ = jax.lax.scan(
                body, (dags, key), (ticks, part_active)
            )
            return dags, key

        return jax.jit(advance)

    from repro import obs as obs_lib   # deferred: repro.obs imports repro.net

    def advance(dags, key, ticks, part_active, adj, drop, stride, part_mask,
                nbr_idx, nbr_valid, period, metrics, ring):
        def body(carry, xs):
            dags, key, metrics, ring = carry
            tick, pact = xs
            key, sub = jax.random.split(key)
            pm = jnp.where(pact, part_mask, True)
            new, edges, t = tick_body(
                dags, sub, tick, pm, adj, drop, stride, nbr_idx, nbr_valid,
                period,
            )
            metrics, ring = obs_lib.observe_round(
                obs, metrics, ring, t, dags, new, live_edges=edges
            )
            return (new, key, metrics, ring), None

        (dags, key, metrics, ring), _ = jax.lax.scan(
            body, (dags, key, metrics, ring), (ticks, part_active)
        )
        return dags, key, metrics, ring

    return jax.jit(advance)


@functools.lru_cache(maxsize=None)
def _converge_faults_jit(impl: str, faults: FaultConfig, obs=None):
    """Faulted ``_converge_jit``: the fixpoint flush under active faults.
    An eclipsed/crashed component that can make no further progress trips
    the stall exit exactly as a partition does."""
    masks = _role_masks(faults)
    tick_body = _faulted_tick(impl, faults, masks)

    if obs is None:
        def converge(dags, key, tick, part_mask, adj, drop, stride, limit,
                     stall_limit, nbr_idx, nbr_valid, period):
            def cond(carry):
                dags, _key, _tick, stalled, done = carry
                return (
                    ~replica_lib.replicas_synced(dags)
                    & (done < limit)
                    & (stalled < stall_limit)
                )

            def body(carry):
                dags, key, tick, stalled, done = carry
                key, sub = jax.random.split(key)
                new, _edges, _t = tick_body(
                    dags, sub, tick, part_mask, adj, drop, stride, nbr_idx,
                    nbr_valid, period,
                )
                stalled = jnp.where(
                    gossip_lib.trees_equal(new, dags), stalled + 1, 0
                )
                return (new, key, tick + 1, stalled, done + 1)

            dags, key, tick, _, done = jax.lax.while_loop(
                cond, body, (dags, key, tick, jnp.int32(0), jnp.int32(0)),
            )
            return dags, key, tick, done, replica_lib.replicas_synced(dags)

        return jax.jit(converge)

    from repro import obs as obs_lib

    def converge(dags, key, tick, part_mask, adj, drop, stride, limit,
                 stall_limit, nbr_idx, nbr_valid, period, metrics, ring):
        def cond(carry):
            dags, _key, _tick, stalled, done = carry[:5]
            return (
                ~replica_lib.replicas_synced(dags)
                & (done < limit)
                & (stalled < stall_limit)
            )

        def body(carry):
            dags, key, tick, stalled, done, metrics, ring = carry
            key, sub = jax.random.split(key)
            new, edges, t = tick_body(
                dags, sub, tick, part_mask, adj, drop, stride, nbr_idx,
                nbr_valid, period,
            )
            stalled = jnp.where(
                gossip_lib.trees_equal(new, dags), stalled + 1, 0
            )
            metrics, ring = obs_lib.observe_round(
                obs, metrics, ring, t, dags, new, live_edges=edges
            )
            return (new, key, tick + 1, stalled, done + 1, metrics, ring)

        dags, key, tick, _, done, metrics, ring = jax.lax.while_loop(
            cond, body,
            (dags, key, tick, jnp.int32(0), jnp.int32(0), metrics, ring),
        )
        return (dags, key, tick, done, replica_lib.replicas_synced(dags),
                metrics, ring)

    return jax.jit(converge)


@functools.lru_cache(maxsize=None)
def _advance_bank_faults_jit(impl: str, bank_impl, faults: FaultConfig,
                             obs=None, codec=None):
    """Faulted ``_advance_bank_jit``: rows merge over the faulted edge
    mask, then the fault-aware chunk service (spoofing, verification,
    back-off, quarantine) replaces ``chunk_step`` with the ``FaultState``
    threaded through the scan carry. ``codec`` (pre-mapped through
    ``delta_codec.codec_key``) prices chunks at encoded bytes — the
    attacker's rejected transfers are billed the COMPRESSED size too;
    ``codec=None`` keeps the literal raw-chunk program."""
    masks = _role_masks(faults)
    tick_body = _faulted_tick(impl, faults, masks)

    def serviced(dags, bstate, fstate, digest, edges, sub, cap_bytes,
                 chunk_bytes):
        if codec is not None:
            chunk_bytes = chunk_bytes * codec.wire_ratio()
        return _fault_chunk_service(
            dags, bstate, fstate, digest, edges, cap_bytes, chunk_bytes,
            jax.random.fold_in(sub, _SALT_SPOOF), faults, masks, bank_impl,
        )

    if obs is None:
        def advance(dags, bstate, fstate, digest, key, ticks, part_active,
                    adj, drop, stride, part_mask, nbr_idx, nbr_valid,
                    cap_bytes, chunk_bytes, period):
            def body(carry, xs):
                dags, bstate, fstate, key = carry
                tick_i, pact = xs
                key, sub = jax.random.split(key)
                pm = jnp.where(pact, part_mask, True)
                dags, edges, _t = tick_body(
                    dags, sub, tick_i, pm, adj, drop, stride, nbr_idx,
                    nbr_valid, period,
                )
                bstate, fstate, _pend = serviced(
                    dags, bstate, fstate, digest, edges, sub, cap_bytes,
                    chunk_bytes,
                )
                return (dags, bstate, fstate, key), None

            (dags, bstate, fstate, key), _ = jax.lax.scan(
                body, (dags, bstate, fstate, key), (ticks, part_active)
            )
            return dags, bstate, fstate, key

        return jax.jit(advance)

    from repro import obs as obs_lib

    def advance(dags, bstate, fstate, digest, key, ticks, part_active, adj,
                drop, stride, part_mask, nbr_idx, nbr_valid, cap_bytes,
                chunk_bytes, period, metrics, ring):
        def body(carry, xs):
            dags, bstate, fstate, key, metrics, ring = carry
            tick_i, pact = xs
            key, sub = jax.random.split(key)
            pm = jnp.where(pact, part_mask, True)
            new, edges, t = tick_body(
                dags, sub, tick_i, pm, adj, drop, stride, nbr_idx,
                nbr_valid, period,
            )
            newb, newf, _pend = serviced(
                new, bstate, fstate, digest, edges, sub, cap_bytes,
                chunk_bytes,
            )
            metrics, ring = obs_lib.observe_round(
                obs, metrics, ring, t, dags, new, live_edges=edges,
                bytes_delta=newb.sent - bstate.sent, bstate=newb,
                digest=digest, bank_impl=bank_impl, old_have=bstate.have,
                rejects=newf.rejects,
                rejects_delta=newf.rejects - fstate.rejects,
                quarantine_after=faults.quarantine_after,
            )
            return (new, newb, newf, key, metrics, ring), None

        (dags, bstate, fstate, key, metrics, ring), _ = jax.lax.scan(
            body, (dags, bstate, fstate, key, metrics, ring),
            (ticks, part_active)
        )
        return dags, bstate, fstate, key, metrics, ring

    return jax.jit(advance)


@functools.lru_cache(maxsize=None)
def _converge_bank_faults_jit(impl: str, bank_impl, faults: FaultConfig,
                              obs=None, codec=None):
    """Faulted ``_converge_bank_jit``. The stall check watches the
    ``FaultState`` too: rejections accruing toward quarantine are progress
    (the back-off/re-route cycle is still converging); once a spoofed
    stripe has re-routed and nothing moves for a full stride cycle the
    flush exits — ``synced`` is then honest about whether every referenced
    chunk VERIFIED, not merely arrived. ``codec`` prices chunks at encoded
    bytes (``codec=None`` keeps the literal raw-chunk program)."""
    masks = _role_masks(faults)
    tick_body = _faulted_tick(impl, faults, masks)

    def serviced(dags, bstate, fstate, digest, edges, sub, cap_bytes,
                 chunk_bytes):
        if codec is not None:
            chunk_bytes = chunk_bytes * codec.wire_ratio()
        return _fault_chunk_service(
            dags, bstate, fstate, digest, edges, cap_bytes, chunk_bytes,
            jax.random.fold_in(sub, _SALT_SPOOF), faults, masks, bank_impl,
        )

    def synced(dags, bstate, digest):
        return replica_lib.replicas_synced(dags) & (
            jnp.max(bank_lib.missing_chunks(dags, bstate, digest,
                                            impl=bank_impl)) == 0
        )

    if obs is None:
        def converge(dags, bstate, fstate, digest, key, tick0, part_mask,
                     adj, drop, stride, limit, stall_limit, nbr_idx,
                     nbr_valid, cap_bytes, chunk_bytes, period):
            def cond(carry):
                dags, bstate, _f, _key, _tick, stalled, done = carry
                return (
                    ~synced(dags, bstate, digest)
                    & (done < limit)
                    & (stalled < stall_limit)
                )

            def body(carry):
                dags, bstate, fstate, key, tick_i, stalled, done = carry
                key, sub = jax.random.split(key)
                new, edges, _t = tick_body(
                    dags, sub, tick_i, part_mask, adj, drop, stride,
                    nbr_idx, nbr_valid, period,
                )
                newb, newf, _pend = serviced(
                    new, bstate, fstate, digest, edges, sub, cap_bytes,
                    chunk_bytes,
                )
                still = gossip_lib.trees_equal(
                    (new, newb, newf), (dags, bstate, fstate)
                )
                stalled = jnp.where(still, stalled + 1, 0)
                return (new, newb, newf, key, tick_i + 1, stalled, done + 1)

            dags, bstate, fstate, key, tick_i, _, done = jax.lax.while_loop(
                cond, body,
                (dags, bstate, fstate, key, tick0, jnp.int32(0),
                 jnp.int32(0)),
            )
            return (dags, bstate, fstate, key, tick_i, done,
                    synced(dags, bstate, digest))

        return jax.jit(converge)

    from repro import obs as obs_lib

    def converge(dags, bstate, fstate, digest, key, tick0, part_mask, adj,
                 drop, stride, limit, stall_limit, nbr_idx, nbr_valid,
                 cap_bytes, chunk_bytes, period, metrics, ring):
        def cond(carry):
            dags, bstate, _f, _key, _tick, stalled, done = carry[:7]
            return (
                ~synced(dags, bstate, digest)
                & (done < limit)
                & (stalled < stall_limit)
            )

        def body(carry):
            (dags, bstate, fstate, key, tick_i, stalled, done,
             metrics, ring) = carry
            key, sub = jax.random.split(key)
            new, edges, t = tick_body(
                dags, sub, tick_i, part_mask, adj, drop, stride, nbr_idx,
                nbr_valid, period,
            )
            newb, newf, _pend = serviced(
                new, bstate, fstate, digest, edges, sub, cap_bytes,
                chunk_bytes,
            )
            still = gossip_lib.trees_equal(
                (new, newb, newf), (dags, bstate, fstate)
            )
            stalled = jnp.where(still, stalled + 1, 0)
            metrics, ring = obs_lib.observe_round(
                obs, metrics, ring, t, dags, new, live_edges=edges,
                bytes_delta=newb.sent - bstate.sent, bstate=newb,
                digest=digest, bank_impl=bank_impl, old_have=bstate.have,
                rejects=newf.rejects,
                rejects_delta=newf.rejects - fstate.rejects,
                quarantine_after=faults.quarantine_after,
            )
            return (new, newb, newf, key, tick_i + 1, stalled, done + 1,
                    metrics, ring)

        (dags, bstate, fstate, key, tick_i, _, done, metrics, ring) = (
            jax.lax.while_loop(
                cond, body,
                (dags, bstate, fstate, key, tick0, jnp.int32(0),
                 jnp.int32(0), metrics, ring),
            )
        )
        return (dags, bstate, fstate, key, tick_i, done,
                synced(dags, bstate, digest), metrics, ring)

    return jax.jit(converge)


# ---------------------------------------------------------------------------
# Event engine: faulted variants of events.py's two jit factories
# ---------------------------------------------------------------------------


def _deliver_round_faults(cfg, masks, impl, dags, qt, fires, key, t, qv,
                          qkind, qsrc, qdst, islot, horizon, fire_cap,
                          part_mask, part_t0, part_t1, drop, nbr_idx,
                          nbr_valid):
    """Faulted ``events._deliver_round``: identical batch/PRNG/reschedule
    arithmetic, with the fault mask composed onto the surviving ``live``
    edges (faults act at the same layer as drop loss — a delivery the
    adversary suppresses still consumed its queue slot) and SYBIL
    inflation applied to the post-round replicas."""
    n = dags.publisher.shape[0]
    batch = qv & (qt == t) & (qkind == events_lib.KIND_DELIVER)
    deliver = events_lib._edge_mask(n, qdst, qsrc, batch)
    pm = events_lib._partition_mask(t, part_mask, part_t0, part_t1)
    key, sub = jax.random.split(key)
    u = jax.random.uniform(sub, (n, n))
    live = deliver & pm & (u >= drop)
    live = fault_edges(
        cfg, masks, t, jax.random.fold_in(sub, _SALT_EDGES), live
    )
    dags = gossip_lib._apply_round(dags, live, nbr_idx, nbr_valid, impl)
    dags = sybil_inflate(dags, masks)
    fires = fires + batch.astype(jnp.int32)
    elide = fires >= fire_cap
    skip = (jnp.floor((horizon - qt) / islot) + 1.0) * islot
    qt = jnp.where(batch, qt + jnp.where(elide, skip, islot), qt)
    return dags, qt, fires, key, deliver, live, pm


@functools.lru_cache(maxsize=None)
def _advance_events_faults_jit(impl: str, faults: FaultConfig, obs=None):
    """Faulted ``events._advance_events_jit`` (bankless)."""
    from repro.kernels.event_pop import event_pop

    masks = _role_masks(faults)

    if obs is None:
        def advance(dags, qtime, qvalid, qkind, qsrc, qdst, qseq, islot, key,
                    horizon, limit, fire_cap, part_mask, part_t0, part_t1,
                    drop, nbr_idx, nbr_valid):

            def cond(carry):
                _dags, qt, qv, _fires, _key, done = carry
                return events_lib._queue_head_due(qt, qv, horizon) & (
                    done < limit
                )

            def body(carry):
                dags, qt, qv, fires, key, done = carry
                idx, _found = event_pop(qt, qkind, qseq, qv)
                t = qt[idx]
                dags, qt, fires, key, _dlv, _live, _pm = (
                    _deliver_round_faults(
                        faults, masks, impl, dags, qt, fires, key, t, qv,
                        qkind, qsrc, qdst, islot, horizon, fire_cap,
                        part_mask, part_t0, part_t1, drop, nbr_idx,
                        nbr_valid,
                    )
                )
                return dags, qt, qv, fires, key, done + 1

            dags, qt, qv, _fires, key, done = jax.lax.while_loop(
                cond, body,
                (dags, qtime, qvalid, jnp.zeros_like(qseq), key,
                 jnp.int32(0)),
            )
            return dags, qt, qv, key, done

        return jax.jit(advance)

    from repro import obs as obs_lib

    def advance(dags, qtime, qvalid, qkind, qsrc, qdst, qseq, islot, key,
                horizon, limit, fire_cap, part_mask, part_t0, part_t1, drop,
                nbr_idx, nbr_valid, metrics, ring):

        def cond(carry):
            _dags, qt, qv = carry[0], carry[1], carry[2]
            done = carry[7]
            return events_lib._queue_head_due(qt, qv, horizon) & (done < limit)

        def body(carry):
            dags, qt, qv, fires, key, metrics, ring, done = carry
            idx, _found = event_pop(qt, qkind, qseq, qv)
            t = qt[idx]
            old = dags
            dags, qt, fires, key, _dlv, live, _pm = _deliver_round_faults(
                faults, masks, impl, dags, qt, fires, key, t, qv, qkind,
                qsrc, qdst, islot, horizon, fire_cap, part_mask, part_t0,
                part_t1, drop, nbr_idx, nbr_valid,
            )
            metrics, ring = obs_lib.observe_round(
                obs, metrics, ring, t, old, dags, live_edges=live
            )
            return dags, qt, qv, fires, key, metrics, ring, done + 1

        dags, qt, qv, _fires, key, metrics, ring, done = jax.lax.while_loop(
            cond, body,
            (dags, qtime, qvalid, jnp.zeros_like(qseq), key, metrics, ring,
             jnp.int32(0)),
        )
        return dags, qt, qv, key, done, metrics, ring

    return jax.jit(advance)


@functools.lru_cache(maxsize=None)
def _advance_events_bank_faults_jit(impl: str, bank_impl,
                                    faults: FaultConfig, obs=None,
                                    codec=None):
    """Faulted ``events._advance_events_bank_jit``.

    Batch structure, continuous budget accrual, and drain re-arm are the
    originals; the chunk service is the fault-aware one. A quarantined
    link gets no stripe assignment, so its drain slot disarms (pending is
    False for it) while deliveries keep firing — the overlay routes
    around it at zero queue cost. The per-batch spoof key folds the batch
    counter in (drain-only batches do not split the main key, so the salt
    alone would repeat draws across consecutive drains)."""
    from repro.kernels.event_pop import event_pop

    masks = _role_masks(faults)

    if obs is not None:
        from repro import obs as obs_lib

    def advance(dags, have, credit, sent, fstate, last_srv, digest, qtime,
                qvalid, qkind, qsrc, qdst, qseq, islot, key, horizon, limit,
                fire_cap, part_mask, part_t0, part_t1, drop, nbr_idx,
                nbr_valid, bw_bytes, chunk_bytes, *obs_carry):
        if codec is not None:
            chunk_bytes = chunk_bytes * codec.wire_ratio()
        n = dags.publisher.shape[0]

        def cond(carry):
            qt, qv, done = carry[5], carry[6], carry[8]
            return events_lib._queue_head_due(qt, qv, horizon) & (done < limit)

        def body(carry):
            if obs is not None:
                (dags, bstate, fstate, last_srv, key, qt, qv, fires, done,
                 metrics, ring) = carry
                old_dags, old_sent, old_rej = dags, bstate.sent, fstate.rejects
                old_have = bstate.have
            else:
                (dags, bstate, fstate, last_srv, key, qt, qv, fires,
                 done) = carry
            idx, _found = event_pop(qt, qkind, qseq, qv)
            t = qt[idx]
            batch = qv & (qt == t)
            is_drn = qkind == events_lib.KIND_DRAIN
            drain = events_lib._edge_mask(n, qdst, qsrc, batch & is_drn)

            def _with_round(op):
                return _deliver_round_faults(
                    faults, masks, impl, *op, t, qv, qkind, qsrc, qdst,
                    islot, horizon, fire_cap, part_mask, part_t0, part_t1,
                    drop, nbr_idx, nbr_valid,
                )

            def _no_round(op):
                dags, qt, fires, key = op
                off = jnp.zeros((n, n), bool)
                pm = events_lib._partition_mask(t, part_mask, part_t0,
                                                part_t1)
                return dags, qt, fires, key, off, off, pm

            dags, qt, fires, key, deliver, live, pm = jax.lax.cond(
                jnp.any(batch & (qkind == events_lib.KIND_DELIVER)),
                _with_round, _no_round, (dags, qt, fires, key),
            )
            svc = live | (drain & pm)
            sched = deliver | drain
            accr = jnp.where(svc, (t - last_srv) * bw_bytes, 0.0)
            skey = jax.random.fold_in(
                jax.random.fold_in(key, _SALT_SPOOF), done
            )
            bstate, fstate, pending = _fault_chunk_service(
                dags, bstate, fstate, digest, svc, accr, chunk_bytes, skey,
                faults, masks, bank_impl,
            )
            last_srv = jnp.where(sched, t, last_srv)
            # strict-progress clamp: see the serve-free drain re-arm in
            # events.py — an f32 credit residue can round the completion
            # instant back to t and livelock the advance
            rate = jnp.maximum(bw_bytes, 1e-9)
            t_next = jnp.nextafter(t, jnp.float32(jnp.inf))
            e_next = jnp.maximum(
                t + (chunk_bytes - bstate.credit) / rate, t_next
            )[qdst, qsrc]
            e_retry = jnp.maximum(t + chunk_bytes / rate, t_next)[qdst, qsrc]
            e_svc = svc[qdst, qsrc]
            e_pend = pending[qdst, qsrc]
            qv = jnp.where(is_drn & e_svc, e_pend, qv)
            qt = jnp.where(is_drn & e_svc,
                           jnp.where(e_pend, e_next, jnp.inf), qt)
            qt = jnp.where(batch & is_drn & ~e_svc, e_retry, qt)
            if obs is not None:
                metrics2, ring2 = obs_lib.observe_round(
                    obs, metrics, ring, t, old_dags, dags, live_edges=live,
                    bytes_delta=bstate.sent - old_sent, bstate=bstate,
                    digest=digest, bank_impl=bank_impl, old_have=old_have,
                    rejects=fstate.rejects,
                    rejects_delta=fstate.rejects - old_rej,
                    quarantine_after=faults.quarantine_after,
                )
                return (dags, bstate, fstate, last_srv, key, qt, qv, fires,
                        done + 1, metrics2, ring2)
            return (dags, bstate, fstate, last_srv, key, qt, qv, fires,
                    done + 1)

        init = (dags,
                bank_lib.BankState(have=have, credit=credit, sent=sent),
                fstate, last_srv, key, qtime, qvalid,
                jnp.zeros_like(qseq), jnp.int32(0)) + tuple(obs_carry)
        out = jax.lax.while_loop(cond, body, init)
        dags, bstate, fstate, last_srv, key, qt, qv, _fires, done = out[:9]
        return (dags, bstate, fstate, last_srv, key, qt, qv, done) + out[9:]

    return jax.jit(advance)
