"""Gossip overlay network for per-node DAG replicas (§III.A's bottom layers).

The paper's three-layer architecture gives every node a *local* DAG that is
"updated by communicating with adjacent nodes"; the simulator historically
ran all systems against one instantly-consistent global ledger. This package
supplies the missing network layer:

  ``topology``  adjacency builders (ring, k-regular, Erdős–Rényi, star,
                full) returning an (N, N) neighbor mask plus per-link
                latency and drop-probability matrices, with component /
                partition helpers.

  ``replica``   ``ReplicaSet`` — N per-node ``DagState`` replicas stacked
                along a leading axis (one vmappable pytree, not N Python
                objects) over one shared model bank, plus read/write/union
                and divergence metrics. Rows are allocated from a global
                sequence number (``publish_local``) so ``dag.merge`` can
                reconcile replicas row-wise by transaction identity.

  ``gossip``    a jittable anti-entropy round — the row-wise ``dag.merge``
                fold fused into one masked winner reduction over the sender
                axis (``repro.kernels.gossip_merge``; the PR-1 vmap/scan
                fold survives as ``impl="scan"``) — plus per-edge
                message-loss sampling, latency-derived sync strides,
                partition schedules (split for [t_a, t_b), then heal), and
                the host-side ``GossipNetwork`` driver, which batches each
                advance window into ONE jitted ``lax.scan`` and runs
                ``converge`` as ONE jitted ``lax.while_loop``.

  ``mesh``      device-mesh placement: partitions the ``ReplicaSet``'s
                leading receiver axis over a mesh's "nodes" axis, turning
                the fused round into a per-shard reduction plus one
                collective gather of sender rows (``shard_map`` body in
                ``gossip``) — bitwise-equal to the single-device round.

  ``events``    continuous-time event engine: a fixed-capacity event queue
                as stacked arrays popped by a masked lexicographic argmin
                (``repro.kernels.event_pop``), advanced by ONE jitted
                ``lax.while_loop`` — per-edge deliveries at the link's
                actual latency (replacing stride quantization), bank
                chunk-drain completions, and the §IV in-system Eq. (4)
                tip simulation (``simulate_insystem_tips``). Selected via
                ``GossipConfig(engine="events")``; its uniform-delay
                degenerate limit is bitwise the tick engine.

  ``bank``      priced model-payload transport: per-node chunk-availability
                bitmaps over ONE content-addressed store, content dedup
                (``repro.kernels.chunk_transfer``), per-link Table-I byte
                budgets with rollover, and view gating — a transaction is
                usable only once its model chunks arrived. Off by default;
                with unlimited capacity it is bitwise the bankless path.

  ``faults``    adversarial fault injection: per-node Byzantine roles
                (crash windows, eclipse adjacency rewrites, probabilistic
                selective forwarding, in-flight chunk spoofing, sybil
                approval forging) applied *inside* the jitted round bodies
                of both engines, salted off the round key so
                ``faults_cfg=None`` — and an all-honest config — is
                bitwise the un-faulted run. Defense: digest verification
                on receive, alternate-holder re-fetch, link quarantine,
                and ``repro.core.anomaly.rejection_credit`` feedback.

Data flow: ``topology`` builds the overlay → ``replica`` stacks the
per-node ledgers → ``gossip`` moves rows between them → ``repro.fl.systems.
run_dagfl_gossip`` interleaves sync ticks with Algorithm-2 prepare/commit
events so tip staleness, exact approver-set convergence across stale
views, and partition/heal recovery become measurable against the
shared-ledger baseline. ``faults`` injects Byzantine roles (crash /
eclipse / selective-forward / spoof / sybil) inside both engines' jitted
loops, with digest verification + quarantine as the defense
(docs/THREAT_MODEL.md).
"""
from repro.net import bank, events, faults, gossip, mesh, replica, topology
from repro.net.bank import BankGossipConfig, BankState
from repro.net.events import EventQueue, simulate_insystem_tips
from repro.net.faults import FaultConfig, FaultState
from repro.net.gossip import GossipConfig, GossipNetwork, PartitionSchedule
from repro.net.mesh import make_gossip_mesh
from repro.net.replica import ReplicaSet
from repro.net.topology import Topology

__all__ = [
    "bank", "events", "faults", "gossip", "mesh", "replica", "topology",
    "BankGossipConfig", "BankState", "EventQueue",
    "FaultConfig", "FaultState",
    "GossipConfig", "GossipNetwork", "PartitionSchedule",
    "ReplicaSet", "Topology", "make_gossip_mesh",
    "simulate_insystem_tips",
]
