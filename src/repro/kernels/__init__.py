"""The repo's Pallas kernel layer: every device-resident reduction the
overlay and the model stack lean on, each shipped as the same triple —
a Pallas kernel (compiled on TPU, interpreted elsewhere), a pure-lax
oracle in ``repro.kernels.ref`` (the allclose/bitwise ground truth and
the CPU fast path), and a dispatcher that picks per backend (``impl``
override for tests). Members:

* ``gossip_merge`` — per-row gossip-merge winner selection (+ the
  degree-compressed candidate-list variant);
* ``chunk_transfer`` — content-addressed chunk dedup, striped
  bandwidth-limited transfer selection, and receive-side digest
  verification for the priced bank;
* ``delta_codec`` — wire compression for bank commits: blocked int8/int4
  symmetric quantization and per-block top-k delta sparsification, plus
  the ``DeltaCodec`` pytree codec the engines price chunks with;
* ``event_pop`` — masked argmin pop for the continuous-time event queue;
* ``fedavg`` / ``model_distance`` — Eq. (1) aggregation and the pairwise
  parameter-space distances anomaly scoring uses;
* ``flash_attention`` / ``wkv`` — the model-side attention/recurrence
  kernels served from the gossiped bank.

``repro.kernels.ops`` re-exports jit'd wrappers with container-aware
``interpret`` defaults.
"""
from repro.kernels import ops, ref
from repro.kernels.delta_codec import DeltaCodec
from repro.kernels.ops import (
    chunk_dedup,
    decode_attention,
    event_pop,
    fedavg,
    flash_attention,
    gossip_winner,
    model_distance,
    quant_blocks,
    topk_blocks,
)

__all__ = [
    "ops",
    "ref",
    "chunk_dedup",
    "decode_attention",
    "event_pop",
    "fedavg",
    "flash_attention",
    "gossip_winner",
    "model_distance",
    "DeltaCodec",
    "quant_blocks",
    "topk_blocks",
]
