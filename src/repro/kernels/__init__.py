from repro.kernels import ops, ref
from repro.kernels.ops import (
    chunk_dedup,
    decode_attention,
    event_pop,
    fedavg,
    flash_attention,
    gossip_winner,
    model_distance,
)

__all__ = [
    "ops",
    "ref",
    "chunk_dedup",
    "decode_attention",
    "event_pop",
    "fedavg",
    "flash_attention",
    "gossip_winner",
    "model_distance",
]
