from repro.kernels import ops, ref
from repro.kernels.ops import (
    decode_attention,
    fedavg,
    flash_attention,
    gossip_winner,
    model_distance,
)

__all__ = [
    "ops",
    "ref",
    "decode_attention",
    "fedavg",
    "flash_attention",
    "gossip_winner",
    "model_distance",
]
