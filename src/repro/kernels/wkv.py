"""Pallas kernel: chunk-parallel RWKV6 WKV with data-dependent decay.

The rwkv6 hot spot (DESIGN.md §3): the recurrence

    y_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t),  S_t = diag(w_t) S_{t-1} + k_t^T v_t

processed one (B, H) tile per grid step with the (hd, hd) state resident in
VMEM across the whole time loop — the TPU analogue of the CUDA kernel's
register-resident state. Within each CHUNK timesteps the pairwise decay
tensor (C, C, hd) is formed in VMEM and contracted on the MXU (all its
exponents are <= 0, so no rescaling pass is needed — see models/rwkv.py).

Grid: (B, H, T/CHUNK); chunk axis innermost so the state scratch persists.
Oracle: repro.models.rwkv.wkv_scan (sequential), cross-checked against
wkv_chunked in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 32


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *, chunk, nc):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    rb = r_ref[0, 0].astype(jnp.float32)          # (C, hd)
    kb = k_ref[0, 0].astype(jnp.float32)
    vb = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)         # log decay <= 0
    u = u_ref[0].astype(jnp.float32)              # (hd,)
    C = chunk

    cum = jnp.cumsum(lw, axis=0)                  # inclusive (C, hd)
    cum_prev = cum - lw                           # exclusive
    # intra-chunk pairwise decay W[t, s, :] = exp(cum_prev[t] - cum[s]), s < t
    expo = cum_prev[:, None, :] - cum[None, :, :]              # (C, C, hd)
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
        > jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    )[..., None]
    W = jnp.where(mask, jnp.exp(expo), 0.0)

    scores = jnp.einsum("td,sd,tsd->ts", rb, kb, W)            # (C, C)
    bonus = jnp.sum(rb * kb * u[None, :], axis=1)              # (C,)
    y = jax.lax.dot_general(
        scores, vb, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y = y + bonus[:, None] * vb

    # inter-chunk: read the carried state
    S = s_ref[...]                                             # (hd, hd)
    rdec = rb * jnp.exp(cum_prev)
    y = y + jax.lax.dot_general(
        rdec, S, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0, 0] = y.astype(o_ref.dtype)

    # state update: S' = exp(cum_C) * S + sum_s (k_s * exp(cum_C - cum_s)) v_s^T
    total = cum[-1]                                            # (hd,)
    kdec = kb * jnp.exp(total[None, :] - cum)
    s_ref[...] = jnp.exp(total)[:, None] * S + jax.lax.dot_general(
        kdec, vb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(
    r: jnp.ndarray,      # (B, T, H, hd)
    k: jnp.ndarray,
    v: jnp.ndarray,
    logw: jnp.ndarray,   # (B, T, H, hd), log decay <= 0
    u: jnp.ndarray,      # (H, hd)
    chunk: int = CHUNK,
    interpret: bool = True,
) -> jnp.ndarray:
    B, T, H, hd = r.shape
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    def arrange(x):
        # (B, T, H, hd) -> (B, H, T, hd) so the chunk dim tiles cleanly
        return jnp.moveaxis(x, 2, 1)

    rr, kk, vv, ww = map(arrange, (r, k, v, logw))
    kernel = functools.partial(_wkv_kernel, chunk=chunk, nc=nc)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, u)
    return jnp.moveaxis(out, 1, 2)                 # back to (B, T, H, hd)
