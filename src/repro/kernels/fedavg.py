"""Pallas kernel: Eq.-(1) FederatedAveraging over k candidate models.

The DAG-FL per-iteration hot spot: a memory-bound streaming reduction
``out[n] = sum_k w[k] * models[k, n]`` over the flattened parameter vector.
Tiled so each grid step holds a (k, BLOCK_N) slab in VMEM; k is tiny (2..8)
so the slab is written (8, 128)-aligned in N.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 16 * 1024  # 16k f32 lanes x k rows ~= 512 KiB @ k=8 — fits VMEM


def _fedavg_kernel(w_ref, x_ref, o_ref):
    # w_ref: (k, 1) f32; x_ref: (k, BLOCK_N); o_ref: (1, BLOCK_N)
    w = w_ref[...].astype(jnp.float32)                  # (k, 1)
    x = x_ref[...].astype(jnp.float32)                  # (k, bn)
    o_ref[...] = jnp.sum(w * x, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fedavg_pallas(
    weights: jnp.ndarray,        # (k,) f32
    models: jnp.ndarray,         # (k, N)
    block_n: int = BLOCK_N,
    interpret: bool = True,
) -> jnp.ndarray:
    k, n = models.shape
    pad = (-n) % block_n
    x = jnp.pad(models, ((0, 0), (0, pad)))
    n_pad = n + pad
    w = weights.reshape(k, 1).astype(jnp.float32)

    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), models.dtype),
        interpret=interpret,
    )(w, x)
    return out[0, :n]
