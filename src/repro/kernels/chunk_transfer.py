"""Pallas kernel: content-addressed chunk dedup + transfer selection.

The bank-gossip hot spot (``repro.net.bank``): every sync tick each node
must decide which model chunks it still needs (content-addressed dedup
against everything it already holds) and which of those its active
neighbors can supply within the tick's per-link byte budget. Both steps are
masked reductions in the same mold as ``repro.kernels.gossip_merge`` — no
data-dependent shapes, so the whole bank tick stays inside the jitted
``lax.scan`` of ``GossipNetwork.advance``.

Two layers, array-level on purpose (no ``DagState``/pytree types here):

``chunk_dedup``        sat[i, s, c] = node i effectively has chunk (s, c):
                       it physically holds some chunk (s', c) with an equal
                       content digest. Chunking is ALIGNED — dedup compares
                       chunks at the same offset c across slots, capturing
                       whole-model identity (lazy republish costs zero
                       bytes) but not offset-shifted collisions. Dense
                       blocked Pallas kernel (the TPU shape; interpreted
                       elsewhere) with ``repro.kernels.ref.chunk_dedup_ref``
                       as the pure-lax oracle/CPU fast path — the same
                       dispatch pattern as ``gossip_winner``.

``transfer_select``    per receiver, STRIPE the still-needed chunks across
                       the active neighbors that have the content (chunk m
                       goes to the (m mod holders)-th lowest-indexed active
                       holder, so parallel links to distinct holders drain
                       distinct chunks instead of idling behind the lowest
                       index), then admit chunks per link in canonical
                       (slot, chunk) order until the link's whole-chunk
                       budget runs out. Pure lax; deterministic (no
                       sampling), so the bank tick never touches the PRNG
                       stream and the gossip round stays bitwise-identical
                       with bank gossip enabled under infinite bandwidth.

Equivalence pallas-vs-ref is property-tested in ``tests/test_net_bank.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

BLOCK_S = 128   # digest slot-block per grid step


def _dedup_kernel(have_ref, dig_ref, dblk_ref, sat_ref):
    # have_ref: (1, S, C) i32 — receiver i's physical presence bitmap
    # dig_ref:  (S, C) f32   — full digest table (the dedup candidates)
    # dblk_ref: (bs, C) f32  — this block's target digests
    # sat_ref:  (1, bs, C) i32 — effective availability for the block
    hv = have_ref[...][0] != 0                               # (S, C)
    eq = dblk_ref[...][:, None, :] == dig_ref[...][None, :, :]   # (bs, S, C)
    sat = jnp.any(eq & hv[None, :, :], axis=1)               # (bs, C)
    sat_ref[...] = sat.astype(jnp.int32)[None]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def chunk_dedup_pallas(
    have: jnp.ndarray,      # (R, S, C) bool
    digest: jnp.ndarray,    # (S, C) f32
    block_s: int = BLOCK_S,
    interpret: bool = True,
) -> jnp.ndarray:
    """(R, S, C) bool effective availability — the Pallas reduction.

    Grid step (i, sb) loads receiver i's presence bitmap once against a
    ``block_s``-slot slab of the digest table and any-reduces the aligned
    content matches. Padding slots carry NaN digests, which compare unequal
    to everything (including themselves), so they can neither satisfy nor
    be satisfied.
    """
    r, s, c = have.shape
    bs = min(block_s, s) if s else block_s
    pad = (-s) % bs
    dig = jnp.pad(jnp.asarray(digest, jnp.float32), ((0, pad), (0, 0)),
                  constant_values=jnp.nan)
    hv = jnp.pad(jnp.asarray(have, jnp.int32), ((0, 0), (0, pad), (0, 0)))

    sat = pl.pallas_call(
        _dedup_kernel,
        grid=(r, (s + pad) // bs),
        in_specs=[
            pl.BlockSpec((1, s + pad, c), lambda i, sb: (i, 0, 0)),
            pl.BlockSpec((s + pad, c), lambda i, sb: (0, 0)),
            pl.BlockSpec((bs, c), lambda i, sb: (sb, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, c), lambda i, sb: (i, sb, 0)),
        out_shape=jax.ShapeDtypeStruct((r, s + pad, c), jnp.int32),
        interpret=interpret,
    )(hv, dig, dig)
    # physical presence short-circuits the digest match (NaN digests — a
    # payload that trained to NaN — compare unequal even to themselves;
    # see ref.chunk_dedup_ref)
    return (sat[:, :s, :] > 0) | jnp.asarray(have, bool)


def chunk_dedup(have, digest, impl: str = None, block_s: int = BLOCK_S,
                interpret: bool = None) -> jnp.ndarray:
    """Content-addressed availability with backend dispatch.

    ``impl``: "pallas" forces the kernel (interpreted off-TPU), "lax" the
    pure-lax oracle; None picks pallas on TPU, lax elsewhere — the same
    rule as ``gossip_merge.gossip_winner``.
    """
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    if impl == "lax":
        return ref.chunk_dedup_ref(have, digest)
    if impl != "pallas":
        raise ValueError(f"unknown chunk_dedup impl: {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return chunk_dedup_pallas(have, digest, block_s=block_s, interpret=interpret)


def transfer_select(
    need: jnp.ndarray,         # (Rb, M) bool — receiver block's wanted chunks
    src_have: jnp.ndarray,     # (R, M) bool — sender effective availability
    edge_active: jnp.ndarray,  # (Rb, R) bool — receiver i hears sender j
    afford: jnp.ndarray,       # (Rb, R) i32 — whole chunks per link this tick
    return_links: bool = False,
):
    """One tick of bandwidth-limited chunk transfers (pure lax, no PRNG).

    Needed chunks are STRIPED across the active senders whose effective
    availability covers them: chunk ``m`` is assigned to the
    ``(m mod holders)``-th lowest-indexed active holder, so when several
    neighbors hold the same content their links drain disjoint chunk sets
    in parallel instead of every chunk queueing behind the lowest-indexed
    holder. A single holder degenerates to exactly the lowest-index rule
    (deterministic — merge ties in the gossip round break the same way).
    Each link then admits its assigned chunks in ascending flat
    (slot, chunk) order until ``afford`` whole chunks have been spent.
    ``Rb`` may be a mesh shard's receiver block reduced against the
    all-gathered availability bitmaps — per-receiver arithmetic only, so
    the sharded tick is bitwise the single-device one.

    Returns ``(take (Rb, M) bool, spent (Rb, R) i32 chunks moved per link,
    pending (Rb, R) bool — link had assigned work left over)``. With
    ``return_links=True`` the per-link admission mask is exposed too:
    ``(take, take_link (Rb, R, M) bool, spent, pending)`` — the fault layer
    (``repro.net.faults``) needs sender attribution to verify digests and
    charge rejections per link; striping guarantees at most one sender per
    (receiver, chunk), so ``take == any(take_link, axis=1)`` loses nothing.
    """
    rb, m = need.shape
    r = src_have.shape[0]
    can = edge_active[:, :, None] & need[:, None, :] & src_have[None, :, :]
    # stripe: among a chunk's active holders (ranked by sender index), pick
    # the (chunk index mod holder count)-th — distinct chunks spread over
    # distinct links, and afford admission below stays per-link
    holder_rank = jnp.cumsum(can.astype(jnp.int32), axis=1) - 1   # (Rb, R, M)
    holders = jnp.sum(can.astype(jnp.int32), axis=1)              # (Rb, M)
    chunk_idx = jnp.arange(m, dtype=jnp.int32)[None, :]
    pick = jnp.where(
        holders > 0, jnp.mod(chunk_idx, jnp.maximum(holders, 1)), -1
    )
    assigned = can & (holder_rank == pick[:, None, :])            # (Rb, R, M)
    rank = jnp.cumsum(assigned.astype(jnp.int32), axis=2) - 1
    take_link = assigned & (rank < afford[:, :, None])
    take = jnp.any(take_link, axis=1)
    spent = jnp.sum(take_link.astype(jnp.int32), axis=2)
    pending = jnp.any(assigned & ~take_link, axis=2)
    if return_links:
        return take, take_link, spent, pending
    return take, spent, pending


def transfer_verify(
    take_link: jnp.ndarray,    # (Rb, R, M) bool — admitted transfers per link
    bad_link: jnp.ndarray,     # (Rb, R, M) bool — payload corrupted in flight
):
    """Digest check on receive: the defense-side reduction next to dedup.

    A receiver recomputes the content digest of every chunk it just pulled
    and compares against the digest table it already gossips
    (``repro.net.bank.chunk_digests``); a mismatch means the sender served
    bytes that do not hash to the announced content, so the chunk is
    dropped before it can satisfy ``need`` — it never reaches
    ``commit_chunks``/``gate_view``. Array form: ``bad_link`` marks the
    admitted transfers whose payload would fail that recomputation (spoofed
    in flight, or re-served from a tainted store).

    Returns ``(ok_take (Rb, M) bool — chunks that verified and may be
    committed, rejects (Rb, R) i32 — rejected chunk count charged to each
    (receiver, sender) link)``. With ``bad_link`` all-False this is bitwise
    ``(any(take_link, axis=1), zeros)`` — the honest path is unchanged.
    """
    rej = take_link & bad_link
    ok_take = jnp.any(take_link & ~bad_link, axis=1)
    return ok_take, jnp.sum(rej.astype(jnp.int32), axis=2)
