"""Pallas wire-compression kernels for the gossiped model bank.

The bank prices every chunk transfer at Table-I bandwidths
(``repro.net.bank.chunk_step``), and on the 1 Mbps constrained class raw
f32 chunks saturate the links — the communication-efficiency axis every
related DAG-FL system optimizes. This module is the codec layer that sits
between a committer and the wire: block-wise symmetric quantization
(int8 / int4, per-block scales) and top-k delta sparsification against
the receiver's last-held version of the same slot. Both are masked
reductions over fixed ``(num_blocks, block)`` shapes in the established
kernel/oracle/dispatch mold (``gossip_merge``, ``chunk_transfer``):

``quant_blocks``   per 128-element block: ``scale = amax / qmax`` (1.0 on
                   an all-zero block so padding round-trips exactly) and
                   ``codes = clip(round(x / scale), -qmax, qmax)``. The
                   Pallas kernel emits int32 codes (TPU-native lane type,
                   the ``chunk_dedup`` convention) cast to int8 outside;
                   int4 uses the same int8 carrier with ``qmax = 7`` and
                   is PRICED at two codes per byte by ``wire_ratio``.

``topk_blocks``    per block keep the k largest-|delta| elements, zero the
                   rest. Rank is the deterministic dense reduction
                   ``rank_i = #{j : |d_j| > |d_i| or (|d_j| = |d_i| and
                   j < i)}`` — no sort, no data-dependent shapes, ties
                   break toward the earlier index, and zeros never beat a
                   nonzero, so ``k >= nnz(block)`` reproduces the delta
                   exactly (property-tested).

``DeltaCodec``     the frozen (hashable — it rides the jit-factory cache
                   keys) pytree codec: ``encode(params, base)`` maps a
                   commit's payload to its wire form — a pytree whose
                   leaves are exactly the bytes that cross the link, so
                   ``bank.chunk_digests`` over it gives digests of the
                   ENCODED bytes and the PR-7 spoof defense verifies what
                   was actually transmitted — and ``decode(enc, base)``
                   inverts it against the receiver's last-held slot
                   content. ``wire_ratio()`` is the encoded/raw byte
                   ratio the engines use to price chunks
                   (``codec_key`` maps every ratio-1.0 codec to ``None``
                   so the identity path keeps the literal PR-7 programs).

Equivalence pallas-vs-ref, the round-trip error bound, and the
identity-codec bitwise property live in ``tests/test_delta_codec.py``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

BLOCK = 128    # codec block length (lane-aligned: the f32 TPU tile is (8, 128))
BLOCK_T = 8    # block rows per pallas grid step

_QMAX = {"int8": 127, "int4": 7}


def _quant_kernel(x_ref, codes_ref, scale_ref, *, qmax):
    # x_ref: (bt, B) f32 — a slab of codec blocks
    # codes_ref: (bt, B) i32, scale_ref: (bt, 1) f32
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0.0, amax / qmax, 1.0)
    codes_ref[...] = jnp.clip(
        jnp.round(x / scale), -qmax, qmax
    ).astype(jnp.int32)
    scale_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("qmax", "block_t", "interpret"))
def quant_blocks_pallas(
    x: jnp.ndarray,          # (nb, B) f32 — one codec block per row
    qmax: int,
    block_t: int = BLOCK_T,
    interpret: bool = True,
) -> tuple:
    """Blocked symmetric quantization — the Pallas reduction.

    Grid step i quantizes a ``block_t``-row slab. Padding rows are zero,
    so their scale is exactly 1.0 and their codes 0 — sliced off outside.
    Returns ``(codes (nb, B) int8, scales (nb,) f32)``.
    """
    nb, b = x.shape
    bt = min(block_t, nb) if nb else block_t
    pad = (-nb) % bt
    xp = jnp.pad(jnp.asarray(x, jnp.float32), ((0, pad), (0, 0)))
    codes, scales = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=float(qmax)),
        grid=((nb + pad) // bt,),
        in_specs=[pl.BlockSpec((bt, b), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, b), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb + pad, b), jnp.int32),
            jax.ShapeDtypeStruct((nb + pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return codes[:nb].astype(jnp.int8), scales[:nb, 0]


def _topk_kernel(d_ref, out_ref, *, k):
    # d_ref/out_ref: (bt, B) f32 — keep the k largest-|d| per row
    d = d_ref[...]
    a = jnp.abs(d)
    b = a.shape[-1]
    jj = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    ii = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    gt = a[:, :, None] > a[:, None, :]                    # [n, j, i]
    eq = (a[:, :, None] == a[:, None, :]) & (jj < ii)[None]
    rank = jnp.sum((gt | eq).astype(jnp.int32), axis=1)   # (bt, B)
    out_ref[...] = jnp.where(rank < k, d, 0.0)


@functools.partial(jax.jit, static_argnames=("k", "block_t", "interpret"))
def topk_blocks_pallas(
    d: jnp.ndarray,          # (nb, B) f32 — one delta block per row
    k: int,
    block_t: int = BLOCK_T,
    interpret: bool = True,
) -> jnp.ndarray:
    """Per-block top-k-|delta| masking — the Pallas reduction.

    The rank comparison materializes a ``(block_t, B, B)`` tensor, which
    is why ``block_t`` stays small. Returns the dense masked delta.
    """
    nb, b = d.shape
    bt = min(block_t, nb) if nb else block_t
    pad = (-nb) % bt
    dp = jnp.pad(jnp.asarray(d, jnp.float32), ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_topk_kernel, k=int(k)),
        grid=((nb + pad) // bt,),
        in_specs=[pl.BlockSpec((bt, b), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb + pad, b), jnp.float32),
        interpret=interpret,
    )(dp)
    return out[:nb]


def quant_blocks(x, qmax: int, impl: str = None, block_t: int = BLOCK_T,
                 interpret: bool = None) -> tuple:
    """Blocked quantization with backend dispatch (the ``chunk_dedup`` rule).

    ``impl``: "pallas" forces the kernel (interpreted off-TPU), "lax" the
    pure-lax oracle; None picks pallas on TPU, lax elsewhere.
    """
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    if impl == "lax":
        return ref.quant_blocks_ref(x, qmax)
    if impl != "pallas":
        raise ValueError(f"unknown quant_blocks impl: {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return quant_blocks_pallas(x, qmax, block_t=block_t, interpret=interpret)


def topk_blocks(d, k: int, impl: str = None, block_t: int = BLOCK_T,
                interpret: bool = None) -> jnp.ndarray:
    """Per-block top-k masking with backend dispatch."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    if impl == "lax":
        return ref.topk_blocks_ref(d, k)
    if impl != "pallas":
        raise ValueError(f"unknown topk_blocks impl: {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return topk_blocks_pallas(d, k, block_t=block_t, interpret=interpret)


def _to_blocks(flat: jnp.ndarray, block: int) -> jnp.ndarray:
    """Zero-pad a flat vector up to whole codec blocks: (n,) -> (nb, block)."""
    n = flat.shape[0]
    nb = max(1, -(-n // block))
    pad = nb * block - n
    return jnp.pad(jnp.asarray(flat, jnp.float32), (0, pad)).reshape(nb, block)


@dataclass(frozen=True)
class DeltaCodec:
    """The wire codec for bank commits (frozen + hashable: it rides the
    ``lru_cache`` keys of the bank jit factories alongside obs/faults).

    ``kind`` — "none" (explicit identity: encode/decode are passthrough
    and the engines keep the literal uncompressed programs), "int8" /
    "int4" (blocked symmetric quantization; int4 codes travel two per
    byte, carried one-per-int8 in simulation), or "topk" (per-block
    top-k delta vs the receiver's last-held slot content);
    ``block`` — codec block length (per-block scale / top-k granularity);
    ``topk_frac`` — fraction of each block kept by "topk";
    ``impl`` — kernel dispatch override ("pallas"/"lax"/None), same
    semantics as ``BankGossipConfig.impl``.
    """

    kind: str = "int8"
    block: int = BLOCK
    topk_frac: float = 0.0625
    impl: Optional[str] = None

    def __post_init__(self):
        if self.kind not in ("none", "int8", "int4", "topk"):
            raise ValueError(f"unknown codec kind: {self.kind!r}")

    @property
    def is_identity(self) -> bool:
        return self.kind == "none"

    def topk_k(self) -> int:
        """Elements kept per block by the "topk" kind (at least 1)."""
        return max(1, int(round(self.topk_frac * self.block)))

    def wire_ratio(self) -> float:
        """Encoded / raw wire bytes per chunk — the pricing the engines
        fold into ``chunk_bytes``.

        Raw: 4 bytes per f32 element. int8: one code byte per element
        plus a 4-byte f32 scale per block. int4: half a code byte per
        element plus the scale. topk: 8 bytes (4-byte index + 4-byte
        value) per kept element — the sparse framing the dense masked
        array stands in for.
        """
        if self.kind == "none":
            return 1.0
        if self.kind == "int8":
            return (self.block + 4.0) / (4.0 * self.block)
        if self.kind == "int4":
            return (self.block / 2.0 + 4.0) / (4.0 * self.block)
        return min(1.0, 8.0 * self.topk_k() / (4.0 * self.block))

    def encode(self, params, base):
        """Payload pytree -> wire pytree.

        The wire pytree's leaves are exactly what crosses the link, so
        digesting it (``bank.chunk_digests`` flattens leaves) digests the
        ENCODED bytes. ``base`` is the receiver's last-held content of
        the same slot ("topk" encodes the delta against it; quant kinds
        ignore it — their encoding is base-free, which is what keeps
        content-addressed dedup of identical payloads alive).
        """
        if self.kind == "none":
            return params
        leaves, treedef = jax.tree_util.tree_flatten(params)
        if self.kind in ("int8", "int4"):
            qmax = _QMAX[self.kind]
            enc = [
                quant_blocks(_to_blocks(jnp.ravel(l), self.block), qmax,
                             impl=self.impl)
                for l in leaves
            ]
            return {
                "codes": jax.tree_util.tree_unflatten(
                    treedef, [c for c, _ in enc]),
                "scales": jax.tree_util.tree_unflatten(
                    treedef, [s for _, s in enc]),
            }
        k = self.topk_k()
        base_leaves = jax.tree_util.tree_leaves(base)
        deltas = [
            topk_blocks(
                _to_blocks(
                    jnp.ravel(l).astype(jnp.float32)
                    - jnp.ravel(b).astype(jnp.float32),
                    self.block,
                ),
                k, impl=self.impl,
            )
            for l, b in zip(leaves, base_leaves)
        ]
        return {"delta": jax.tree_util.tree_unflatten(treedef, deltas)}

    def decode(self, enc, base):
        """Wire pytree -> payload pytree (shape/dtype of ``base``)."""
        if self.kind == "none":
            return enc

        def _restore(flat, b):
            return flat[: b.size].reshape(b.shape)

        if self.kind in ("int8", "int4"):
            return jax.tree_util.tree_map(
                lambda c, s, b: _restore(
                    jnp.ravel(c.astype(jnp.float32) * s[:, None]), b
                ).astype(b.dtype),
                enc["codes"], enc["scales"], base,
            )
        return jax.tree_util.tree_map(
            lambda d, b: (
                b.astype(jnp.float32) + _restore(jnp.ravel(d), b)
            ).astype(b.dtype),
            enc["delta"], base,
        )


def codec_key(codec: Optional[DeltaCodec]) -> Optional[DeltaCodec]:
    """The static codec key the engines hand their jit factories.

    Every codec that prices like raw bytes (``None``, kind "none", or a
    degenerate ratio-1.0 configuration) maps to ``None``, so the factories
    keep the LITERAL uncompressed program — multiplying ``chunk_bytes``
    by 1.0 would change the XLA graph and break the bitwise-identity
    contract the identity-codec tests pin.
    """
    if codec is None or codec.wire_ratio() == 1.0:
        return None
    return codec
