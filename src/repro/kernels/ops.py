"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; TPU is
the compilation TARGET), and False on real TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.chunk_transfer import chunk_dedup, transfer_select
from repro.kernels.delta_codec import DeltaCodec, quant_blocks, topk_blocks
from repro.kernels.event_pop import event_pop
from repro.kernels.fedavg import fedavg_pallas
from repro.kernels.flash_attention import decode_attention_pallas, flash_attention_pallas
from repro.kernels.gossip_merge import gossip_winner, gossip_winner_nbr
from repro.kernels.hist_bincount import hist_bincount_pallas
from repro.kernels.model_distance import model_distance_pallas
from repro.kernels.wkv import wkv_pallas
from repro.kernels import ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def fedavg(weights: jnp.ndarray, models: jnp.ndarray, block_n: int = 16384) -> jnp.ndarray:
    """Eq. (1) weighted model average. weights (k,), models (k, N) -> (N,)."""
    return fedavg_pallas(weights, models, block_n=block_n, interpret=_interpret_default())


def model_distance(models: jnp.ndarray, block_n: int = 16384) -> jnp.ndarray:
    """Pairwise squared-L2 distances (k, N) -> (k, k)."""
    return model_distance_pallas(models, block_n=block_n, interpret=_interpret_default())


def flash_attention(q, k, v, window: int = 0, block_q: int = 128, block_k: int = 128):
    """Causal (optionally sliding-window) GQA attention (B,H,S,hd)."""
    return flash_attention_pallas(
        q, k, v, window=window, block_q=block_q, block_k=block_k,
        interpret=_interpret_default(),
    )


def decode_attention(q, k, v, lengths, block_s: int = 512):
    """Single-token GQA decode attention against an S-slot cache."""
    return decode_attention_pallas(
        q, k, v, lengths, block_s=block_s, interpret=_interpret_default()
    )


def wkv(r, k, v, logw, u, chunk: int = 32):
    """Chunk-parallel RWKV6 WKV recurrence (B,T,H,hd)."""
    return wkv_pallas(r, k, v, logw, u, chunk=chunk, interpret=_interpret_default())


def hist_bincount(idx, weights, num_bins: int, impl: str = None,
                  block_m: int = 512):
    """Weighted bincount for the streaming histograms (m,) -> (num_bins,).

    ``impl``: None picks "pallas" on TPU and the pure-lax scatter-add
    oracle elsewhere (the ``event_pop`` dispatch rule) — in-loop
    histogram updates stay cheap on CPU hosts.
    """
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    if impl == "lax":
        return ref.hist_bincount_ref(idx, weights, num_bins)
    if impl != "pallas":
        raise ValueError(f"unknown hist_bincount impl: {impl!r}")
    return hist_bincount_pallas(
        idx, weights, num_bins, block_m=block_m,
        interpret=_interpret_default(),
    )


__all__ = [
    "fedavg", "model_distance", "flash_attention", "decode_attention", "wkv",
    "gossip_winner", "gossip_winner_nbr", "chunk_dedup", "transfer_select",
    "event_pop", "hist_bincount", "DeltaCodec", "quant_blocks",
    "topk_blocks", "ref",
]
