"""Pallas kernel: fused per-receiver gossip-merge winner selection.

The anti-entropy hot spot (``repro.net.gossip``): every sync tick each of
the R nodes folds its active neighbors' DAG replicas into its own. The
row-wise merge rule (``repro.core.dag.merge``) is commutative/associative —
per ledger row the surviving transaction is the occupied candidate with the
lexicographically largest ``(publish_time, publisher)`` key, and the
``approval_count`` of that identity is the monotone max over every candidate
holding it — so the whole O(N) sender fold collapses into one masked
reduction over the sender axis (O(log N) depth, no N² ``DagState``
intermediates).

This module is ARRAY-level on purpose: it sees only the key/counter columns
``(publish_time, publisher, approval_count)`` plus the candidate mask, and
returns per-(receiver, row) winner *indices* — ``repro.core.dag.merge_select``
turns those into the merged ``DagState`` (payload gather + watermark max).
Keeping ``DagState`` out of this layer avoids an import cycle
(``repro.core.aggregation`` already imports ``repro.kernels.ops``).

Outputs, per receiver i and ledger row r (senders j masked by ``mask[i, j]``,
which INCLUDES the diagonal — the receiver itself is a candidate):

  src[i, r]   index j of the winning sender (i itself when the local row
              already holds the winning identity, or when no candidate is
              occupied — merge keeps the local row in both cases);
  ac[i, r]    max ``approval_count`` over candidates holding the winning
              identity (CRDT union-by-max; 0 when every candidate is empty,
              which is bitwise the empty row's counter).

Ties on the key prefer the receiver's own replica, then the lowest sender
index — exactly the order the PR-1 ``vmap``-over-``scan`` fold visited
candidates, so the fused round is bitwise-identical to it (tested by
``tests/test_gossip_merge.py``).

The kernel tiles (receivers x cap) — grid step (i, c) loads the (R, block_c)
key slab once and reduces it against receiver i's mask column. On this
CPU container ``interpret=True`` drives the same kernel through the Pallas
interpreter; ``repro.kernels.ref.gossip_winner_ref`` is the pure-lax
fallback/oracle that production CPU paths route through.

Since the mesh-sharded round (PR 3), every entry point is BLOCK-addressed:
``mask`` may be a rectangular (Rr, R) receiver block of the full sender
axis — a shard reduces its own receivers against the all-gathered senders —
with the block's global position supplied as ``row_ids`` (per-receiver
sender ids, lax paths) or ``row_offset`` (contiguous block start, the
Pallas kernel's (1, 1) scalar input), so self-tie-preference and the
all-empty fallback keep addressing the receiver's own global row.
``row_ids=None`` / ``row_offset=0`` is the identity block (receiver i IS
sender i — the single-device round). ``repro.kernels.chunk_transfer`` is
the sibling reduction for bank gossip: chunk-availability dedup + transfer
selection in the same masked-reduction mold.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

BLOCK_C = 256   # (R, 256) i32/f32 slabs x 4 inputs: ~400 KiB VMEM @ R=100


def _winner_kernel(off_ref, mask_ref, t_ref, p_ref, ac_ref, src_ref, ac_out_ref):
    # off_ref: (1, 1) i32 — global sender index of the block's receiver 0
    # mask_ref: (R, 1) i32 — receiver i's candidate column (self included)
    # t_ref/p_ref/ac_ref: (R, bc) — all senders' key/counter slabs
    # src_ref/ac_out_ref: (1, bc) — winner index + merged counter for row i
    i = pl.program_id(0)
    gid = i + off_ref[0, 0]                                  # global receiver id
    r = t_ref.shape[0]
    m = mask_ref[...] != 0                                   # (R, 1)
    p = p_ref[...]
    valid = m & (p >= 0)                                     # occupied candidates
    tm = jnp.where(valid, t_ref[...], -jnp.inf)
    best_t = jnp.max(tm, axis=0, keepdims=True)              # (1, bc)
    tie = valid & (tm == best_t)
    pm = jnp.where(tie, p, jnp.iinfo(jnp.int32).min)
    best_p = jnp.max(pm, axis=0, keepdims=True)
    win = tie & (pm == best_p)                               # winning identity
    idx = jax.lax.broadcasted_iota(jnp.int32, win.shape, 0)
    first = jnp.min(jnp.where(win, idx, r), axis=0, keepdims=True)
    self_win = jnp.any(win & (idx == gid), axis=0, keepdims=True)
    src = jnp.where(self_win | (first >= r), gid, first)     # first>=r: all empty
    src_ref[...] = src.astype(jnp.int32)
    ac_out_ref[...] = jnp.max(jnp.where(win, ac_ref[...], 0), axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def gossip_winner_pallas(
    publish_time: jnp.ndarray,    # (R, cap) f32
    publisher: jnp.ndarray,       # (R, cap) i32
    approval_count: jnp.ndarray,  # (R, cap) i32
    mask: jnp.ndarray,            # (Rr, R) bool — mask[i, j]: i hears j
    block_c: int = BLOCK_C,
    interpret: bool = True,
    row_offset=0,                 # () i32 — global sender index of receiver 0
) -> tuple:
    """(src, ac): per-row winner index and merged approval counter.

    ``mask`` may be a rectangular receiver block: a mesh shard
    (``repro.net.mesh``) computes its R/shards receivers against the
    all-gathered sender axis, passing the block's global start index as
    ``row_offset`` so self-tie-preference and the all-empty fallback keep
    addressing the receiver's own global row.
    """
    r, c = publish_time.shape
    rr = mask.shape[0]
    bc = min(block_c, c) if c else block_c
    pad = (-c) % bc
    t = jnp.pad(publish_time, ((0, 0), (0, pad)))
    p = jnp.pad(publisher, ((0, 0), (0, pad)), constant_values=-1)
    ac = jnp.pad(approval_count, ((0, 0), (0, pad)))
    off = jnp.asarray(row_offset, jnp.int32)
    # the receiver is always a candidate (see ref.gossip_winner_ref)
    rows = jnp.arange(rr, dtype=jnp.int32)
    mask = jnp.asarray(mask).at[rows, off + rows].set(True)
    mask_t = mask.astype(jnp.int32).T                        # column i = receiver i

    src, ac_out = pl.pallas_call(
        _winner_kernel,
        grid=(rr, (c + pad) // bc),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, cb: (0, 0)),
            pl.BlockSpec((r, 1), lambda i, cb: (0, i)),
            pl.BlockSpec((r, bc), lambda i, cb: (0, cb)),
            pl.BlockSpec((r, bc), lambda i, cb: (0, cb)),
            pl.BlockSpec((r, bc), lambda i, cb: (0, cb)),
        ],
        out_specs=[
            pl.BlockSpec((1, bc), lambda i, cb: (i, cb)),
            pl.BlockSpec((1, bc), lambda i, cb: (i, cb)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rr, c + pad), jnp.int32),
            jax.ShapeDtypeStruct((rr, c + pad), jnp.int32),
        ],
        interpret=interpret,
    )(off.reshape(1, 1), mask_t, t, p, ac)
    return src[:, :c], ac_out[:, :c]


def gossip_winner_nbr(
    publish_time: jnp.ndarray,    # (R, cap) f32
    publisher: jnp.ndarray,       # (R, cap) i32
    approval_count: jnp.ndarray,  # (R, cap) i32
    nbr_idx: jnp.ndarray,         # (Rr, D) i32 candidate sender lists
    nbr_act: jnp.ndarray,         # (Rr, D) bool candidate activity
    row_ids: jnp.ndarray = None,  # (Rr,) i32 global sender index per receiver
) -> tuple:
    """Degree-compressed winner selection — the CPU/sparse-overlay fast path.

    Same rule as ``ref.gossip_winner_ref`` but candidates are gathered from
    per-receiver lists instead of masked out of the full sender axis:
    O(R * D * cap) work for max degree D instead of O(R^2 * cap), which is
    what makes the fused round beat the sequential fold on sparse overlays
    even on a single CPU core. ``nbr_idx`` rows may contain duplicates
    (padding); a receiver that should be its own candidate (always, in
    gossip) must appear in its list with ``nbr_act`` true. ``row_ids`` maps
    a rectangular receiver block to its global sender indices (a mesh shard
    reduces its own receivers against the gathered sender axis; None means
    receiver i is sender i). Equivalence with the dense oracle is
    property-tested.
    """
    r = publish_time.shape[0]
    t = publish_time[nbr_idx]                                # (Rr, D, cap)
    p = publisher[nbr_idx]
    a = approval_count[nbr_idx]
    valid = nbr_act[:, :, None] & (p >= 0)
    tm = jnp.where(valid, t, -jnp.inf)
    best_t = jnp.max(tm, axis=1)                             # (Rr, cap)
    tie = valid & (tm == best_t[:, None])
    pm = jnp.where(tie, p, jnp.iinfo(jnp.int32).min)
    best_p = jnp.max(pm, axis=1)
    win = tie & (pm == best_p[:, None])
    first = jnp.min(jnp.where(win, nbr_idx[:, :, None], r), axis=1)
    if row_ids is None:
        rows = jnp.arange(nbr_idx.shape[0], dtype=jnp.int32)[:, None]
        own_time, own_pub = publish_time, publisher
    else:
        rows = jnp.asarray(row_ids, jnp.int32)[:, None]
        own_time, own_pub = publish_time[rows[:, 0]], publisher[rows[:, 0]]
    self_act = jnp.any(nbr_act & (nbr_idx == rows), axis=1)
    self_win = (
        self_act[:, None]
        & (own_pub >= 0)
        & (own_time == best_t)
        & (own_pub == best_p)
    )
    src = jnp.where(self_win | (first >= r), rows, first)
    ac = jnp.max(jnp.where(win, a, 0), axis=1)
    return src.astype(jnp.int32), ac.astype(jnp.int32)


def gossip_winner(
    publish_time, publisher, approval_count, mask,
    impl: str = None, block_c: int = BLOCK_C, interpret: bool = None,
    row_offset=None,
):
    """Winner-selection reduction with backend dispatch.

    ``impl``: "pallas" forces the kernel (interpreted off-TPU), "lax" the
    pure-lax fallback; None picks pallas on TPU, lax elsewhere (the Pallas
    interpreter's per-grid-step loop is slower than one fused lax reduction
    on CPU). ``row_offset`` (() i32) marks ``mask`` as a contiguous receiver
    block starting at that global sender index — the mesh-sharded round.
    """
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    if impl == "lax":
        row_ids = None
        if row_offset is not None:
            rr = mask.shape[0]
            row_ids = jnp.asarray(row_offset, jnp.int32) + jnp.arange(rr, dtype=jnp.int32)
        return ref.gossip_winner_ref(
            publish_time, publisher, approval_count, mask, row_ids=row_ids
        )
    if impl != "pallas":
        raise ValueError(f"unknown gossip_winner impl: {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return gossip_winner_pallas(
        publish_time, publisher, approval_count, mask,
        block_c=block_c, interpret=interpret,
        row_offset=0 if row_offset is None else row_offset,
    )
