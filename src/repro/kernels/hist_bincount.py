"""Blocked weighted bincount — the streaming-histogram scatter-add.

``repro.obs.hist`` folds every in-loop latency sample into fixed
log-spaced bins; the hot step is ``counts[idx[i]] += w[i]`` over a flat
batch of pre-binned indices. On TPU a data-dependent scatter serializes
badly, so the kernel walks the batch in ``(1, block_m)`` slabs over a
sequential grid and accumulates a one-hot-masked partial sum into a
single resident ``(1, num_bins)`` output block (the ``event_pop``
blocking pattern: every grid step maps to output block (0, 0), with a
``pl.when(b == 0)`` init).

Out-of-range indices are DROPPED (no lane of the one-hot compare
matches) — the caller bins with ``hist.bin_index`` which already clamps
into [0, bins], so a dropped index can only mean a caller bug, never a
silently-corrupted neighbouring bin.

The pure-lax oracle lives in ``kernels/ref.py`` (``hist_bincount_ref``)
and the dispatcher in ``kernels/ops.py`` (``hist_bincount``), following
the ``gossip_winner``/``delta_codec`` convention.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 512
_LANES = 128


def _bincount_kernel(idx_ref, w_ref, out_ref):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...].astype(jnp.int32)        # (1, bm)
    w = w_ref[...].astype(jnp.int32)            # (1, bm)
    bm = idx.shape[1]
    nb = out_ref.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, nb), 1)
    onehot = (idx.reshape(bm, 1) == cols).astype(jnp.int32)
    out_ref[...] += jnp.sum(
        onehot * w.reshape(bm, 1), axis=0, keepdims=True
    )


@functools.partial(
    jax.jit, static_argnames=("num_bins", "block_m", "interpret")
)
def hist_bincount_pallas(idx, weights, num_bins, block_m=BLOCK_M,
                         interpret=True):
    """(num_bins,) i32 weighted bincount of ``idx`` via the blocked kernel.

    ``idx`` i32 (m,) in [0, num_bins); ``weights`` i32 (m,). The batch is
    padded to a block multiple with an out-of-range index (dropped by the
    one-hot compare) and the bin axis to the 128-lane boundary.
    """
    (m,) = idx.shape
    bm = min(block_m, max(m, 1))
    m_pad = -(-max(m, 1) // bm) * bm
    nb_pad = -(-num_bins // _LANES) * _LANES
    idx = jnp.full((m_pad,), num_bins, jnp.int32).at[:m].set(
        idx.astype(jnp.int32)
    )
    w = jnp.zeros((m_pad,), jnp.int32).at[:m].set(
        weights.astype(jnp.int32)
    )
    nblocks = m_pad // bm
    out = pl.pallas_call(
        _bincount_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, bm), lambda b: (0, b)),
            pl.BlockSpec((1, bm), lambda b: (0, b)),
        ],
        out_specs=pl.BlockSpec((1, nb_pad), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, nb_pad), jnp.int32),
        interpret=interpret,
    )(idx.reshape(1, m_pad), w.reshape(1, m_pad))
    return out[0, :num_bins]
