"""Pallas kernel: masked lexicographic argmin over the event queue.

The continuous-time hot spot (``repro.net.events``): every iteration of the
device-resident event loop must find the next event to fire — the valid
queue slot with the smallest ``(time, kind, seq)`` key. That is the
``gossip_merge`` reduction with min in place of max: a masked lexicographic
reduction over one axis, no data-dependent shapes, so the whole horizon
stays inside one jitted ``lax.while_loop``.

The kernel tiles the queue into ``(1, block_q)`` slabs — grid step ``b``
reduces its slab to a local ``(time, kind, seq, idx)`` best and folds it
into a running best held in the output refs (TPU grid steps execute
sequentially, the same accumulation pattern as the flash-attention
running-max). ``repro.kernels.ref.event_pop_ref`` is the pure-lax
oracle/CPU fast path; equivalence is property-tested in
``tests/test_net_events.py``. On this CPU container ``interpret=True``
drives the kernel through the Pallas interpreter.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

BLOCK_Q = 512   # 4 x (1, 512) i32/f32 slabs per step: ~8 KiB VMEM


def _pop_kernel(t_ref, k_ref, s_ref, v_ref, bt_ref, ba_ref):
    # t/k/s/v_ref: (1, bq) — this step's queue slab (time, kind, seq, valid)
    # bt_ref: (1, 1) f32 running best time; ba_ref: (1, 3) i32 running best
    # (kind, seq, global idx) — every grid step maps to the same output
    # block, so the fold accumulates across the sequential grid.
    b = pl.program_id(0)
    bq = t_ref.shape[1]
    imax = jnp.iinfo(jnp.int32).max
    v = v_ref[...] != 0
    t = jnp.where(v, t_ref[...], jnp.inf)
    bt = jnp.min(t)
    tie = v & (t == bt)
    kk = jnp.where(tie, k_ref[...], imax)
    bk = jnp.min(kk)
    tie = tie & (kk == bk)
    ss = jnp.where(tie, s_ref[...], imax)
    bs = jnp.min(ss)
    tie = tie & (ss == bs)
    iota = jax.lax.broadcasted_iota(jnp.int32, tie.shape, 1)
    bi = jnp.min(jnp.where(tie, iota, imax))
    bi = jnp.where(bi == imax, imax, bi + b * bq)   # imax = empty sentinel

    @pl.when(b == 0)
    def _init():
        bt_ref[0, 0] = jnp.inf
        ba_ref[0, 0] = imax
        ba_ref[0, 1] = imax
        ba_ref[0, 2] = imax

    ct, ck, cs = bt_ref[0, 0], ba_ref[0, 0], ba_ref[0, 1]
    better = (bt < ct) | (
        (bt == ct) & ((bk < ck) | ((bk == ck) & (bs < cs)))
    )
    bt_ref[0, 0] = jnp.where(better, bt, ct)
    ba_ref[0, 0] = jnp.where(better, bk, ck)
    ba_ref[0, 1] = jnp.where(better, bs, cs)
    ba_ref[0, 2] = jnp.where(better, bi, ba_ref[0, 2])


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def event_pop_pallas(
    time: jnp.ndarray,      # (Q,) f32
    kind: jnp.ndarray,      # (Q,) i32
    seq: jnp.ndarray,       # (Q,) i32
    valid: jnp.ndarray,     # (Q,) bool
    block_q: int = BLOCK_Q,
    interpret: bool = True,
):
    """(idx () i32, found () bool) — the queue-head reduction as a kernel.

    Padding slots arrive invalid (they can never win); an all-invalid queue
    leaves the idx sentinel untouched, which the wrapper folds into
    ``found`` so the outputs are bitwise ``ref.event_pop_ref``.
    """
    q = time.shape[0]
    bq = min(block_q, q) if q else block_q
    pad = (-q) % bq
    nb = (q + pad) // bq
    t = jnp.pad(jnp.asarray(time, jnp.float32), (0, pad),
                constant_values=jnp.inf).reshape(nb, bq)
    k = jnp.pad(jnp.asarray(kind, jnp.int32), (0, pad)).reshape(nb, bq)
    s = jnp.pad(jnp.asarray(seq, jnp.int32), (0, pad)).reshape(nb, bq)
    v = jnp.pad(jnp.asarray(valid, jnp.int32), (0, pad)).reshape(nb, bq)

    _, ba = pl.pallas_call(
        _pop_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, bq), lambda b: (b, 0)) for _ in range(4)],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b: (0, 0)),
            pl.BlockSpec((1, 3), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 3), jnp.int32),
        ],
        interpret=interpret,
    )(t, k, s, v)
    found = ba[0, 2] != jnp.iinfo(jnp.int32).max
    idx = jnp.where(found, jnp.minimum(ba[0, 2], max(q - 1, 0)), 0)
    return idx.astype(jnp.int32), found


def event_pop(time, kind, seq, valid, impl: Optional[str] = None,
              block_q: int = BLOCK_Q, interpret: Optional[bool] = None):
    """Queue-head selection with backend dispatch.

    ``impl``: "pallas" forces the kernel (interpreted off-TPU), "lax" the
    pure-lax oracle; None picks pallas on TPU, lax elsewhere — the same
    rule as ``gossip_merge.gossip_winner``.
    """
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "lax"
    if impl == "lax":
        return ref.event_pop_ref(time, kind, seq, valid)
    if impl != "pallas":
        raise ValueError(f"unknown event_pop impl: {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return event_pop_pallas(time, kind, seq, valid,
                            block_q=block_q, interpret=interpret)
