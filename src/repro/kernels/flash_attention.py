"""Pallas TPU flash attention: causal/sliding-window prefill + GQA decode.

Prefill kernel: grid (B, H, num_q_blocks, num_k_blocks); online-softmax
accumulators (m, l, acc) live in VMEM scratch and persist across the
innermost k-block dimension; fully-masked k-blocks (beyond causal frontier
or outside the sliding window) skip their compute. Block shapes are
(8,128)-aligned; the MXU sees (bq, hd) x (hd, bk) matmuls.

Decode kernel: one query per (batch, kv-head) group against an S-slot cache,
grid (B, KV, num_s_blocks), same online softmax; GQA groups share the kv
block so each cache byte is read once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, bq, bk, nk, window, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_lo = iq * bq
    k_lo = ik * bk
    # causal frontier: any k in block usable by any q in block?
    needed = k_lo <= q_lo + bq - 1
    if window:
        needed = jnp.logical_and(needed, k_lo + bk - 1 > q_lo - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                        # (bq, bk)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos <= q_pos
        if window:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]                                # (bq, 128) replicated
        l_prev = l_s[...]
        m_cur = jnp.max(s, axis=1)[:, None]              # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 128)
        p = jnp.exp(s - m_new[:, :1])                    # (bq, bk)
        l_new = alpha * l_prev + jnp.broadcast_to(
            jnp.sum(p, axis=1)[:, None], l_prev.shape
        )
        acc_s[...] = acc_s[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_s[...] = m_new
        l_s[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = l_s[...][:, :1]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0, 0] = (acc_s[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jnp.ndarray,   # (B, H, S, hd)
    k: jnp.ndarray,   # (B, KV, S, hd)
    v: jnp.ndarray,   # (B, KV, S, hd)
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, S, hd = q.shape
    KV = k.shape[1]
    bq, bk = min(block_q, S), min(block_k, S)
    pad_q = (-S) % bq
    pad_k = (-S) % bk
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq, Sk = S + pad_q, S + pad_k
    nq, nk = Sq // bq, Sk // bk
    group = H // KV
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, window=window, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, bs, ns, scale):
    isb = pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    length = len_ref[0, 0]
    s_lo = isb * bs

    @pl.when(s_lo < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                        # (G, bs)
        pos = s_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev, l_prev = m_s[...], l_s[...]
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1])
        l_s[...] = alpha * l_prev + jnp.broadcast_to(jnp.sum(p, axis=1)[:, None], l_prev.shape)
        acc_s[...] = acc_s[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_s[...] = m_new

    @pl.when(isb == ns - 1)
    def _finalize():
        denom = l_s[...][:, :1]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0, 0] = (acc_s[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(
    q: jnp.ndarray,        # (B, H, hd)
    k: jnp.ndarray,        # (B, S, KV, hd)
    v: jnp.ndarray,        # (B, S, KV, hd)
    lengths: jnp.ndarray,  # (B,) int32
    block_s: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    bs = min(block_s, S)
    pad = (-S) % bs
    kk = jnp.moveaxis(k, 2, 1)                           # (B, KV, S, hd)
    vv = jnp.moveaxis(v, 2, 1)
    if pad:
        kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, 0), (0, pad), (0, 0)))
    ns = (S + pad) // bs
    qg = q.reshape(B, KV, G, hd)
    lens = lengths.reshape(B, 1).astype(jnp.int32)
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_decode_kernel, bs=bs, ns=ns, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, g, s: (b, 0)),
            pl.BlockSpec((1, 1, G, hd), lambda b, g, s: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, g, s: (b, g, s, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, g, s: (b, g, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, g, s: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qg, kk, vv)
    return out.reshape(B, H, hd)
