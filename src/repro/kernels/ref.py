"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def fedavg_ref(weights: jnp.ndarray, models: jnp.ndarray) -> jnp.ndarray:
    """Eq. (1): weighted average of k flattened models.

    weights: (k,) f32, models: (k, N) -> (N,) in models.dtype.
    """
    out = jnp.einsum("k,kn->n", weights.astype(jnp.float32), models.astype(jnp.float32))
    return out.astype(models.dtype)


def model_distance_ref(models: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared-L2 distance matrix between k flattened models.

    models: (k, N) -> (k, k) f32. Used by anomaly detection (parameter-space
    outlier scoring of tips).
    """
    x = models.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    return sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)


def mqa_attention_ref(
    q: jnp.ndarray,  # (B, H, S, hd)
    k: jnp.ndarray,  # (B, KV, S, hd)
    v: jnp.ndarray,  # (B, KV, S, hd)
    window: int = 0,
) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention, GQA head mapping."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    rep = H // KV
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = j <= i
    if window:
        ok = ok & (j > i - window)
    scores = jnp.where(ok, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vv)


def decode_attention_ref(
    q: jnp.ndarray,        # (B, H, hd)  one query per batch row
    k: jnp.ndarray,        # (B, S, KV, hd)
    v: jnp.ndarray,        # (B, S, KV, hd)
    lengths: jnp.ndarray,  # (B,) int32 — valid cache entries per row
) -> jnp.ndarray:
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    rep = H // KV
    kk = jnp.repeat(k, rep, axis=2)    # (B, S, H, hd)
    vv = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q, kk).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhs,bshd->bhd", probs, vv)
