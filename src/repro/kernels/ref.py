"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gossip_winner_ref(
    publish_time: jnp.ndarray,    # (R, cap) f32
    publisher: jnp.ndarray,       # (R, cap) i32, -1 = empty row
    approval_count: jnp.ndarray,  # (R, cap) i32
    mask: jnp.ndarray,            # (Rr, R) bool — receiver i hears sender j
    row_ids: jnp.ndarray = None,  # (Rr,) i32 — global sender index of each
                                  # receiver (None: receiver i IS sender i)
):
    """Per-row gossip-merge winner selection (oracle + CPU fast path).

    For each receiver i (row of ``mask``; the entry at the receiver's own
    sender index marks its local replica as a candidate) and ledger row r,
    the winner is the occupied candidate with the lexicographically largest
    ``(publish_time, publisher)`` key; the merged ``approval_count`` is the
    max over candidates holding that identity (CRDT union-by-max, see
    ``repro.core.dag.merge``). Key ties prefer the receiver itself, then the
    lowest sender index — the visit order of the sequential merge fold, so
    the reduction is bitwise-faithful to it.

    Returns (src (Rr, cap) i32 winner indices, ac (Rr, cap) i32 counters).
    ``mask`` may be rectangular: ``merge_all``'s union fold is the Rr=1
    case, and a mesh shard (``repro.net.mesh``) passes its receiver block's
    global indices via ``row_ids`` (receiver i of the block is sender
    ``row_ids[i]`` of the gathered axis).
    """
    mask = jnp.asarray(mask)
    rr, r = mask.shape
    rows = jnp.arange(rr, dtype=jnp.int32)
    recv = rows if row_ids is None else jnp.asarray(row_ids, jnp.int32)
    # the receiver is ALWAYS a candidate (the sequential fold starts from the
    # local replica) — force its own entry so a mask built from a
    # zero-diagonal adjacency cannot zero an occupied local row's counter
    mask = mask.at[rows, recv].set(True)
    occ = publisher >= 0
    valid = mask[:, :, None] & occ[None]                      # (Rr, R, cap)
    tm = jnp.where(valid, publish_time[None], -jnp.inf)
    best_t = jnp.max(tm, axis=1)                              # (Rr, cap)
    tie = valid & (tm == best_t[:, None])
    pm = jnp.where(tie, publisher[None], jnp.iinfo(jnp.int32).min)
    best_p = jnp.max(pm, axis=1)
    win = tie & (pm == best_p[:, None])                       # winning identity
    idx = jnp.arange(r, dtype=jnp.int32)[None, :, None]
    first = jnp.min(jnp.where(win, idx, r), axis=1)           # (Rr, cap)
    # the receiver's own replica is sender recv[i]; it wins ties iff it
    # holds the key
    self_win = (
        occ[recv]
        & (publish_time[recv] == best_t)
        & (publisher[recv] == best_p)
    )
    src = jnp.where(self_win | (first >= r), recv[:, None], first)
    ac = jnp.max(jnp.where(win, approval_count[None], 0), axis=1)
    return src.astype(jnp.int32), ac.astype(jnp.int32)


def event_pop_ref(
    time: jnp.ndarray,      # (Q,) f32 event fire times (finite on valid slots)
    kind: jnp.ndarray,      # (Q,) i32 event kind (repro.net.events ordering)
    seq: jnp.ndarray,       # (Q,) i32 insertion order (tie-break)
    valid: jnp.ndarray,     # (Q,) bool slot occupancy mask
):
    """Earliest-event selection for the continuous-time engine (oracle + CPU
    fast path).

    The head of a ``repro.net.events.EventQueue`` is the valid slot with the
    lexicographically smallest ``(time, kind, seq)`` key — kind orders
    simultaneous events (deliveries merge before drains settle before
    publishes land before starts read, mirroring the tick driver's intra-tick
    order) and ``seq`` makes ties deterministic. The masked argmin is the
    ``gossip_winner`` reduction with min in place of max.

    Returns ``(idx () i32, found () bool)``; ``idx`` is 0 when nothing is
    valid (callers gate on ``found``).
    """
    valid = jnp.asarray(valid, bool)
    imax = jnp.iinfo(jnp.int32).max
    t = jnp.where(valid, time, jnp.inf)
    tie = valid & (t == jnp.min(t))
    kk = jnp.where(tie, kind, imax)
    tie = tie & (kk == jnp.min(kk))
    ss = jnp.where(tie, seq, imax)
    tie = tie & (ss == jnp.min(ss))
    return jnp.argmax(tie).astype(jnp.int32), jnp.any(valid)


def chunk_dedup_ref(
    have: jnp.ndarray,      # (R, S, C) bool — physical chunk presence per node
    digest: jnp.ndarray,    # (S, C) f32 — content digest of every store chunk
) -> jnp.ndarray:
    """Content-addressed chunk availability (oracle + CPU fast path).

    A node effectively HAS chunk (s, c) of the model store iff it physically
    holds some chunk (s', c) whose content digest equals ``digest[s, c]`` —
    identical payloads (e.g. a lazy node republishing the aggregated model
    verbatim) therefore cost zero transfer bytes the second time. Chunking is
    ALIGNED: dedup only compares chunks at the same offset ``c`` across
    slots, which captures whole-model and per-chunk identity but not
    offset-shifted collisions (see ``repro.net.bank``).

    Returns ``sat (R, S, C) bool`` — the effective-availability bitmap the
    transfer-selection step subtracts from each node's referenced set.

    Physical presence short-circuits the digest comparison (``have`` ORs
    into the result): a chunk a node actually holds is available even when
    its digest is NaN (a payload that trained to NaN compares unequal to
    ITSELF), so degenerate models can still gossip at physical identity —
    they just lose cross-slot dedup.
    """
    have = jnp.asarray(have, bool)
    # eq[p, s, c]: store chunk (p, c) holds the same content as (s, c)
    eq = digest[:, None, :] == digest[None, :, :]             # (S, S, C)
    # sat[i, s, c] = have[i, s, c] | any_p have[i, p, c] & eq[p, s, c]
    return have | (jnp.einsum(
        "ipc,psc->isc", have.astype(jnp.int32), eq.astype(jnp.int32)
    ) > 0)


def quant_blocks_ref(
    x: jnp.ndarray,     # (nb, B) f32 — one codec block per row
    qmax: int,          # 127 for int8, 7 for int4
):
    """Blocked symmetric quantization (oracle + CPU fast path).

    Per block: ``scale = amax / qmax`` when the block has any signal and
    exactly 1.0 on an all-zero block (so zero padding round-trips to zero
    bit-exactly), then ``codes = clip(round(x / scale), -qmax, qmax)``.
    The worst-case round-trip error is ``scale / 2`` per element —
    ``amax / (2 * qmax)`` of that block, the bound
    ``tests/test_delta_codec.py`` property-tests.

    Returns ``(codes (nb, B) int8, scales (nb,) f32)``.
    """
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0.0, amax / qmax, 1.0)
    codes = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return codes, scale[:, 0]


def dequant_blocks_ref(
    codes: jnp.ndarray,   # (nb, B) int8
    scales: jnp.ndarray,  # (nb,) f32
) -> jnp.ndarray:
    """Inverse of ``quant_blocks_ref``: codes * per-block scale, in f32."""
    return codes.astype(jnp.float32) * scales[:, None]


def topk_blocks_ref(
    d: jnp.ndarray,     # (nb, B) f32 — one delta block per row
    k: int,
) -> jnp.ndarray:
    """Per-block top-k-|delta| masking (oracle + CPU fast path).

    Element i survives iff fewer than ``k`` elements of its block rank
    strictly ahead of it, where j ranks ahead of i when ``|d_j| > |d_i|``
    or (``|d_j| == |d_i|`` and ``j < i``) — a deterministic dense
    reduction (no sort, ties break toward the earlier index). Zeros never
    outrank a nonzero, so ``k >= nnz(block)`` keeps every nonzero and the
    masked delta IS the delta (the exactness property the tests pin).

    Returns the dense masked delta, same shape as ``d``.
    """
    d = jnp.asarray(d, jnp.float32)
    a = jnp.abs(d)
    idx = jnp.arange(d.shape[-1], dtype=jnp.int32)
    gt = a[:, :, None] > a[:, None, :]                        # [n, j, i]
    eq = (a[:, :, None] == a[:, None, :]) & (idx[:, None] < idx[None, :])
    rank = jnp.sum((gt | eq).astype(jnp.int32), axis=1)       # (nb, B)
    return jnp.where(rank < k, d, 0.0)


def fedavg_ref(weights: jnp.ndarray, models: jnp.ndarray) -> jnp.ndarray:
    """Eq. (1): weighted average of k flattened models.

    weights: (k,) f32, models: (k, N) -> (N,) in models.dtype.
    """
    out = jnp.einsum("k,kn->n", weights.astype(jnp.float32), models.astype(jnp.float32))
    return out.astype(models.dtype)


def model_distance_ref(models: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared-L2 distance matrix between k flattened models.

    models: (k, N) -> (k, k) f32. Used by anomaly detection (parameter-space
    outlier scoring of tips).
    """
    x = models.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    return sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)


def mqa_attention_ref(
    q: jnp.ndarray,  # (B, H, S, hd)
    k: jnp.ndarray,  # (B, KV, S, hd)
    v: jnp.ndarray,  # (B, KV, S, hd)
    window: int = 0,
) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention, GQA head mapping."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    rep = H // KV
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = j <= i
    if window:
        ok = ok & (j > i - window)
    scores = jnp.where(ok, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vv)


def decode_attention_ref(
    q: jnp.ndarray,        # (B, H, hd)  one query per batch row
    k: jnp.ndarray,        # (B, S, KV, hd)
    v: jnp.ndarray,        # (B, S, KV, hd)
    lengths: jnp.ndarray,  # (B,) int32 — valid cache entries per row
) -> jnp.ndarray:
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    rep = H // KV
    kk = jnp.repeat(k, rep, axis=2)    # (B, S, H, hd)
    vv = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q, kk).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhs,bshd->bhd", probs, vv)


def hist_bincount_ref(
    idx: jnp.ndarray,      # (m,) int32 bin indices
    weights: jnp.ndarray,  # (m,) int32 sample weights
    num_bins: int,
) -> jnp.ndarray:
    """Weighted bincount: out[b] = sum of weights where idx == b.

    Out-of-range indices are dropped on BOTH sides, matching the Pallas
    kernel's one-hot compare — ``mode="drop"`` alone would Python-wrap
    negatives into the tail bins, so they are remapped past the end
    first; never scattered into a clamped neighbouring bin.
    """
    idx = jnp.where(idx < 0, jnp.int32(num_bins), idx)
    out = jnp.zeros((num_bins,), jnp.int32)
    return out.at[idx].add(weights.astype(jnp.int32), mode="drop")
