"""Pallas kernel: pairwise squared-L2 distances between k flattened models.

Feeds DAG-FL anomaly detection (parameter-space outlier scoring of tips —
poisoned models sit far from the normal cluster). Streaming MXU pattern:
grid over N blocks, (k, k) output block revisited and accumulated each step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 16 * 1024


def _dist_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                  # (k, bn)
    sq = jnp.sum(x * x, axis=1)                         # (k,)
    cross = jax.lax.dot_general(                        # (k, k) on the MXU
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += sq[:, None] + sq[None, :] - 2.0 * cross


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def model_distance_pallas(
    models: jnp.ndarray,         # (k, N)
    block_n: int = BLOCK_N,
    interpret: bool = True,
) -> jnp.ndarray:
    k, n = models.shape
    pad = (-n) % block_n
    x = jnp.pad(models, ((0, 0), (0, pad)))             # zero pad: dist-safe
    n_pad = n + pad

    return pl.pallas_call(
        _dist_kernel,
        grid=(n_pad // block_n,),
        in_specs=[pl.BlockSpec((k, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((k, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        interpret=interpret,
    )(x)
