"""DAG-FL core: the paper's contribution (ledger, consensus, algorithms 1&2)."""
from repro.core import aggregation, anomaly, bank, consensus, controller, dag, stability, validation
from repro.core.consensus import IterationOut, make_dagfl_iteration
from repro.core.controller import Controller, ControllerState
from repro.core.dag import DagState, empty_dag, merge, publish, publish_at, select_tips, tip_mask

__all__ = [
    "aggregation", "anomaly", "bank", "consensus", "controller", "dag",
    "stability", "validation",
    "IterationOut", "make_dagfl_iteration", "Controller", "ControllerState",
    "DagState", "empty_dag", "merge", "publish", "publish_at", "select_tips", "tip_mask",
]
