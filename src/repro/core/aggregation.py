"""Eq.-(1) FederatedAveraging and the §VI.C weighted extension.

Three interchangeable implementations of the same aggregation:
  * ``fedavg_pytree``   — tree_map weighted sum (clear, autodiff-safe),
  * ``fedavg_flat``     — the Pallas kernel over flattened params (TPU path),
  * ``bank_average``    — one-hot matmul over the model bank (sharded path,
                          lives in repro.core.bank).
All are cross-checked in tests.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def uniform_weights(k: int) -> jnp.ndarray:
    """Paper default: n_i = 1/k."""
    return jnp.full((k,), 1.0 / k, jnp.float32)


def fedavg_pytree(stacked: Any, weights: jnp.ndarray) -> Any:
    """stacked: pytree with leading k axis; weights (k,) summing to 1."""

    def avg(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        return jnp.sum(leaf.astype(jnp.float32) * w, axis=0).astype(leaf.dtype)

    return jax.tree_util.tree_map(avg, stacked)


def flatten_params(params: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(params)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])


def unflatten_like(flat: jnp.ndarray, template: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(template)
    out = []
    ofs = 0
    for l in leaves:
        out.append(flat[ofs : ofs + l.size].reshape(l.shape).astype(l.dtype))
        ofs += l.size
    return jax.tree_util.tree_unflatten(treedef, out)


def fedavg_flat(stacked: Any, weights: jnp.ndarray) -> Any:
    """Pallas-kernel path: flatten the k models, run the tiled kernel."""
    template = jax.tree_util.tree_map(lambda l: l[0], stacked)
    flat = jax.vmap(flatten_params)(stacked)              # (k, N)
    out = kops.fedavg(weights, flat)
    return unflatten_like(out, template)


def staleness_accuracy_weights(
    accuracies: jnp.ndarray,      # (k,) f32
    staleness: jnp.ndarray,       # (k,) f32 seconds
    tau_max: float,
    temperature: float = 4.0,
) -> jnp.ndarray:
    """§VI.C weighted aggregation: fresher + more accurate tips weigh more.

    w_i ∝ softmax(temperature * acc_i) * (1 - staleness_i / (2*tau_max)).
    Reduces to ~uniform when accuracies/staleness are equal.
    """
    a = jax.nn.softmax(temperature * accuracies)
    fresh = jnp.clip(1.0 - staleness / (2.0 * tau_max), 0.1, 1.0)
    w = a * fresh
    return w / jnp.maximum(jnp.sum(w), 1e-9)
