"""Anomaly detection (§V.4 + §VI.B): contribution rates and credit scores.

The paper's detector: a transaction with <= m approvals is *isolated*; a
node's contribution rate r = contributing / published. Abnormal nodes show
r0 / r well below 1 (Table IV). ``credit_scores`` implements the §VI.B
extension (tips from low-credit nodes get down-weighted during selection),
and ``parameter_outlier_scores`` the §VI.A-style model-space validation
using the pairwise-distance Pallas kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dag import DagState
from repro.kernels import ops as kops


class ContributionReport(NamedTuple):
    rates: jnp.ndarray          # (N,) per-node contribution rate
    mean_rate: jnp.ndarray      # ()   r   (all nodes)
    flagged: jnp.ndarray        # (N,) bool — below threshold


def contribution_rates(dag: DagState, m: int = 0) -> jnp.ndarray:
    contrib = dag.contributing_m0 if m == 0 else dag.contributing_m1
    pub = jnp.maximum(dag.published_per_node, 1)
    return contrib.astype(jnp.float32) / pub.astype(jnp.float32)


def contribution_report(
    dag: DagState, m: int = 0, flag_fraction: float = 0.5
) -> ContributionReport:
    rates = contribution_rates(dag, m)
    active = dag.published_per_node > 0
    mean = jnp.sum(jnp.where(active, rates, 0.0)) / jnp.maximum(jnp.sum(active), 1)
    flagged = active & (rates < flag_fraction * mean)
    return ContributionReport(rates, mean, flagged)


def credit_scores(dag: DagState, m: int = 0, floor: float = 0.05) -> jnp.ndarray:
    """§VI.B: per-node credit in [floor, 1], proportional to contribution."""
    rates = contribution_rates(dag, m)
    mean = jnp.maximum(jnp.mean(rates), 1e-6)
    return jnp.clip(rates / mean, floor, 1.0)


def rejection_credit(
    rejects: jnp.ndarray, floor: float = 0.05, scale: float = 1.0
) -> jnp.ndarray:
    """Per-sender trust from digest-rejection counts (the transport-layer
    complement of ``credit_scores``).

    ``rejects`` is the (N, N) matrix the fault-injected bank service
    accumulates (``repro.net.faults.FaultState.rejects`` — receiver i
    charged sender j one count per chunk that failed digest verification).
    A sender's credit decays exponentially in its TOTAL rejections across
    all receivers, clipped to ``[floor, 1]``: a clean node keeps exactly
    1.0 (zero rejections — the honest path is unperturbed), a spoofer
    collapses to the floor within a few rejected chunks. Feed the log of
    this into tip-selection bias (``credit_weighted_tip_scores`` composes
    the same way) to quarantine spoofers from approval, not just from
    transport.
    """
    per_sender = jnp.sum(
        jnp.asarray(rejects, jnp.int32), axis=0
    ).astype(jnp.float32)
    return jnp.clip(jnp.exp(-scale * per_sender), floor, 1.0)


def credit_weighted_tip_scores(
    dag: DagState, tip_scores: jnp.ndarray, credits: jnp.ndarray
) -> jnp.ndarray:
    """Scale gumbel tip-selection scores by the publisher's credit."""
    pub = jnp.maximum(dag.publisher, 0)
    c = credits[pub]
    return tip_scores + jnp.log(jnp.where(dag.publisher >= 0, c, 1.0))


def parameter_outlier_scores(flat_models: jnp.ndarray) -> jnp.ndarray:
    """§VI.A-style model-space screening of candidate tips.

    flat_models (k, N) -> (k,) mean distance to the other candidates;
    poisoned models sit far from the normal cluster.
    """
    d = kops.model_distance(flat_models)                  # (k, k)
    k = d.shape[0]
    off = jnp.where(jnp.eye(k, dtype=bool), 0.0, d)
    return jnp.sum(off, axis=1) / jnp.maximum(k - 1, 1)
