"""§IV deployment/stability model: Eqs. (4)-(8) + a Poisson-process simulator.

The paper models iteration completions as a Poisson process with rate
lambda = n*p; with k approvals per new transaction the equilibrium tip count
is L0 = k*lambda*h/(k-1) (Eq. 4, following the tangle analysis), with the
per-iteration delay h = d0 + d1 from the Table-I constants (Eqs. 5-7).
``simulate_tip_count`` verifies Eq. (4) empirically — the bench
``stability_tips`` compares the two.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.configs.base import DagFLConfig


def training_delay(cfg: DagFLConfig, f: float) -> float:
    """Eq. (5): d0 = eta0 * phi0 * beta / f."""
    return cfg.train_density * cfg.minibatch_size_bits * cfg.beta / f


def validation_delay(cfg: DagFLConfig, f: float) -> float:
    """Eq. (6): d1 = eta1 * phi1 * alpha / f."""
    return cfg.validate_density * cfg.valset_size_bits * cfg.alpha / f


def iteration_delay(cfg: DagFLConfig, f: float) -> float:
    """Eq. (7): h = d0 + d1."""
    return training_delay(cfg, f) + validation_delay(cfg, f)


def transmission_delay(cfg: DagFLConfig) -> float:
    """Broadcasting one transaction of phi bits at bandwidth B."""
    return cfg.tx_size_bits / cfg.bandwidth


def equilibrium_tips(cfg: DagFLConfig, f: Optional[float] = None) -> float:
    """Eq. (8): L0 = k*lambda*(eta0*phi0*beta + eta1*phi1*alpha) / ((k-1)*f)."""
    if f is None:
        f = 0.5 * (cfg.cpu_freq_range[0] + cfg.cpu_freq_range[1])
    h = iteration_delay(cfg, f)
    return cfg.k * cfg.arrival_rate * h / (cfg.k - 1)


def tail_mean(tips: np.ndarray, frac: float = 0.5) -> float:
    """Mean over the trailing ``frac`` of samples (equilibrium estimate).

    ``n`` is clamped to >= 1: a short trace (``len * frac < 1``) degrades
    to the last sample instead of ``tips[-0:]`` silently averaging the
    WHOLE trace, and an empty trace is NaN rather than a numpy warning.
    Shared by ``TipTrace`` (the standalone sim) and
    ``repro.net.events.InSystemTrace`` (the in-system sim) so the two
    equilibrium estimates use one rule.
    """
    if len(tips) == 0:
        return float("nan")
    n = max(int(len(tips) * frac), 1)
    return float(np.mean(tips[-n:]))


@dataclass
class TipTrace:
    times: np.ndarray
    tips: np.ndarray

    def tail_mean(self, frac: float = 0.5) -> float:
        return tail_mean(self.tips, frac)


def simulate_tip_count(
    cfg: DagFLConfig,
    horizon: float = 2000.0,
    seed: int = 0,
    f: Optional[float] = None,
) -> TipTrace:
    """Event-driven M/G/inf-style simulation of the tip population.

    Arrivals ~ Poisson(lambda); each iteration takes h seconds during which
    the node has already *reserved* (validated) k tips; at completion the
    new transaction becomes a tip and its k approvals stop being tips.
    The k selected tips are only marked approved at publish time (the paper's
    stage 4), so in-flight iterations can pick overlapping tips — that
    overlap is exactly why the equilibrium exceeds lambda*h/(k-1)*k only
    approximately; Eq. (4) matches the long-run mean.
    """
    if f is None:
        f = 0.5 * (cfg.cpu_freq_range[0] + cfg.cpu_freq_range[1])
    h = iteration_delay(cfg, f)
    rng = np.random.default_rng(seed)
    lam = cfg.arrival_rate

    tips: set = {0}
    next_id = 1
    pending: list = []          # (finish_time, approved ids)
    t = 0.0
    times, counts = [0.0], [1]

    while t < horizon:
        t += rng.exponential(1.0 / lam)
        # complete any pending iterations first
        pending.sort()
        while pending and pending[0][0] <= t:
            _, approved, tid = pending.pop(0)
            for a in approved:
                tips.discard(a)
            tips.add(tid)
            times.append(t)
            counts.append(len(tips))
        # new iteration starts now: select (up to) k distinct current tips
        pool = list(tips)
        kk = min(cfg.k, len(pool))
        approved = list(rng.choice(pool, size=kk, replace=False)) if kk else []
        pending.append((t + h, approved, next_id))
        next_id += 1

    return TipTrace(np.asarray(times), np.asarray(counts, np.float64))
