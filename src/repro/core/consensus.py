"""DAG-FL consensus: one full Algorithm-2 iteration as a jittable function.

Stage 1  select <= alpha tips within tau_max          (dag.select_tips)
Stage 2  authenticate + validate their models          (validation)
Stage 3  FedAvg the k best, train beta epochs locally  (aggregation + train_fn)
Stage 4  publish the new transaction with k approvals  (dag.publish)

``make_dagfl_iteration`` closes over the task's ``eval_fn(params, batch)``
and ``train_fn(params, batch, key) -> (params, metrics)`` so the same
consensus drives the paper's CNN/LSTM tasks, the assigned architectures,
and the distributed runtime.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import DagFLConfig
from repro.core import aggregation as agg
from repro.core import bank as bank_lib
from repro.core import dag as dag_lib
from repro.core import validation as val_lib


class IterationOut(NamedTuple):
    dag: dag_lib.DagState
    bank: Any
    new_accuracy: jnp.ndarray       # accuracy of the freshly published model
    chosen_rows: jnp.ndarray        # (k,) dag rows approved
    num_tips_seen: jnp.ndarray


class Prepared(NamedTuple):
    """Stages 1-3 output, awaiting stage-4 publication at completion time.

    Decoupling select(t0) from publish(t1 = t0 + h) is what lets tips
    accumulate to the paper's L0 = k*lambda*h/(k-1) equilibrium — iterations
    in flight select overlapping tip sets (Fig. 4's t1/t2 timeline).
    """

    new_params: Any
    chosen_rows: jnp.ndarray
    new_accuracy: jnp.ndarray
    num_tips_seen: jnp.ndarray


def make_dagfl_iteration(
    cfg: DagFLConfig,
    eval_fn: Callable[[Any, Any], jnp.ndarray],
    train_fn: Callable[[Any, Any, jnp.ndarray], Any],
    weighted: bool = False,
):
    """Returns iteration(dag, bank, node_id, now, key, train_batch, val_batch)."""
    validator = val_lib.make_validator(eval_fn)

    def iteration(
        dag, bank, node_id, now, key, train_batch, val_batch, node_bias=None
    ) -> IterationOut:
        k_sel, k_train = jax.random.split(key)

        # --- stage 1: tip selection -------------------------------------
        rows, nvalid = dag_lib.select_tips(
            dag, k_sel, cfg.alpha, now, cfg.tau_max, node_bias=node_bias
        )
        slots = jnp.where(rows >= 0, dag.model_slot[jnp.maximum(rows, 0)], -1)

        # --- stage 2: authenticate + validate ---------------------------
        auth_ok = val_lib.authenticate(dag.auth_tag, bank, slots)
        accs = validator(bank, slots, val_batch)
        accs = jnp.where(auth_ok, accs, -jnp.inf)

        # --- stage 3: top-k FedAvg + local training ----------------------
        chosen_slots, top_pos, top_acc = val_lib.select_top_k(accs, slots, cfg.k)
        chosen_rows = jnp.where(
            jnp.isfinite(top_acc), rows[top_pos], dag_lib.NO_TX
        ).astype(jnp.int32)
        n_chosen = jnp.sum(chosen_slots >= 0)

        if weighted:
            stale = now - dag.publish_time[jnp.maximum(chosen_rows, 0)]
            weights = agg.staleness_accuracy_weights(
                jnp.where(jnp.isfinite(top_acc), top_acc, 0.0), stale, cfg.tau_max
            )
        else:
            weights = agg.uniform_weights(cfg.k)

        aggregated = bank_lib.bank_average(bank, chosen_slots, weights)
        # no usable tips -> continue from the most recent model (genesis early on)
        last_slot = dag.model_slot[jnp.mod(dag.count - 1, dag_lib.capacity_of(dag))]
        fallback = bank_lib.bank_read(bank, jnp.maximum(last_slot, 0))
        global_model = jax.tree_util.tree_map(
            lambda a, f: jnp.where(n_chosen > 0, a, f), aggregated, fallback
        )

        new_params = global_model
        for _ in range(cfg.beta):                          # beta local epochs
            new_params, _ = train_fn(new_params, train_batch, k_train)

        # --- stage 4: publish --------------------------------------------
        new_acc = eval_fn(new_params, val_batch).astype(jnp.float32)
        tag = bank_lib.auth_checksum(new_params)
        slot = jnp.mod(dag.count, dag_lib.capacity_of(dag))
        bank = bank_lib.bank_write(bank, slot, new_params)
        dag = dag_lib.publish(
            dag,
            jnp.asarray(node_id, jnp.int32),
            jnp.asarray(now, jnp.float32),
            chosen_rows,
            new_acc,
            tag,
            slot,
        )
        return IterationOut(dag, bank, new_acc, chosen_rows, nvalid)

    return iteration


def make_dagfl_stages(
    cfg: DagFLConfig,
    eval_fn: Callable[[Any, Any], jnp.ndarray],
    train_fn: Callable[[Any, Any, jnp.ndarray], Any],
    weighted: bool = False,
):
    """Split Algorithm 2 into prepare (stages 1-3, at iteration START) and
    commit (stage 4, at COMPLETION). Returns (prepare_fn, commit_fn)."""
    validator = val_lib.make_validator(eval_fn)

    def prepare(dag, bank, now, key, train_batch, val_batch, node_bias=None) -> Prepared:
        k_sel, k_train = jax.random.split(key)
        rows, nvalid = dag_lib.select_tips(
            dag, k_sel, cfg.alpha, now, cfg.tau_max, node_bias=node_bias
        )
        slots = jnp.where(rows >= 0, dag.model_slot[jnp.maximum(rows, 0)], -1)
        auth_ok = val_lib.authenticate(dag.auth_tag, bank, slots)
        accs = jnp.where(auth_ok, validator(bank, slots, val_batch), -jnp.inf)
        chosen_slots, top_pos, top_acc = val_lib.select_top_k(accs, slots, cfg.k)
        chosen_rows = jnp.where(
            jnp.isfinite(top_acc), rows[top_pos], dag_lib.NO_TX
        ).astype(jnp.int32)
        n_chosen = jnp.sum(chosen_slots >= 0)

        if weighted:
            stale = now - dag.publish_time[jnp.maximum(chosen_rows, 0)]
            weights = agg.staleness_accuracy_weights(
                jnp.where(jnp.isfinite(top_acc), top_acc, 0.0), stale, cfg.tau_max
            )
        else:
            weights = agg.uniform_weights(cfg.k)
        aggregated = bank_lib.bank_average(bank, chosen_slots, weights)
        last_slot = dag.model_slot[jnp.mod(dag.count - 1, dag_lib.capacity_of(dag))]
        fallback = bank_lib.bank_read(bank, jnp.maximum(last_slot, 0))
        global_model = jax.tree_util.tree_map(
            lambda a, f: jnp.where(n_chosen > 0, a, f), aggregated, fallback
        )
        new_params = global_model
        for _ in range(cfg.beta):
            new_params, _ = train_fn(new_params, train_batch, k_train)
        new_acc = eval_fn(new_params, val_batch).astype(jnp.float32)
        return Prepared(new_params, chosen_rows, new_acc, nvalid)

    return prepare, commit_prepared


def commit_prepared(dag, bank, node_id, t_publish, prepared: Prepared,
                    slot=None, new_count=None):
    """Stage-4 publication of a ``Prepared`` iteration — the single commit
    body shared by every runtime.

    Default (``slot=None``): append at the ledger-local row
    ``count % capacity`` (the shared-ledger runtime). Gossip replicas
    (``repro.net``) instead pass a slot and count watermark derived from the
    global publish sequence, so the same transaction lands in the same slot
    on every replica.
    """
    if slot is None:
        slot = jnp.mod(dag.count, dag_lib.capacity_of(dag))
        new_count = dag.count + 1
    elif new_count is None:
        raise ValueError("commit_prepared: slot and new_count go together "
                         "(see repro.net.replica.global_row)")
    tag = bank_lib.auth_checksum(prepared.new_params)
    bank = bank_lib.bank_write(bank, slot, prepared.new_params)
    dag = dag_lib.publish_at(
        dag,
        slot,
        new_count,
        jnp.asarray(node_id, jnp.int32),
        jnp.asarray(t_publish, jnp.float32),
        prepared.chosen_rows,
        prepared.new_accuracy,
        tag,
        slot,
    )
    return dag, bank
