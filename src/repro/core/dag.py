"""The DAG ledger: fixed-capacity struct-of-arrays, fully jittable.

Transactions are rows of parallel arrays; approvals are index edges that
always point to OLDER rows (acyclicity by construction). Capacity is a ring:
slots older than ``tau_max`` can never be tips again (§IV.B), so evicting
the oldest row is semantically safe; per-node contribution statistics are
kept as cumulative counters (updated the moment a transaction crosses the
``m`` approvals threshold) so Table-IV metrics survive eviction.

The model payload of each transaction lives in a separate "model bank"
(see ``repro.core.bank``); rows store only the bank slot.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

NO_TX = jnp.int32(-1)


class DagState(NamedTuple):
    publisher: jnp.ndarray          # (cap,) int32  node id, -1 = empty
    publish_time: jnp.ndarray       # (cap,) f32
    approvals: jnp.ndarray          # (cap, k) int32 indices approved by row
    approval_count: jnp.ndarray     # (cap,) int32  times row was approved
    accuracy: jnp.ndarray           # (cap,) f32    validation accuracy at publish
    auth_tag: jnp.ndarray           # (cap,) f32    integrity checksum of payload
    model_slot: jnp.ndarray         # (cap,) int32  index into the model bank
    count: jnp.ndarray              # () int32      total ever published
    # cumulative per-node stats (Table IV), for isolation thresholds m=0,1
    published_per_node: jnp.ndarray     # (N,) int32
    contributing_m0: jnp.ndarray        # (N,) int32  rows that got > 0 approvals
    contributing_m1: jnp.ndarray        # (N,) int32  rows that got > 1 approvals


def empty_dag(capacity: int, k: int, num_nodes: int) -> DagState:
    return DagState(
        publisher=jnp.full((capacity,), NO_TX, jnp.int32),
        publish_time=jnp.zeros((capacity,), jnp.float32),
        approvals=jnp.full((capacity, k), NO_TX, jnp.int32),
        approval_count=jnp.zeros((capacity,), jnp.int32),
        accuracy=jnp.zeros((capacity,), jnp.float32),
        auth_tag=jnp.zeros((capacity,), jnp.float32),
        model_slot=jnp.full((capacity,), NO_TX, jnp.int32),
        count=jnp.zeros((), jnp.int32),
        published_per_node=jnp.zeros((num_nodes,), jnp.int32),
        contributing_m0=jnp.zeros((num_nodes,), jnp.int32),
        contributing_m1=jnp.zeros((num_nodes,), jnp.int32),
    )


def capacity_of(dag: DagState) -> int:
    return dag.publisher.shape[0]


def publish_at(
    dag: DagState,
    row: jnp.ndarray,            # () int32 slot to write
    new_count: jnp.ndarray,      # () int32 ledger watermark after the write
    publisher: jnp.ndarray,      # () int32
    time: jnp.ndarray,           # () f32
    approvals: jnp.ndarray,      # (k,) int32, NO_TX padded
    accuracy: jnp.ndarray,       # () f32
    auth_tag: jnp.ndarray,       # () f32
    model_slot: jnp.ndarray,     # () int32
) -> DagState:
    """Write a transaction into an explicit row and credit its approvals.

    ``publish`` is the single-ledger special case (row = count % cap).
    Gossip replicas (``repro.net``) allocate rows from a *global* sequence
    number instead, so the same transaction lands in the same slot on every
    replica and ``merge`` can reconcile row-wise by identity.
    """
    # credit each approved transaction; track threshold crossings
    def credit(carry, tx):
        ac, c0, c1 = carry
        ok = tx >= 0
        idx = jnp.maximum(tx, 0)
        old = ac[idx]
        ac = ac.at[idx].add(jnp.where(ok, 1, 0))
        pub = dag.publisher[idx]
        crossed0 = ok & (old == 0) & (pub >= 0)
        crossed1 = ok & (old == 1) & (pub >= 0)
        safe_pub = jnp.maximum(pub, 0)
        c0 = c0.at[safe_pub].add(jnp.where(crossed0, 1, 0))
        c1 = c1.at[safe_pub].add(jnp.where(crossed1, 1, 0))
        return (ac, c0, c1), None

    (ac, c0, c1), _ = jax.lax.scan(
        credit, (dag.approval_count, dag.contributing_m0, dag.contributing_m1), approvals
    )

    return DagState(
        publisher=dag.publisher.at[row].set(publisher.astype(jnp.int32)),
        publish_time=dag.publish_time.at[row].set(time.astype(jnp.float32)),
        approvals=dag.approvals.at[row].set(approvals.astype(jnp.int32)),
        approval_count=ac.at[row].set(0),
        accuracy=dag.accuracy.at[row].set(accuracy.astype(jnp.float32)),
        auth_tag=dag.auth_tag.at[row].set(auth_tag.astype(jnp.float32)),
        model_slot=dag.model_slot.at[row].set(model_slot.astype(jnp.int32)),
        count=jnp.asarray(new_count, jnp.int32),
        published_per_node=dag.published_per_node.at[publisher].add(1),
        contributing_m0=c0,
        contributing_m1=c1,
    )


def publish(
    dag: DagState,
    publisher: jnp.ndarray,      # () int32
    time: jnp.ndarray,           # () f32
    approvals: jnp.ndarray,      # (k,) int32, NO_TX padded
    accuracy: jnp.ndarray,       # () f32
    auth_tag: jnp.ndarray,       # () f32
    model_slot: jnp.ndarray,     # () int32
) -> DagState:
    """Append a transaction (Algorithm 2 stage 4) and credit approvals."""
    cap = capacity_of(dag)
    return publish_at(
        dag, jnp.mod(dag.count, cap), dag.count + 1,
        publisher, time, approvals, accuracy, auth_tag, model_slot,
    )


def tip_mask(dag: DagState, now: jnp.ndarray, tau_max: float) -> jnp.ndarray:
    """Tips (§II.B / §IV.B): occupied, unapproved, staleness <= tau_max."""
    fresh = (now - dag.publish_time) <= tau_max
    return (dag.publisher >= 0) & (dag.approval_count == 0) & fresh


def select_tips(
    dag: DagState,
    key: jnp.ndarray,
    alpha: int,
    now: jnp.ndarray,
    tau_max: float,
    node_bias=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sample up to alpha tips without replacement (stage 1).

    Returns (idx (alpha,) int32 with NO_TX padding, num_valid ()).
    Gumbel top-k gives an exact uniform sample under jit. ``node_bias``
    ((num_nodes+1,) log-weights indexed by publisher) skews the draw —
    used by §VI.B credit-weighted selection and by the simulator's
    backdoor JOINT attack (§V.A.4).
    """
    mask = tip_mask(dag, now, tau_max)
    cap = capacity_of(dag)
    gumbel = -jnp.log(-jnp.log(jax.random.uniform(key, (cap,), minval=1e-9, maxval=1.0)))
    if node_bias is not None:
        gumbel = gumbel + node_bias[jnp.maximum(dag.publisher, 0)]
    scores = jnp.where(mask, gumbel, -jnp.inf)
    top_scores, top_idx = jax.lax.top_k(scores, alpha)
    ok = jnp.isfinite(top_scores)
    idx = jnp.where(ok, top_idx, NO_TX).astype(jnp.int32)
    return idx, jnp.sum(ok.astype(jnp.int32))


def num_tips(dag: DagState, now: jnp.ndarray, tau_max: float) -> jnp.ndarray:
    return jnp.sum(tip_mask(dag, now, tau_max).astype(jnp.int32))


def isolated_mask(dag: DagState, m: int) -> jnp.ndarray:
    """Transactions with <= m approvals are isolated (§V.4)."""
    return (dag.publisher >= 0) & (dag.approval_count <= m)


def merge(local: DagState, remote: DagState) -> DagState:
    """Anti-entropy reconciliation of two replicas of the same logical ledger
    (§III.A: each node's local DAG is "updated by communicating with adjacent
    nodes").

    Row-wise, keyed by the ``(publish_time, publisher)`` identity of the
    transaction stored in each slot:

    * a slot occupied on only one side adopts that side's row;
    * two *different* transactions in the same slot (divergent histories, or
      ring wrap-around on one side) resolve to the LATER one — ring semantics
      already make the later transaction the overwriting one — with the
      publisher id breaking exact publish-time ties, so the merge is
      deterministic, commutative, and associative (gossip order cannot
      matter);
    * the *same* transaction on both sides keeps the element-wise MAXIMUM
      approval count: each replica may have credited a disjoint subset of
      approvers, and max is the monotone (CRDT-style) bound that never
      un-approves. Concurrent approvals of one row on two replicas therefore
      collapse (union-by-max, not sum) — ``repro.net`` exposes this as the
      measurable duplicate-approval deficit of a gossiped deployment.

    ``count`` and the per-node contribution counters are monotone watermarks
    and merge by element-wise max, so they never decrease.
    """
    l_occ = local.publisher >= 0
    r_occ = remote.publisher >= 0
    same_tx = (
        l_occ & r_occ
        & (local.publish_time == remote.publish_time)
        & (local.publisher == remote.publisher)
    )
    remote_newer = (remote.publish_time > local.publish_time) | (
        (remote.publish_time == local.publish_time)
        & (remote.publisher > local.publisher)
    )
    take_remote = (r_occ & ~l_occ) | (r_occ & l_occ & ~same_tx & remote_newer)

    def pick(a, b):
        sel = take_remote.reshape(take_remote.shape + (1,) * (a.ndim - 1))
        return jnp.where(sel, b, a)

    approval_count = jnp.where(
        take_remote, remote.approval_count, local.approval_count
    )
    approval_count = jnp.where(
        same_tx, jnp.maximum(local.approval_count, remote.approval_count),
        approval_count,
    )
    return DagState(
        publisher=pick(local.publisher, remote.publisher),
        publish_time=pick(local.publish_time, remote.publish_time),
        approvals=pick(local.approvals, remote.approvals),
        approval_count=approval_count,
        accuracy=pick(local.accuracy, remote.accuracy),
        auth_tag=pick(local.auth_tag, remote.auth_tag),
        model_slot=pick(local.model_slot, remote.model_slot),
        count=jnp.maximum(local.count, remote.count),
        published_per_node=jnp.maximum(
            local.published_per_node, remote.published_per_node
        ),
        contributing_m0=jnp.maximum(local.contributing_m0, remote.contributing_m0),
        contributing_m1=jnp.maximum(local.contributing_m1, remote.contributing_m1),
    )
