"""The DAG ledger: fixed-capacity struct-of-arrays, fully jittable.

Transactions are rows of parallel arrays; approvals are index edges that
always point to OLDER rows (acyclicity by construction). Capacity is a ring:
slots older than ``tau_max`` can never be tips again (§IV.B), so evicting
the oldest row is semantically safe; per-node contribution statistics are
kept as cumulative counters (updated the moment a transaction crosses the
``m`` approvals threshold) so Table-IV metrics survive eviction.

The model payload of each transaction lives in a separate "model bank"
(see ``repro.core.bank``); rows store only the bank slot.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

NO_TX = jnp.int32(-1)


class DagState(NamedTuple):
    publisher: jnp.ndarray          # (cap,) int32  node id, -1 = empty
    publish_time: jnp.ndarray       # (cap,) f32
    approvals: jnp.ndarray          # (cap, k) int32 indices approved by row
    approvers: jnp.ndarray          # (cap, N) bool  node n approved row r
    approval_count: jnp.ndarray     # (cap,) int32  distinct approver nodes
                                    # (= popcount of the approvers row)
    accuracy: jnp.ndarray           # (cap,) f32    validation accuracy at publish
    auth_tag: jnp.ndarray           # (cap,) f32    integrity checksum of payload
    model_slot: jnp.ndarray         # (cap,) int32  index into the model bank
    count: jnp.ndarray              # () int32      total ever published
    # cumulative per-node stats (Table IV), for isolation thresholds m=0,1
    published_per_node: jnp.ndarray     # (N,) int32
    contributing_m0: jnp.ndarray        # (N,) int32  rows that got > 0 approvals
    contributing_m1: jnp.ndarray        # (N,) int32  rows that got > 1 approvals


def empty_dag(capacity: int, k: int, num_nodes: int) -> DagState:
    return DagState(
        publisher=jnp.full((capacity,), NO_TX, jnp.int32),
        publish_time=jnp.zeros((capacity,), jnp.float32),
        approvals=jnp.full((capacity, k), NO_TX, jnp.int32),
        approvers=jnp.zeros((capacity, num_nodes), jnp.bool_),
        approval_count=jnp.zeros((capacity,), jnp.int32),
        accuracy=jnp.zeros((capacity,), jnp.float32),
        auth_tag=jnp.zeros((capacity,), jnp.float32),
        model_slot=jnp.full((capacity,), NO_TX, jnp.int32),
        count=jnp.zeros((), jnp.int32),
        published_per_node=jnp.zeros((num_nodes,), jnp.int32),
        contributing_m0=jnp.zeros((num_nodes,), jnp.int32),
        contributing_m1=jnp.zeros((num_nodes,), jnp.int32),
    )


def capacity_of(dag: DagState) -> int:
    return dag.publisher.shape[0]


def publish_at(
    dag: DagState,
    row: jnp.ndarray,            # () int32 slot to write
    new_count: jnp.ndarray,      # () int32 ledger watermark after the write
    publisher: jnp.ndarray,      # () int32
    time: jnp.ndarray,           # () f32
    approvals: jnp.ndarray,      # (k,) int32, NO_TX padded
    accuracy: jnp.ndarray,       # () f32
    auth_tag: jnp.ndarray,       # () f32
    model_slot: jnp.ndarray,     # () int32
) -> DagState:
    """Write a transaction into an explicit row and credit its approvals.

    ``publish`` is the single-ledger special case (row = count % cap).
    Gossip replicas (``repro.net``) allocate rows from a *global* sequence
    number instead, so the same transaction lands in the same slot on every
    replica and ``merge`` can reconcile row-wise by identity.
    """
    # Credit each approved transaction by marking this publisher in its
    # approver set; approval_count is the set's popcount, so re-approving a
    # row the node already credited (directly or via a replayed stale view)
    # cannot inflate the count. Threshold crossings gate on *newly set* bits.
    pub_i = publisher.astype(jnp.int32)

    def credit(carry, tx):
        appr, c0, c1 = carry
        ok = tx >= 0
        idx = jnp.maximum(tx, 0)
        old = jnp.sum(appr[idx].astype(jnp.int32))
        newly = ok & ~appr[idx, pub_i]
        appr = appr.at[idx, pub_i].set(appr[idx, pub_i] | ok)
        pub = dag.publisher[idx]
        crossed0 = newly & (old == 0) & (pub >= 0)
        crossed1 = newly & (old == 1) & (pub >= 0)
        safe_pub = jnp.maximum(pub, 0)
        c0 = c0.at[safe_pub].add(jnp.where(crossed0, 1, 0))
        c1 = c1.at[safe_pub].add(jnp.where(crossed1, 1, 0))
        return (appr, c0, c1), None

    (appr, c0, c1), _ = jax.lax.scan(
        credit, (dag.approvers, dag.contributing_m0, dag.contributing_m1), approvals
    )
    appr = appr.at[row].set(False)      # ring reuse: a fresh row is unapproved

    return DagState(
        publisher=dag.publisher.at[row].set(publisher.astype(jnp.int32)),
        publish_time=dag.publish_time.at[row].set(time.astype(jnp.float32)),
        approvals=dag.approvals.at[row].set(approvals.astype(jnp.int32)),
        approvers=appr,
        approval_count=jnp.sum(appr.astype(jnp.int32), axis=1),
        accuracy=dag.accuracy.at[row].set(accuracy.astype(jnp.float32)),
        auth_tag=dag.auth_tag.at[row].set(auth_tag.astype(jnp.float32)),
        model_slot=dag.model_slot.at[row].set(model_slot.astype(jnp.int32)),
        count=jnp.asarray(new_count, jnp.int32),
        published_per_node=dag.published_per_node.at[publisher].add(1),
        contributing_m0=c0,
        contributing_m1=c1,
    )


def publish(
    dag: DagState,
    publisher: jnp.ndarray,      # () int32
    time: jnp.ndarray,           # () f32
    approvals: jnp.ndarray,      # (k,) int32, NO_TX padded
    accuracy: jnp.ndarray,       # () f32
    auth_tag: jnp.ndarray,       # () f32
    model_slot: jnp.ndarray,     # () int32
) -> DagState:
    """Append a transaction (Algorithm 2 stage 4) and credit approvals."""
    cap = capacity_of(dag)
    return publish_at(
        dag, jnp.mod(dag.count, cap), dag.count + 1,
        publisher, time, approvals, accuracy, auth_tag, model_slot,
    )


def tip_mask(dag: DagState, now: jnp.ndarray, tau_max: float) -> jnp.ndarray:
    """Tips (§II.B / §IV.B): occupied, unapproved, staleness <= tau_max."""
    fresh = (now - dag.publish_time) <= tau_max
    return (dag.publisher >= 0) & (dag.approval_count == 0) & fresh


def select_tips(
    dag: DagState,
    key: jnp.ndarray,
    alpha: int,
    now: jnp.ndarray,
    tau_max: float,
    node_bias=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sample up to alpha tips without replacement (stage 1).

    Returns (idx (alpha,) int32 with NO_TX padding, num_valid ()).
    Gumbel top-k gives an exact uniform sample under jit. ``node_bias``
    ((num_nodes+1,) log-weights indexed by publisher) skews the draw —
    used by §VI.B credit-weighted selection and by the simulator's
    backdoor JOINT attack (§V.A.4).
    """
    mask = tip_mask(dag, now, tau_max)
    cap = capacity_of(dag)
    gumbel = -jnp.log(-jnp.log(jax.random.uniform(key, (cap,), minval=1e-9, maxval=1.0)))
    if node_bias is not None:
        gumbel = gumbel + node_bias[jnp.maximum(dag.publisher, 0)]
    scores = jnp.where(mask, gumbel, -jnp.inf)
    top_scores, top_idx = jax.lax.top_k(scores, alpha)
    ok = jnp.isfinite(top_scores)
    idx = jnp.where(ok, top_idx, NO_TX).astype(jnp.int32)
    return idx, jnp.sum(ok.astype(jnp.int32))


def num_tips(dag: DagState, now: jnp.ndarray, tau_max: float) -> jnp.ndarray:
    return jnp.sum(tip_mask(dag, now, tau_max).astype(jnp.int32))


def isolated_mask(dag: DagState, m: int) -> jnp.ndarray:
    """Transactions with <= m approvals are isolated (§V.4)."""
    return (dag.publisher >= 0) & (dag.approval_count <= m)


# ---------------------------------------------------------------------------
# Merge: reduction-friendly views shared by the scalar fold and the fused
# gossip kernel (repro.kernels.gossip_merge)
# ---------------------------------------------------------------------------


class MergeViews(NamedTuple):
    """One ``DagState`` split by merge role.

    ``keys``        the (publish_time, publisher) row identity the winner
                    rule reduces over;
    ``approvers``   per-row approver-node bitsets — merged as the exact set
                    UNION (bitwise OR) across candidates holding the winning
                    identity; ``approval_count`` is rederived as the union's
                    popcount, never taken from any single candidate;
    ``payload``     row-addressed leaves that follow the winning identity
                    wholesale (keys included: the winner's bits survive);
    ``watermarks``  monotone ledger-wide counters merged by element-wise max.

    The scalar two-replica ``merge``, the N-way union fold
    (``repro.net.replica.merge_all``), and the fused anti-entropy kernel all
    consume these views, so a new ``DagState`` field only needs to be
    classified here once to merge correctly everywhere.
    """

    keys: Tuple[jnp.ndarray, jnp.ndarray]       # (publish_time, publisher)
    approvers: jnp.ndarray                      # (cap, N) bool
    payload: Tuple[Tuple[str, jnp.ndarray], ...]
    watermarks: Tuple[Tuple[str, jnp.ndarray], ...]


def merge_views(dag: DagState) -> MergeViews:
    return MergeViews(
        keys=(dag.publish_time, dag.publisher),
        approvers=dag.approvers,
        payload=(
            ("publisher", dag.publisher),
            ("publish_time", dag.publish_time),
            ("approvals", dag.approvals),
            ("accuracy", dag.accuracy),
            ("auth_tag", dag.auth_tag),
            ("model_slot", dag.model_slot),
        ),
        watermarks=(
            ("count", dag.count),
            ("published_per_node", dag.published_per_node),
            ("contributing_m0", dag.contributing_m0),
            ("contributing_m1", dag.contributing_m1),
        ),
    )


def row_winner(
    local_keys: Tuple[jnp.ndarray, jnp.ndarray],
    remote_keys: Tuple[jnp.ndarray, jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(take_remote, same_tx) masks — THE row-merge rule.

    A slot occupied on one side only adopts that side; two different
    transactions resolve to the lexicographically larger
    ``(publish_time, publisher)`` key (ring semantics make the later
    transaction the overwriting one; publisher id breaks exact time ties, so
    the rule is deterministic, commutative, and associative); the same
    transaction on both sides is ``same_tx`` (approver sets union).
    """
    l_time, l_pub = local_keys
    r_time, r_pub = remote_keys
    l_occ = l_pub >= 0
    r_occ = r_pub >= 0
    same_tx = l_occ & r_occ & (l_time == r_time) & (l_pub == r_pub)
    remote_newer = (r_time > l_time) | ((r_time == l_time) & (r_pub > l_pub))
    take_remote = (r_occ & ~l_occ) | (r_occ & l_occ & ~same_tx & remote_newer)
    return take_remote, same_tx


def merge(local: DagState, remote: DagState) -> DagState:
    """Anti-entropy reconciliation of two replicas of the same logical ledger
    (§III.A: each node's local DAG is "updated by communicating with adjacent
    nodes").

    Row-wise by the ``row_winner`` rule over ``merge_views``:

    * payload leaves follow the winning ``(publish_time, publisher)``
      identity wholesale;
    * the *same* transaction on both sides keeps the UNION of the two
      approver bitsets (a grow-only set CRDT that never un-approves) and
      rederives ``approval_count`` as the union's popcount. Each replica may
      have credited a disjoint subset of approvers; the exact union counts
      every distinct approver once — duplicate approvals across stale (or
      adversarially replayed) views no longer collapse to a single max;
    * ``count`` and the per-node contribution counters are monotone
      watermarks and merge by element-wise max, so they never decrease.

    The N-way fold of this function is what ``merge_select`` (driven by the
    fused ``repro.kernels.gossip_merge`` winner reduction) computes in one
    masked pass.
    """
    lv, rv = merge_views(local), merge_views(remote)
    take_remote, same_tx = row_winner(lv.keys, rv.keys)
    remote_payload = dict(rv.payload)

    def pick(a, b):
        sel = take_remote.reshape(take_remote.shape + (1,) * (a.ndim - 1))
        return jnp.where(sel, b, a)

    approvers = jnp.where(take_remote[:, None], rv.approvers, lv.approvers)
    approvers = jnp.where(same_tx[:, None], lv.approvers | rv.approvers, approvers)
    fields = {name: pick(a, remote_payload[name]) for name, a in lv.payload}
    fields.update(
        {name: jnp.maximum(a, dict(rv.watermarks)[name]) for name, a in lv.watermarks}
    )
    return DagState(
        approvers=approvers,
        approval_count=jnp.sum(approvers.astype(jnp.int32), axis=1),
        **fields,
    )


def merge_select(
    dags: DagState,
    src: jnp.ndarray,             # (Rr, cap) i32 winner indices per row
    mask: jnp.ndarray = None,     # (Rr, R) bool dense candidate mask
    nbr_idx: jnp.ndarray = None,  # (Rr, D) i32 candidate lists (sparse form)
    nbr_act: jnp.ndarray = None,  # (Rr, D) bool candidate activity
) -> DagState:
    """Materialize merged replicas from per-row winner indices.

    The counterpart of the fused winner reduction
    (``repro.kernels.gossip_merge`` / ``repro.kernels.ref``): payload leaves
    gather the winning sender's row (``out[i, r] = leaf[src[i, r], r]``) and
    watermark leaves max-reduce over the candidate senders — given either as
    a dense (Rr, R) ``mask`` (the Pallas/TPU form) or as per-receiver
    ``(nbr_idx, nbr_act)`` candidate lists (the degree-compressed form; the
    receiver itself must be an active candidate). Approver bitsets take the
    exact OR-union over every candidate holding the winning row identity and
    ``approval_count`` is the union's popcount — NOT the winner reduction's
    union-by-max counter, which undercounts when replicas credited disjoint
    approvers (the kernels' ``ac`` output is now only an array-level
    reduction invariant, unused here). ``dags`` is a stacked replica set —
    every leaf carries a leading (R, ...) axis (see ``repro.net.replica``).
    """
    views = merge_views(dags)

    def gather(x):
        idx = src
        while idx.ndim < x.ndim:
            idx = idx[..., None]
        return jnp.take_along_axis(x, idx, axis=0)

    if mask is not None:
        def watermark(w):
            m = mask.reshape(mask.shape + (1,) * (w.ndim - 1))
            return jnp.max(jnp.where(m, w[None], 0), axis=1)
    else:
        def watermark(w):
            m = nbr_act.reshape(nbr_act.shape + (1,) * (w.ndim - 1))
            return jnp.max(jnp.where(m, w[nbr_idx], 0), axis=1)

    fields = {name: gather(x) for name, x in views.payload}
    fields.update({name: watermark(w) for name, w in views.watermarks})

    # Exact approver union: a candidate contributes its bitset for row r iff
    # it is active and holds the winning (publish_time, publisher) identity.
    # The 0/1 float einsum contracts over candidates without materializing
    # the (Rr, R, cap, N) broadcast; sums are exact in f32 (N << 2**24).
    w_time, w_pub = fields["publish_time"], fields["publisher"]
    t_all, p_all = views.keys
    if mask is not None:
        same = (
            mask[:, :, None]
            & (p_all[None] == w_pub[:, None])
            & (t_all[None] == w_time[:, None])
            & (w_pub[:, None] >= 0)
        )
        union = jnp.einsum(
            "ijr,jrn->irn", same.astype(jnp.float32),
            views.approvers.astype(jnp.float32),
        ) > 0
    else:
        same = (
            nbr_act[:, :, None]
            & (p_all[nbr_idx] == w_pub[:, None])
            & (t_all[nbr_idx] == w_time[:, None])
            & (w_pub[:, None] >= 0)
        )
        union = jnp.einsum(
            "ijr,ijrn->irn", same.astype(jnp.float32),
            views.approvers[nbr_idx].astype(jnp.float32),
        ) > 0

    return DagState(
        approvers=union,
        approval_count=jnp.sum(union.astype(jnp.int32), axis=-1),
        **fields,
    )
