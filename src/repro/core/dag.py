"""The DAG ledger: fixed-capacity struct-of-arrays, fully jittable.

Transactions are rows of parallel arrays; approvals are index edges that
always point to OLDER rows (acyclicity by construction). Capacity is a ring:
slots older than ``tau_max`` can never be tips again (§IV.B), so evicting
the oldest row is semantically safe; per-node contribution statistics are
kept as cumulative counters (updated the moment a transaction crosses the
``m`` approvals threshold) so Table-IV metrics survive eviction.

The model payload of each transaction lives in a separate "model bank"
(see ``repro.core.bank``); rows store only the bank slot.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

NO_TX = jnp.int32(-1)


class DagState(NamedTuple):
    publisher: jnp.ndarray          # (cap,) int32  node id, -1 = empty
    publish_time: jnp.ndarray       # (cap,) f32
    approvals: jnp.ndarray          # (cap, k) int32 indices approved by row
    approval_count: jnp.ndarray     # (cap,) int32  times row was approved
    accuracy: jnp.ndarray           # (cap,) f32    validation accuracy at publish
    auth_tag: jnp.ndarray           # (cap,) f32    integrity checksum of payload
    model_slot: jnp.ndarray         # (cap,) int32  index into the model bank
    count: jnp.ndarray              # () int32      total ever published
    # cumulative per-node stats (Table IV), for isolation thresholds m=0,1
    published_per_node: jnp.ndarray     # (N,) int32
    contributing_m0: jnp.ndarray        # (N,) int32  rows that got > 0 approvals
    contributing_m1: jnp.ndarray        # (N,) int32  rows that got > 1 approvals


def empty_dag(capacity: int, k: int, num_nodes: int) -> DagState:
    return DagState(
        publisher=jnp.full((capacity,), NO_TX, jnp.int32),
        publish_time=jnp.zeros((capacity,), jnp.float32),
        approvals=jnp.full((capacity, k), NO_TX, jnp.int32),
        approval_count=jnp.zeros((capacity,), jnp.int32),
        accuracy=jnp.zeros((capacity,), jnp.float32),
        auth_tag=jnp.zeros((capacity,), jnp.float32),
        model_slot=jnp.full((capacity,), NO_TX, jnp.int32),
        count=jnp.zeros((), jnp.int32),
        published_per_node=jnp.zeros((num_nodes,), jnp.int32),
        contributing_m0=jnp.zeros((num_nodes,), jnp.int32),
        contributing_m1=jnp.zeros((num_nodes,), jnp.int32),
    )


def capacity_of(dag: DagState) -> int:
    return dag.publisher.shape[0]


def publish(
    dag: DagState,
    publisher: jnp.ndarray,      # () int32
    time: jnp.ndarray,           # () f32
    approvals: jnp.ndarray,      # (k,) int32, NO_TX padded
    accuracy: jnp.ndarray,       # () f32
    auth_tag: jnp.ndarray,       # () f32
    model_slot: jnp.ndarray,     # () int32
) -> DagState:
    """Append a transaction (Algorithm 2 stage 4) and credit approvals."""
    cap = capacity_of(dag)
    row = jnp.mod(dag.count, cap)

    # credit each approved transaction; track threshold crossings
    def credit(carry, tx):
        ac, c0, c1 = carry
        ok = tx >= 0
        idx = jnp.maximum(tx, 0)
        old = ac[idx]
        ac = ac.at[idx].add(jnp.where(ok, 1, 0))
        pub = dag.publisher[idx]
        crossed0 = ok & (old == 0) & (pub >= 0)
        crossed1 = ok & (old == 1) & (pub >= 0)
        safe_pub = jnp.maximum(pub, 0)
        c0 = c0.at[safe_pub].add(jnp.where(crossed0, 1, 0))
        c1 = c1.at[safe_pub].add(jnp.where(crossed1, 1, 0))
        return (ac, c0, c1), None

    (ac, c0, c1), _ = jax.lax.scan(
        credit, (dag.approval_count, dag.contributing_m0, dag.contributing_m1), approvals
    )

    return DagState(
        publisher=dag.publisher.at[row].set(publisher.astype(jnp.int32)),
        publish_time=dag.publish_time.at[row].set(time.astype(jnp.float32)),
        approvals=dag.approvals.at[row].set(approvals.astype(jnp.int32)),
        approval_count=ac.at[row].set(0),
        accuracy=dag.accuracy.at[row].set(accuracy.astype(jnp.float32)),
        auth_tag=dag.auth_tag.at[row].set(auth_tag.astype(jnp.float32)),
        model_slot=dag.model_slot.at[row].set(model_slot.astype(jnp.int32)),
        count=dag.count + 1,
        published_per_node=dag.published_per_node.at[publisher].add(1),
        contributing_m0=c0,
        contributing_m1=c1,
    )


def tip_mask(dag: DagState, now: jnp.ndarray, tau_max: float) -> jnp.ndarray:
    """Tips (§II.B / §IV.B): occupied, unapproved, staleness <= tau_max."""
    fresh = (now - dag.publish_time) <= tau_max
    return (dag.publisher >= 0) & (dag.approval_count == 0) & fresh


def select_tips(
    dag: DagState,
    key: jnp.ndarray,
    alpha: int,
    now: jnp.ndarray,
    tau_max: float,
    node_bias=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sample up to alpha tips without replacement (stage 1).

    Returns (idx (alpha,) int32 with NO_TX padding, num_valid ()).
    Gumbel top-k gives an exact uniform sample under jit. ``node_bias``
    ((num_nodes+1,) log-weights indexed by publisher) skews the draw —
    used by §VI.B credit-weighted selection and by the simulator's
    backdoor JOINT attack (§V.A.4).
    """
    mask = tip_mask(dag, now, tau_max)
    cap = capacity_of(dag)
    gumbel = -jnp.log(-jnp.log(jax.random.uniform(key, (cap,), minval=1e-9, maxval=1.0)))
    if node_bias is not None:
        gumbel = gumbel + node_bias[jnp.maximum(dag.publisher, 0)]
    scores = jnp.where(mask, gumbel, -jnp.inf)
    top_scores, top_idx = jax.lax.top_k(scores, alpha)
    ok = jnp.isfinite(top_scores)
    idx = jnp.where(ok, top_idx, NO_TX).astype(jnp.int32)
    return idx, jnp.sum(ok.astype(jnp.int32))


def num_tips(dag: DagState, now: jnp.ndarray, tau_max: float) -> jnp.ndarray:
    return jnp.sum(tip_mask(dag, now, tau_max).astype(jnp.int32))


def isolated_mask(dag: DagState, m: int) -> jnp.ndarray:
    """Transactions with <= m approvals are isolated (§V.4)."""
    return (dag.publisher >= 0) & (dag.approval_count <= m)


def merge(local: DagState, remote: DagState) -> DagState:
    """Gossip reconciliation: adopt the longer history (row-wise max merge).

    Both replicas share the append order (publish is serialized through the
    global ledger in the runtime), so the element-wise maximum of counters
    plus preferring rows from the longer chain reproduces §III.A's
    "local DAG updated by communicating with adjacent nodes".
    """
    take_remote = remote.count > local.count

    def pick(a, b):
        return jnp.where(take_remote, b, a)

    picked = jax.tree_util.tree_map(pick, local, remote)
    # approval counts / contribution counters advance monotonically: take max
    return picked._replace(
        approval_count=jnp.maximum(local.approval_count, remote.approval_count)
        * (picked.publisher >= 0),
        contributing_m0=jnp.maximum(local.contributing_m0, remote.contributing_m0),
        contributing_m1=jnp.maximum(local.contributing_m1, remote.contributing_m1),
    )
