"""Model bank: transaction payloads as one stacked pytree.

Slot i of every leaf is transaction i's model. Keeping payloads stacked
(instead of a python list) lets tip validation vmap over candidates, lets
Eq.-1 aggregation be a one-hot matmul (shardable over the ``model`` mesh
axis), and gives checkpointing a single pytree to serialize.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_bank(template: Any, slots: int) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((slots,) + p.shape, p.dtype), template
    )


def bank_write(bank: Any, slot: jnp.ndarray, params: Any) -> Any:
    return jax.tree_util.tree_map(lambda b, p: b.at[slot].set(p), bank, params)


def bank_read(bank: Any, slot: jnp.ndarray) -> Any:
    return jax.tree_util.tree_map(lambda b: b[slot], bank)


def bank_gather(bank: Any, slots: jnp.ndarray) -> Any:
    """slots (k,) -> stacked params with leading k (invalid slots clamp to 0)."""
    safe = jnp.maximum(slots, 0)
    return jax.tree_util.tree_map(lambda b: b[safe], bank)


def bank_average(bank: Any, slots: jnp.ndarray, weights: jnp.ndarray) -> Any:
    """Eq. (1) over bank slots via one-hot matmul (GSPMD-friendly).

    slots (k,) int32 (NO_TX = -1 entries get zero weight); weights (k,) f32.
    """
    n = jax.tree_util.tree_leaves(bank)[0].shape[0]
    w = jnp.where(slots >= 0, weights, 0.0).astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-9)       # renormalize over VALID slots
    onehot = jax.nn.one_hot(jnp.maximum(slots, 0), n, dtype=jnp.float32) * w[:, None]
    coeff = jnp.sum(onehot, axis=0)                       # (slots,)

    def avg(b):
        flat = b.reshape(n, -1).astype(jnp.float32)
        out = coeff @ flat
        return out.reshape(b.shape[1:]).astype(b.dtype)

    return jax.tree_util.tree_map(avg, bank)


def auth_checksum(params: Any) -> jnp.ndarray:
    """Cheap integrity tag standing in for the RSA signature (DESIGN.md §3).

    A fixed pseudo-random projection of every leaf — any bit flip in the
    payload moves the tag; impersonation (publishing someone else's params
    under a new tag) is what the simulator's lazy nodes do.
    """
    total = jnp.zeros((), jnp.float32)
    for i, leaf in enumerate(jax.tree_util.tree_leaves(params)):
        flat = leaf.reshape(-1).astype(jnp.float32)
        idx = jnp.arange(flat.shape[0], dtype=jnp.float32)
        proj = jnp.cos(idx * (0.618033988749895 + 0.001 * i))
        total = total + jnp.dot(flat, proj)
    return total
