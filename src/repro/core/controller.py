"""Algorithm 1 — DAG-FL Controlling, run by the external agent E.

E is a host-side smart-contract analogue: it publishes the genesis
transaction, periodically reconstructs a candidate target model from the
best-k tips of its local DAG, and broadcasts the end signal once
ACC_t >= ACC_0.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DagFLConfig
from repro.core import aggregation as agg
from repro.core import bank as bank_lib
from repro.core import dag as dag_lib
from repro.core import validation as val_lib


@dataclass
class ControllerState:
    dag: dag_lib.DagState
    bank: Any
    done: bool = False
    best_accuracy: float = 0.0
    target_model: Any = None
    checks: int = 0


class Controller:
    """External agent E (Algorithm 1)."""

    def __init__(
        self,
        cfg: DagFLConfig,
        eval_fn: Callable[[Any, Any], jnp.ndarray],
        target_accuracy: Optional[float] = None,
    ):
        self.cfg = cfg
        self.eval_fn = eval_fn
        self.validator = val_lib.make_validator(eval_fn)
        self.acc0 = target_accuracy if target_accuracy is not None else cfg.target_accuracy

    def genesis(self, init_params: Any, val_batch, capacity: Optional[int] = None) -> ControllerState:
        """Initialize the ledger with the initial model transaction."""
        cap = capacity or self.cfg.capacity
        dag = dag_lib.empty_dag(cap, self.cfg.k, self.cfg.num_nodes + 1)
        bank = bank_lib.init_bank(init_params, cap)
        bank = bank_lib.bank_write(bank, jnp.asarray(0), init_params)
        acc = self.eval_fn(init_params, val_batch)
        dag = dag_lib.publish(
            dag,
            jnp.asarray(self.cfg.num_nodes, jnp.int32),     # E's node id
            jnp.asarray(0.0, jnp.float32),
            jnp.full((self.cfg.k,), dag_lib.NO_TX, jnp.int32),
            jnp.asarray(acc, jnp.float32),
            bank_lib.auth_checksum(init_params),
            jnp.asarray(0, jnp.int32),
        )
        return ControllerState(dag=dag, bank=bank)

    def check(self, state: ControllerState, key, now: float, val_batch) -> ControllerState:
        """One Algorithm-1 loop body: validate alpha tips, build omega_0,
        test ACC_t >= ACC_0."""
        rows, _ = dag_lib.select_tips(
            state.dag, key, self.cfg.alpha, jnp.asarray(now, jnp.float32), self.cfg.tau_max
        )
        slots = jnp.where(rows >= 0, state.dag.model_slot[jnp.maximum(rows, 0)], -1)
        accs = self.validator(state.bank, slots, val_batch)
        chosen, _, top_acc = val_lib.select_top_k(accs, slots, self.cfg.k)
        n_ok = int(jnp.sum(chosen >= 0))
        if n_ok == 0:
            state.checks += 1
            return state
        model = bank_lib.bank_average(
            state.bank, chosen, agg.uniform_weights(self.cfg.k)
        )
        acc_t = float(self.eval_fn(model, val_batch))
        state.checks += 1
        if acc_t > state.best_accuracy:
            state.best_accuracy = acc_t
            state.target_model = model
        if acc_t >= self.acc0:
            state.done = True                               # end signal to D
        return state
