"""Stage-2 validation: authenticate tips + score their models (consensus).

``make_validator(eval_fn)`` builds a jittable function that, given the model
bank and alpha candidate slots, returns per-candidate accuracy — a single
vmapped forward pass over the candidate axis. The paper validates with a
small local test set (Section III.B); the same hook accepts any scorer
(e.g. the autoencoder idea of §VI.A).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import bank as bank_lib


def make_validator(eval_fn: Callable[[Any, Any], jnp.ndarray]):
    """eval_fn(params, batch) -> scalar accuracy in [0, 1]."""

    def validate(model_bank, slots: jnp.ndarray, batch) -> jnp.ndarray:
        """slots (alpha,) int32 (NO_TX padded) -> accuracies (alpha,) f32.

        Invalid slots score -inf so top-k never picks them.
        """
        cands = bank_lib.bank_gather(model_bank, slots)
        accs = jax.vmap(lambda p: eval_fn(p, batch))(cands)
        return jnp.where(slots >= 0, accs.astype(jnp.float32), -jnp.inf)

    return validate


def authenticate(dag_tags: jnp.ndarray, model_bank, slots: jnp.ndarray) -> jnp.ndarray:
    """Recompute payload checksums and compare with the published tags."""
    cands = bank_lib.bank_gather(model_bank, slots)
    tags = jax.vmap(bank_lib.auth_checksum)(cands)
    stored = dag_tags[jnp.maximum(slots, 0)]
    ok = jnp.abs(tags - stored) <= 1e-3 * (1.0 + jnp.abs(stored))
    return ok & (slots >= 0)


def select_top_k(accuracies: jnp.ndarray, slots: jnp.ndarray, k: int):
    """Stage 3: keep the k highest-accuracy validated tips.

    Returns (chosen slots (k,), their dag rows? caller keeps mapping, gates).
    """
    top_acc, top_pos = jax.lax.top_k(accuracies, k)
    chosen = jnp.where(jnp.isfinite(top_acc), slots[top_pos], -1)
    return chosen.astype(jnp.int32), top_pos, top_acc
