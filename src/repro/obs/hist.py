"""Device-resident streaming histograms for the gossip overlay.

The fixed-capacity series in ``repro.obs.metrics`` keeps the FIRST
``series_capacity`` raw samples and drops the rest — honest, but the
paper's §IV claims (tip equilibria, iteration delays, confirmation
latencies) are *distributional*: percentile statements over every sample
an unbounded horizon produces. This module adds the complementary
accumulator: a streaming histogram with fixed log-spaced bin edges keyed
by a frozen ``HistConfig``, counts as i32 arrays that NEVER drop a sample
(out-of-range values fold into the first / overflow bin instead of
vanishing), small enough to ride the same scan/while-loop carries as
``MetricsState`` — it lives in ``MetricsState.hist`` and is updated by
``repro.obs.observe_round`` when ``ObsConfig.hist`` is set.

Bin layout (``bins`` regular bins + 1 overflow, counts shape (bins+1,)):

  bin 0          v <= edges[1]           (underflow folds in; the bound
                                          below a bin-0 percentile is 0)
  bin i          edges[i] < v <= edges[i+1]   for 1 <= i < bins
  bin ``bins``   v > edges[bins] = hi    (overflow; a percentile landing
                                          here reports hi with err = inf)

with ``edges[i] = lo * (hi/lo)**(i/bins)`` — log-spacing makes the
percentile error a fixed RELATIVE bound, ``(hi/lo)**(1/bins) - 1``
(~33% per bin at the 8-decade default), the right shape for latency
tails.

Histograms collected (all in one shared ``HistState`` pytree):

  ``merge_lat``    per-row publish -> first-merge latency: every round,
                   each (replica, row) whose row IDENTITY changed
                   (publisher or publish_time — approval-credit drift is
                   not a first sight) samples ``t - publish_time``;
  ``commit_lat``   per-row publish -> commit latency, where "commit" is
                   full propagation: the first sample instant at which
                   every replica agrees on the row's identity — the §IV
                   confirmation-delay distribution. ``all_have`` latches
                   which rows were already propagated so each row version
                   samples exactly once (ring reuse re-arms the latch);
  ``chunk_lat``    bank transport: each chunk bit newly set this round
                   samples ``t - publish_time`` of the receiver's view of
                   the slot's row (weight = chunks completed; slots whose
                   row has not merged yet have no reference and skip);
  ``queue_wait``   per-request admission wait in ``repro.net.serve``:
                   an arrival-instant FIFO (``qwait_t``/``qwait_head``,
                   capacity = the serve queue's) mirrors the queue
                   counter exactly, so each admitted request samples its
                   own ``t - arrival``;
  ``serve_stale``  per-request staleness at serve (weight = batch size
                   admitted at that node's staleness).

Everything here is a PURE READ of the simulation state — the hist-on run
is bitwise the hist-off run (``tests/test_hist.py`` pins it across
ticks/events x bank x serve x faulted arms), and ``hist=None`` (the
default) keeps every jitted program literally what it was.

The bin scatter-add runs through ``repro.kernels.ops.hist_bincount``
(blocked Pallas kernel on TPU, pure-lax oracle elsewhere — the
``gossip_winner`` dispatch rule). Host-side percentile extraction
(``percentile`` / ``summary``) reports the quantile bin's upper edge with
its bin width as the error bound; ``tests/test_hist.py`` property-tests
the bound against exact ``numpy.percentile`` of replayed samples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

HIST_NAMES = ("merge_lat", "commit_lat", "chunk_lat", "queue_wait",
              "serve_stale")


@dataclass(frozen=True)
class HistConfig:
    """Histogram knobs (frozen + hashable: rides ``ObsConfig`` into the
    jit-factory cache keys).

    ``bins`` regular log-spaced bins spanning ``[lo, hi]`` plus one
    overflow bin; ``impl`` picks the bincount backend ("pallas"/"lax",
    None = pallas on TPU, lax elsewhere — the shared dispatcher rule).
    """

    bins: int = 64
    lo: float = 1e-4
    hi: float = 1e4
    impl: Optional[str] = None


class HistState(NamedTuple):
    """The streaming-histogram carry (shapes static per (B, cap, N, Q))."""

    merge_lat: jnp.ndarray    # (B+1,) i32 publish -> first-merge latency
    commit_lat: jnp.ndarray   # (B+1,) i32 publish -> full propagation
    chunk_lat: jnp.ndarray    # (B+1,) i32 chunk transfer-completion delay
    queue_wait: jnp.ndarray   # (B+1,) i32 per-request admission wait
    serve_stale: jnp.ndarray  # (B+1,) i32 per-request staleness at serve
    all_have: jnp.ndarray     # (cap,) bool rows already fully propagated
    qwait_t: jnp.ndarray      # (N, Q) f32 arrival-instant FIFO per node
    qwait_head: jnp.ndarray   # (N,) i32 FIFO head (pops advance it mod Q)


def edges(cfg: HistConfig) -> np.ndarray:
    """(bins+1,) float64 log-spaced edges, ``edges[0]=lo .. edges[-1]=hi``."""
    b = int(cfg.bins)
    return cfg.lo * (cfg.hi / cfg.lo) ** (np.arange(b + 1) / b)


def bin_index(values: jnp.ndarray, cfg: HistConfig) -> jnp.ndarray:
    """i32 bin index in [0, bins] for each value (jit-safe).

    ``v <= lo`` maps to 0 (underflow folds into the first bin),
    ``v > hi`` to the overflow bin ``bins`` — no sample is ever dropped.
    """
    b = int(cfg.bins)
    ratio = float(np.log(cfg.hi / cfg.lo) / b)
    v = jnp.maximum(jnp.asarray(values, jnp.float32), jnp.float32(cfg.lo))
    x = jnp.log(v / jnp.float32(cfg.lo)) / jnp.float32(ratio)
    idx = jnp.ceil(x).astype(jnp.int32) - 1
    return jnp.clip(idx, 0, b)


def record(counts: jnp.ndarray, values, weights, cfg: HistConfig):
    """counts + bincount(values binned per ``cfg``, weighted) — jit-safe.

    ``values`` f32 and ``weights`` i32 flatten together; zero-weight
    entries contribute nothing, which is how masked batches ride a fixed
    shape. Dispatches through ``ops.hist_bincount`` (Pallas on TPU).
    """
    from repro.kernels import ops  # deferred: keep obs importable early

    idx = bin_index(jnp.ravel(values), cfg)
    w = jnp.ravel(jnp.asarray(weights)).astype(jnp.int32)
    return counts + ops.hist_bincount(
        idx, w, int(cfg.bins) + 1, impl=cfg.impl
    )


def rows_propagated(dags) -> jnp.ndarray:
    """(cap,) bool — rows whose identity every replica agrees on.

    Replica 0 is the reference; a row is "committed" (fully propagated)
    once it is occupied and every replica holds the same
    (publisher, publish_time). Approval credit keeps accruing after
    propagation and is deliberately not part of the predicate.
    """
    p0 = dags.publisher[0]
    t0 = dags.publish_time[0]
    agree = jnp.all(
        (dags.publisher == p0[None, :])
        & (dags.publish_time == t0[None, :]),
        axis=0,
    )
    return agree & (p0 >= 0)


def init_hist(cfg: HistConfig, dags, queue_cap: int = 0) -> HistState:
    """Fresh carry for the stacked replicas ``dags``.

    ``all_have`` starts from the ACTUAL initial propagation state (the
    genesis row is everywhere already — it must not sample a bogus
    commit latency at the first round). ``queue_cap`` sizes the serve
    arrival FIFO; 0 (no serving) keeps zero-size arrays that no traced
    path touches.
    """
    b = int(cfg.bins) + 1
    n = dags.publisher.shape[0]
    q = int(queue_cap)
    return HistState(
        merge_lat=jnp.zeros((b,), jnp.int32),
        commit_lat=jnp.zeros((b,), jnp.int32),
        chunk_lat=jnp.zeros((b,), jnp.int32),
        queue_wait=jnp.zeros((b,), jnp.int32),
        serve_stale=jnp.zeros((b,), jnp.int32),
        all_have=rows_propagated(dags),
        qwait_t=jnp.zeros((n, q), jnp.float32),
        qwait_head=jnp.zeros((n,), jnp.int32),
    )


def observe(
    cfg: HistConfig,
    h: HistState,
    t,                       # () f32 sample instant
    old_dags,                # stacked replicas BEFORE the round
    new_dags,                # stacked replicas AFTER the round
    old_have=None,           # (N, S, C) bool chunk presence BEFORE (bank)
    bstate=None,             # post-round BankState (bank runs only)
    serve_arrived=None,      # (N,) i32 arrivals fired at this instant
    serve_enq=None,          # (N,) i32 arrivals that found queue room
    serve_admit=None,        # (N,) i32 batch sizes admitted at this instant
    serve_queued=None,       # (N,) i32 queue length AFTER admission
    serve_stale_node=None,   # (N,) i32 gated staleness per node now
) -> HistState:
    """One histogram accumulation step (jit-safe, pure read).

    Runs inside ``observe_round`` when ``ObsConfig.hist`` is set; every
    argument is state the loop body already carries, so the update adds
    no new data dependencies to the simulation.
    """
    t = jnp.asarray(t, jnp.float32)

    # publish -> first merge: rows whose identity changed on some replica
    changed = (
        (new_dags.publisher != old_dags.publisher)
        | (new_dags.publish_time != old_dags.publish_time)
    ) & (new_dags.publisher >= 0)
    lat = jnp.maximum(t - new_dags.publish_time, 0.0)
    merge_lat = record(h.merge_lat, lat, changed, cfg)

    # publish -> commit (full propagation): first instant all replicas
    # agree; the latch makes each row version sample exactly once
    prop = rows_propagated(new_dags)
    newly = prop & ~h.all_have
    clat = jnp.maximum(t - new_dags.publish_time[0], 0.0)
    commit_lat = record(h.commit_lat, clat, newly, cfg)
    all_have = prop

    # chunk transfer completion: chunks that landed this round, dated
    # against the receiver's merged view of the slot's row
    chunk_lat = h.chunk_lat
    if bstate is not None and old_have is not None:
        arrived = jnp.sum(
            (bstate.have & ~old_have).astype(jnp.int32), axis=-1
        )                                               # (N, S) new chunks
        known = new_dags.publisher >= 0                 # (N, S) row merged
        w = jnp.where(known, arrived, 0)
        slat = jnp.maximum(t - new_dags.publish_time, 0.0)
        chunk_lat = record(h.chunk_lat, slat, w, cfg)

    # per-request queue wait + staleness at serve: the arrival FIFO
    # mirrors the serve queue counter exactly (push the enqueued
    # arrivals at t, pop the admitted batch from the head)
    queue_wait, serve_stale = h.queue_wait, h.serve_stale
    qwait_t, qwait_head = h.qwait_t, h.qwait_head
    qcap = h.qwait_t.shape[1]
    if serve_admit is not None and qcap > 0:
        n = qwait_t.shape[0]
        enq = serve_enq.astype(jnp.int32)
        adm = serve_admit.astype(jnp.int32)
        # queue length before this instant's pushes: post-admission
        # length + admitted - enqueued
        len_before = serve_queued.astype(jnp.int32) + adm - enq
        tail = (qwait_head + len_before) % qcap
        rows = jnp.arange(n, dtype=jnp.int32)
        qwait_t = qwait_t.at[rows, tail].set(
            jnp.where(enq > 0, t, qwait_t[rows, tail])
        )
        j = jnp.arange(qcap, dtype=jnp.int32)
        take = j[None, :] < adm[:, None]                  # (N, Q)
        slots = (qwait_head[:, None] + j[None, :]) % qcap
        waits = jnp.maximum(t - jnp.take_along_axis(qwait_t, slots, 1), 0.0)
        queue_wait = record(queue_wait, waits, take, cfg)
        serve_stale = record(
            serve_stale, serve_stale_node.astype(jnp.float32), adm, cfg
        )
        qwait_head = (qwait_head + adm) % qcap

    return HistState(
        merge_lat=merge_lat, commit_lat=commit_lat, chunk_lat=chunk_lat,
        queue_wait=queue_wait, serve_stale=serve_stale, all_have=all_have,
        qwait_t=qwait_t, qwait_head=qwait_head,
    )


# ---------------------------------------------------------------------------
# Host-side percentile extraction
# ---------------------------------------------------------------------------


def percentile(counts: np.ndarray, cfg: HistConfig, q: float):
    """(value, err) — the q-th percentile with its bin-resolution bound.

    Inverted-CDF over the bins: the reported value is the UPPER edge of
    the bin holding the ceil(q/100 * total)-th sample, the error bound
    its bin width (bin 0's support extends down to 0, so its bound is
    the full first edge; the overflow bin reports ``hi`` with err=inf).
    The exact percentile of the replayed samples lies within ``err`` of
    the reported value (property-tested in ``tests/test_hist.py``).

    Returns ``(nan, nan)`` on an empty histogram.
    """
    counts = np.asarray(counts)
    total = int(counts.sum())
    if total == 0:
        return float("nan"), float("nan")
    rank = max(int(np.ceil(q / 100.0 * total)), 1)
    b = int(np.searchsorted(np.cumsum(counts), rank))
    e = edges(cfg)
    if b >= int(cfg.bins):
        return float(e[-1]), float("inf")
    value = float(e[b + 1])
    err = float(e[b + 1]) if b == 0 else float(e[b + 1] - e[b])
    return value, err


def summary(counts: np.ndarray, cfg: HistConfig,
            qs=(50.0, 95.0, 99.0)) -> dict:
    """{"samples", "p50", "p50_err", ...} for one histogram (host-side)."""
    out = {"samples": int(np.asarray(counts).sum())}
    for q in qs:
        v, err = percentile(counts, cfg, q)
        key = f"p{q:g}".replace(".", "_")
        out[key] = v
        out[f"{key}_err"] = err
    return out


def report_dict(h: HistState, cfg: HistConfig) -> dict:
    """Drain one ``HistState`` to a host dict for ``ObsReport.hist``."""
    counts = {name: np.asarray(getattr(h, name)) for name in HIST_NAMES}
    return {
        "bins": int(cfg.bins),
        "lo": float(cfg.lo),
        "hi": float(cfg.hi),
        "edges": edges(cfg),
        "counts": counts,
        "percentiles": {
            name: summary(c, cfg) for name, c in counts.items()
        },
    }
