"""Device-resident telemetry for the gossip overlay (``repro.obs``).

Collectors that live INSIDE the jitted loops as pytree carries — metric
accumulators (``repro.obs.metrics``) and an event trace ring
(``repro.obs.trace``) threaded through the tick advance scan, the
converge while-loop, and both event-engine advance jits — plus host-side
export (``repro.obs.export``: Chrome/Perfetto traces, JSONL metrics).

Contract: collection is a PURE READ. Obs-enabled runs split the same PRNG
keys and produce bitwise the same final state as obs-off runs; obs-off
(``obs_cfg=None``, the default everywhere) leaves every jitted program
literally unchanged. ``tests/test_obs.py`` pins both claims over engines,
round impls, topologies, partitions, the bank, and the mesh.

Entry points: ``GossipNetwork(obs_cfg=ObsConfig(...))``,
``run_dagfl_gossip(obs=ObsConfig(...))`` -> ``SimResult.extras["obs"]``
(an ``ObsReport``), and ``scripts/obs_report.py`` for files on disk.
"""
import jax.numpy as jnp

from repro.obs import hist as _hist_lib
from repro.obs import metrics as _metrics_lib
from repro.obs import trace as _trace_lib
from repro.obs.export import (ObsReport, chrome_trace, metrics_jsonl_lines,
                              write_chrome_trace, write_metrics_jsonl)
from repro.obs.hist import HistConfig, HistState, init_hist
from repro.obs.metrics import MetricsState, ObsConfig, init_metrics
from repro.obs.trace import (KIND_COMMIT, KIND_DELIVER, KIND_DRAIN,
                             KIND_INFER, KIND_PARTITION, KIND_PUBLISH,
                             KIND_REJECT, TraceRing, init_trace)


def observe_round(
    cfg: ObsConfig,
    metrics: MetricsState,
    ring: TraceRing,
    t,                        # () f32 sample instant
    old_dags,                 # stacked replicas BEFORE the round
    new_dags,                 # stacked replicas AFTER the round
    live_edges=None,          # (N, N) bool deliveries that survived
    bytes_delta=None,         # (N, N) f32 payload bytes moved this round
    bstate=None,              # post-round BankState (bank runs only)
    digest=None,
    bank_impl=None,
    rejects=None,             # (N, N) i32 cumulative digest rejections
    rejects_delta=None,       # (N, N) i32 rejections charged this round
    quarantine_after=0,
    serve_counts=None,        # (N,) i32 cumulative requests served
    serve_stale=None,         # () i32 max gated staleness at this admit
    infer_nodes=None,         # (N,) bool nodes that admitted a batch now
    infer_arg=None,           # (N,) i32 batch size admitted per node
    old_have=None,            # (N, S, C) bool chunk presence BEFORE (bank)
    serve_arrived=None,       # (N,) i32 arrivals fired at this instant
    serve_enq=None,           # (N,) i32 arrivals that found queue room
    serve_queued=None,        # (N,) i32 queue length AFTER admission
    serve_stale_node=None,    # (N,) i32 gated staleness per node now
) -> tuple:
    """THE collector step every obs-enabled loop body runs (jit-safe).

    One metrics accumulation + sample, one DELIVER trace append over the
    surviving edges (arg = rows the receiver merged), and — when payload
    moved — one DRAIN append (arg = bytes). Fault runs
    (``repro.net.faults``) additionally pass their rejection state: the
    rejected/quarantined series sample from ``rejects`` and each link that
    rejected chunks this round appends one REJECT record. Serve runs
    (``repro.net.serve``) pass their counters: the requests_served /
    serve_staleness series sample from ``serve_counts`` / ``serve_stale``
    and each node admitting a batch this instant appends one INFER record
    (arg = batch size). When ``cfg.hist`` is set the streaming histograms
    of ``repro.obs.hist`` accumulate in the same step (publish->merge /
    publish->commit row provenance always; chunk completion when the bank
    state and ``old_have`` are passed; per-request queue wait + staleness
    when the serve deltas are). Pure read of its
    inputs: no PRNG, no writes, so threading it through a carry cannot
    perturb the simulation (the bitwise claim ``tests/test_obs.py`` pins).
    """
    delta = _metrics_lib.rows_changed(new_dags, old_dags)
    metrics = _metrics_lib.update(
        metrics, cfg, t, new_dags, delta, bstate, digest, bank_impl,
        rejects=rejects, quarantine_after=quarantine_after,
        serve_counts=serve_counts, serve_stale=serve_stale,
    )
    if cfg.hist is not None:
        metrics = metrics._replace(hist=_hist_lib.observe(
            cfg.hist, metrics.hist, t, old_dags, new_dags,
            old_have=old_have, bstate=bstate,
            serve_arrived=serve_arrived, serve_enq=serve_enq,
            serve_admit=infer_arg, serve_queued=serve_queued,
            serve_stale_node=serve_stale_node,
        ))
    if cfg.trace:
        if live_edges is not None:
            arg = jnp.broadcast_to(
                delta[:, None], live_edges.shape
            ).astype(jnp.float32)
            ring = _trace_lib.append_edges(
                ring, t, KIND_DELIVER, live_edges, arg
            )
        if bytes_delta is not None:
            ring = _trace_lib.append_edges(
                ring, t, KIND_DRAIN, bytes_delta > 0, bytes_delta
            )
        if rejects_delta is not None:
            ring = _trace_lib.append_edges(
                ring, t, KIND_REJECT, rejects_delta > 0,
                rejects_delta.astype(jnp.float32),
            )
        if infer_nodes is not None:
            n = infer_nodes.shape[0]
            eye = jnp.eye(n, dtype=bool)
            ring = _trace_lib.append_edges(
                ring, t, KIND_INFER, infer_nodes[:, None] & eye,
                jnp.broadcast_to(
                    infer_arg[:, None], (n, n)
                ).astype(jnp.float32),
            )
    return metrics, ring

__all__ = [
    "ObsConfig", "ObsReport", "MetricsState", "TraceRing",
    "HistConfig", "HistState", "init_hist",
    "init_metrics", "init_trace", "observe_round",
    "chrome_trace", "write_chrome_trace",
    "metrics_jsonl_lines", "write_metrics_jsonl",
    "KIND_DELIVER", "KIND_DRAIN", "KIND_PUBLISH", "KIND_COMMIT",
    "KIND_PARTITION", "KIND_REJECT", "KIND_INFER",
]
