"""Device-resident metric accumulators for the gossip overlay.

The paper's claims are time-series claims — iteration delay (Table II),
tip-count stability around Eq. (4), accuracy under abnormal nodes
(Fig. 6-11) — but the overlay's hot loops are single jitted dispatches
(``lax.scan`` advance windows, ``lax.while_loop`` flushes and event
batches), so nothing host-side can see *inside* an advance. This module
moves the collectors into the loop: ``MetricsState`` is one small pytree
that rides the scan/while carry, accumulating per-round counters and
sampling a fixed-capacity series row after every merge round / event
batch. Everything here is a PURE READ of the simulation state — no PRNG
use, no writes to dags/bank/queue — which is what makes the obs-on
trajectory bitwise the obs-off one (property-tested in
``tests/test_obs.py``).

Accumulators (exact, never dropped):

  ``rounds``       merge rounds / event batches executed;
  ``rows_merged``  (N,) rows of each node's replica changed by a round —
                   the per-node anti-entropy work actually done;
  ``link_bytes``   (N, N) cumulative payload bytes per directed link
                   (mirrors ``BankState.sent``; zero without bank gossip).

Series (fixed capacity S, one row per round/batch; overflow increments
``dropped`` and keeps the FIRST S samples — no silent wraparound):

  ``t``            sample instant: ``(tick + 1) * sync_period`` on the
                   tick engine (the tick's wall-clock position), the batch
                   instant on the event engine. A ``converge()`` flush has
                   no timeline; its samples reuse the tick arithmetic
                   (all-zero ``t`` on an ideal wire).
  ``tips``         tip count of the union view (Eq. 4's observable);
  ``staleness``    worst per-replica row lag behind the union;
  ``rows_delta``   total rows merged this round (progress per round);
  ``chunk_lag``    worst referenced-but-unavailable chunk count
                   (``bank.missing_chunks``; 0 without bank gossip);
  ``bytes_total``  cumulative payload bytes at the sample instant;
  ``staleness_node`` (S, N) the PER-NODE staleness vector behind the
                   ``staleness`` max — who is lagging, not just how far
                   (an eclipsed or crashed node shows up here long before
                   the max does on a busy overlay);
  ``staleness_link`` (S, N, N) the PER-LINK lag matrix
                   (``replica.missing_vs_peer``): entry (i, j) is the
                   occupied rows receiver i still lacks of sender j's
                   view — which SIDE of the overlay owes which rows
                   (``staleness_node`` is its row-wise view vs the union;
                   a starved receiver is a pinned row here long before
                   it dominates the max);
  ``rejected``     cumulative digest-verification rejections
                   (``repro.net.faults``; 0 without fault injection);
  ``quarantined``  directed links currently quarantined by the rejection
                   counter (0 without fault injection).

Capacity discipline matches the repo's fixed-shape rule (``EventQueue``,
``InSystemTrace``): shapes are static, overflow is counted, and the host
decides how big is big enough (``ObsConfig.series_capacity``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import dag as dag_lib
from repro.core.dag import DagState
from repro.net import bank as bank_lib
from repro.net import replica as replica_lib
from repro.obs.hist import HistConfig


@dataclass(frozen=True)
class ObsConfig:
    """Telemetry knobs (frozen + hashable: it keys the jit-factory caches).

    ``series_capacity`` — metric samples kept (one per round/batch);
    ``trace_capacity`` — event records kept (``repro.obs.trace``);
    ``trace`` — record the PUBLISH/COMMIT/DELIVER/DRAIN/PARTITION event
    trace (metrics alone are cheaper when spans are not needed);
    ``annotate`` — wrap each jitted dispatch in a
    ``jax.profiler.TraceAnnotation`` so device profiles name the overlay's
    phases; ``tau_max`` — the staleness threshold the sampled tip count
    uses (``dag.num_tips``; default = ``DagFLConfig.tau_max``);
    ``hist`` — when set, stream every in-loop latency sample into the
    fixed-bin histograms of ``repro.obs.hist`` (``MetricsState.hist``
    carries them; None keeps that field an empty pytree and the programs
    literally hist-free); ``device_spans`` — record host-initiated
    PUBLISH/COMMIT spans through the device trace ring
    (``GossipNetwork.trace_device``) instead of the host-event list.
    """

    series_capacity: int = 2048
    trace_capacity: int = 16384
    trace: bool = True
    annotate: bool = True
    tau_max: float = 20.0
    hist: Optional[HistConfig] = None
    device_spans: bool = False


class MetricsState(NamedTuple):
    """The in-loop accumulator pytree (shapes static per (N, S))."""

    rounds: jnp.ndarray       # ()   i32 rounds / event batches executed
    rows_merged: jnp.ndarray  # (N,) i32 cumulative rows changed per node
    link_bytes: jnp.ndarray   # (N,N) f32 cumulative payload bytes per link
    cursor: jnp.ndarray       # ()   i32 samples attempted (monotone)
    dropped: jnp.ndarray      # ()   i32 samples past capacity (dropped)
    t: jnp.ndarray            # (S,) f32 sample instants
    tips: jnp.ndarray         # (S,) i32 union tip count
    staleness: jnp.ndarray    # (S,) i32 max rows any replica lags the union
    rows_delta: jnp.ndarray   # (S,) i32 total rows merged this round
    chunk_lag: jnp.ndarray    # (S,) i32 max referenced-but-missing chunks
    bytes_total: jnp.ndarray  # (S,) f32 cumulative payload bytes
    staleness_node: jnp.ndarray  # (S, N) i32 per-node row lag behind union
    staleness_link: jnp.ndarray  # (S, N, N) i32 rows receiver i lacks of j
    rejected: jnp.ndarray     # (S,) i32 cumulative digest rejections
    quarantined: jnp.ndarray  # (S,) i32 quarantined directed links
    requests_served: jnp.ndarray  # (S, N) i32 cumulative inference requests
    serve_staleness: jnp.ndarray  # (S,) i32 gated staleness at batch admit
                                  # (-1 = no batch admitted this sample)
    hist: Any = ()                # HistState when ObsConfig.hist is set;
                                  # () = zero leaves, the hist-free carry


def init_metrics(num_nodes: int, cfg: ObsConfig) -> MetricsState:
    s = int(cfg.series_capacity)
    return MetricsState(
        rounds=jnp.zeros((), jnp.int32),
        rows_merged=jnp.zeros((num_nodes,), jnp.int32),
        link_bytes=jnp.zeros((num_nodes, num_nodes), jnp.float32),
        cursor=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
        t=jnp.zeros((s,), jnp.float32),
        tips=jnp.zeros((s,), jnp.int32),
        staleness=jnp.zeros((s,), jnp.int32),
        rows_delta=jnp.zeros((s,), jnp.int32),
        chunk_lag=jnp.zeros((s,), jnp.int32),
        bytes_total=jnp.zeros((s,), jnp.float32),
        staleness_node=jnp.zeros((s, num_nodes), jnp.int32),
        staleness_link=jnp.zeros((s, num_nodes, num_nodes), jnp.int32),
        rejected=jnp.zeros((s,), jnp.int32),
        quarantined=jnp.zeros((s,), jnp.int32),
        requests_served=jnp.zeros((s, num_nodes), jnp.int32),
        serve_staleness=jnp.full((s,), -1, jnp.int32),
    )


def rows_changed(new: DagState, old: DagState) -> jnp.ndarray:
    """(N,) i32 — rows of each stacked replica a merge round changed.

    A merged row moves its identity (publisher / publish_time) or its
    approval credit; payload columns ride along with the same winner, so
    these three fields witness every visible change the round rule can
    make.
    """
    ch = (
        (new.publisher != old.publisher)
        | (new.publish_time != old.publish_time)
        | (new.approval_count != old.approval_count)
    )
    return jnp.sum(ch.astype(jnp.int32), axis=-1)


def update(
    m: MetricsState,
    cfg: ObsConfig,
    t: jnp.ndarray,                   # () f32 sample instant
    dags: DagState,                   # post-round stacked replicas
    rows_delta: jnp.ndarray,          # (N,) i32 from rows_changed
    bstate: Optional[bank_lib.BankState] = None,
    digest: Optional[jnp.ndarray] = None,
    bank_impl: Optional[str] = None,
    rejects: Optional[jnp.ndarray] = None,   # (N, N) i32 cumulative rejections
    quarantine_after: int = 0,
    serve_counts: Optional[jnp.ndarray] = None,  # (N,) i32 cumulative served
    serve_stale: Optional[jnp.ndarray] = None,   # () i32 staleness at admit
) -> MetricsState:
    """Accumulate one round and sample one series row (jit-safe, pure read).

    Runs inside the advance scan / converge while-loop / event-batch loop;
    under a mesh the union fold and lag reductions are global, so GSPMD
    inserts the collectives (the sampled values are the same as the
    single-device ones, like every other cross-replica reduction here).
    ``rejects`` is the fault layer's cumulative rejection matrix (fault
    runs only); without it the rejected/quarantined samples stay zero.
    ``serve_counts`` / ``serve_stale`` are the inference-serving layer's
    cumulative per-node served counters and the max gated staleness any
    batch admitted at this instant saw (serve runs only; without them the
    requests_served row stays zero and serve_staleness the -1 sentinel).
    """
    union = replica_lib.merge_all(dags)
    tips = dag_lib.num_tips(union, t, cfg.tau_max)
    stale_node = replica_lib.missing_vs_union(dags, union)
    stale_link = replica_lib.missing_vs_peer(dags)
    stale = jnp.max(stale_node)
    if rejects is not None:
        rejected = jnp.sum(rejects)
        quar = jnp.sum((rejects >= quarantine_after).astype(jnp.int32))
    else:
        rejected = jnp.zeros((), jnp.int32)
        quar = jnp.zeros((), jnp.int32)
    if bstate is not None:
        lag = jnp.max(
            bank_lib.missing_chunks(dags, bstate, digest, impl=bank_impl)
        )
        total = jnp.sum(bstate.sent)
        link_bytes = bstate.sent
    else:
        lag = jnp.zeros((), jnp.int32)
        total = jnp.zeros((), jnp.float32)
        link_bytes = m.link_bytes
    n = dags.publisher.shape[0]
    if serve_counts is None:
        serve_counts = jnp.zeros((n,), jnp.int32)
    if serve_stale is None:
        serve_stale = jnp.full((), -1, jnp.int32)
    cap = m.t.shape[0]
    # first-S-samples policy: past capacity the scatter index goes out of
    # bounds and mode="drop" discards it — count, never wrap
    slot = jnp.where(m.cursor < cap, m.cursor, cap)
    return MetricsState(
        rounds=m.rounds + 1,
        rows_merged=m.rows_merged + rows_delta,
        link_bytes=link_bytes,
        cursor=m.cursor + 1,
        dropped=m.dropped + (m.cursor >= cap).astype(jnp.int32),
        t=m.t.at[slot].set(t, mode="drop"),
        tips=m.tips.at[slot].set(tips.astype(jnp.int32), mode="drop"),
        staleness=m.staleness.at[slot].set(
            stale.astype(jnp.int32), mode="drop"
        ),
        rows_delta=m.rows_delta.at[slot].set(
            jnp.sum(rows_delta), mode="drop"
        ),
        chunk_lag=m.chunk_lag.at[slot].set(lag.astype(jnp.int32), mode="drop"),
        bytes_total=m.bytes_total.at[slot].set(total, mode="drop"),
        staleness_node=m.staleness_node.at[slot].set(
            stale_node.astype(jnp.int32), mode="drop"
        ),
        staleness_link=m.staleness_link.at[slot].set(
            stale_link.astype(jnp.int32), mode="drop"
        ),
        rejected=m.rejected.at[slot].set(
            rejected.astype(jnp.int32), mode="drop"
        ),
        quarantined=m.quarantined.at[slot].set(quar, mode="drop"),
        requests_served=m.requests_served.at[slot].set(
            serve_counts.astype(jnp.int32), mode="drop"
        ),
        serve_staleness=m.serve_staleness.at[slot].set(
            serve_stale.astype(jnp.int32), mode="drop"
        ),
        hist=m.hist,
    )
