"""Host-side export of drained telemetry: Chrome traces and JSONL metrics.

``ObsReport`` is the host-side snapshot ``GossipNetwork.obs_report()``
builds from the in-loop collectors (``repro.obs.metrics`` /
``repro.obs.trace``) — everything numpy, nothing device-resident — and
what rides ``SimResult.extras["obs"]``. Two serializations:

  Chrome trace     ``chrome_trace`` / ``write_chrome_trace`` produce the
                   Trace Event Format JSON (``{"traceEvents": [...]}``)
                   that chrome://tracing and https://ui.perfetto.dev load
                   directly: one track (tid) per node plus an "overlay"
                   control track, iteration spans from PUBLISH records
                   (arg = duration), instantaneous deliver/drain/commit
                   slices, and PARTITION begin/heal pairs as spans.
                   Timestamps are microseconds (the format's unit);
                   events are emitted time-sorted, so per-track
                   timestamps are monotone (pinned by
                   ``tests/test_obs.py``).
  JSONL metrics    one summary line (rounds, drops, dispatch counts,
                   final scalars) then one line per metric sample —
                   greppable, plottable, diffable. Histogram runs
                   (``ObsConfig.hist``) add one ``"kind": "hist"`` line
                   per histogram: bin edges, counts, and the
                   p50/p95/p99 summaries with their bin-width error
                   bounds.

Histogram counter tracks: when ``report.hist`` is present the Chrome
trace additionally carries one ``"ph": "C"`` counter series per
non-empty histogram (``hist:<name>``), plotting count against BIN INDEX
in microseconds (ts = bin index, args.le = the bin's upper edge in the
measured unit) — a compact distribution-shape strip at the trace origin
rather than a timeline series, since latency bins are not instants.

``scripts/obs_report.py`` is the CLI wrapper: run a small simulation with
telemetry on, write both files, print the summary.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.obs import trace as trace_lib


@dataclass
class ObsReport:
    """Drained telemetry for one run (all host-side numpy)."""

    num_nodes: int
    engine: str
    rounds: int
    series: Dict[str, np.ndarray]         # t/tips/staleness/rows_delta/...
    rows_merged: np.ndarray               # (N,) per-node rows merged
    link_bytes: np.ndarray                # (N, N) payload bytes per link
    samples_dropped: int
    trace: Dict[str, np.ndarray]          # t/kind/src/dst/arg, time-sorted
    trace_dropped: int
    dispatch_counts: Dict[str, int] = field(default_factory=dict)
    final: Dict[str, float] = field(default_factory=dict)
    hist: Optional[dict] = None           # repro.obs.hist.report_dict

    @property
    def samples(self) -> int:
        return int(self.series["t"].shape[0])

    @property
    def trace_records(self) -> int:
        return int(self.trace["t"].shape[0])


_US = 1e6   # trace-event timestamps are microseconds


def chrome_trace(report: ObsReport,
                 latency: Optional[np.ndarray] = None) -> dict:
    """Trace Event Format dict for one report.

    ``latency`` (N, N) seconds, when given, back-dates each DELIVER slice
    by its link's wire time so the span covers the transfer; without it
    deliveries render as 1 us instants. Tracks: tid 0..N-1 = nodes, tid N
    = the overlay control track (partitions).
    """
    n = report.num_nodes
    events = [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": f"dagfl-overlay[{report.engine}]"}},
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": n,
         "args": {"name": "overlay"}},
    ]
    for i in range(n):
        events.append({"name": "thread_name", "ph": "M", "pid": 0, "tid": i,
                       "args": {"name": f"node {i}"}})
    tr = report.trace
    slices = []
    part_open = None
    t_max = float(tr["t"][-1]) if len(tr["t"]) else 0.0
    for t, kind, src, dst, arg in zip(
        tr["t"], tr["kind"], tr["src"], tr["dst"], tr["arg"]
    ):
        t, kind, src, dst, arg = (
            float(t), int(kind), int(src), int(dst), float(arg)
        )
        if kind == trace_lib.KIND_DELIVER:
            dur = 0.0
            if latency is not None and 0 <= dst < n and 0 <= src < n:
                lat = float(latency[dst, src])
                dur = lat if np.isfinite(lat) else 0.0
            slices.append({
                "name": "deliver", "ph": "X", "pid": 0, "tid": dst,
                "ts": max(t - dur, 0.0) * _US, "dur": max(dur * _US, 1.0),
                "args": {"src": src, "rows": arg},
            })
        elif kind == trace_lib.KIND_DRAIN:
            slices.append({
                "name": "drain", "ph": "X", "pid": 0, "tid": dst,
                "ts": t * _US, "dur": 1.0,
                "args": {"src": src, "bytes": arg},
            })
        elif kind == trace_lib.KIND_PUBLISH:
            # arg = iteration duration: the span IS the node's h_i work
            slices.append({
                "name": "iteration", "ph": "X", "pid": 0, "tid": dst,
                "ts": t * _US, "dur": max(arg * _US, 1.0), "args": {},
            })
        elif kind == trace_lib.KIND_COMMIT:
            slices.append({
                "name": "commit", "ph": "X", "pid": 0, "tid": dst,
                "ts": t * _US, "dur": 1.0, "args": {"seq": int(arg)},
            })
        elif kind == trace_lib.KIND_REJECT:
            slices.append({
                "name": "reject", "ph": "X", "pid": 0, "tid": dst,
                "ts": t * _US, "dur": 1.0,
                "args": {"src": src, "chunks": arg},
            })
        elif kind == trace_lib.KIND_INFER:
            slices.append({
                "name": "infer", "ph": "X", "pid": 0, "tid": dst,
                "ts": t * _US, "dur": 1.0,
                "args": {"src": src, "batch": arg},
            })
        elif kind == trace_lib.KIND_PARTITION:
            if arg >= 0.5:
                part_open = t
            else:
                t0 = part_open if part_open is not None else 0.0
                part_open = None
                slices.append({
                    "name": "partition", "ph": "X", "pid": 0, "tid": n,
                    "ts": t0 * _US, "dur": max((t - t0) * _US, 1.0),
                    "args": {},
                })
    if part_open is not None:          # never healed within the horizon
        slices.append({
            "name": "partition", "ph": "X", "pid": 0, "tid": n,
            "ts": part_open * _US,
            "dur": max((t_max - part_open) * _US, 1.0), "args": {},
        })
    slices.sort(key=lambda e: e["ts"])
    counters = []
    if report.hist is not None:
        edges = report.hist["edges"]
        for hname, counts in report.hist["counts"].items():
            if int(np.asarray(counts).sum()) == 0:
                continue
            for b, c in enumerate(np.asarray(counts)):
                le = float(edges[b + 1]) if b + 1 < len(edges) else None
                counters.append({
                    "name": f"hist:{hname}", "ph": "C", "pid": 0, "tid": 0,
                    "ts": float(b),
                    "args": {"count": int(c), "le": le},
                })
    return {"traceEvents": events + slices + counters,
            "displayTimeUnit": "ms"}


def write_chrome_trace(report: ObsReport, path: str,
                       latency: Optional[np.ndarray] = None) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(report, latency=latency), f)
    return path


def metrics_jsonl_lines(report: ObsReport) -> list:
    """Summary line + one line per metric sample (all plain JSON)."""
    lines = [json.dumps({
        "kind": "summary",
        "engine": report.engine,
        "num_nodes": report.num_nodes,
        "rounds": report.rounds,
        "samples": report.samples,
        "samples_dropped": report.samples_dropped,
        "trace_records": report.trace_records,
        "trace_dropped": report.trace_dropped,
        "dispatch_counts": report.dispatch_counts,
        "rows_merged": [int(x) for x in report.rows_merged],
        "final": {k: float(v) for k, v in report.final.items()},
    })]
    if report.hist is not None:
        for hname, counts in report.hist["counts"].items():
            lines.append(json.dumps({
                "kind": "hist",
                "name": hname,
                "bins": report.hist["bins"],
                "lo": report.hist["lo"],
                "hi": report.hist["hi"],
                "edges": [float(x) for x in report.hist["edges"]],
                "counts": [int(x) for x in counts],
                **{k: (v if np.isfinite(v) else None)
                   if isinstance(v, float) else v
                   for k, v in report.hist["percentiles"][hname].items()},
            }))
    keys = [k for k in report.series if k != "t"]
    for i, t in enumerate(report.series["t"]):
        row = {"kind": "sample", "t": float(t)}
        for k in keys:
            v = report.series[k][i]
            # vector-valued series (e.g. per-node staleness) emit a list
            row[k] = (float(v) if np.ndim(v) == 0
                      else [float(x) for x in np.ravel(v)])
        lines.append(json.dumps(row))
    return lines


def write_metrics_jsonl(report: ObsReport, path: str) -> str:
    with open(path, "w") as f:
        f.write("\n".join(metrics_jsonl_lines(report)) + "\n")
    return path
