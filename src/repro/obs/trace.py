"""Fixed-capacity event trace ring for the gossip overlay.

The ``EventQueue`` layout, reused for recording instead of scheduling: a
trace is stacked arrays ``(t, kind, src, dst, arg)`` plus a write cursor
and an overflow counter, small enough to ride a ``lax.scan`` /
``lax.while_loop`` carry. Device-side appends happen per merge round /
event batch (every live delivery edge and every link that moved payload
bytes becomes one record); host-side spans (PUBLISH / COMMIT — the FL
driver knows iteration start and completion instants — and PARTITION
transitions) are buffered on the host and merged at drain time, so
recording them costs zero device dispatches.

Overflow policy: the ring KEEPS the first ``capacity`` records and counts
the rest in ``dropped`` — it never wraps. A wrapped ring silently loses
the oldest spans, which is exactly the failure mode a post-mortem trace
exists to avoid; a full ring with a nonzero ``dropped`` is an honest
"raise ``ObsConfig.trace_capacity``" signal (pinned by
``tests/test_obs.py``).

Record kinds (``arg`` meaning per kind):

  ``KIND_DELIVER``    anti-entropy delivery src -> dst survived drop/
                      partition; arg = rows the receiver merged that round;
  ``KIND_DRAIN``      payload bytes moved src -> dst; arg = bytes;
  ``KIND_PUBLISH``    node began an iteration (host record at t0);
                      arg = its duration h (seconds), so the exporter can
                      draw the iteration span without pairing records;
  ``KIND_COMMIT``     node landed its transaction (host record at t1);
                      arg = global sequence number;
  ``KIND_PARTITION``  overlay partition transition (host record);
                      arg = 1.0 begin / 0.0 heal, src = dst = -1;
  ``KIND_REJECT``     receiver dst rejected chunks from src that failed
                      digest verification (``repro.net.faults``);
                      arg = chunks rejected this round.

``repro.obs.export`` turns a drained ring into Chrome trace-event JSON
(one Perfetto track per node) and the metrics series into JSONL.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

KIND_DELIVER = 0
KIND_DRAIN = 1
KIND_PUBLISH = 2
KIND_COMMIT = 3
KIND_PARTITION = 4
KIND_REJECT = 5
KIND_INFER = 6

KIND_NAMES = {
    KIND_DELIVER: "deliver",
    KIND_DRAIN: "drain",
    KIND_PUBLISH: "publish",
    KIND_COMMIT: "commit",
    KIND_PARTITION: "partition",
    KIND_REJECT: "reject",
    KIND_INFER: "infer",
}


class TraceRing(NamedTuple):
    """Stacked-array trace ring (shapes static per capacity C)."""

    t: jnp.ndarray        # (C,) f32 record instant
    kind: jnp.ndarray     # (C,) i32 KIND_*
    src: jnp.ndarray      # (C,) i32 sender / acting node (-1 = overlay)
    dst: jnp.ndarray      # (C,) i32 receiver / acting node (-1 = overlay)
    arg: jnp.ndarray      # (C,) f32 kind-specific payload
    cursor: jnp.ndarray   # ()   i32 records attempted (monotone)
    dropped: jnp.ndarray  # ()   i32 records past capacity (dropped)


def init_trace(capacity: int) -> TraceRing:
    c = int(capacity)
    return TraceRing(
        t=jnp.zeros((c,), jnp.float32),
        kind=jnp.full((c,), -1, jnp.int32),
        src=jnp.full((c,), -1, jnp.int32),
        dst=jnp.full((c,), -1, jnp.int32),
        arg=jnp.zeros((c,), jnp.float32),
        cursor=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
    )


def append_edges(ring: TraceRing, t, kind: int, mask, arg) -> TraceRing:
    """Append one record per True edge of ``mask`` (jit-safe, O(N^2)).

    ``mask`` is (N, N) bool in the overlay's [receiver, sender] layout;
    ``arg`` broadcasts against it. Active edges take consecutive slots in
    flat index order (deterministic — a prefix sum assigns positions);
    edges landing past capacity scatter out of bounds and are DROPPED
    (``mode="drop"``), with ``dropped`` counting them.
    """
    n = mask.shape[0]
    cap = ring.t.shape[0]
    flat = mask.reshape(-1)
    vals = jnp.broadcast_to(arg, mask.shape).reshape(-1).astype(jnp.float32)
    fi = flat.astype(jnp.int32)
    pos = jnp.cumsum(fi) - fi
    idx = ring.cursor + pos
    # inactive edges and overflow both target slot `cap` — out of bounds,
    # so the scatters discard them; in-bounds active slots are unique
    slot = jnp.where(flat & (idx < cap), idx, cap)
    ids = jnp.arange(n, dtype=jnp.int32)
    dst_ids = jnp.broadcast_to(ids[:, None], (n, n)).reshape(-1)
    src_ids = jnp.broadcast_to(ids[None, :], (n, n)).reshape(-1)
    return TraceRing(
        t=ring.t.at[slot].set(jnp.asarray(t, jnp.float32), mode="drop"),
        kind=ring.kind.at[slot].set(jnp.int32(kind), mode="drop"),
        src=ring.src.at[slot].set(src_ids, mode="drop"),
        dst=ring.dst.at[slot].set(dst_ids, mode="drop"),
        arg=ring.arg.at[slot].set(vals, mode="drop"),
        cursor=ring.cursor + jnp.sum(fi),
        dropped=ring.dropped + jnp.sum(fi * (idx >= cap).astype(jnp.int32)),
    )


def drain(ring: TraceRing, host_events=()) -> dict:
    """Pull the ring to host and merge buffered host-side records.

    ``host_events`` is an iterable of ``(t, kind, src, dst, arg)`` tuples
    (PUBLISH/COMMIT/PARTITION — recorded host-side for free). Returns
    ``{"t", "kind", "src", "dst", "arg"}`` numpy arrays sorted by
    ``(t, kind)`` — the same lexicographic tie order the event engine pops
    in — plus nothing else; ``ring.dropped`` is the caller's to report.
    """
    n = int(min(int(ring.cursor), ring.t.shape[0]))
    t = np.asarray(ring.t)[:n]
    kind = np.asarray(ring.kind)[:n]
    src = np.asarray(ring.src)[:n]
    dst = np.asarray(ring.dst)[:n]
    arg = np.asarray(ring.arg)[:n]
    if host_events:
        h = np.asarray(list(host_events), np.float64).reshape(-1, 5)
        t = np.concatenate([t.astype(np.float64), h[:, 0]])
        kind = np.concatenate([kind, h[:, 1].astype(np.int32)])
        src = np.concatenate([src, h[:, 2].astype(np.int32)])
        dst = np.concatenate([dst, h[:, 3].astype(np.int32)])
        arg = np.concatenate([arg.astype(np.float64), h[:, 4]])
    order = np.lexsort((kind, t))
    return {
        "t": np.asarray(t, np.float64)[order],
        "kind": kind[order],
        "src": src[order],
        "dst": dst[order],
        "arg": np.asarray(arg, np.float64)[order],
    }
