"""Ablation (paper §VI.C): uniform Eq.-1 weights vs staleness/accuracy-
weighted aggregation — the paper proposes this as future work; we implement
and measure it."""
from benchmarks.common import emit, fmt_curve, timed
from repro.fl.experiments import default_dagfl_config, make_cnn_setup
from repro.fl.systems import SimConfig, run_dagfl


def run(iterations: int = 200, seed: int = 0):
    task, nodes, gval, _ = make_cnn_setup(num_nodes=50, seed=seed)
    dcfg = default_dagfl_config(num_nodes=50)
    sim = SimConfig(iterations=iterations, eval_every=50, seed=seed)
    out = {}
    for name, weighted in (("uniform", False), ("weighted", True)):
        with timed() as t:
            res = run_dagfl(task, nodes, dcfg, sim, gval, weighted=weighted)
        out[name] = res
        emit(
            f"ablation_vi_c/{name}",
            (t["s"] / iterations) * 1e6,
            f"final_acc={res.accs[-1]:.3f};curve={fmt_curve(res.iters, res.accs)}",
        )
    return out
