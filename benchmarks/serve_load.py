"""Serving the gossiped bank under Poisson inference load (event engine).

The PR-9 serving layer (``repro.net.serve``) turns every node into an
inference endpoint: Poisson request arrivals per node, fixed-slot batched
service against the node's availability-gated local bank view. This bench
prices that load on the Table-I link classes and machine-checks two
claims into ``BENCH_gossip_sync.json`` under ``serve_load``:

* ZERO-RATE (the CI tripwire): ``ServeConfig(rate=0.0)`` and
  ``serve=None`` compile the identical program — the run is bitwise the
  serve-free PR-8 path end to end (accuracy curve, timing, union
  ledger). The serving layer is OFF by construction, not by a branch
  that still perturbs the PRNG stream;
* LOAD: sweeping the link classes with the serve layer armed, throughput
  (requests/s) stays pinned to the offered Poisson rate — serving reads
  the local view and never waits on the wire — while staleness-at-serve
  (chunks missing from the gated view at admission, in model rows)
  grows as links shrink from the ideal wire to the IoT-class 1 Mbps
  uplink. A mid-run partition arm shows the same decoupling under a
  healed split: requests keep flowing, the staleness tail pays for the
  isolation.

The load sweep additionally runs with the PR-10 streaming histograms
armed (``ObsConfig(hist=HistConfig())`` — bitwise-neutral by the obs
tripwire) and each ``kind="load"`` row carries a per-request
``request_percentiles`` ladder: queue-wait (arrival -> admission
seconds) and staleness-at-serve p50/p95/p99 with their bin-width error
bounds, read off the device-resident quantile sketches rather than any
host-side sample array.

Every counter row is read off ``extras["serve_report"]`` — the drained
on-device serve counters — not off ``GossipNetwork`` private state.
"""
import numpy as np

from benchmarks.common import emit
from benchmarks.gossip_propagation import _results_bitwise_equal
from repro.fl.experiments import default_dagfl_config, make_cnn_setup
from repro.fl.systems import SimConfig, run_dagfl_gossip
from repro.net import gossip as gossip_lib
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig
from repro.net.serve import ServeConfig, arrival_times
from repro.obs import HistConfig, ObsConfig


def _finite(x) -> float:
    """NaN-free float for the JSON record (``json`` would emit bare NaN)."""
    x = float(x)
    return x if np.isfinite(x) else None


def _run_serving(n, iterations, seed, bandwidth, serve, partition=None,
                 slot_bytes=7e6, obs=None):
    dcfg = default_dagfl_config(num_nodes=n)
    sim = SimConfig(iterations=iterations, eval_every=max(iterations // 4, 1),
                    seed=seed)
    task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=seed)
    return run_dagfl_gossip(
        task, nodes, dcfg, sim, gval,
        topology=topo.ring(n, seed=seed, bandwidth=bandwidth),
        # phi = 7 MB on a priced link generates thousands of drain events;
        # headroom over the default 8192-events-per-advance backstop so a
        # saturated final advance can never strand past-due arrivals
        gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=seed,
                                       impl="fused",
                                       max_events_per_advance=65536),
        bank_gossip=BankGossipConfig(chunks_per_slot=4,
                                     slot_bytes=slot_bytes),
        engine="events", serve=serve, partition=partition, obs=obs,
    )


def _request_percentiles(res) -> dict:
    """The per-request p50/p95/p99 ladder off the streaming histograms."""
    obs_rep = res.extras.get("obs")
    if obs_rep is None or obs_rep.hist is None:
        return None
    pct = obs_rep.hist["percentiles"]
    return {
        "queue_wait": {k: _finite(v) if isinstance(v, float) else v
                       for k, v in pct["queue_wait"].items()},
        "staleness": {k: _finite(v) if isinstance(v, float) else v
                      for k, v in pct["serve_stale"].items()},
    }


def _load_row(res, iterations, n, seed) -> dict:
    rep = res.extras["serve_report"]
    horizon = float(res.times[-1]) if len(res.times) else float(iterations)
    horizon = max(horizon, 1e-9)
    cfg = ServeConfig(rate=rep["rate"])
    replay = sum(
        len(arrival_times(seed, cfg, node, horizon)) for node in range(n)
    )
    row = dict(
        rate_per_node=float(rep["rate"]),
        arrivals_match_replay=bool(rep["arrived_total"] == replay),
        served_total=int(rep["served_total"]),
        arrived_total=int(rep["arrived_total"]),
        dropped_total=int(rep["dropped_total"]),
        requests_per_s=float(rep["served_total"]) / horizon,
        staleness_p50=_finite(rep["staleness_p50"]),
        staleness_p99=_finite(rep["staleness_p99"]),
        staleness_max=int(rep["staleness_max"]),
        staleness_samples=int(rep["samples"]),
        final_acc=float(res.accs[-1]),
    )
    ladder = _request_percentiles(res)
    if ladder is not None:
        row["request_percentiles"] = ladder
    return row


def run_serve_load(
    n: int = 8, iterations: int = 16, seed: int = 0, rate: float = 2.0,
    link_classes=("ideal", "lte_10mbps", "constrained_1mbps"),
    record: dict = None,
):
    """Zero-rate equivalence + the Table-I serving sweep + a partition arm.

    ``rate`` is the per-node Poisson arrival rate (requests per simulated
    second); the paper's phi = 7 MB model payload prices the bank
    transport, so on the constrained classes the gated view lags the
    union and the staleness-at-serve percentiles show it.
    """
    rows = []

    # -- zero-rate tripwire: rate 0.0 IS the serve-free program -----------
    zero_cls = "lte_10mbps" if "lte_10mbps" in link_classes else link_classes[0]
    bw = topo.TABLE1_LINK_CLASSES[zero_cls]
    base = _run_serving(n, iterations, seed, bw, None)
    zero = _run_serving(n, iterations, seed, bw, ServeConfig(rate=0.0))
    equivalent = (_results_bitwise_equal(base, zero)
                  and "serve_report" not in zero.extras)
    emit(
        "gossip/serve_load/zero_rate", float(equivalent),
        f"bitwise_equal_unserved={equivalent};link={zero_cls}",
    )
    rows.append(dict(
        kind="zero_rate", link_class=zero_cls, n=n, iterations=iterations,
        bitwise_equal_unserved=bool(equivalent),
    ))

    # -- load sweep over the Table-I link classes -------------------------
    # histograms armed: the queue-wait / staleness-at-serve percentile
    # ladder rides each row (bitwise-neutral — the obs smoke tripwire)
    hist_obs = ObsConfig(hist=HistConfig())
    for cls in link_classes:
        bw = topo.TABLE1_LINK_CLASSES[cls]
        res = _run_serving(n, iterations, seed, bw,
                           ServeConfig(rate=rate), obs=hist_obs)
        row = _load_row(res, iterations, n, seed)
        qw = row["request_percentiles"]["queue_wait"]
        emit(
            f"gossip/serve_load/sweep/{cls}", row["requests_per_s"],
            f"served={row['served_total']};"
            f"stale_p50={row['staleness_p50']};"
            f"stale_p99={row['staleness_p99']};"
            f"qwait_p50={qw['p50']};qwait_p99={qw['p99']};"
            f"final_acc={row['final_acc']:.3f}",
        )
        rows.append(dict(
            kind="load", link_class=cls,
            bandwidth_bps=bw if np.isfinite(bw) else None,
            slot_bytes=7e6, n=n, iterations=iterations, **row,
        ))

    # -- partition arm: split the ring for the middle third ---------------
    # Priced at a bench-scale 175 KB payload so chunks complete within
    # the horizon: at the paper's phi = 7 MB the chunk backlog already
    # saturates the gate on these links and the split cannot make the
    # gated view any staler — the partition's blocking only shows once
    # transport would otherwise have kept up. Measured against its
    # unpartitioned twin at the same scale.
    part_cls = link_classes[min(1, len(link_classes) - 1)]
    part_sb = 1.75e5
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(n),
        t_start=iterations / 3.0,
        t_end=2.0 * iterations / 3.0,
    )
    bw = topo.TABLE1_LINK_CLASSES[part_cls]
    twin = _run_serving(n, iterations, seed, bw, ServeConfig(rate=rate),
                        slot_bytes=part_sb)
    res = _run_serving(n, iterations, seed, bw, ServeConfig(rate=rate),
                       partition=part, slot_bytes=part_sb)
    row = _load_row(res, iterations, n, seed)
    base = _load_row(twin, iterations, n, seed)

    # whole-run percentiles dilute a mid-run window, so also price the
    # split where it lives: mean staleness-at-serve before t_start vs
    # from t_start through the post-heal catch-up, partitioned vs twin
    def _window_means(r):
        rep = r.extras["serve_report"]
        late = rep["staleness_t"] >= part.t_start
        s = rep["staleness_samples"]
        return (
            float(s[~late].mean()) if (~late).any() else None,
            float(s[late].mean()) if late.any() else None,
        )

    pre, post = _window_means(res)
    h_pre, h_post = _window_means(twin)
    emit(
        f"gossip/serve_load/partition/{part_cls}", row["requests_per_s"],
        f"served={row['served_total']};"
        f"stale_mean_from_split={post}_vs_healed_{h_post};"
        f"stale_p99={row['staleness_p99']}"
        f"_vs_healed_{base['staleness_p99']}",
    )
    rows.append(dict(
        kind="partition", link_class=part_cls, slot_bytes=part_sb,
        t_start=float(part.t_start), t_end=float(part.t_end),
        n=n, iterations=iterations,
        stale_mean_before_split=pre, stale_mean_from_split=post,
        healed_mean_before_split=h_pre, healed_mean_from_split=h_post,
        healed_p50=base["staleness_p50"], healed_p99=base["staleness_p99"],
        healed_max=base["staleness_max"], **row,
    ))

    if record is not None:
        record["serve_load"] = rows
    return rows


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run_serve_load()
