"""Paper Fig. 5: test accuracy of the four FL systems, ideal case.

Paper claims validated (at bench scale): all four converge; DAG-FL tracks
Async FL; Google FL converges per-round; Block FL is the slowest early.
"""
from benchmarks.common import emit, fmt_curve, timed
from repro.fl.experiments import ideal_convergence_experiment


def run(task_name: str = "cnn", iterations: int = 400, seed: int = 0):
    with timed() as t:
        res = ideal_convergence_experiment(task_name, iterations, seed)
    for name, r in res.items():
        emit(
            f"fig5/{task_name}/{name}",
            (t["s"] / max(iterations, 1)) * 1e6,
            f"final_acc={r.accs[-1]:.3f};curve={fmt_curve(r.iters, r.accs)}",
        )
    return res
