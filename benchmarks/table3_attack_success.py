"""Paper Table III: backdoor attack success rates at 5000 iterations.

Paper: DAG-FL 0.006/0.356/0.624 at 5/10/20 backdoor nodes; Block 0.619,
Google 0.917, Async 0.921 at 20. Validated ordering at bench scale:
DAG-FL(5) << DAG-FL(20) ~= Block(20) << Google/Async(20).
"""
from benchmarks.common import emit, timed
from repro.fl.experiments import abnormal_experiment


def run(iterations: int = 300, seed: int = 0):
    rows = {}
    for n in (5, 10, 20):
        with timed() as t:
            res = abnormal_experiment(
                "cnn", "backdoor", n, iterations, seed, systems=("dagfl",)
            )["dagfl"]
        asr = res.extras.get("attack_success", float("nan"))
        rows[("dagfl", n)] = asr
        emit(f"table3/dagfl/backdoor{n}", (t["s"] / iterations) * 1e6,
             f"attack_success={asr:.4f}")
    for sysname in ("block", "google", "async"):
        with timed() as t:
            res = abnormal_experiment(
                "cnn", "backdoor", 20, iterations, seed, systems=(sysname,)
            )[sysname]
        asr = res.extras.get("attack_success", float("nan"))
        rows[(sysname, 20)] = asr
        emit(f"table3/{sysname}/backdoor20", (t["s"] / iterations) * 1e6,
             f"attack_success={asr:.4f}")
    return rows
