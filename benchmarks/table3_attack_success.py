"""Paper Table III: backdoor attack success rates at 5000 iterations.

Paper: DAG-FL 0.006/0.356/0.624 at 5/10/20 backdoor nodes; Block 0.619,
Google 0.917, Async 0.921 at 20. Validated ordering at bench scale:
DAG-FL(5) << DAG-FL(20) ~= Block(20) << Google/Async(20).

``run_transport`` extends the table with TRANSPORT-level adversaries
(``repro.net.faults``): payload spoofers against digest verification and
sybil approval inflation — the attack-success observable there is
corrupted chunks reaching a gated view (must be 0 with the defense on)
rather than backdoor-label accuracy. The machine-readable copy of the
transport rows lives in ``BENCH_gossip_sync.json`` under ``attack_suite``
(``benchmarks.gossip_propagation.run_fault_suite``).
"""
import numpy as np

from benchmarks.common import emit, timed
from repro.fl.experiments import abnormal_experiment, default_dagfl_config, make_cnn_setup
from repro.fl.systems import SimConfig, run_dagfl_gossip
from repro.net import gossip as gossip_lib
from repro.net import topology as topo
from repro.net.bank import BankGossipConfig
from repro.net.faults import ROLE_HONEST, ROLE_SPOOF, ROLE_SYBIL, FaultConfig


def run(iterations: int = 300, seed: int = 0):
    rows = {}
    for n in (5, 10, 20):
        with timed() as t:
            res = abnormal_experiment(
                "cnn", "backdoor", n, iterations, seed, systems=("dagfl",)
            )["dagfl"]
        asr = res.extras.get("attack_success", float("nan"))
        rows[("dagfl", n)] = asr
        emit(f"table3/dagfl/backdoor{n}", (t["s"] / iterations) * 1e6,
             f"attack_success={asr:.4f}")
    for sysname in ("block", "google", "async"):
        with timed() as t:
            res = abnormal_experiment(
                "cnn", "backdoor", 20, iterations, seed, systems=(sysname,)
            )[sysname]
        asr = res.extras.get("attack_success", float("nan"))
        rows[(sysname, 20)] = asr
        emit(f"table3/{sysname}/backdoor20", (t["s"] / iterations) * 1e6,
             f"attack_success={asr:.4f}")
    return rows


def run_transport(iterations: int = 30, seed: int = 0, n: int = 12):
    """Transport-level attack rows: spoofers (with/without the digest
    defense) and sybil approval inflation on the DAG-FL gossip system."""
    rows = {}

    def _run(faults, bank=None):
        dcfg = default_dagfl_config(num_nodes=n)
        sim = SimConfig(iterations=iterations,
                        eval_every=max(iterations // 3, 1), seed=seed)
        task, nodes, gval, _ = make_cnn_setup(num_nodes=n, seed=seed)
        return run_dagfl_gossip(
            task, nodes, dcfg, sim, gval,
            topology=topo.full(n, link_latency=1.0, seed=seed),
            gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=seed),
            bank_gossip=bank, faults=faults,
        )

    spoof_roles = tuple(
        ROLE_SPOOF if i < 3 else ROLE_HONEST for i in range(n)
    )
    bank = BankGossipConfig(chunks_per_slot=4)
    for tag, verify in (("defended", True), ("undefended", False)):
        with timed() as t:
            res = _run(
                FaultConfig(roles=spoof_roles, spoof_rate=1.0,
                            verify_digests=verify, quarantine_after=3),
                bank=bank,
            )
        rep = res.extras["fault_report"]
        asr = int(np.asarray(rep["tainted_in_views"]).sum())
        rows[("spoof", tag)] = asr
        emit(f"table3/transport/spoof_{tag}", (t["s"] / iterations) * 1e6,
             f"attack_success={asr};rejected={rep['rejected_total']};"
             f"quarantined={rep['quarantined_links']};"
             f"final_acc={res.accs[-1]:.3f}")

    sybil_roles = tuple(
        ROLE_SYBIL if i < 3 else ROLE_HONEST for i in range(n)
    )
    with timed() as t:
        res = _run(FaultConfig(roles=sybil_roles))
    dag = res.extras["dag"]
    own = np.asarray(dag.publisher)
    forged = int(np.asarray(dag.approval_count)[np.isin(own, [0, 1, 2])].sum())
    rows[("sybil", "inflation")] = forged
    emit(f"table3/transport/sybil_inflation", (t["s"] / iterations) * 1e6,
         f"approvals_on_sybil_rows={forged};"
         f"approvals_in_union={res.extras['approvals_in_union']}")
    return rows
