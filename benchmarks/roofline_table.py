"""Roofline table: reads the dry-run artifact (benchmarks/artifacts/*.jsonl)
written by ``python -m repro.launch.dryrun --all --jsonl ...`` and prints the
three roofline terms per (arch x shape x mesh).

(The dry-run itself needs 512 host devices and must run in its own process;
this bench only formats its artifact.)
"""
import json
import os

from benchmarks.common import emit

ARTIFACTS = [
    os.path.join(os.path.dirname(__file__), "artifacts", "dryrun_single.jsonl"),
    os.path.join(os.path.dirname(__file__), "artifacts", "dryrun_multi.jsonl"),
]


def run():
    found = False
    for path in ARTIFACTS:
        if not os.path.exists(path):
            continue
        found = True
        rows = [json.loads(l) for l in open(path) if l.strip()]
        # keep the newest row per (arch, shape, mesh)
        latest = {}
        for r in rows:
            latest[(r["arch"], r["shape"], r["mesh"])] = r
        for (arch, shape, mesh), r in sorted(latest.items()):
            emit(
                f"roofline/{mesh}/{arch}/{shape}",
                r.get("compile_s", 0.0) * 1e6,
                f"t_compute={r['t_compute_s']};t_memory={r['t_memory_s']};"
                f"t_collective={r['t_collective_s']};dominant={r['dominant']};"
                f"useful_flops_ratio={round(r.get('useful_flops_ratio', 0), 3)}",
            )
    if not found:
        emit("roofline/SKIPPED", 0.0, "run repro.launch.dryrun --all --jsonl first")
