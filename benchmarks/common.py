"""Shared helpers for the benchmark harness (CSV conventions)."""
from __future__ import annotations

import sys
import time
from contextlib import contextmanager
from typing import Iterable


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def header() -> None:
    print("name,us_per_call,derived")


@contextmanager
def timed():
    t0 = time.perf_counter()
    box = {}
    yield box
    box["s"] = time.perf_counter() - t0


def fmt_curve(iters: Iterable, accs: Iterable) -> str:
    return ";".join(f"{int(i)}:{a:.3f}" for i, a in zip(iters, accs))
