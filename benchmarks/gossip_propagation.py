"""Gossip overlay: accuracy-vs-time across sync periods / drop rates, the
partition scenario vs the ideal shared-ledger baseline, and the wall time of
one vectorized anti-entropy round at N=25.

Claims validated (at bench scale):
* sync period -> 0, drop 0 recovers the shared-ledger curve (ideal limit);
* slower sync / lossier links leave replicas further behind the union view
  (``max_missing`` rows) without destabilizing training;
* a mid-run partition grows divergence that collapses again after healing;
* the anti-entropy round is ONE jitted device call over the stacked replica
  set — ``sync_round`` rows report its per-call wall time for N=25.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, fmt_curve, timed
from repro.core import dag as dag_lib
from repro.fl.experiments import default_dagfl_config, make_cnn_setup
from repro.fl.systems import SimConfig, run_dagfl, run_dagfl_gossip
from repro.net import gossip as gossip_lib
from repro.net import replica as replica_lib
from repro.net import topology as topo


def _emit_result(tag: str, res, wall_s: float, iterations: int) -> None:
    miss = res.extras.get("missing_rows_final")
    extra = (
        f"final_acc={res.accs[-1]:.3f};sync_rounds={res.extras.get('sync_rounds', 0)};"
        f"max_missing={int(miss.max()) if miss is not None else 0};"
        f"dup_approvals={res.extras.get('approvals_issued', 0) - res.extras.get('approvals_in_union', 0)};"
        f"curve={fmt_curve(res.iters, res.accs)}"
    )
    emit(tag, (wall_s / max(iterations, 1)) * 1e6, extra)


def run_sweep(iterations: int = 150, num_nodes: int = 25, seed: int = 0):
    """Accuracy vs time across sync periods and drop rates on a k-regular
    overlay, against the shared-ledger baseline."""
    dcfg = default_dagfl_config(num_nodes=num_nodes)
    sim = SimConfig(iterations=iterations, eval_every=25, seed=seed)

    task, nodes, gval, _ = make_cnn_setup(num_nodes=num_nodes, seed=seed)
    with timed() as t:
        base = run_dagfl(task, nodes, dcfg, sim, gval)
    _emit_result("gossip/baseline_shared_ledger", base, t["s"], iterations)

    for period in (0.0, 1.0, 4.0, 16.0):
        for drop in (0.0, 0.3):
            if period == 0.0 and drop > 0:
                continue                    # ideal wire is loss-free by definition
            task, nodes, gval, _ = make_cnn_setup(num_nodes=num_nodes, seed=seed)
            top = topo.k_regular(num_nodes, 4, drop=drop, seed=seed)
            with timed() as t:
                res = run_dagfl_gossip(
                    task, nodes, dcfg, sim, gval, topology=top,
                    gossip=gossip_lib.GossipConfig(sync_period=period, seed=seed),
                )
            _emit_result(
                f"gossip/period_{period:g}/drop_{drop:g}", res, t["s"], iterations
            )
    return base


def run_partition(iterations: int = 150, num_nodes: int = 25, seed: int = 0):
    """Split the overlay down the middle for the middle third of the run."""
    dcfg = default_dagfl_config(num_nodes=num_nodes)
    sim = SimConfig(iterations=iterations, eval_every=25, seed=seed)
    # Poisson arrivals at rate 1/s: t ~ iteration index
    part = gossip_lib.PartitionSchedule(
        assignment=topo.split_halves(num_nodes),
        t_start=iterations / 3.0,
        t_end=2.0 * iterations / 3.0,
    )
    task, nodes, gval, _ = make_cnn_setup(num_nodes=num_nodes, seed=seed)
    with timed() as t:
        res = run_dagfl_gossip(
            task, nodes, dcfg, sim, gval,
            topology=topo.k_regular(num_nodes, 4, seed=seed),
            gossip=gossip_lib.GossipConfig(sync_period=1.0, seed=seed),
            partition=part,
        )
    _emit_result("gossip/partition_heal", res, t["s"], iterations)
    div = res.extras["divergence_curve"]
    if len(div):
        peak = int(div[:, 2].max())
        emit("gossip/partition_peak_divergence", peak, f"rows={peak}")
    return res


def run_sync_round_timing(num_nodes: int = 25, capacity: int = 512, reps: int = 50,
                          seed: int = 0):
    """Wall time of ONE anti-entropy round (single jitted call, N=25)."""
    dag = dag_lib.empty_dag(capacity, 2, num_nodes + 1)
    rng = np.random.default_rng(seed)
    for i in range(capacity // 2):      # half-full ledger, realistic occupancy
        dag = dag_lib.publish(
            dag, jnp.asarray(int(rng.integers(0, num_nodes)), jnp.int32),
            jnp.float32(i * 0.5), jnp.full((2,), dag_lib.NO_TX, jnp.int32),
            jnp.float32(0.5), jnp.float32(0.0), jnp.asarray(i, jnp.int32),
        )
    rs = replica_lib.init_replicas(dag, bank=jnp.zeros((capacity, 8)), num_replicas=num_nodes)
    top = topo.k_regular(num_nodes, 4, seed=seed)
    round_fn = gossip_lib.make_gossip_round()
    edges = jnp.asarray(top.adjacency)
    dags = round_fn(rs.dags, edges)                      # compile
    jax.block_until_ready(dags.publisher)
    t0 = time.perf_counter()
    for _ in range(reps):
        dags = round_fn(dags, edges)
    jax.block_until_ready(dags.publisher)
    per_call = (time.perf_counter() - t0) / reps
    emit(
        f"gossip/sync_round_n{num_nodes}",
        per_call * 1e6,
        f"capacity={capacity};one_jitted_call=true",
    )
    return per_call


def run(iterations: int = 150, num_nodes: int = 25, seed: int = 0):
    run_sync_round_timing(num_nodes=num_nodes, seed=seed)
    run_sweep(iterations=iterations, num_nodes=num_nodes, seed=seed)
    run_partition(iterations=iterations, num_nodes=num_nodes, seed=seed)


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
